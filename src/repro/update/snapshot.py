"""Durable cube snapshots + restart protocol (DESIGN.md §9).

Everything the sparse tier is — consolidated blocks, overlay blocks,
tombstones, the primary routing index, per-server replica indexes, the
update cursor — lives in process memory; a crash or deploy loses it all.
This module is the durability layer: periodic snapshots of the
:class:`~repro.core.cube.ParameterCube` published with the delta log's
proven discipline, so a restarted node recovers by

    newest valid snapshot  +  delta-log replay from snapshot_version+1

and is bit-identical to a node that never crashed.

On-disk layout (one directory per snapshot, named by the DELTA version it
captures — the cube's internal version also bumps on index folds and
compaction passes, so the delta cursor is the cross-process coordinate)::

    <dir>/snap_<delta_version>/
        meta.json           # cube config, per-group shapes, group registry,
                            # (cube_version, delta_version)
        primary.npz         # the pinned primary snapshot: sigs/srv/blk/off
        server_<sid>.npz    # per-server index at the pinned version + every
                            # value block it (or the primary) references
        CHECKSUMS           # sha256 per file above — torn/corrupt detection
        DONE                # publish marker, written LAST
        aux.json            # reverse maps + touched-key log (advisory)
        AUX_CHECKSUMS
        AUX_DONE            # aux publish marker

The DONE-marker-last + re-hash-on-read discipline is the delta log's: a
snapshot missing DONE, or whose files fail their manifest, is detected and
IGNORED — recovery falls back to the previous valid snapshot (replaying a
longer delta suffix). Aux state (reverse maps for exact warm-start
invalidation, the touched-key log) publishes AFTER the snapshot proper,
behind its own marker: a crash between the two leaves a fully valid
snapshot whose caches merely start cold — never a torn one.

Consistency: the writer captures ``(delta cursor, cube pin, touched log)``
atomically under the UpdateManager's apply lock (no delta can be
mid-flight), then serializes OFF the lock under the pin — the pin keeps
every referenced block and versioned server index alive while delta
batches and compactions keep landing. The writer-lock holds are the
capture only, never the serialization.

Retention: ``CubeSnapshotter`` keeps the last K valid snapshots and owns
delta-log GC — delta dirs strictly older than the oldest retained
snapshot's version are pruned, but never ahead of any registered live
watcher's cursor (a replica still replaying must find its suffix).
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import signal
import threading
import time
from typing import Optional

import numpy as np

from repro.faults.crash import crash_point
from repro.obs.log import log_event

log = logging.getLogger(__name__)

_PREFIX = "snap_"
_CHECKSUMS = "CHECKSUMS"
_AUX_CHECKSUMS = "AUX_CHECKSUMS"
_AUX_FILES = ("aux.json",)
# sharded (mesh) snapshots publish behind their own marker pair —
# deliberately NOT "DONE", so legacy single-cube listing/recovery treats
# a sharded snapshot as unpublished and skips it instead of half-loading
_MESH_DONE = "MESH_DONE"
_MESH_CHECKSUMS = "MESH_CHECKSUMS"
_SHARD_PREFIX = "shard_"

__all__ = [
    "SnapshotIntegrityError", "snapshot_path", "write_cube_snapshot",
    "write_aux_state", "verify_snapshot", "load_cube_snapshot",
    "load_aux_state", "list_snapshots", "latest_valid_snapshot",
    "prune_snapshots", "prune_delta_log", "CubeSnapshotter",
    "write_sharded_snapshot", "verify_sharded_snapshot",
    "load_sharded_snapshot", "list_sharded_snapshots",
    "latest_valid_sharded_snapshot",
]


class SnapshotIntegrityError(ValueError):
    """A published snapshot's content does not match its CHECKSUMS
    manifest — it must be ignored (fall back to an older one)."""


def snapshot_path(snapshot_dir: str, delta_version: int) -> str:
    # delta versions start at 0; version -1 (a snapshot taken before any
    # delta ever applied) encodes as snap_-00000000001, still sortable by
    # the parsed int
    return os.path.join(snapshot_dir, f"{_PREFIX}{delta_version:012d}")


def _sha256(path: str) -> str:
    import hashlib
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


# ----------------------------------------------------------------- writing

def write_cube_snapshot(snapshot_dir: str, cube, pv, delta_version: int,
                        groups=(), extra_meta: Optional[dict] = None) -> str:
    """Serialize the cube state pinned by ``pv`` into
    ``snap_<delta_version>``: data files → CHECKSUMS → DONE last. The
    caller must hold the pin for the duration (``CubeSnapshotter`` does);
    a re-write of an existing version UNPUBLISHES first (markers removed
    before any file is replaced), mirroring ``write_delta``'s re-emit
    discipline. Returns the snapshot directory."""
    path = snapshot_path(snapshot_dir, delta_version)
    _unpublish(path)
    _write_snapshot_files(path, cube, pv, delta_version,
                          groups=groups, extra_meta=extra_meta)
    return path


def _unpublish(path: str):
    """Remove a snapshot dir marker-first: a reader listing mid-rewrite
    must see an unpublished directory, never a published one being
    replaced."""
    if os.path.exists(path):
        for marker in ("AUX_DONE", "DONE", _MESH_DONE, _AUX_CHECKSUMS,
                       _CHECKSUMS, _MESH_CHECKSUMS):
            try:
                os.remove(os.path.join(path, marker))
            except OSError:
                pass
        shutil.rmtree(path, ignore_errors=True)


def _write_snapshot_files(path: str, cube, pv, delta_version: int,
                          groups=(), extra_meta: Optional[dict] = None):
    """One cube's snapshot payload into ``path`` (data → CHECKSUMS →
    DONE last). Shared by the single-cube and per-shard writers."""
    os.makedirs(path, exist_ok=True)
    ver, psigs, psrv, pblk, poff = pv.snap
    meta = {
        "format": 1,
        "cube_version": int(ver),
        "delta_version": int(delta_version),
        "n_servers": cube.n_servers,
        "replication": cube.replication,
        "block_rows": cube.block_rows,
        "mem_block_fraction": cube.mem_block_fraction,
        "generation": cube.generation,
        "shapes": {str(g): [int(dim), np.dtype(dt).name]
                   for g, (dim, dt) in cube._shapes.items()},
        "groups": [[str(f), int(v), int(g)] for f, v, g in groups],
        "extra": extra_meta or {},
    }
    files = ["meta.json", "primary.npz"]
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)
    np.savez(os.path.join(path, "primary.npz"),
             sigs=psigs, srv=psrv, blk=pblk, off=poff)

    # the block set a recovered reader can reach at the pinned version:
    # primary routes (srv, blk) plus every server's index-at-pin routes —
    # all protected from reclaim by the caller's pin
    referenced: dict[int, set] = {sid: set() for sid in range(cube.n_servers)}
    live = psrv >= 0
    for sid, bid in zip(psrv[live].tolist(), pblk[live].tolist()):
        referenced[sid].add(bid)
    for sid, srv in enumerate(cube.servers):
        isigs, iblk, ioff = srv._index_at(ver)
        referenced[sid].update(iblk.tolist())
        arrays = {"isigs": isigs, "iblk": iblk, "ioff": ioff}
        bids = sorted(referenced[sid])
        arrays["block_ids"] = np.asarray(bids, np.int64)
        arrays["block_disk"] = np.asarray(
            [bool(srv.blocks[b].on_disk) for b in bids], bool)
        for b in bids:
            # .view: plain-ndarray copy-on-write read of the (possibly
            # memmapped) values; savez writes a dense copy
            arrays[f"block_{b}"] = srv.blocks[b].view
        np.savez(os.path.join(path, f"server_{sid}.npz"), **arrays)
        files.append(f"server_{sid}.npz")

    crash_point("snapshot.pre_manifest")
    sums = [f"{_sha256(os.path.join(path, fn))}  {fn}" for fn in files]
    with open(os.path.join(path, _CHECKSUMS), "w") as f:
        f.write("\n".join(sums) + "\n")
    crash_point("snapshot.pre_done")
    with open(os.path.join(path, "DONE"), "w"):
        pass
    return path


def _encode_key(k):
    # cube-cache keys are ints (group 0) or (group, id) tuples — JSON
    # round-trip: tuple → 2-list, int → int
    return list(k) if isinstance(k, tuple) else int(k)


def _decode_key(k):
    return tuple(k) if isinstance(k, list) else int(k)


def write_aux_state(snap_path: str, reverse_maps: dict,
                    touched_log=(), touched_floor: int = -1) -> str:
    """Persist the advisory warm-start state AFTER the snapshot published:
    per-group reverse maps (bucket → raw items, the exact-invalidation
    index) and the manager's touched-key log. Gated by its own
    AUX_CHECKSUMS + AUX_DONE so a crash here degrades to a valid snapshot
    with cold caches, never a torn snapshot."""
    crash_point("snapshot.pre_aux")
    aux = {
        "reverse_maps": {
            str(g): {str(b): sorted(int(i) for i in items)
                     for b, items in buckets.items()}
            for g, buckets in reverse_maps.items()},
        "touched": [[int(v), [_encode_key(k) for k in keys],
                     sorted(int(i) for i in items)]
                    for v, keys, items in touched_log],
        "touched_floor": int(touched_floor),
    }
    p = os.path.join(snap_path, "aux.json")
    with open(p, "w") as f:
        json.dump(aux, f)
    with open(os.path.join(snap_path, _AUX_CHECKSUMS), "w") as f:
        f.write(f"{_sha256(p)}  aux.json\n")
    with open(os.path.join(snap_path, "AUX_DONE"), "w"):
        pass
    return p


# ---------------------------------------------------------------- reading

def verify_snapshot(path: str) -> bool:
    """DONE present + every manifested file re-hashes clean + no
    unmanifested data file on disk (aux files are covered by their own
    manifest). Raises :class:`SnapshotIntegrityError` on any violation;
    returns True when verified."""
    if not os.path.exists(os.path.join(path, "DONE")):
        raise SnapshotIntegrityError(
            f"{os.path.basename(path)}: unpublished (no DONE)")
    manifest = os.path.join(path, _CHECKSUMS)
    if not os.path.exists(manifest):
        raise SnapshotIntegrityError(
            f"{os.path.basename(path)}: no CHECKSUMS manifest")
    expected = {}
    with open(manifest) as f:
        for line in f:
            line = line.strip()
            if line:
                try:
                    digest, fn = line.split(None, 1)
                except ValueError:
                    raise SnapshotIntegrityError(
                        f"{os.path.basename(path)}: malformed CHECKSUMS "
                        f"line {line!r}")
                expected[fn.strip()] = digest
    skip = {"DONE", "AUX_DONE", _CHECKSUMS, _AUX_CHECKSUMS, *_AUX_FILES}
    on_disk = {fn for fn in os.listdir(path) if fn not in skip}
    extra = sorted(on_disk - set(expected))
    if extra:
        raise SnapshotIntegrityError(
            f"{os.path.basename(path)}: {extra} on disk but not in "
            f"CHECKSUMS")
    for fn, digest in expected.items():
        full = os.path.join(path, fn)
        if not os.path.exists(full):
            raise SnapshotIntegrityError(
                f"{os.path.basename(path)}: {fn} named in CHECKSUMS but "
                f"missing")
        got = _sha256(full)
        if got != digest:
            raise SnapshotIntegrityError(
                f"{os.path.basename(path)}: {fn} sha256 mismatch "
                f"(manifest {digest[:12]}…, file {got[:12]}…)")
    return True


def load_cube_snapshot(path: str, verify: bool = True):
    """Rebuild a ParameterCube from a published snapshot. Returns
    ``(cube, meta)``. Blocks are re-added slot by slot (fresh block ids)
    and every routing array is remapped through the old→new id table, so
    the restored cube serves lookups bit-identical to the pinned state —
    including replica failover at the restored version."""
    from repro.core.cube import ParameterCube
    if verify:
        verify_snapshot(path)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    cube = ParameterCube(
        n_servers=int(meta["n_servers"]),
        replication=int(meta["replication"]),
        block_rows=int(meta["block_rows"]),
        mem_block_fraction=float(meta["mem_block_fraction"]),
        generation=int(meta["generation"]))
    cube_version = int(meta["cube_version"])
    for g, (dim, dt) in meta["shapes"].items():
        cube._shapes[int(g)] = (int(dim), np.dtype(dt))
        if cube._dim is None:
            cube._dim, cube._dtype = int(dim), np.dtype(dt)

    remaps: list[np.ndarray] = []
    for sid in range(cube.n_servers):
        srv = cube.servers[sid]
        with np.load(os.path.join(path, f"server_{sid}.npz")) as z:
            bids = z["block_ids"]
            disk = z["block_disk"]
            remap = (np.full(int(bids.max()) + 1, -1, np.int32)
                     if bids.size else np.empty(0, np.int32))
            for old_bid, on_disk in zip(bids.tolist(), disk.tolist()):
                new_bid = srv.add_block(np.empty(0, np.uint64),
                                        z[f"block_{old_bid}"],
                                        on_disk=bool(on_disk), index=False)
                remap[old_bid] = new_bid
            isigs, iblk, ioff = z["isigs"], z["iblk"], z["ioff"]
            srv.install_index(isigs, remap[iblk] if iblk.size else iblk,
                              ioff)
            srv.publish_version(cube_version)
            remaps.append(remap)

    with np.load(os.path.join(path, "primary.npz")) as z:
        psigs, psrv = z["sigs"], z["srv"]
        pblk, poff = z["blk"].copy(), z["off"]
    for sid in range(cube.n_servers):
        sel = psrv == sid
        if sel.any():
            pblk[sel] = remaps[sid][pblk[sel]]
    cube._snap = (cube_version, psigs, psrv, pblk, poff)
    return cube, meta


def load_aux_state(path: str) -> Optional[dict]:
    """The advisory aux state, or None when absent/torn/corrupt (recovery
    proceeds with cold caches — safe, just less warm)."""
    if not os.path.exists(os.path.join(path, "AUX_DONE")):
        return None
    manifest = os.path.join(path, _AUX_CHECKSUMS)
    if not os.path.exists(manifest):
        return None
    try:
        with open(manifest) as f:
            digest, fn = f.read().strip().split(None, 1)
        p = os.path.join(path, fn.strip())
        if _sha256(p) != digest:
            log_event(log, "snapshot_aux_checksum_failed",
                      level=logging.WARNING,
                      snapshot=os.path.basename(path))
            return None
        with open(p) as f:
            raw = json.load(f)
    except (OSError, ValueError):
        return None
    return {
        "reverse_maps": {
            int(g): {int(b): set(items) for b, items in buckets.items()}
            for g, buckets in raw.get("reverse_maps", {}).items()},
        "touched": [(int(v), frozenset(_decode_key(k) for k in keys),
                     frozenset(int(i) for i in items))
                    for v, keys, items in raw.get("touched", [])],
        "touched_floor": int(raw.get("touched_floor", -1)),
    }


def list_snapshots(snapshot_dir: str):
    """All snapshot dirs (published or not) as ``(version, path,
    published)``, version-sorted."""
    if not os.path.isdir(snapshot_dir):
        return []
    out = []
    for d in os.listdir(snapshot_dir):
        if not d.startswith(_PREFIX):
            continue
        try:
            ver = int(d[len(_PREFIX):])
        except ValueError:
            continue
        full = os.path.join(snapshot_dir, d)
        out.append((ver, full,
                    os.path.exists(os.path.join(full, "DONE"))))
    out.sort()
    return out


def latest_valid_snapshot(snapshot_dir: str) -> Optional[str]:
    """Newest snapshot that is published AND passes verification; torn or
    corrupt snapshots are logged and skipped — the fall-back-to-previous
    rule that makes a crash mid-snapshot harmless."""
    for ver, path, published in reversed(list_snapshots(snapshot_dir)):
        if not published:
            continue
        try:
            verify_snapshot(path)
            return path
        except SnapshotIntegrityError as e:
            log_event(log, "snapshot_corrupt_ignored",
                      level=logging.WARNING, version=ver,
                      snapshot=os.path.basename(path), error=str(e))
    return None


# ------------------------------------------------------ sharded snapshots

def write_sharded_snapshot(snapshot_dir: str, mesh, record,
                           delta_version: int, groups=(),
                           extra_meta: Optional[dict] = None) -> str:
    """Capture a sharded (mesh) cube: ``snap_<v>/shard_<s>/`` — each shard
    serialized with the single-cube discipline (its own meta/CHECKSUMS/
    DONE) at the shard version pinned by ``record`` (a MeshCube's
    ``_MeshRecord``: one cross-shard frontier, so the snapshot is
    batch-atomic across shards exactly like a pinned read). A top-level
    ``mesh_meta.json`` records the per-shard cursor map + topology, and
    ``MESH_DONE`` publishes LAST. The marker is deliberately not ``DONE``:
    legacy single-cube recovery sees an unpublished dir and skips it.

    This is the item-5 hook: a mesh restart = per-shard restore from the
    shard cursors + delta-log replay from ``delta_version + 1``."""
    path = snapshot_path(snapshot_dir, delta_version)
    _unpublish(path)
    os.makedirs(path, exist_ok=True)
    for s, (shard, pin) in enumerate(zip(mesh.shards, record.shard_pins)):
        _write_snapshot_files(os.path.join(path, f"{_SHARD_PREFIX}{s}"),
                              shard, pin, delta_version,
                              groups=groups, extra_meta=extra_meta)
    topo = mesh.router.topology
    meta = {
        "format": 1,
        "n_shards": int(mesh.n_shards),
        "mesh_version": int(record.version),
        "delta_version": int(delta_version),
        # per-shard cursor: the shard-local cube version each shard_<s>/
        # captures — the coordinate a per-shard replayer resumes from
        "shard_cursors": {str(s): int(p.version)
                          for s, p in enumerate(record.shard_pins)},
        "topology": {"version": int(topo.version), "seed": int(topo.seed),
                     "hosts": list(topo.hosts),
                     "assignments": [list(a) for a in topo.assignments]},
        "shapes": {str(g): [int(dim), np.dtype(dt).name]
                   for g, (dim, dt) in mesh._shapes.items()},
        "groups": [[str(f), int(v), int(g)] for f, v, g in groups],
        "extra": extra_meta or {},
    }
    mp = os.path.join(path, "mesh_meta.json")
    with open(mp, "w") as f:
        json.dump(meta, f)
    crash_point("snapshot.pre_mesh_manifest")
    with open(os.path.join(path, _MESH_CHECKSUMS), "w") as f:
        f.write(f"{_sha256(mp)}  mesh_meta.json\n")
    crash_point("snapshot.pre_mesh_done")
    with open(os.path.join(path, _MESH_DONE), "w"):
        pass
    return path


def verify_sharded_snapshot(path: str) -> bool:
    """MESH_DONE present, mesh_meta re-hashes clean, and every shard dir
    passes the single-cube verification. Raises
    :class:`SnapshotIntegrityError` on any violation."""
    base = os.path.basename(path)
    if not os.path.exists(os.path.join(path, _MESH_DONE)):
        raise SnapshotIntegrityError(f"{base}: unpublished (no MESH_DONE)")
    manifest = os.path.join(path, _MESH_CHECKSUMS)
    if not os.path.exists(manifest):
        raise SnapshotIntegrityError(f"{base}: no MESH_CHECKSUMS")
    with open(manifest) as f:
        digest, fn = f.read().strip().split(None, 1)
    if _sha256(os.path.join(path, fn.strip())) != digest:
        raise SnapshotIntegrityError(f"{base}: mesh_meta.json sha256 "
                                     f"mismatch")
    with open(os.path.join(path, "mesh_meta.json")) as f:
        meta = json.load(f)
    for s in range(int(meta["n_shards"])):
        sdir = os.path.join(path, f"{_SHARD_PREFIX}{s}")
        if not os.path.isdir(sdir):
            raise SnapshotIntegrityError(f"{base}: missing shard_{s}")
        verify_snapshot(sdir)
    return True


def load_sharded_snapshot(path: str, verify: bool = True):
    """Rebuild every shard cube of a sharded snapshot. Returns
    ``(shard_cubes, mesh_meta)`` — each shard restored with the proven
    single-cube loader (bit-identical lookups at its pinned cursor,
    replica failover included)."""
    if verify:
        verify_sharded_snapshot(path)
    with open(os.path.join(path, "mesh_meta.json")) as f:
        meta = json.load(f)
    shards = []
    for s in range(int(meta["n_shards"])):
        cube, _smeta = load_cube_snapshot(
            os.path.join(path, f"{_SHARD_PREFIX}{s}"), verify=False)
        shards.append(cube)
    return shards, meta


def list_sharded_snapshots(snapshot_dir: str):
    """Sharded snapshot dirs as ``(version, path, published)``,
    version-sorted (published = MESH_DONE present)."""
    if not os.path.isdir(snapshot_dir):
        return []
    out = []
    for d in os.listdir(snapshot_dir):
        if not d.startswith(_PREFIX):
            continue
        try:
            ver = int(d[len(_PREFIX):])
        except ValueError:
            continue
        full = os.path.join(snapshot_dir, d)
        if not os.path.isdir(os.path.join(full, f"{_SHARD_PREFIX}0")) \
                and not os.path.exists(os.path.join(full, _MESH_DONE)):
            continue
        out.append((ver, full,
                    os.path.exists(os.path.join(full, _MESH_DONE))))
    out.sort()
    return out


def latest_valid_sharded_snapshot(snapshot_dir: str) -> Optional[str]:
    """Newest published sharded snapshot that verifies clean; torn ones
    are logged and skipped."""
    for ver, path, published in reversed(list_sharded_snapshots(
            snapshot_dir)):
        if not published:
            continue
        try:
            verify_sharded_snapshot(path)
            return path
        except SnapshotIntegrityError as e:
            log_event(log, "sharded_snapshot_corrupt_ignored",
                      level=logging.WARNING, version=ver,
                      snapshot=os.path.basename(path), error=str(e))
    return None


# --------------------------------------------------------------- retention

def prune_snapshots(snapshot_dir: str, keep: int = 2) -> list[str]:
    """Keep the newest ``keep`` VALID snapshots; remove every snapshot dir
    (torn ones included) strictly older than the oldest retained. Returns
    the removed paths."""
    assert keep >= 1
    snaps = list_snapshots(snapshot_dir)
    valid = []
    for ver, path, published in snaps:
        if published:
            try:
                verify_snapshot(path)
                valid.append(ver)
            except SnapshotIntegrityError:
                pass
    if not valid:
        return []
    floor = sorted(valid)[-keep:][0]     # oldest retained valid version
    removed = []
    for ver, path, _pub in snaps:
        if ver < floor:
            shutil.rmtree(path, ignore_errors=True)
            removed.append(path)
    return removed


def prune_delta_log(log_dir: str, upto_version: int) -> int:
    """Remove delta dirs with version ≤ ``upto_version`` (they are baked
    into every retained snapshot). The caller computes the bound — oldest
    retained snapshot's version, floored by every live watcher cursor."""
    if not os.path.isdir(log_dir):
        return 0
    removed = 0
    for d in os.listdir(log_dir):
        if not d.startswith("delta_"):
            continue
        try:
            ver = int(d.split("_")[-1])
        except ValueError:
            continue
        if ver <= upto_version:
            shutil.rmtree(os.path.join(log_dir, d), ignore_errors=True)
            removed += 1
    return removed


# ------------------------------------------------------------- snapshotter

class CubeSnapshotter:
    """Periodic off-hot-path snapshots of a ``ServingSubstrate``'s cube +
    update-plane state, with retention and delta-log GC.

    ``maybe_snapshot`` (called by the substrate watcher after applies)
    snapshots once the delta cursor advanced ``every_deltas`` past the
    last snapshot; ``snapshot`` captures atomically under the manager's
    apply lock and serializes under a pin (writers keep publishing
    throughout). ``graceful_shutdown`` is the planned-restart fast path:
    stop the registered watchers, take a final snapshot at the quiescent
    cursor — the restarted node replays ZERO deltas."""

    def __init__(self, substrate, snapshot_dir: str, every_deltas: int = 8,
                 keep: int = 2, delta_log_dir: Optional[str] = None):
        assert every_deltas >= 1
        self.sub = substrate
        self.snapshot_dir = snapshot_dir
        self.every_deltas = every_deltas
        self.keep = keep
        self.delta_log_dir = delta_log_dir
        os.makedirs(snapshot_dir, exist_ok=True)
        self.watchers: list = []         # live cursors the delta GC floors on
        self.snapshots_taken = 0
        self.deltas_pruned = 0
        self.last_snapshot_s = 0.0       # duration of the last snapshot
        self._lock = threading.Lock()    # one snapshot in flight at a time
        # resume-aware: an existing valid snapshot already covers its
        # version — don't rewrite it on the first post-restart apply
        self.last_snapshot_version = -1
        newest = latest_valid_snapshot(snapshot_dir)
        meta_name = "meta.json"
        if newest is None:
            newest = latest_valid_sharded_snapshot(snapshot_dir)
            meta_name = "mesh_meta.json"
        if newest is not None:
            try:
                with open(os.path.join(newest, meta_name)) as f:
                    self.last_snapshot_version = int(
                        json.load(f)["delta_version"])
            except (OSError, ValueError, KeyError):
                pass

    def register_watcher(self, watcher):
        """Register a live delta watcher whose cursor floors the delta-log
        GC (pruning must never outrun a replaying consumer)."""
        self.watchers.append(watcher)
        return watcher

    # ------------------------------------------------------------ capture
    def maybe_snapshot(self) -> Optional[str]:
        mgr = self.sub.updates
        if (mgr.stats.last_version - self.last_snapshot_version
                < self.every_deltas):
            return None
        return self.snapshot()

    def snapshot(self, force: bool = False) -> Optional[str]:
        """Take one snapshot at the current delta cursor. Returns the
        snapshot path, or None when the cursor has not advanced since the
        last snapshot (``force`` overrides — a same-version rewrite)."""
        with self._lock:
            t0 = time.perf_counter()
            mgr = self.sub.updates
            with mgr.pinned_capture() as (pv, state):
                delta_ver, touched_log, touched_floor = state
                if delta_ver <= self.last_snapshot_version and not force:
                    return None
                groups = [(f, v, g)
                          for (f, v), g in self.sub.groups.items()]
                if getattr(self.sub.cube, "is_mesh", False):
                    # sharded capture: pv pins a MeshCube record — one
                    # cross-shard frontier; each shard serializes at its
                    # pinned cursor under snap_<v>/shard_<s>/. Aux state
                    # is skipped (mesh recovery starts with cold caches).
                    path = write_sharded_snapshot(
                        self.snapshot_dir, self.sub.cube, pv.snap,
                        delta_ver, groups=groups,
                        extra_meta={"tail_dim": self.sub.tail_dim})
                else:
                    path = write_cube_snapshot(
                        self.snapshot_dir, self.sub.cube, pv, delta_ver,
                        groups=groups,
                        extra_meta={"tail_dim": self.sub.tail_dim})
                    write_aux_state(
                        path,
                        {g: rm.export()
                         for g, rm in self.sub.bucket_items.items()},
                        touched_log, touched_floor)
            self.last_snapshot_version = delta_ver
            self.snapshots_taken += 1
            self.last_snapshot_s = time.perf_counter() - t0
            log_event(log, "snapshot_published",
                      watcher=type(self).__name__, version=delta_ver,
                      duration_s=self.last_snapshot_s,
                      snapshot=os.path.basename(path))
            self.gc()
            return path

    # ---------------------------------------------------------- retention
    def gc(self):
        """Retention + delta-log GC: keep the newest K valid snapshots;
        prune delta dirs ≤ min(oldest retained snapshot version, every
        registered watcher cursor)."""
        prune_snapshots(self.snapshot_dir, keep=self.keep)
        if self.delta_log_dir is None:
            return
        retained = []
        for ver, path, published in list_snapshots(self.snapshot_dir):
            if published:
                retained.append(ver)
        if not retained:
            return
        upto = min(retained)
        for w in self.watchers:
            upto = min(upto, w.applied_version)
        self.deltas_pruned += prune_delta_log(self.delta_log_dir, upto)

    # ----------------------------------------------------------- shutdown
    def graceful_shutdown(self) -> Optional[str]:
        """Planned restart: quiesce the watchers, snapshot the final
        cursor. A recover() from this snapshot replays zero deltas."""
        for w in self.watchers:
            try:
                w.stop()
            except Exception:            # noqa: BLE001 — best-effort stop
                pass
        return self.snapshot()

    def install_sigterm_hook(self, chain: bool = True):
        """SIGTERM (preemption notice) → graceful_shutdown, then chain to
        the previous handler (mirrors AsyncCheckpointer's emergency-save
        hook). Returns the installed handler."""
        prev = signal.getsignal(signal.SIGTERM)

        def handler(signum, frame):
            try:
                self.graceful_shutdown()
            finally:
                if chain:
                    if callable(prev):
                        prev(signum, frame)
                    else:
                        signal.default_int_handler(signum, frame)
        signal.signal(signal.SIGTERM, handler)
        return handler
