"""UpdateManager: one delta batch in, every storage/cache layer coherent
out (DESIGN.md §6).

Apply order per batch — chosen so no reader can observe a NEW cache entry
over OLD cube rows, or OLD cache rows attributed to a NEW version. Every
step runs at BATCH granularity (all groups together, DESIGN.md §6.6):

  1. caches      — targeted ``invalidate_keys`` / ``invalidate_items`` of
                   exactly the touched keys/items of EVERY group, BEFORE
                   the publish (LFU counts persist);
  2. cube        — ``ParameterCube.apply_batch`` publishes ALL groups'
                   rows with ONE atomic version bump (pinned/in-flight
                   readers keep their snapshot — and a pin taken at any
                   instant sees every group at the same version);
  3. HBM head    — in-place donated-buffer scatter for the touched
                   signatures currently resident; deletes demote;
  4. caches      — the same targeted invalidation AGAIN, post-publish.

The double invalidation brackets the publish: pass 1 closes the window
where a reader pinning the new version could cache-hit a not-yet-
invalidated pre-delta row (old rows stamped with the new version — torn
attribution); pass 4 plus the serving ops' cache-aside guards remove any
entry a racing reader re-inserted around the publish itself. A request
racing the apply therefore either reads the old rows coherently (old
cache + old pinned version) or misses and refetches; it can never
cache-hit its way to a torn mix. Because the bracket spans the WHOLE
batch, the per-version touched-key log carries one entry per batch —
the serving ops' guards see all groups' touched keys under the single
published version, matching the cube's batch-atomic swap.

The manager is also the DoubleBuffer ``on_swap`` subscriber: a whole-
generation hot swap bumps the caches' model version — the fix for the
latent staleness bug where a swap kept serving the previous generation's
scores out of the query cache for up to its TTL window.

``rebalance`` runs the frequency-driven tier migration: cube-cache LFU
counts → ``PromoteDemotePolicy`` → head promote/demote, rows sourced from
the cube tail. ``maybe_compact`` folds cube overlay blocks back into base
blocks once they pile past a threshold. Both belong OFF the request path
(the serving loop calls them from the update thread).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.update.delta import DeltaBatch
from repro.update.policy import (PromoteDemotePolicy, merged_lfu_counts,
                                 slice_group_counts)


@dataclass
class UpdateStats:
    deltas_applied: int = 0
    deltas_skipped: int = 0        # stale/duplicate versions (replay)
    rows_upserted: int = 0
    rows_deleted: int = 0
    head_rows_updated: int = 0
    cube_keys_invalidated: int = 0
    query_entries_invalidated: int = 0
    promotions: int = 0
    demotions: int = 0
    compactions: int = 0
    generation_swaps: int = 0
    last_version: int = -1
    # apply/compaction timings (obs registry: jizhi_update_stats{...})
    apply_s_total: float = 0.0     # cumulative wall time under the apply lock
    apply_s_last: float = 0.0      # duration of the most recent apply
    compact_s_total: float = 0.0   # cumulative compaction wall time
    compact_s_last: float = 0.0    # duration of the most recent compaction


def _default_cache_key_fn(group: int, ids: np.ndarray):
    """Cube-cache keys for a group's raw ids. The serving stack keys its
    cube cache by the bare (hashed) id for the primary group and by
    (group, id) otherwise — override per deployment."""
    if group == 0:
        return [int(i) for i in ids]
    return [(group, int(i)) for i in ids]


class UpdateManager:
    def __init__(self, cube, cube_cache=None, query_cache=None, head=None,
                 policy: Optional[PromoteDemotePolicy] = None,
                 policies: Optional[dict] = None,
                 cache_key_fn: Callable = _default_cache_key_fn,
                 qcache_items_fn: Optional[Callable] = None,
                 compact_after_blocks: int = 256,
                 compact_max_rows_per_pass: Optional[int] = None,
                 swap_invalidates_cube_cache: bool = False):
        self.cube = cube
        self.cube_cache = cube_cache
        self.query_cache = query_cache
        self.head = head
        self.policy = policy
        # per-group promote/demote policies (multi-scenario substrates
        # split the head budget across groups); ``policy`` stays as the
        # single-group default when a group has no dedicated entry
        self.policies: dict = dict(policies or {})
        self.cache_key_fn = cache_key_fn
        # (group, touched cube ids) → the RAW item keys the query cache is
        # scored under. When the cube id space is a hash of the item space
        # (the serving stack), the deployment must supply the reverse
        # mapping — falling back to GroupDelta.item_ids / the cube ids
        # themselves is only correct when the two spaces coincide.
        self.qcache_items_fn = qcache_items_fn
        self.compact_after_blocks = compact_after_blocks
        # None → monolithic one-pass compaction; an int bounds the rows
        # moved per writer-lock hold (incremental compaction, DESIGN.md
        # §6.6) so maybe_compact never stalls concurrent delta appliers
        # or reader pin churn for a full-rebuild pause
        self.compact_max_rows_per_pass = compact_max_rows_per_pass
        # a dense-generation hot swap does NOT change cube rows (those only
        # move via apply_delta, already invalidated key-by-key) — wiping
        # the warm ~84%-hit cube cache on every swap buys no coherence and
        # costs a remote-refetch burst. Opt in only for deployments whose
        # generation payload swaps the sparse tier too.
        self.swap_invalidates_cube_cache = swap_invalidates_cube_cache
        self.stats = UpdateStats()
        self._lock = threading.Lock()      # appliers serialize
        # per-group raw ids currently holding head slots (rebalance assumes
        # the cube cache is keyed by the group's raw ids — the serving
        # convention for the primary group)
        self._resident_ids: dict[int, set] = {}
        # per-version touched-key log: the serving ops' cache-aside guards
        # consult it to drop ONLY the entries a racing delta actually
        # touched — a batch-wide drop would fire on nearly every batch
        # under a continuous stream and collapse the query-cache hit ratio
        self._touched_log: deque = deque()
        self._touched_floor = -1       # log is complete for versions > floor
        self._touched_cap = 512

    # ------------------------------------------------------------- deltas
    def apply(self, batch: DeltaBatch) -> int:
        """Apply one versioned delta batch across all layers. Idempotent
        under replay: versions at or below the last applied one are
        skipped (the watcher may re-offer a delta after a crash)."""
        with self._lock:
            if batch.version <= self.stats.last_version:
                self.stats.deltas_skipped += 1
                return self.stats.last_version
            t_apply0 = time.perf_counter()
            # validate EVERY group before applying ANY: last_version only
            # advances after the whole batch lands, so a malformed group
            # failing mid-batch would otherwise leave the earlier groups
            # applied — and every watcher retry would re-apply them
            # (duplicate overlay blocks, double-counted stats)
            for g in batch.groups:
                ids = np.atleast_1d(np.asarray(g.ids)).reshape(-1)
                if ids.size:
                    rows = np.asarray(g.rows)
                    if rows.ndim != 2 or rows.shape[0] != ids.size:
                        raise ValueError(
                            f"delta v{batch.version} group {g.group}: rows "
                            f"{rows.shape} vs {ids.size} ids")
                    shape = self.cube.row_shape(g.group)
                    if shape is not None and rows.shape[1] != shape[0]:
                        raise ValueError(
                            f"delta v{batch.version} group {g.group}: dim "
                            f"{rows.shape[1]} != cube dim {shape[0]}")
            # fold every group's touched key/item sets FIRST so the whole
            # batch shares ONE invalidation bracket around ONE cube publish
            parts = []        # (group, ids, rows, dels) per group, in order
            keys: list = []
            items_set: set = set()
            for g in batch.groups:
                ids = np.atleast_1d(np.asarray(g.ids)).reshape(-1)
                dels = np.atleast_1d(np.asarray(g.delete_ids)).reshape(-1)
                parts.append((g.group, ids,
                              np.asarray(g.rows) if ids.size else None,
                              dels))
                touched = np.concatenate([ids, dels]) if dels.size else ids
                if touched.size:
                    keys.extend(self.cache_key_fn(g.group, touched))
                if self.qcache_items_fn is not None:
                    items_set |= set(self.qcache_items_fn(g.group, touched))
                    # the training side may ship the raw item ids alongside
                    # the delta (GroupDelta.item_ids): union them in so
                    # invalidation no longer depends on the serving side
                    # having SEEN an item since start — a delta landing
                    # before an item's first request still invalidates any
                    # warm-started query-cache entry for it
                    if g.item_ids is not None:
                        items_set |= {int(i)
                                      for i in np.atleast_1d(g.item_ids)}
                else:
                    items_set |= {int(i) for i in g.touched_item_ids()}
            items = list(items_set)
            # FIRST invalidation pass, BEFORE the cube publish — once for
            # the whole batch. The old invalidate-after-publish order had
            # a torn-attribution window: a reader pinning the NEW version
            # could probe the cache before the invalidation landed and
            # cache-hit a pre-delta row, stamping old rows with the new
            # version. Invalidating first closes it — a reader that
            # re-inserts after this pass is inserting rows that are still
            # current (nothing has published yet), and the SECOND pass
            # below plus the serving ops' own cache-aside guards cover
            # every insert that races the publish itself.
            if self.cube_cache is not None and keys:
                self.stats.cube_keys_invalidated += \
                    self.cube_cache.invalidate_keys(keys)
            if self.query_cache is not None and items:
                self.stats.query_entries_invalidated += \
                    self.query_cache.invalidate_items(items)
            # ONE atomic publish covering every group: a reader pinning at
            # any instant sees either no group or all groups at the batch
            # version — the §7.3 cross-group torn window cannot open
            v_after = self.cube.apply_batch(
                [(grp, ids if ids.size else None, rows,
                  dels if dels.size else None)
                 for grp, ids, rows, dels in parts])
            # log BEFORE the post-publish invalidation: the serving-side
            # guards read this concurrently — appended after, a guard
            # checking in the window between invalidate and append would
            # see an empty span and keep a just-resurrected stale entry.
            # Appended first, it can only over-report (harmless drop).
            # ONE entry per batch, at the single published version.
            self._touched_log.append(
                (v_after, frozenset(keys), frozenset(items)))
            while len(self._touched_log) > self._touched_cap:
                self._touched_floor = self._touched_log.popleft()[0]
            # SECOND invalidation pass, AFTER the publish (and before the
            # head scatter — the head never reads the caches, so earlier
            # is strictly a smaller stale window): catches entries a
            # concurrent reader re-inserted during the publish window
            # whose own cache-aside guard ran before the new version
            # became visible to it.
            if self.cube_cache is not None and keys:
                self.stats.cube_keys_invalidated += \
                    self.cube_cache.invalidate_keys(keys)
            if self.query_cache is not None and items:
                self.stats.query_entries_invalidated += \
                    self.query_cache.invalidate_items(items)
            for grp, ids, rows, dels in parts:
                if self.head is not None:
                    if ids.size:
                        self.stats.head_rows_updated += self.head.update_rows(
                            grp, ids, rows)
                    if dels.size:
                        self.head.demote(grp, dels)
                        # keep the policy's membership view in sync — a
                        # drifted resident set undercounts free slots and
                        # wastes hysteresis evictions on already-gone keys
                        if grp in self._resident_ids:
                            self._resident_ids[grp] -= \
                                {int(i) for i in dels}
                self.stats.rows_upserted += int(ids.size)
                self.stats.rows_deleted += int(dels.size)
            self.stats.deltas_applied += 1
            self.stats.last_version = batch.version
            self.stats.apply_s_last = time.perf_counter() - t_apply0
            self.stats.apply_s_total += self.stats.apply_s_last
            return batch.version

    @contextmanager
    def pinned_capture(self):
        """Atomic capture for the snapshotter (DESIGN.md §9): under the
        apply lock — so no delta batch is mid-flight — pin the cube and
        read the delta cursor + touched-key log, then RELEASE the lock and
        yield. Serialization happens outside the lock under the pin: the
        pin keeps every captured block and versioned server index alive
        against reclaim/compaction while appliers keep publishing.

        Yields ``(pinned_version, (last_version, touched_log,
        touched_floor))``. The lock is plain (non-reentrant); nothing
        inside the critical section may call back into the manager."""
        from repro.core.cube import PinnedVersion
        with self._lock:
            snap = self.cube._pin_current()
            state = (self.stats.last_version, list(self._touched_log),
                     self._touched_floor)
        try:
            yield PinnedVersion(snap), state
        finally:
            self.cube._pin_release(snap[0])

    def restore_state(self, last_version: int, touched_log=None,
                      touched_floor: Optional[int] = None):
        """Recovery-side inverse of ``pinned_capture``: position the delta
        cursor (replay resumes at ``last_version + 1``; older versions hit
        the idempotence skip) and rehydrate the touched-key log. With no
        persisted aux state the floor snaps to ``last_version`` so
        ``touched_since`` answers None — conservative invalidation —
        instead of a falsely-empty span for pre-snapshot versions."""
        with self._lock:
            self.stats.last_version = int(last_version)
            self._touched_log.clear()
            if touched_log:
                self._touched_log.extend(
                    (int(v), frozenset(ks), frozenset(its))
                    for v, ks, its in touched_log)
            self._touched_floor = int(
                last_version if touched_floor is None else touched_floor)

    def touched_since(self, version: int):
        """(cube_keys, item_keys) touched by deltas published at versions >
        ``version``, or None when the log no longer reaches back that far
        (callers must then invalidate conservatively). Versions bumped by
        index folds and compaction touch nothing and legitimately have no
        log entry."""
        if version < self._touched_floor:
            return None
        keys: set = set()
        items: set = set()
        for v, ks, its in list(self._touched_log):
            if v > version:
                keys |= ks
                items |= its
        return keys, items

    # -------------------------------------------------------- generations
    def on_generation_swap(self, gen=None):
        """DoubleBuffer on_swap hook: the dense model changed, so every
        cached SCORE is stale at once; cube ROWS survive unless this
        deployment swaps the sparse tier with the generation."""
        if self.query_cache is not None:
            self.query_cache.bump_model_version()
        if self.cube_cache is not None and self.swap_invalidates_cube_cache:
            self.cube_cache.bump_generation()
        self.stats.generation_swaps += 1

    # -------------------------------------------------- background passes
    def rebalance(self, group: int = 0,
                  _merged: Optional[dict] = None) -> tuple[int, int]:
        """One promote/demote pass for ``group``: the group's slice of the
        cube-cache LFU counts → the group's policy plan → head migration
        (rows gathered from the cube tail in one batched lookup, scattered
        into HBM in one donated launch). Returns (promoted, demoted).
        ``_merged`` lets ``rebalance_all`` fold the two cache tiers once
        and share the result across every group's slice."""
        policy = self.policies.get(group, self.policy)
        if self.head is None or policy is None or self.cube_cache is None:
            return (0, 0)
        with self._lock:
            counts = slice_group_counts(
                merged_lfu_counts(self.cube_cache) if _merged is None
                else _merged, group)
            resident_ids = self._resident_ids.setdefault(group, set())
            plan = policy.plan(counts, resident_ids)
            promoted = demoted = 0
            if plan.demote:
                ids = np.asarray([k for k in plan.demote], np.int64)
                demoted = self.head.demote(group, ids)
                resident_ids -= set(plan.demote)
            if plan.promote:
                ids = np.asarray([k for k in plan.promote], np.int64)
                live = self.cube.contains(group, ids)
                ids = ids[live]                 # only rows the tail still has
                if ids.size:
                    rows = self.cube.lookup(group, ids)
                    promoted = self.head.promote(group, ids, rows)
                    resident_ids |= {int(i) for i in ids}
            self.stats.promotions += promoted
            self.stats.demotions += demoted
            return (promoted, demoted)

    def rebalance_all(self) -> dict:
        """One promote/demote pass per group that owns a policy (or group
        0 under the legacy single-policy wiring). The mem+disk LFU count
        fold runs ONCE and is sliced per group — this runs after every
        applied delta batch, so N full folds per apply would dominate."""
        if self.head is None or self.cube_cache is None:
            return {}
        groups = sorted(self.policies) if self.policies else [0]
        merged = merged_lfu_counts(self.cube_cache)
        return {g: self.rebalance(g, _merged=merged) for g in groups}

    def maybe_compact(self) -> bool:
        """Fold cube overlays once enough have piled up — off the hot path;
        readers keep their pinned snapshots throughout."""
        if self.cube.overlay_blocks < self.compact_after_blocks:
            return False
        t0 = time.perf_counter()
        self.cube.compact(max_rows_per_pass=self.compact_max_rows_per_pass)
        self.stats.compact_s_last = time.perf_counter() - t0
        self.stats.compact_s_total += self.stats.compact_s_last
        self.stats.compactions += 1
        return True
