"""The HBM-resident head tier of the sparse store (DESIGN.md §6.3).

A fixed-slot device table mirroring the hottest cube rows: the cube tail
(host/disk) stays the source of truth for every row; the head holds copies
of the rows worth HBM. Membership is a host-side signature → slot map (the
same compact signatures the cube keys by, so both tiers agree on identity);
row data moves with ``sparse.sharded.sharded_row_update`` — a donated-buffer
scatter per mesh shard, so promotions, demotions and delta updates touch
rows *in place* in the live table, never rebuilding it.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.sparse.hashing import signature_np
from repro.sparse.sharded import sharded_row_update


@dataclass
class HeadStats:
    promotions: int = 0
    demotions: int = 0
    inplace_updates: int = 0
    hits: int = 0
    misses: int = 0
    scatters: int = 0            # device scatter launches (batched)

    @property
    def hit_ratio(self):
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class HBMHead:
    """Fixed-capacity device row store with host-side membership.

    The sig → slot map is kept as parallel sorted numpy arrays (one
    ``searchsorted`` resolves a whole batch, mirroring the cube's index
    discipline) and swapped atomically as one tuple; membership changes
    (promote/demote) rebuild it off the hot path."""

    def __init__(self, n_slots: int, dim: int, dtype=jnp.float32):
        self.n_slots = n_slots
        self.dim = dim
        self.table = jnp.zeros((n_slots, dim), dtype)
        self._map = (np.empty(0, np.uint64), np.empty(0, np.int32))
        self._free = list(range(n_slots - 1, -1, -1))   # pop() → lowest first
        self._lock = threading.Lock()                   # writers serialize
        self.stats = HeadStats()

    # ---------------------------------------------------------- membership
    @property
    def resident_count(self) -> int:
        return self._map[0].size

    def resident_sigs(self) -> np.ndarray:
        return self._map[0].copy()

    def _resolve(self, sigs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(slots, found) for a batch of signatures against the current map
        snapshot; slots are valid only where found."""
        msigs, mslots = self._map
        if msigs.size == 0:
            return np.zeros(sigs.size, np.int32), np.zeros(sigs.size, bool)
        pos = np.searchsorted(msigs, sigs)
        np.minimum(pos, msigs.size - 1, out=pos)
        found = msigs[pos] == sigs
        return mslots[pos], found

    def resident(self, group: int, raw_ids: np.ndarray) -> np.ndarray:
        ids = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
        _, found = self._resolve(signature_np(group, ids))
        return found

    # ------------------------------------------------------------- access
    def lookup(self, group: int, raw_ids: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]:
        """(rows, found): device-gathered rows for the resident subset
        (rows at non-found positions are zeros — callers fall back to the
        cube tail for those). Takes the writer lock: scatters DONATE the
        table buffer on TPU/GPU, so an unlocked reader could capture a
        reference XLA has already consumed (deleted-array crash)."""
        ids = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
        with self._lock:
            slots, found = self._resolve(signature_np(group, ids))
            rows = np.array(jnp.take(self.table,
                                     jnp.asarray(np.where(found, slots, 0)),
                                     axis=0))
        rows[~found] = 0
        self.stats.hits += int(found.sum())
        self.stats.misses += int((~found).sum())
        return rows, found

    # ------------------------------------------------------------ updates
    def update_rows(self, group: int, raw_ids: np.ndarray,
                    rows: np.ndarray) -> int:
        """Delta application: in-place scatter of new row values for the
        signatures ALREADY resident (non-resident ids are the cube tail's
        problem). One donated-buffer device scatter per call. Duplicate ids
        are resolved here, last occurrence wins — a repeated-index scatter
        applies in UNSPECIFIED order, which would let the head diverge from
        the cube (whose merge is last-wins). Returns rows updated."""
        ids = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
        rows = np.asarray(rows)
        if ids.size > 1:
            _, first_in_rev = np.unique(ids[::-1], return_index=True)
            last = ids.size - 1 - first_in_rev
            ids, rows = ids[last], rows[last]
        with self._lock:
            slots, found = self._resolve(signature_np(group, ids))
            n = int(found.sum())
            if n == 0:
                return 0
            self.table = sharded_row_update(
                self.table, slots[found], rows[found])
            self.stats.inplace_updates += n
            self.stats.scatters += 1
            return n

    def promote(self, group: int, raw_ids: np.ndarray,
                rows: np.ndarray) -> int:
        """Migrate rows INTO the head: assign free slots (already-resident
        ids degrade to an in-place refresh) and scatter the row data in one
        device launch. Promotes at most the free-slot budget — callers
        demote first to make room. Returns rows newly promoted."""
        ids = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
        rows = np.asarray(rows)
        with self._lock:
            sigs = np.asarray(signature_np(group, ids))
            slots, found = self._resolve(sigs)
            fresh = np.flatnonzero(~found)[:len(self._free)]
            new_slots = np.array([self._free.pop() for _ in fresh], np.int32)
            scatter_slots = np.concatenate([slots[found], new_slots])
            scatter_rows = np.concatenate([rows[found], rows[fresh]])
            if scatter_slots.size:
                self.table = sharded_row_update(
                    self.table, scatter_slots, scatter_rows)
                self.stats.scatters += 1
            if fresh.size:
                msigs, mslots = self._map
                order = np.argsort(np.concatenate([msigs, sigs[fresh]]),
                                   kind="stable")
                self._map = (np.concatenate([msigs, sigs[fresh]])[order],
                             np.concatenate([mslots, new_slots])[order])
            self.stats.promotions += int(fresh.size)
            self.stats.inplace_updates += int(found.sum())
            return int(fresh.size)

    def demote(self, group: int, raw_ids: np.ndarray) -> int:
        """Migrate rows OUT of the head: membership-only — the row data
        already lives in the cube tail, so demotion frees the slot without
        touching HBM. Returns rows demoted."""
        ids = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
        with self._lock:
            sigs = np.asarray(signature_np(group, ids))
            slots, found = self._resolve(sigs)
            if not found.any():
                return 0
            gone = np.unique(sigs[found])
            msigs, mslots = self._map
            # vectorized membership: this runs under the lock the serving
            # path's lookup() contends on — a per-element Python scan would
            # stall requests for O(resident) at every delete/rebalance
            keep = ~np.isin(msigs, gone)
            self._free.extend(int(s) for s in mslots[~keep])
            self._map = (msigs[keep], mslots[keep])
            self.stats.demotions += int(gone.size)
            return int(gone.size)
