"""The delta log: versioned parameter-update batches between training and
serving (DESIGN.md §6.1).

A continuously-retrained sparse model touches a tiny slice of rows per
pass — shipping whole generations (serve/hotload.py) for that is the
full-snapshot anti-pattern. The delta log is the streaming alternative:

  * ``GroupDelta`` — per-feature-group arrays of ``(id, row)`` upserts plus
    optional deletes; ids are raw ids in the group's key space (the same
    ids ``ParameterCube.lookup`` takes — signatures are derived at apply
    time so host and cube agree). ``item_ids`` optionally carries the raw
    item ids a serving-side query cache keys scores by, when that space
    differs from the cube's (hashed) id space.
  * ``DeltaBatch`` — one atomic publish unit: a monotonically increasing
    ``version`` plus one GroupDelta per touched group. Within a batch,
    deletes apply after upserts.

On-disk layout (the training-side emitter writes, the serving-side watcher
tails): ``<dir>/delta_<version>/group_<g>.npz`` + a ``CHECKSUMS`` manifest
(per-file sha256, the stream-integrity record) + an empty ``DONE`` marker
written LAST — the marker is the publish point, exactly like hot-load
generations, so a half-written delta is never consumed.

Integrity: the DONE marker catches a TORN delta (partial write), but not a
CORRUPTED one (bit rot, a truncated copy that still parses, a tampered
file). ``verify_delta`` re-hashes every npz against the manifest; the
watcher runs it before apply, so a corrupt batch is logged and skipped —
and retried after backoff, preserving version order — never half-applied.
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
import shutil
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.faults.crash import crash_point
from repro.obs.log import log_event
from repro.serve.hotload import PollWatcher

log = logging.getLogger(__name__)

_PREFIX = "delta_"
_CHECKSUMS = "CHECKSUMS"


class DeltaIntegrityError(ValueError):
    """A published delta's npz content does not match its CHECKSUMS
    manifest — the batch must be skipped (and re-emitted), never applied."""


@dataclass
class GroupDelta:
    group: int
    ids: np.ndarray = field(default_factory=lambda: np.empty(0, np.int64))
    rows: np.ndarray = field(default_factory=lambda: np.empty((0, 0),
                                                              np.float32))
    delete_ids: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.int64))
    # raw item ids for targeted query-cache invalidation; None → the cube
    # ids double as the item keys (single-hash deployments)
    item_ids: Optional[np.ndarray] = None

    def touched_item_ids(self) -> np.ndarray:
        if self.item_ids is not None:
            return np.atleast_1d(np.asarray(self.item_ids))
        return np.concatenate([np.atleast_1d(np.asarray(self.ids)),
                               np.atleast_1d(np.asarray(self.delete_ids))])


@dataclass
class DeltaBatch:
    version: int
    groups: List[GroupDelta]

    @property
    def n_upserts(self) -> int:
        return sum(np.asarray(g.ids).size for g in self.groups)

    @property
    def n_deletes(self) -> int:
        return sum(np.asarray(g.delete_ids).size for g in self.groups)


# ----------------------------------------------------------------- log I/O

def delta_path(log_dir: str, version: int) -> str:
    return os.path.join(log_dir, f"{_PREFIX}{version:012d}")


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def write_delta(log_dir: str, batch: DeltaBatch) -> str:
    """Training-side emit: per-group npz files first, then the CHECKSUMS
    manifest (sha256 per npz), DONE marker last (the atomic publish
    point). Returns the delta directory."""
    path = delta_path(log_dir, batch.version)
    os.makedirs(path, exist_ok=True)
    # a re-emit of this version (the corrupt-delta recovery path) must
    # UNPUBLISH first: remove DONE — so a watcher polling mid-rewrite
    # sees an unpublished directory, not a published one whose npz files
    # are being replaced under it — then the stale manifest (a torn
    # rewrite must fail verification, not pass against old sums)
    done = os.path.join(path, "DONE")
    if os.path.exists(done):
        os.remove(done)
    manifest = os.path.join(path, _CHECKSUMS)
    if os.path.exists(manifest):
        os.remove(manifest)
    # the re-emit may carry fewer groups: drop leftovers so the directory
    # always matches the manifest exactly (verify_delta rejects
    # unmanifested files)
    want = {f"group_{g.group}.npz" for g in batch.groups}
    for fn in os.listdir(path):
        if fn.startswith("group_") and fn.endswith(".npz") and fn not in want:
            os.remove(os.path.join(path, fn))
    sums = []
    for g in batch.groups:
        kw = {"ids": np.atleast_1d(np.asarray(g.ids)),
              "rows": np.asarray(g.rows),
              "delete_ids": np.atleast_1d(np.asarray(g.delete_ids))}
        if g.item_ids is not None:
            kw["item_ids"] = np.atleast_1d(np.asarray(g.item_ids))
        fn = f"group_{g.group}.npz"
        np.savez(os.path.join(path, fn), **kw)
        sums.append(f"{_sha256(os.path.join(path, fn))}  {fn}")
    crash_point("delta.pre_manifest")
    with open(os.path.join(path, _CHECKSUMS), "w") as f:
        f.write("\n".join(sums) + "\n")
    crash_point("delta.pre_done")
    with open(os.path.join(path, "DONE"), "w"):
        pass
    return path


def verify_delta(path: str) -> bool:
    """Re-hash every npz against the CHECKSUMS manifest. Raises
    :class:`DeltaIntegrityError` on any mismatch, a file the manifest
    names that is missing, or a group npz present on disk that the
    manifest does NOT name (``read_delta`` would apply it — a re-emitted
    delta with fewer groups must not resurrect a stale leftover, and a
    stray file dropped into a published dir must not slip past the
    check). Returns True when verified, False when the delta predates
    checksums (no manifest — accepted for compatibility, nothing to
    verify against)."""
    manifest = os.path.join(path, _CHECKSUMS)
    if not os.path.exists(manifest):
        return False
    expected = {}
    with open(manifest) as f:
        for line in f:
            line = line.strip()
            if line:
                digest, fn = line.split(None, 1)
                expected[fn.strip()] = digest
    on_disk = {fn for fn in os.listdir(path)
               if fn.startswith("group_") and fn.endswith(".npz")}
    extra = sorted(on_disk - set(expected))
    if extra:
        raise DeltaIntegrityError(
            f"{os.path.basename(path)}: {extra} present on disk but not "
            f"in the CHECKSUMS manifest")
    for fn, digest in expected.items():
        full = os.path.join(path, fn)
        if not os.path.exists(full):
            raise DeltaIntegrityError(
                f"{os.path.basename(path)}: {fn} named in manifest "
                f"but missing on disk")
        got = _sha256(full)
        if got != digest:
            raise DeltaIntegrityError(
                f"{os.path.basename(path)}: {fn} sha256 mismatch "
                f"(manifest {digest[:12]}…, file {got[:12]}…)")
    return True


def read_delta(path: str) -> DeltaBatch:
    version = int(os.path.basename(path).split("_")[-1])
    # sort by PARSED group id, not filename: lexical order puts
    # group_10.npz before group_2.npz, so at ≥10 groups the apply order
    # would diverge from group numbering
    names = [(int(fn[len("group_"):-len(".npz")]), fn)
             for fn in os.listdir(path)
             if fn.startswith("group_") and fn.endswith(".npz")]
    groups = []
    for gid, fn in sorted(names):
        with np.load(os.path.join(path, fn)) as z:
            groups.append(GroupDelta(
                group=gid,
                ids=z["ids"], rows=z["rows"], delete_ids=z["delete_ids"],
                item_ids=z["item_ids"] if "item_ids" in z else None))
    return DeltaBatch(version=version, groups=groups)


def list_deltas(log_dir: str, after_version: int = -1
                ) -> List[tuple[int, str]]:
    """Published (DONE-marked) deltas newer than ``after_version``, in
    version order — the watcher's tailing primitive."""
    if not os.path.isdir(log_dir):
        return []
    out = []
    for d in os.listdir(log_dir):
        if not d.startswith(_PREFIX):
            continue
        try:
            ver = int(d.split("_")[-1])
        except ValueError:
            continue
        full = os.path.join(log_dir, d)
        if ver > after_version and os.path.exists(os.path.join(full, "DONE")):
            out.append((ver, full))
    out.sort()
    return out


class DeltaEmitter:
    """Training-side convenience: stamps monotonically increasing versions
    onto batches and writes them to the log directory.

    Restarted on an existing log (``start_version=None``, the default) it
    scans the directory and resumes at ``max(existing) + 1`` — the old
    resume-at-0 default silently rewrote already-published delta
    directories in place, corrupting any watcher mid-stream. The scan
    counts every ``delta_*`` directory, published or not: a torn emit
    (no DONE) still owns its version; re-using it would race the crashed
    writer's leftovers. Pass an explicit ``start_version`` to override
    (replay/testing)."""

    def __init__(self, log_dir: str, start_version: Optional[int] = None):
        self.log_dir = log_dir
        os.makedirs(log_dir, exist_ok=True)
        if start_version is None:
            existing = [-1]
            for d in os.listdir(log_dir):
                if d.startswith(_PREFIX):
                    try:
                        existing.append(int(d.split("_")[-1]))
                    except ValueError:
                        pass
            start_version = max(existing) + 1
        self.next_version = start_version

    def emit(self, groups: List[GroupDelta]) -> DeltaBatch:
        batch = DeltaBatch(version=self.next_version, groups=groups)
        write_delta(self.log_dir, batch)
        self.next_version += 1
        return batch


class CheckpointDiffEmitter:
    """Training-side bridge from whole checkpoints to the delta log
    (ROADMAP item 5's emitter — until now only tests and benches emitted
    deltas): row-diff the embedding tables of two ``train/checkpoint.py``
    checkpoints into ONE ``DeltaBatch`` — upserts for changed and new
    rows, tombstones for ids the new table dropped — and publish it via
    :class:`DeltaEmitter`.

    ``table_groups`` maps checkpoint leaf names (``tree_paths`` form, e.g.
    ``"params/embed/table"``) to cube group ids. Row index IS the raw id —
    the same convention ``ServingSubstrate`` loads tables under — so a
    grown table contributes ``[len(old), len(new))`` as new ids and a
    shrunk one tombstones ``[len(new), len(old))``. Leaves are read
    straight from the manifest (DONE-gated), never through the jax restore
    path: the emitter runs beside training and only needs host arrays."""

    def __init__(self, log_dir: str, table_groups: dict,
                 start_version: Optional[int] = None):
        self.emitter = DeltaEmitter(log_dir, start_version=start_version)
        self.table_groups = dict(table_groups)

    def _load_tables(self, ckpt_path: str) -> dict:
        if not os.path.exists(os.path.join(ckpt_path, "DONE")):
            raise FileNotFoundError(
                f"checkpoint {ckpt_path} incomplete (no DONE)")
        with open(os.path.join(ckpt_path, "manifest.json")) as f:
            manifest = json.load(f)
        want = set(self.table_groups)
        out = {}
        for rec in manifest["leaves"]:
            if rec["name"] in want:
                out[rec["name"]] = np.load(
                    os.path.join(ckpt_path, rec["file"]))
        missing = sorted(want - set(out))
        if missing:
            raise KeyError(
                f"checkpoint {ckpt_path} has no leaves {missing} "
                f"(available: {[r['name'] for r in manifest['leaves']]})")
        return out

    def diff(self, old_path: Optional[str],
             new_path: str) -> List[GroupDelta]:
        """GroupDeltas turning ``old_path``'s tables into ``new_path``'s.
        ``old_path=None`` is the bootstrap diff: every row an upsert.
        Tables with no changed rows produce no GroupDelta."""
        new = self._load_tables(new_path)
        old = self._load_tables(old_path) if old_path is not None else {}
        groups = []
        for name in sorted(self.table_groups, key=self.table_groups.get):
            gid = self.table_groups[name]
            b = np.asarray(new[name])
            if b.ndim != 2:
                raise ValueError(f"{name}: embedding table must be 2-D, "
                                 f"got shape {b.shape}")
            a = np.asarray(old[name]) if name in old else None
            if a is None:
                ids = np.arange(b.shape[0], dtype=np.int64)
                dels = np.empty(0, np.int64)
            else:
                n = min(a.shape[0], b.shape[0])
                changed = (np.flatnonzero((a[:n] != b[:n]).any(axis=1))
                           if n else np.empty(0, np.int64))
                grown = np.arange(n, b.shape[0], dtype=np.int64)
                ids = np.concatenate([changed.astype(np.int64), grown])
                dels = np.arange(b.shape[0], a.shape[0], dtype=np.int64)
            if ids.size or dels.size:
                rows = (b[ids] if ids.size
                        else np.empty((0, b.shape[1]), b.dtype))
                groups.append(GroupDelta(group=gid, ids=ids, rows=rows,
                                         delete_ids=dels))
        return groups

    def emit_diff(self, old_path: Optional[str],
                  new_path: str) -> Optional[DeltaBatch]:
        """Diff and publish. Returns the emitted batch, or None when the
        checkpoints' tables are identical (no version burned — an empty
        delta would still cost every watcher a verify+apply cycle)."""
        groups = self.diff(old_path, new_path)
        if not groups:
            return None
        return self.emitter.emit(groups)


class DeltaWatcher(PollWatcher):
    """Serving-side tail of the delta log — the streaming generalization of
    ``ModelMonitor`` (which it shares the PollWatcher skeleton with): where
    the monitor loads only the LATEST whole generation, the watcher applies
    EVERY pending delta strictly in version order (deltas compose; skipping
    one would corrupt the cube state). A failed apply stops at that delta
    and retries it after backoff, preserving the order.

    ``prune_applied``: remove each delta directory once applied. Without
    it, the log directory grows one directory per delta forever and every
    poll's os.listdir scans the full history — enable when this watcher is
    the log's only consumer (the serving wiring); leave off for shared
    logs, where retention belongs to the training side.

    ``verify_checksums`` (default on): each delta's npz files are re-hashed
    against its CHECKSUMS manifest BEFORE apply. A corrupted batch raises
    :class:`DeltaIntegrityError` — the poll thread logs it, backs off and
    retries at the same version (the training side must re-emit), so a
    corrupt delta is skipped rather than half-applied, and later versions
    are never applied over it out of order."""

    def __init__(self, watch_dir: str, apply_fn: Callable[[DeltaBatch], int],
                 poll_s: float = 0.25, max_backoff_s: float = 10.0,
                 start_after_version: int = -1, prune_applied: bool = False,
                 verify_checksums: bool = True, **kw):
        super().__init__(poll_s=poll_s, max_backoff_s=max_backoff_s, **kw)
        self.watch_dir = watch_dir
        self.apply_fn = apply_fn
        self.applied_version = start_after_version
        self.prune_applied = prune_applied
        self.verify_checksums = verify_checksums
        self.integrity_failures = 0

    def check_once(self) -> bool:
        applied = False
        for ver, path in list_deltas(self.watch_dir, self.applied_version):
            if self.verify_checksums:
                try:
                    verify_delta(path)
                except DeltaIntegrityError as e:
                    self.integrity_failures += 1
                    log_event(log, "delta_checksum_failed",
                              level=logging.WARNING,
                              watcher=type(self).__name__, version=ver,
                              path=path, error=str(e))
                    raise
            self.apply_fn(read_delta(path))
            self.applied_version = ver
            log_event(log, "delta_applied", watcher=type(self).__name__,
                      version=ver)
            applied = True
            if self.prune_applied:
                shutil.rmtree(path, ignore_errors=True)
        return applied
