"""The delta log: versioned parameter-update batches between training and
serving (DESIGN.md §6.1).

A continuously-retrained sparse model touches a tiny slice of rows per
pass — shipping whole generations (serve/hotload.py) for that is the
full-snapshot anti-pattern. The delta log is the streaming alternative:

  * ``GroupDelta`` — per-feature-group arrays of ``(id, row)`` upserts plus
    optional deletes; ids are raw ids in the group's key space (the same
    ids ``ParameterCube.lookup`` takes — signatures are derived at apply
    time so host and cube agree). ``item_ids`` optionally carries the raw
    item ids a serving-side query cache keys scores by, when that space
    differs from the cube's (hashed) id space.
  * ``DeltaBatch`` — one atomic publish unit: a monotonically increasing
    ``version`` plus one GroupDelta per touched group. Within a batch,
    deletes apply after upserts.

On-disk layout (the training-side emitter writes, the serving-side watcher
tails): ``<dir>/delta_<version>/group_<g>.npz`` + an empty ``DONE`` marker
written LAST — the marker is the publish point, exactly like hot-load
generations, so a half-written delta is never consumed.
"""
from __future__ import annotations

import os
import shutil
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.serve.hotload import PollWatcher

_PREFIX = "delta_"


@dataclass
class GroupDelta:
    group: int
    ids: np.ndarray = field(default_factory=lambda: np.empty(0, np.int64))
    rows: np.ndarray = field(default_factory=lambda: np.empty((0, 0),
                                                              np.float32))
    delete_ids: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.int64))
    # raw item ids for targeted query-cache invalidation; None → the cube
    # ids double as the item keys (single-hash deployments)
    item_ids: Optional[np.ndarray] = None

    def touched_item_ids(self) -> np.ndarray:
        if self.item_ids is not None:
            return np.atleast_1d(np.asarray(self.item_ids))
        return np.concatenate([np.atleast_1d(np.asarray(self.ids)),
                               np.atleast_1d(np.asarray(self.delete_ids))])


@dataclass
class DeltaBatch:
    version: int
    groups: List[GroupDelta]

    @property
    def n_upserts(self) -> int:
        return sum(np.asarray(g.ids).size for g in self.groups)

    @property
    def n_deletes(self) -> int:
        return sum(np.asarray(g.delete_ids).size for g in self.groups)


# ----------------------------------------------------------------- log I/O

def delta_path(log_dir: str, version: int) -> str:
    return os.path.join(log_dir, f"{_PREFIX}{version:012d}")


def write_delta(log_dir: str, batch: DeltaBatch) -> str:
    """Training-side emit: per-group npz files first, DONE marker last (the
    atomic publish point). Returns the delta directory."""
    path = delta_path(log_dir, batch.version)
    os.makedirs(path, exist_ok=True)
    for g in batch.groups:
        kw = {"ids": np.atleast_1d(np.asarray(g.ids)),
              "rows": np.asarray(g.rows),
              "delete_ids": np.atleast_1d(np.asarray(g.delete_ids))}
        if g.item_ids is not None:
            kw["item_ids"] = np.atleast_1d(np.asarray(g.item_ids))
        np.savez(os.path.join(path, f"group_{g.group}.npz"), **kw)
    with open(os.path.join(path, "DONE"), "w"):
        pass
    return path


def read_delta(path: str) -> DeltaBatch:
    version = int(os.path.basename(path).split("_")[-1])
    groups = []
    for fn in sorted(os.listdir(path)):
        if not (fn.startswith("group_") and fn.endswith(".npz")):
            continue
        with np.load(os.path.join(path, fn)) as z:
            groups.append(GroupDelta(
                group=int(fn[len("group_"):-len(".npz")]),
                ids=z["ids"], rows=z["rows"], delete_ids=z["delete_ids"],
                item_ids=z["item_ids"] if "item_ids" in z else None))
    return DeltaBatch(version=version, groups=groups)


def list_deltas(log_dir: str, after_version: int = -1
                ) -> List[tuple[int, str]]:
    """Published (DONE-marked) deltas newer than ``after_version``, in
    version order — the watcher's tailing primitive."""
    if not os.path.isdir(log_dir):
        return []
    out = []
    for d in os.listdir(log_dir):
        if not d.startswith(_PREFIX):
            continue
        try:
            ver = int(d.split("_")[-1])
        except ValueError:
            continue
        full = os.path.join(log_dir, d)
        if ver > after_version and os.path.exists(os.path.join(full, "DONE")):
            out.append((ver, full))
    out.sort()
    return out


class DeltaEmitter:
    """Training-side convenience: stamps monotonically increasing versions
    onto batches and writes them to the log directory."""

    def __init__(self, log_dir: str, start_version: int = 0):
        self.log_dir = log_dir
        self.next_version = start_version
        os.makedirs(log_dir, exist_ok=True)

    def emit(self, groups: List[GroupDelta]) -> DeltaBatch:
        batch = DeltaBatch(version=self.next_version, groups=groups)
        write_delta(self.log_dir, batch)
        self.next_version += 1
        return batch


class DeltaWatcher(PollWatcher):
    """Serving-side tail of the delta log — the streaming generalization of
    ``ModelMonitor`` (which it shares the PollWatcher skeleton with): where
    the monitor loads only the LATEST whole generation, the watcher applies
    EVERY pending delta strictly in version order (deltas compose; skipping
    one would corrupt the cube state). A failed apply stops at that delta
    and retries it after backoff, preserving the order.

    ``prune_applied``: remove each delta directory once applied. Without
    it, the log directory grows one directory per delta forever and every
    poll's os.listdir scans the full history — enable when this watcher is
    the log's only consumer (the serving wiring); leave off for shared
    logs, where retention belongs to the training side."""

    def __init__(self, watch_dir: str, apply_fn: Callable[[DeltaBatch], int],
                 poll_s: float = 0.25, max_backoff_s: float = 10.0,
                 start_after_version: int = -1, prune_applied: bool = False):
        super().__init__(poll_s=poll_s, max_backoff_s=max_backoff_s)
        self.watch_dir = watch_dir
        self.apply_fn = apply_fn
        self.applied_version = start_after_version
        self.prune_applied = prune_applied

    def check_once(self) -> bool:
        applied = False
        for ver, path in list_deltas(self.watch_dir, self.applied_version):
            self.apply_fn(read_delta(path))
            self.applied_version = ver
            applied = True
            if self.prune_applied:
                shutil.rmtree(path, ignore_errors=True)
        return applied
