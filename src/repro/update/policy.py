"""Frequency-driven promote/demote policy (DESIGN.md §6.3).

Decides which cube-tail rows deserve HBM head slots. The signal is free:
the two-tier LFU cube cache (paper §5.2) already maintains per-key access
counts that persist across evictions — exactly the heavy-tailed popularity
estimate Fig. 5a says drifts slowly. The policy reads those counts,
computes the desired head membership, and emits a (promote, demote) plan;
``UpdateManager.rebalance`` executes it against the head + cube.

Hysteresis: a resident row keeps its slot unless the head is full AND a
strictly hotter candidate (by ``hysteresis``×) needs it — popularity drift
is slow, so ping-ponging rows across tiers would pay two migrations for
zero hit-rate gain.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List


@dataclass
class TierPlan:
    promote: List = field(default_factory=list)   # keys to move into HBM
    demote: List = field(default_factory=list)    # keys to drop back to tail

    @property
    def empty(self) -> bool:
        return not (self.promote or self.demote)


class PromoteDemotePolicy:
    def __init__(self, capacity: int, min_count: int = 2,
                 hysteresis: float = 2.0):
        assert capacity >= 0 and hysteresis >= 1.0
        self.capacity = capacity
        self.min_count = min_count
        self.hysteresis = hysteresis

    def plan(self, counts: Dict, resident: Iterable) -> TierPlan:
        """counts: key → LFU access count (e.g. merged cube-cache tiers);
        resident: keys currently holding head slots. Deterministic: ties
        break on the key itself."""
        resident = set(resident)
        hot = sorted(((c, k) for k, c in counts.items()
                      if c >= self.min_count),
                     key=lambda ck: (-ck[0], repr(ck[1])))
        desired = [k for _, k in hot[:self.capacity]]
        desired_set = set(desired)
        candidates = [k for k in desired if k not in resident]
        free = max(0, self.capacity - len(resident))
        promote = candidates[:free]          # free slots fill unconditionally
        overflow = candidates[free:]         # each needs an eviction
        cold = sorted((k for k in resident if k not in desired_set),
                      key=lambda k: (counts.get(k, 0), repr(k)))
        demote: List = []
        for newcomer, victim in zip(overflow, cold):
            # hysteresis gate: displace only for a decisively hotter row
            if counts.get(newcomer, 0) >= \
                    self.hysteresis * max(1, counts.get(victim, 0)):
                demote.append(victim)
                promote.append(newcomer)
        return TierPlan(promote=promote, demote=demote)


def slice_group_counts(merged: Dict, group: int) -> Dict:
    """One feature group's slice of merged LFU counts, keyed by RAW id.
    Follows the ``_default_cache_key_fn`` convention the serving stack
    uses: bare keys belong to group 0, ``(group, id)`` tuples to their
    group — so each group's promote/demote policy ranks only its own rows
    instead of competing against every other group's popularity."""
    out: Dict = {}
    for k, c in merged.items():
        if group == 0:
            if isinstance(k, tuple):
                continue
            out[k] = c
        elif isinstance(k, tuple) and len(k) == 2 and k[0] == group:
            out[k[1]] = c
    return out


def group_lfu_counts(cube_cache, group: int) -> Dict:
    return slice_group_counts(merged_lfu_counts(cube_cache), group)


def merged_lfu_counts(cube_cache) -> Dict:
    """Fold both cache tiers' persistent LFU counts into one popularity
    estimate. Elementwise MAX, not sum: `_LFU.get` increments a tier's
    counter on every probe — hit or miss — so a non-mem-resident key
    accumulates counts in BOTH tiers per access (mem miss + disk probe)
    while a mem-hot key touches only one; summing would double-weight
    exactly the keys the policy should rank lower."""
    counts: Dict = dict(cube_cache.disk.counts)
    # list(): serving threads insert into counts concurrently with this
    # (update-thread) pass — a bare Python-level .items() loop would raise
    # "dictionary changed size during iteration"
    for k, c in list(cube_cache.mem.counts.items()):
        if c > counts.get(k, 0):
            counts[k] = c
    return counts
