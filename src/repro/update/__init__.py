"""Streaming parameter-update subsystem (DESIGN.md §6): versioned delta
ingestion for uninterrupted serving — delta log + watcher, MVCC cube
application, HBM-head in-place migration, cache coherence — plus the
durability layer (DESIGN.md §9): periodic cube snapshots and the
snapshot+replay restart protocol."""
from repro.update.delta import (CheckpointDiffEmitter, DeltaBatch,
                                DeltaEmitter, DeltaIntegrityError,
                                DeltaWatcher, GroupDelta, list_deltas,
                                read_delta, verify_delta, write_delta)
from repro.update.hbm_head import HBMHead
from repro.update.manager import UpdateManager, UpdateStats
from repro.update.policy import (PromoteDemotePolicy, TierPlan,
                                 group_lfu_counts, merged_lfu_counts)
from repro.update.snapshot import (CubeSnapshotter, SnapshotIntegrityError,
                                   latest_valid_snapshot, list_snapshots,
                                   load_aux_state, load_cube_snapshot,
                                   prune_delta_log, prune_snapshots,
                                   verify_snapshot, write_aux_state,
                                   write_cube_snapshot)

__all__ = [
    "CheckpointDiffEmitter", "CubeSnapshotter",
    "DeltaBatch", "DeltaEmitter", "DeltaIntegrityError", "DeltaWatcher",
    "GroupDelta", "HBMHead", "PromoteDemotePolicy",
    "SnapshotIntegrityError", "TierPlan",
    "UpdateManager", "UpdateStats", "group_lfu_counts",
    "latest_valid_snapshot", "list_deltas", "list_snapshots",
    "load_aux_state", "load_cube_snapshot", "merged_lfu_counts",
    "prune_delta_log", "prune_snapshots", "read_delta", "verify_delta",
    "verify_snapshot", "write_aux_state", "write_cube_snapshot",
    "write_delta",
]
