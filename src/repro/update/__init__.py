"""Streaming parameter-update subsystem (DESIGN.md §6): versioned delta
ingestion for uninterrupted serving — delta log + watcher, MVCC cube
application, HBM-head in-place migration, and cache coherence."""
from repro.update.delta import (DeltaBatch, DeltaEmitter,
                                DeltaIntegrityError, DeltaWatcher,
                                GroupDelta, list_deltas, read_delta,
                                verify_delta, write_delta)
from repro.update.hbm_head import HBMHead
from repro.update.manager import UpdateManager, UpdateStats
from repro.update.policy import (PromoteDemotePolicy, TierPlan,
                                 group_lfu_counts, merged_lfu_counts)

__all__ = [
    "DeltaBatch", "DeltaEmitter", "DeltaIntegrityError", "DeltaWatcher",
    "GroupDelta", "HBMHead", "PromoteDemotePolicy", "TierPlan",
    "UpdateManager", "UpdateStats", "group_lfu_counts", "list_deltas",
    "merged_lfu_counts", "read_delta", "verify_delta", "write_delta",
]
