"""Mesh/runtime context shared by model code.

Model code never owns a mesh: the launcher (or a test) installs one with
``use_mesh``; layers consult ``current_mesh()`` at trace time to decide
whether to emit shard_map collectives / sharding constraints. With no mesh
installed everything degrades to single-device dense JAX (used by smoke
tests and CPU examples).
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH_STACK: list[Mesh] = []


def current_mesh() -> Optional[Mesh]:
    return _MESH_STACK[-1] if _MESH_STACK else None


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    _MESH_STACK.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _MESH_STACK.pop()


def axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None or name not in mesh.shape:
        return 1
    return mesh.shape[name]


def has_axis(name: str) -> bool:
    return axis_size(name) > 1


def batch_axes() -> tuple[str, ...]:
    """Mesh axes the global batch is sharded over (pod composes with data)."""
    axes = tuple(a for a in ("pod", "data") if has_axis(a))
    return axes or ("data",)


def data_axis_size() -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    n = 1
    for a in ("pod", "data"):
        n *= mesh.shape.get(a, 1)
    return n


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """jax.shard_map moved out of jax.experimental after 0.4.x (and renamed
    check_rep → check_vma); dispatch to whichever this jax provides."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy_sm
    return legacy_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma)


def shard(x, *spec):
    """with_sharding_constraint that no-ops without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def named_sharding(*spec) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, P(*spec))


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def divides(n: int, name: str) -> bool:
    return n % axis_size(name) == 0
