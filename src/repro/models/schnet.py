"""SchNet [arXiv:1706.08566] — continuous-filter convolution GNN.

Message passing is built on ``jnp.take`` (edge gather) + ``jax.ops.segment_sum``
(node scatter) — JAX's native sparse substrate (no SpMM needed for the
triplet-free SchNet regime). Two input modes:

  * molecular: atom types (embedding) + 3-D positions → pairwise distances
  * generic feature graphs (cora / ogb-products shapes): node features →
    linear projection; per-edge scalar "distances" supplied as input

Edges are an explicit (E, 2) int32 [src, dst] list; padding edges point at a
sentinel node (n_nodes) and are masked. Edge arrays are sharded over the
flattened ("data","model") axes; node arrays stay replicated (they fit) and
XLA inserts the cross-shard reduction for the scatter.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.configs.base import GNNConfig
from repro.models.layers import dense_apply, dense_init


def shifted_softplus(x):
    return jax.nn.softplus(x) - np.log(2.0)


def gaussian_rbf(dist: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """(E,) → (E, n_rbf): Gaussian radial basis on [0, cutoff]."""
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 1.0 / ((cutoff / n_rbf) ** 2)
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def cosine_cutoff(dist: jax.Array, cutoff: float) -> jax.Array:
    c = 0.5 * (jnp.cos(np.pi * dist / cutoff) + 1.0)
    return jnp.where(dist < cutoff, c, 0.0)


def init(key, cfg: GNNConfig, d_feat_in: Optional[int] = None) -> dict:
    h, r = cfg.d_hidden, cfg.n_rbf
    ks = jax.random.split(key, 4 + cfg.n_interactions)
    params: dict = {}
    if d_feat_in is None:
        params["embed"] = (jax.random.normal(ks[0], (cfg.n_atom_types, h),
                                             jnp.float32) * 0.1)
    else:
        params["in_proj"] = dense_init(ks[0], d_feat_in, h, jnp.float32)

    def interaction_init(k):
        k1, k2, k3, k4, k5 = jax.random.split(k, 5)
        return {
            "filt1": dense_init(k1, r, h, jnp.float32),
            "filt2": dense_init(k2, h, h, jnp.float32),
            "w_in": dense_init(k3, h, h, jnp.float32, bias=False),
            "w_out1": dense_init(k4, h, h, jnp.float32),
            "w_out2": dense_init(k5, h, h, jnp.float32),
        }

    ikeys = jax.random.split(ks[1], cfg.n_interactions)
    params["interactions"] = jax.vmap(interaction_init)(ikeys)
    params["head1"] = dense_init(ks[2], h, h // 2, jnp.float32)
    params["head2"] = dense_init(ks[3], h // 2, 1, jnp.float32)
    return params


def _interaction(p, x, edges, edge_dist, n_nodes, cfg: GNNConfig):
    """One cfconv + atom-wise update. x (N+1, h) with sentinel row N."""
    src, dst = edges[:, 0], edges[:, 1]
    rbf = gaussian_rbf(edge_dist, cfg.n_rbf, cfg.cutoff)            # (E, r)
    w = shifted_softplus(dense_apply(p["filt1"], rbf))
    w = dense_apply(p["filt2"], w)                                   # (E, h)
    w = w * cosine_cutoff(edge_dist, cfg.cutoff)[:, None]
    w = runtime.shard(w, ("data", "model"), None)
    xin = dense_apply(p["w_in"], x)
    msg = jnp.take(xin, src, axis=0, mode="clip") * w                # (E, h)
    agg = jax.ops.segment_sum(msg, dst, num_segments=n_nodes + 1)
    v = dense_apply(p["w_out1"], agg)
    v = shifted_softplus(v)
    v = dense_apply(p["w_out2"], v)
    return x + v


def forward(params, inputs: dict, cfg: GNNConfig, n_graphs: int = 1) -> jax.Array:
    """Per-graph energies.

    inputs: either {atom_z (N,), positions (N,3)} or {node_feat (N, d)};
    always {edges (E,2), edge_dist (E,) or None, graph_ids (N,), n_graphs}.
    Sentinel node index N marks padding (edges to N are dropped by
    segment_sum bounds; sentinel row is stripped before readout).
    """
    edges = inputs["edges"]
    if "node_feat" in inputs:
        x = dense_apply(params["in_proj"], inputs["node_feat"])
        n_nodes = inputs["node_feat"].shape[0]
        dist = inputs["edge_dist"]
    else:
        z = inputs["atom_z"]
        x = jnp.take(params["embed"], z, axis=0, mode="clip")
        n_nodes = z.shape[0]
        pos = inputs["positions"]
        d = jnp.take(pos, edges[:, 0], 0, mode="clip") - jnp.take(pos, edges[:, 1], 0, mode="clip")
        dist = jnp.sqrt(jnp.sum(d * d, -1) + 1e-12)
    edges = runtime.shard(edges, ("data", "model"), None)
    dist = runtime.shard(dist, ("data", "model"))
    x = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)])    # sentinel

    n_int = jax.tree.leaves(params["interactions"])[0].shape[0]
    for i in range(n_int):
        p_i = jax.tree.map(lambda a: a[i], params["interactions"])
        x = _interaction(p_i, x, edges, dist, n_nodes, cfg)

    x = x[:n_nodes]
    h = shifted_softplus(dense_apply(params["head1"], x))
    atom_e = dense_apply(params["head2"], h)[:, 0]                   # (N,)
    graph_ids = inputs.get("graph_ids")
    if graph_ids is None:
        return jnp.sum(atom_e)[None]
    return jax.ops.segment_sum(atom_e, graph_ids, num_segments=n_graphs)


def loss_fn(params, inputs: dict, targets: jax.Array, cfg: GNNConfig,
            n_graphs: int = 1) -> jax.Array:
    pred = forward(params, inputs, cfg, n_graphs=n_graphs)
    return jnp.mean((pred - targets) ** 2)
