"""Shared neural building blocks (norms, RoPE, FFN) — pure functional JAX."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_apply(x, p, kind: str, eps: float):
    if kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"], eps)
    return rmsnorm(x, p["scale"], eps)


def norm_init(d: int, kind: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def activation(x, kind: str):
    return jax.nn.gelu(x) if kind == "gelu" else jax.nn.silu(x)


# ---------------------------------------------------------------- RoPE

def rope_freqs(d_rot: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, D) with positions (..., S) — rotate the full D."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                   # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                            # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLP

def mlp_init(key, d_in: int, d_ff: int, d_out: int, glu: bool, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_in)
    s_ff = 1.0 / np.sqrt(d_ff)
    p = {"w1": jax.random.normal(k1, (d_in, d_ff), jnp.float32) * s_in,
         "w2": jax.random.normal(k2, (d_ff, d_out), jnp.float32) * s_ff}
    if glu:
        p["w3"] = jax.random.normal(k3, (d_in, d_ff), jnp.float32) * s_in
    return jax.tree.map(lambda a: a.astype(dtype), p)


def mlp_apply(p: dict, x: jax.Array, act: str, glu: bool) -> jax.Array:
    h = x @ p["w1"]
    h = activation(h, act)
    if glu:
        h = h * (x @ p["w3"])
    return h @ p["w2"]


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = True, scale=None) -> dict:
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def mlp_tower_init(key, d_in: int, widths, dtype, out_bias=True) -> list:
    keys = jax.random.split(key, len(widths))
    layers, d = [], d_in
    for k, w in zip(keys, widths):
        layers.append(dense_init(k, d, w, dtype, bias=out_bias))
        d = w
    return layers


def mlp_tower_apply(layers: list, x: jax.Array, act: str = "silu",
                    final_act: bool = False) -> jax.Array:
    for i, p in enumerate(layers):
        x = dense_apply(p, x)
        if i < len(layers) - 1 or final_act:
            x = activation(x, act)
    return x
