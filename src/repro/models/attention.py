"""Attention: GQA + MLA, with memory-efficient (online-softmax) prefill/train
and KV-cache decode. Pure JAX — the Pallas ``flash_decode`` kernel mirrors the
decode path for the TPU hot-spot; this module is also its oracle.

Layouts:
  q: (B, Sq, Hkv, G, D)   grouped — G = n_heads // n_kv (no KV repeat!)
  k: (B, Sk, Hkv, D)
  v: (B, Sk, Hkv, Dv)

Train/prefill never materialize (Sq, Sk): lax.scan over KV chunks with a
running (m, l, acc) — FlashAttention recurrence in XLA-native form, which is
the TPU-correct adaptation (VMEM-sized chunks, MXU-aligned matmuls) of the
GPU kernel the literature assumes.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _chunk_scores(q, k, scale):
    # q (B,Sq,H,G,D) k (B,C,H,D) -> (B,H,G,Sq,C)
    return jnp.einsum("bqhgd,bchd->bhgqc", q, k,
                      preferred_element_type=jnp.float32) * scale


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk: int, q_offset=0,
                      scale: Optional[float] = None,
                      q_blocks: int = 4) -> jax.Array:
    """Online-softmax attention, O(Sq/q_blocks * chunk) live memory.

    q (B,Sq,H,G,D); k,v (B,Sk,H,D/Dv). q_offset: position of q[0] within the
    kv axis (chunked prefill). Returns (B,Sq,H,G,Dv).

    Causal inputs are processed in ``q_blocks`` row blocks, each scanning
    ONLY the KV chunks at or below its diagonal — skipping the fully-masked
    upper triangle halves both the FLOPs and the score traffic vs the naive
    full scan (flash-attention's causal-block skipping, in XLA form).
    """
    B, Sq, H, G, D = q.shape
    Sk = k.shape[1]
    if (causal and q_blocks > 1 and Sq == Sk and q_offset == 0
            and Sq % q_blocks == 0 and Sq // q_blocks >= chunk):
        qb = Sq // q_blocks
        outs = []
        for i in range(q_blocks):
            hi = (i + 1) * qb
            outs.append(_chunked_attention(
                q[:, i * qb: hi], k[:, :hi], v[:, :hi],
                causal=True, chunk=chunk, q_offset=i * qb, scale=scale))
        return jnp.concatenate(outs, axis=1)
    return _chunked_attention(q, k, v, causal=causal, chunk=chunk,
                              q_offset=q_offset, scale=scale)


def _chunked_attention(q, k, v, *, causal, chunk, q_offset=0, scale=None):
    B, Sq, H, G, D = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, H, Dv).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(Sq)

    @jax.checkpoint
    def body(carry, xs):
        # checkpointed: backward recomputes the (Sq, C) score block instead
        # of saving one per chunk (flash-attention backward discipline —
        # without this the scan stacks n_chunks × (B,H,G,Sq,C) f32).
        m, l, acc = carry
        idx, k_i, v_i = xs
        s = _chunk_scores(q, k_i, scale)                        # (B,H,G,Sq,C) f32
        k_pos = idx * chunk + jnp.arange(chunk)
        valid = k_pos < Sk
        if causal:
            valid = valid[None, :] & (q_pos[:, None] >= k_pos[None, :])
            s = jnp.where(valid[None, None, None], s, NEG_INF)
        else:
            s = jnp.where(valid[None, None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        pv = jnp.einsum("bhgqc,bchd->bhgqd", p.astype(v_i.dtype), v_i,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, G, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)       # (B,Sq,H,G,Dv)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-token decode. q (B,1,H,G,D); caches (B,Smax,H,D/Dv);
    cache_len: number of valid cache positions (static or traced scalar).
    O(Smax) per step — sub-quadratic by construction; with the cache sequence
    dim sharded, XLA turns the reductions into psums (distributed softmax)."""
    B, _, H, G, D = q.shape
    Smax, Dv = k_cache.shape[1], v_cache.shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    s = jnp.einsum("bqhgd,bshd->bhgqs", q, k_cache,
                   preferred_element_type=jnp.float32) * scale   # (B,H,G,1,S)
    mask = jnp.arange(Smax) < cache_len
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(-1, keepdims=True)
    out = jnp.einsum("bhgqs,bshd->bhgqd", (p / l).astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)          # (B,1,H,G,Dv)


# ---------------------------------------------------------------- GQA block

def gqa_init(key, cfg, dtype) -> dict:
    from repro.models.layers import norm_init
    d, Hq, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    p = {
        "wq": (jax.random.normal(ks[0], (d, Hq * D), jnp.float32) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, Hkv * D), jnp.float32) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, Hkv * D), jnp.float32) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (Hq * D, d), jnp.float32)
               / np.sqrt(Hq * D)).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(D, "rmsnorm", dtype)
        p["k_norm"] = norm_init(D, "rmsnorm", dtype)
    return p


def _gqa_qkv(p, x, positions, cfg):
    from repro.models.layers import rmsnorm
    B, S, _ = x.shape
    Hq, Hkv, D = cfg.n_heads, cfg.n_kv, cfg.d_head
    G = Hq // Hkv
    q = (x @ p["wq"]).reshape(B, S, Hkv, G, D)
    k = (x @ p["wk"]).reshape(B, S, Hkv, D)
    v = (x @ p["wv"]).reshape(B, S, Hkv, D)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"]["scale"], cfg.norm_eps)
    # RoPE on the last dim; q grouped layout rotates per (Hkv,G) head.
    q = apply_rope_grouped(q, positions, cfg.rope_theta)
    k = apply_rope_heads(k, positions, cfg.rope_theta)
    return q, k, v


def apply_rope_heads(x, positions, theta):
    from repro.models.layers import apply_rope
    return apply_rope(x, positions, theta)


def apply_rope_grouped(q, positions, theta):
    from repro.models.layers import apply_rope
    B, S, H, G, D = q.shape
    q = apply_rope(q.reshape(B, S, H * G, D), positions, theta)
    return q.reshape(B, S, H, G, D)


def gqa_forward(p, x, positions, cfg, *, cache=None, cache_len=None):
    """cache=None: full/train self-attention (causal). With cache: decode —
    x is (B,1,d); returns (out, (k_new, v_new)) for the cache update."""
    B, S, _ = x.shape
    q, k, v = _gqa_qkv(p, x, positions, cfg)
    if cache is None:
        o = chunked_attention(q, k, v, causal=True, chunk=min(cfg.attn_chunk, S))
        new_kv = (k, v)
    else:
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), cache_len, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), cache_len, 1)
        o = decode_attention(q, k_cache, v_cache, cache_len + S)
        new_kv = (k_cache, v_cache)
    o = o.reshape(B, S, cfg.n_heads * cfg.d_head)
    return o @ p["wo"], new_kv


# ---------------------------------------------------------------- MLA block

def mla_init(key, cfg, dtype) -> dict:
    from repro.models.layers import norm_init
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dq = m.d_nope + m.d_rope
    ks = jax.random.split(key, 8)
    s = 1.0 / np.sqrt(d)
    p = {}
    if m.q_lora:
        p["wq_a"] = (jax.random.normal(ks[0], (d, m.q_lora), jnp.float32) * s).astype(dtype)
        p["q_norm"] = norm_init(m.q_lora, "rmsnorm", dtype)
        p["wq_b"] = (jax.random.normal(ks[1], (m.q_lora, H * dq), jnp.float32)
                     / np.sqrt(m.q_lora)).astype(dtype)
    else:
        p["wq"] = (jax.random.normal(ks[0], (d, H * dq), jnp.float32) * s).astype(dtype)
    p["wkv_a"] = (jax.random.normal(ks[2], (d, m.kv_lora + m.d_rope), jnp.float32) * s).astype(dtype)
    p["kv_norm"] = norm_init(m.kv_lora, "rmsnorm", dtype)
    p["wk_b"] = (jax.random.normal(ks[3], (m.kv_lora, H * m.d_nope), jnp.float32)
                 / np.sqrt(m.kv_lora)).astype(dtype)
    p["wv_b"] = (jax.random.normal(ks[4], (m.kv_lora, H * m.v_dim), jnp.float32)
                 / np.sqrt(m.kv_lora)).astype(dtype)
    p["wo"] = (jax.random.normal(ks[5], (H * m.v_dim, d), jnp.float32)
               / np.sqrt(H * m.v_dim)).astype(dtype)
    return p


def _mla_q(p, x, positions, cfg):
    from repro.models.layers import rmsnorm, apply_rope
    m = cfg.mla
    B, S, _ = x.shape
    H, dq = cfg.n_heads, m.d_nope + m.d_rope
    if m.q_lora:
        ql = rmsnorm(x @ p["wq_a"], p["q_norm"]["scale"], cfg.norm_eps)
        q = (ql @ p["wq_b"]).reshape(B, S, H, dq)
    else:
        q = (x @ p["wq"]).reshape(B, S, H, dq)
    q_nope, q_rope = q[..., : m.d_nope], q[..., m.d_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(p, x, positions, cfg, *, cache=None, cache_len=None):
    """MLA attention. Cache holds the LATENT (c_kv, k_rope): kv_lora + d_rope
    per token — the paper-family (DeepSeek-V2) KV compression. Decode uses the
    absorbed form: w_k_b folds into q, w_v_b applies after the latent-space
    attention, so per-step cost is O(S * kv_lora), never re-expanding S heads.
    """
    from repro.models.layers import rmsnorm, apply_rope
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    scale = 1.0 / np.sqrt(m.d_nope + m.d_rope)

    kv = x @ p["wkv_a"]                                     # (B,S,kv_lora+d_rope)
    c_kv = rmsnorm(kv[..., : m.kv_lora], p["kv_norm"]["scale"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, m.kv_lora:], positions, cfg.rope_theta)[:, :, 0]

    q_nope, q_rope = _mla_q(p, x, positions, cfg)           # (B,S,H,d_nope/d_rope)

    if cache is None:
        # Train/prefill: expand per-head k,v from the latent (flops-optimal at
        # large S because scores are computed once per (q,k) pair anyway).
        k_nope = (c_kv @ p["wk_b"]).reshape(B, S, H, m.d_nope)
        v = (c_kv @ p["wv_b"]).reshape(B, S, H, m.v_dim)
        q = jnp.concatenate([q_nope, q_rope], -1)[:, :, :, None]  # (B,S,H,1,dq)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                                      (B, S, H, m.d_rope))], -1)
        o = chunked_attention(q, k, v, causal=True,
                              chunk=min(cfg.attn_chunk, S), scale=scale)
        o = o[:, :, :, 0]                                   # (B,S,H,v_dim)
        new_cache = (c_kv, k_rope)
    else:
        c_cache, r_cache = cache                            # (B,Smax,kv_lora),(B,Smax,d_rope)
        c_cache = jax.lax.dynamic_update_slice_in_dim(c_cache, c_kv.astype(c_cache.dtype), cache_len, 1)
        r_cache = jax.lax.dynamic_update_slice_in_dim(r_cache, k_rope.astype(r_cache.dtype), cache_len, 1)
        Smax = c_cache.shape[1]
        # Absorbed decode: q_c = q_nope @ wk_b^T per head → latent space.
        wkb = p["wk_b"].reshape(m.kv_lora, H, m.d_nope)
        q_c = jnp.einsum("bshd,lhd->bshl", q_nope, wkb)     # (B,1,H,kv_lora)
        s_l = jnp.einsum("bshl,bSl->bhsS", q_c, c_cache, preferred_element_type=jnp.float32)
        s_r = jnp.einsum("bshd,bSd->bhsS", q_rope, r_cache, preferred_element_type=jnp.float32)
        s = (s_l + s_r) * scale                             # (B,H,1,Smax)
        mask = jnp.arange(Smax) < (cache_len + S)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhsS,bSl->bshl", pr.astype(c_cache.dtype), c_cache)
        wvb = p["wv_b"].reshape(m.kv_lora, H, m.v_dim)
        o = jnp.einsum("bshl,lhv->bshv", o_lat, wvb)        # (B,1,H,v_dim)
        new_cache = (c_cache, r_cache)
    o = o.reshape(B, S, H * m.v_dim).astype(x.dtype)
    return o @ p["wo"], new_cache


def attn_init(key, cfg, dtype):
    return mla_init(key, cfg, dtype) if cfg.mla else gqa_init(key, cfg, dtype)


def attn_forward(p, x, positions, cfg, *, cache=None, cache_len=None):
    if cfg.mla:
        return mla_forward(p, x, positions, cfg, cache=cache, cache_len=cache_len)
    return gqa_forward(p, x, positions, cfg, cache=cache, cache_len=cache_len)
