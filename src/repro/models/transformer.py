"""LM transformer assembly: dense (qwen3/smollm/starcoder2) and MoE+MLA
(deepseek v2-lite / v3), with scan-over-layers, remat, chunked vocab loss,
KV-cache prefill/decode, and optional MTP head (deepseek-v3).

Params layout (stacked over layers so lax.scan keeps HLO size O(1) in depth):
  embed.table (V, d)
  dense_layers.* (n_dense, ...)     -- only for MoE configs' leading dense FFN layers
  layers.* (n_scan, ...)            -- the homogeneous scanned stack
  final_norm, lm_head.w (d, V)      -- lm_head absent when tie_embeddings
  mtp.{proj, norm_h, norm_e, block} -- deepseek-v3 multi-token prediction
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.configs.base import LMConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models.layers import (mlp_apply, mlp_init, norm_apply, norm_init)
from repro.sparse.sharded import sharded_lookup


def _dtype(cfg: LMConfig):
    return jnp.dtype(cfg.param_dtype)


def _is_moe_layer_cfg(cfg: LMConfig) -> bool:
    return cfg.moe is not None


def _layer_init(key, cfg: LMConfig, moe_layer: bool) -> dict:
    dt = _dtype(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": norm_init(cfg.d_model, cfg.norm, dt),
         "ln2": norm_init(cfg.d_model, cfg.norm, dt),
         "attn": attn.attn_init(k1, cfg, dt)}
    if moe_layer:
        p["moe"] = moe_lib.moe_expert_init(k2, cfg.d_model, cfg.moe, dt)
        if cfg.moe.n_shared:
            p["shared"] = mlp_init(k3, cfg.d_model,
                                   cfg.moe.n_shared * cfg.moe.d_ff_expert,
                                   cfg.d_model, cfg.glu, dt)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None:
            d_ff = cfg.moe.dense_d_ff or cfg.d_ff
        p["mlp"] = mlp_init(k2, cfg.d_model, d_ff, cfg.d_model, cfg.glu, dt)
    return p


def init(key, cfg: LMConfig) -> dict:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    n_dense = cfg.moe.n_dense_layers if cfg.moe else 0
    n_scan = cfg.n_layers - n_dense
    params: dict = {
        "embed": {"table": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                              jnp.float32) * 0.02).astype(dt)},
        "final_norm": norm_init(cfg.d_model, cfg.norm, dt),
    }
    if n_dense:
        dkeys = jax.random.split(ks[1], n_dense)
        params["dense_layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, moe_layer=False))(dkeys)
    lkeys = jax.random.split(ks[2], n_scan)
    params["layers"] = jax.vmap(
        lambda k: _layer_init(k, cfg, moe_layer=cfg.moe is not None))(lkeys)
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": (jax.random.normal(ks[3], (cfg.d_model, cfg.vocab),
                                                     jnp.float32)
                                   / np.sqrt(cfg.d_model)).astype(dt)}
    if cfg.mtp:
        params["mtp"] = {
            "proj": (jax.random.normal(ks[4], (2 * cfg.d_model, cfg.d_model),
                                       jnp.float32) / np.sqrt(2 * cfg.d_model)).astype(dt),
            "norm_h": norm_init(cfg.d_model, cfg.norm, dt),
            "norm_e": norm_init(cfg.d_model, cfg.norm, dt),
            "block": _layer_init(ks[5], cfg, moe_layer=cfg.moe is not None),
        }
    return params


# ----------------------------------------------------------------- blocks

def _block(p, x, positions, cfg: LMConfig, moe_layer: bool):
    """Pre-norm transformer block. Returns (x, aux_loss)."""
    if cfg.shard_carry:
        # pin BOTH ends of the scan carry so the remat-saved layer-input
        # stack stays model-sharded (d/16 per device)
        x = runtime.shard(x, runtime.batch_axes(), None, "model")
    else:
        x = runtime.shard(x, runtime.batch_axes(), None, None)
    h, _ = attn.attn_forward(p["attn"], norm_apply(x, p["ln1"], cfg.norm, cfg.norm_eps),
                             positions, cfg)
    x = x + h
    ff_in = norm_apply(x, p["ln2"], cfg.norm, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if moe_layer:
        ff, aux = moe_lib.moe_apply(p["moe"], ff_in, cfg.moe, cfg.act)
        if "shared" in p:
            ff = ff + mlp_apply(p["shared"], ff_in, cfg.act, cfg.glu)
    else:
        ff = mlp_apply(p["mlp"], ff_in, cfg.act, cfg.glu)
    out = x + ff
    if cfg.shard_carry:
        # shard the residual stream (and thus the remat-saved layer inputs)
        # over ``model`` — Megatron-SP-style; layer entry re-gathers
        out = runtime.shard(out, runtime.batch_axes(), None, "model")
    return out, aux


def _block_decode(p, x, positions, cfg: LMConfig, moe_layer: bool, cache, cache_len):
    h, new_cache = attn.attn_forward(
        p["attn"], norm_apply(x, p["ln1"], cfg.norm, cfg.norm_eps),
        positions, cfg, cache=cache, cache_len=cache_len)
    x = x + h
    ff_in = norm_apply(x, p["ln2"], cfg.norm, cfg.norm_eps)
    if moe_layer:
        ff, _ = moe_lib.moe_apply(p["moe"], ff_in, cfg.moe, cfg.act)
        if "shared" in p:
            ff = ff + mlp_apply(p["shared"], ff_in, cfg.act, cfg.glu)
    else:
        ff = mlp_apply(p["mlp"], ff_in, cfg.act, cfg.glu)
    return x + ff, new_cache


def hidden_states(params, tokens, cfg: LMConfig):
    """Embed + all blocks + final norm. tokens (B,S) → (B,S,d), aux."""
    B, S = tokens.shape
    x = sharded_lookup(params["embed"]["table"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux_total = jnp.zeros((), jnp.float32)

    if "dense_layers" in params:
        n_dense = jax.tree.leaves(params["dense_layers"])[0].shape[0]
        for i in range(n_dense):
            p_i = jax.tree.map(lambda a: a[i], params["dense_layers"])
            x, _ = _block(p_i, x, positions, cfg, moe_layer=False)

    moe_layer = cfg.moe is not None

    def body(p, x):
        return _block(p, x, positions, cfg, moe_layer)

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    def scan_fn(x, p):
        new_x, aux = body(p, x)
        return new_x, aux

    x, auxes = jax.lax.scan(scan_fn, x, params["layers"])
    aux_total = aux_total + auxes.sum()
    x = norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    return x, aux_total


def _head_w(params, cfg: LMConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


def chunked_xent(x, head_w, labels, mask, chunk: int = 512):
    """Cross-entropy without materializing (B,S,V): scan over S chunks.
    head_w may be vocab-sharded on ``model`` — GSPMD turns the logsumexp
    into a psum over the vocab shards."""
    B, S, d = x.shape
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xc = x.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    V = head_w.shape[-1]

    def body(carry, xs):
        tot, cnt = carry
        xi, li, mi = xs
        logits = (xi @ head_w).astype(jnp.float32)           # (B,c,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via masked sum — take_along_axis over a vocab-sharded
        # dim would force an all-gather of the full logits chunk; this form
        # reduces locally and psums only (B,c).
        onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
                  == li[..., None])
        gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        nll = (lse - gold) * mi
        return (tot + nll.sum(), cnt + mi.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, tokens, cfg: LMConfig, aux_weight: float = 1e-3):
    """Next-token loss (+MTP loss for deepseek-v3). tokens (B,S)."""
    x, aux = hidden_states(params, tokens, cfg)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1] * 0], axis=1)
    mask = jnp.concatenate([jnp.ones_like(tokens[:, 1:], jnp.float32),
                            jnp.zeros_like(tokens[:, :1], jnp.float32)], axis=1)
    head_w = _head_w(params, cfg)
    loss = chunked_xent(x, head_w, labels, mask)
    if cfg.mtp and "mtp" in params:
        # MTP depth 1: combine h_t with embedding of token t+1, one extra
        # block, predict token t+2 (deepseek-v3 §2.2).
        mp = params["mtp"]
        emb_next = sharded_lookup(params["embed"]["table"],
                                  jnp.roll(tokens, -1, axis=1))
        h = jnp.concatenate([
            norm_apply(x, mp["norm_h"], cfg.norm, cfg.norm_eps),
            norm_apply(emb_next, mp["norm_e"], cfg.norm, cfg.norm_eps)], -1)
        h = h @ mp["proj"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h, _ = _block(mp["block"], h, positions, cfg, moe_layer=cfg.moe is not None)
        labels2 = jnp.roll(tokens, -2, axis=1)
        mask2 = jnp.concatenate([jnp.ones_like(tokens[:, 2:], jnp.float32),
                                 jnp.zeros_like(tokens[:, :2], jnp.float32)], 1)
        loss = loss + 0.3 * chunked_xent(h, head_w, labels2, mask2)
    return loss + aux_weight * aux


# ----------------------------------------------------------------- serving

class KVCache(NamedTuple):
    """Per-layer stacks. GQA: a=(L,B,Smax,Hkv,D) k, b=v. MLA: a=(L,B,Smax,kv_lora)
    latent, b=(L,B,Smax,d_rope) rope keys. length: valid prefix."""
    a: jax.Array
    b: jax.Array
    length: jax.Array

    @staticmethod
    def shapes(cfg: LMConfig, batch: int, smax: int):
        dt = jnp.dtype(cfg.param_dtype)
        n_scan = cfg.n_layers - (cfg.moe.n_dense_layers if cfg.moe else 0)
        L = cfg.n_layers
        if cfg.mla:
            a = jax.ShapeDtypeStruct((L, batch, smax, cfg.mla.kv_lora), dt)
            b = jax.ShapeDtypeStruct((L, batch, smax, cfg.mla.d_rope), dt)
        else:
            a = jax.ShapeDtypeStruct((L, batch, smax, cfg.n_kv, cfg.d_head), dt)
            b = jax.ShapeDtypeStruct((L, batch, smax, cfg.n_kv, cfg.d_head), dt)
        return KVCache(a=a, b=b, length=jax.ShapeDtypeStruct((), jnp.int32))


def _split_cache(cache: KVCache, cfg: LMConfig):
    n_dense = cfg.moe.n_dense_layers if cfg.moe else 0
    dense = (cache.a[:n_dense], cache.b[:n_dense])
    scanned = (cache.a[n_dense:], cache.b[n_dense:])
    return dense, scanned, n_dense


def decode_step(params, cache: KVCache, tokens, cfg: LMConfig):
    """One decode step: tokens (B,1) + cache → (logits (B,V), new cache)."""
    B = tokens.shape[0]
    x = sharded_lookup(params["embed"]["table"], tokens)
    positions = jnp.broadcast_to(cache.length, (B, 1))
    (da, db), (sa, sb), n_dense = _split_cache(cache, cfg)

    new_da, new_db = [], []
    for i in range(n_dense):
        p_i = jax.tree.map(lambda a: a[i], params["dense_layers"])
        x, (ka, kb) = _block_decode(p_i, x, positions, cfg, False,
                                    (da[i], db[i]), cache.length)
        new_da.append(ka); new_db.append(kb)

    moe_layer = cfg.moe is not None

    # NOTE: a carried-stack variant (cache stacks in the scan carry, updated
    # via dynamic_update_index so XLA aliases the donated buffers) MEASURED
    # WORSE on the dry-run backend (+1 GB/dev: the DUS-in-carry copies
    # instead of aliasing) — refuted, reverted; see EXPERIMENTS §Perf.
    def scan_fn(x, xs):
        p, ca, cb = xs
        x, (na, nb) = _block_decode(p, x, positions, cfg, moe_layer,
                                    (ca, cb), cache.length)
        return x, (na, nb)

    x, (ns_a, ns_b) = jax.lax.scan(scan_fn, x, (params["layers"], sa, sb))
    x = norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    logits = (x[:, -1] @ _head_w(params, cfg)).astype(jnp.float32)
    a = jnp.concatenate([jnp.stack(new_da), ns_a]) if n_dense else ns_a
    b = jnp.concatenate([jnp.stack(new_db), ns_b]) if n_dense else ns_b
    return logits, KVCache(a=a, b=b, length=cache.length + 1)


def prefill(params, tokens, cfg: LMConfig, smax: int):
    """Prefill: tokens (B,S) → (last-position logits, KVCache padded to smax)."""
    B, S = tokens.shape
    x = sharded_lookup(params["embed"]["table"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    n_dense = cfg.moe.n_dense_layers if cfg.moe else 0
    pad = smax - S

    def pad_kv(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

    new_a, new_b = [], []
    for i in range(n_dense):
        p_i = jax.tree.map(lambda a: a[i], params["dense_layers"])
        ff_x = norm_apply(x, p_i["ln1"], cfg.norm, cfg.norm_eps)
        h, kv = attn.attn_forward(p_i["attn"], ff_x, positions, cfg)
        x = x + h
        x = x + mlp_apply(p_i["mlp"], norm_apply(x, p_i["ln2"], cfg.norm, cfg.norm_eps),
                          cfg.act, cfg.glu)
        new_a.append(pad_kv(kv[0])); new_b.append(pad_kv(kv[1]))

    moe_layer = cfg.moe is not None

    def body(p, x):
        h, kv = attn.attn_forward(
            p["attn"], norm_apply(x, p["ln1"], cfg.norm, cfg.norm_eps),
            positions, cfg)
        x = x + h
        ff_in = norm_apply(x, p["ln2"], cfg.norm, cfg.norm_eps)
        if moe_layer:
            ff, _ = moe_lib.moe_apply(p["moe"], ff_in, cfg.moe, cfg.act)
            if "shared" in p:
                ff = ff + mlp_apply(p["shared"], ff_in, cfg.act, cfg.glu)
        else:
            ff = mlp_apply(p["mlp"], ff_in, cfg.act, cfg.glu)
        return x + ff, kv

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    def scan_fn(x, p):
        new_x, kv = body(p, x)
        return new_x, (pad_kv(kv[0]), pad_kv(kv[1]))

    x, (sa, sb) = jax.lax.scan(scan_fn, x, params["layers"])
    x = norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    logits = (x[:, -1] @ _head_w(params, cfg)).astype(jnp.float32)
    a = jnp.concatenate([jnp.stack(new_a), sa]) if n_dense else sa
    b = jnp.concatenate([jnp.stack(new_b), sb]) if n_dense else sb
    return logits, KVCache(a=a, b=b, length=jnp.asarray(S, jnp.int32))
