"""Shared recsys substrate: hashed feature fields → TB-scale sharded tables."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FeatureField, RecsysConfig
from repro.sparse.sharded import sharded_embedding_bag_2d


def tables_init(key, cfg: RecsysConfig) -> dict:
    fields = cfg.user_fields + cfg.item_fields
    keys = jax.random.split(key, len(fields))
    return {f.name: (jax.random.normal(k, (f.vocab, cfg.embed_dim), jnp.float32)
                     * 0.01)
            for f, k in zip(fields, keys)}


def embed_fields(tables: dict, fields: tuple[FeatureField, ...],
                 ids: dict) -> jax.Array:
    """ids[name]: (B,) or (B, bag) int32 → concat (B, n_fields * D)."""
    outs = []
    for f in fields:
        outs.append(sharded_embedding_bag_2d(tables[f.name], ids[f.name],
                                             combiner=f.combiner))
    return jnp.concatenate(outs, axis=-1)


def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    ls = jax.nn.log_sigmoid(logits)
    return -jnp.mean(labels * ls + (1 - labels) * (ls - logits))


def sampled_softmax_loss(user_vecs: jax.Array, item_vecs: jax.Array,
                         log_q: jax.Array | None = None,
                         temperature: float = 0.05) -> jax.Array:
    """In-batch sampled softmax with logQ correction [Yi et al., RecSys'19].
    user/item (B, D) row-aligned positives."""
    logits = (user_vecs @ item_vecs.T) / temperature       # (B, B)
    if log_q is not None:
        logits = logits - log_q[None, :]
    labels = jnp.arange(user_vecs.shape[0])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def l2_normalize(x: jax.Array, eps: float = 1e-9) -> jax.Array:
    return x / jnp.sqrt(jnp.sum(x * x, -1, keepdims=True) + eps)
