"""DIN [arXiv:1706.06978] — deep interest network (target attention).

The local activation unit scores each history item against the candidate via
an MLP over [h, t, h−t, h⊙t] (80→40→1, paper-exact), then weighted-sum pools
WITHOUT softmax normalization (paper §4.3). The Pallas ``din_attention``
kernel fuses this unit; this module is its oracle and the default path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.layers import mlp_tower_apply, mlp_tower_init
from repro.models.recsys.common import bce_loss, embed_fields, tables_init
from repro.sparse.sharded import sharded_embedding_bag_2d


def init(key, cfg: RecsysConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    D = cfg.embed_dim
    # final MLP sees [pooled, target, all user fields, item fields sans item_id]
    d_other = (len(cfg.user_fields) + len(cfg.item_fields) - 1) * D
    return {
        "tables": tables_init(k1, cfg),
        "attn_mlp": mlp_tower_init(k2, 4 * D, cfg.attn_mlp + (1,), jnp.float32),
        "mlp": mlp_tower_init(k3, D + D + d_other, cfg.mlp + (1,), jnp.float32),
    }


def attention_pool(params, hist: jax.Array, mask: jax.Array,
                   target: jax.Array) -> jax.Array:
    """hist (B,T,D), target (B,D) → (B,D) activation-weighted sum."""
    t = jnp.broadcast_to(target[:, None], hist.shape)
    feat = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)  # (B,T,4D)
    w = mlp_tower_apply(params["attn_mlp"], feat, act="silu")[..., 0]
    w = w * mask
    return jnp.einsum("bt,btd->bd", w, hist)


def _hist_emb(params, hist_ids, cfg):
    mask = (hist_ids >= 0).astype(jnp.float32)
    emb = sharded_embedding_bag_2d(
        params["tables"]["item_id"], jnp.maximum(hist_ids, 0).reshape(-1, 1))
    emb = emb.reshape(*hist_ids.shape, cfg.embed_dim) * mask[..., None]
    return emb, mask


def logits_fn(params, batch: dict, cfg: RecsysConfig) -> jax.Array:
    hist, mask = _hist_emb(params, batch["user"]["hist"], cfg)
    target = sharded_embedding_bag_2d(params["tables"]["item_id"],
                                      batch["item"]["item_id"])
    other_u = embed_fields(params["tables"], cfg.user_fields, batch["user"]["fields"])
    other_i = embed_fields(params["tables"],
                           tuple(f for f in cfg.item_fields if f.name != "item_id"),
                           batch["item"])
    pooled = attention_pool(params, hist, mask, target)
    x = jnp.concatenate([pooled, target, other_u, other_i], axis=-1)
    return mlp_tower_apply(params["mlp"], x, act="silu")[..., 0]


def loss_fn(params, batch: dict, cfg: RecsysConfig) -> jax.Array:
    return bce_loss(logits_fn(params, batch, cfg), batch["label"])


def serve_scores(params, batch: dict, cfg: RecsysConfig) -> jax.Array:
    return jax.nn.sigmoid(logits_fn(params, batch, cfg))


def score_candidates(params, user_batch: dict, cand_ids: dict,
                     cfg: RecsysConfig, top_k: int = 100,
                     path: str = "fused"):
    """Re-rank phase vs C candidates: hist computed once, attention per
    candidate (C as batch).

    ``path="fused"`` (the serving default) routes through the
    ``kernels/rerank_score`` fused scorer: the shared history is NEVER
    broadcast to (C, T, D) and the attention + score MLPs run in one pass.
    ``path="jnp"`` is the original broadcast-everything math, kept verbatim
    as the parity oracle (benchmarks/rerank_bench.py gates max-abs-diff
    ≤ 1e-5 between the two) and as the path that carries the mesh sharding
    constraints (the launch cells pin it explicitly). Callers should hand
    ``user_batch["hist"]`` compacted/bucketed
    (serve/bucketing.compact_history) so the fused pass scores only the
    valid history rows. Candidate-gather dedup happens where it actually
    saves traffic — host-side in ``ParameterCube.lookup`` (dynamic
    ``np.unique``); under jit a static-size unique still gathers C rows,
    so the device path gathers directly."""
    from repro import runtime
    from repro.sparse.sharded import sharded_gather_a2a
    C = cand_ids["item_id"].shape[0]
    hist, mask = _hist_emb(params, user_batch["hist"], cfg)   # (1,T,D)
    if path == "fused" and len(cfg.attn_mlp) == 2 and len(cfg.mlp) == 2:
        target = sharded_gather_a2a(params["tables"]["item_id"],
                                    cand_ids["item_id"])       # (C,D)
        other_u = embed_fields(params["tables"], cfg.user_fields,
                               user_batch["fields"])[0]        # (d_u,)
        other_i = embed_fields(
            params["tables"],
            tuple(f for f in cfg.item_fields if f.name != "item_id"),
            cand_ids)                                          # (C, d_i)
        from repro.kernels.rerank_score import rerank_score
        scores = rerank_score(hist[0], mask[0], target, other_u, other_i,
                              params["attn_mlp"], params["mlp"])
    else:
        hist = runtime.shard(jnp.broadcast_to(hist, (C, *hist.shape[1:])),
                             ("data", "model"), None, None)
        mask = jnp.broadcast_to(mask, (C, mask.shape[1]))
        target = sharded_gather_a2a(params["tables"]["item_id"],
                                    cand_ids["item_id"])       # (C,D)
        target = runtime.shard(target, ("data", "model"), None)
        pooled = attention_pool(params, hist, mask, target)
        other_u = embed_fields(params["tables"], cfg.user_fields,
                               user_batch["fields"])           # (1, ...)
        other_u = jnp.broadcast_to(other_u, (C, other_u.shape[-1]))
        other_i = embed_fields(
            params["tables"],
            tuple(f for f in cfg.item_fields if f.name != "item_id"),
            cand_ids)
        x = jnp.concatenate([pooled, target, other_u, other_i], axis=-1)
        scores = mlp_tower_apply(params["mlp"], x, act="silu")[..., 0]
    v, i = jax.lax.top_k(scores.astype(jnp.float32), top_k)
    return v, i
