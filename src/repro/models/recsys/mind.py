"""MIND [arXiv:1904.08030] — multi-interest capsule network.

Behavior-to-interest (B2I) dynamic routing: T history embeddings → K interest
capsules (squash nonlinearity, routing logits NOT backpropagated across
iterations, per the paper). Label-aware attention (pow-2) for training;
serving scores are max over interests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.configs.base import RecsysConfig
from repro.models.layers import mlp_tower_apply, mlp_tower_init
from repro.models.recsys.common import (embed_fields, l2_normalize,
                                        sampled_softmax_loss, tables_init)
from repro.sparse.sharded import sharded_embedding_bag_2d


def init(key, cfg: RecsysConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    D = cfg.embed_dim
    return {
        "tables": tables_init(k1, cfg),
        "s_bilinear": jax.random.normal(k2, (D, D), jnp.float32) / np.sqrt(D),
        "interest_mlp": mlp_tower_init(k3, D, cfg.mlp + (D,), jnp.float32),
    }


def squash(s: jax.Array) -> jax.Array:
    n2 = jnp.sum(s * s, -1, keepdims=True)
    return (n2 / (1.0 + n2)) * s / jnp.sqrt(n2 + 1e-9)


def interests(params, hist_emb: jax.Array, hist_mask: jax.Array,
              cfg: RecsysConfig) -> jax.Array:
    """hist_emb (B,T,D), mask (B,T) → (B,K,D) interest capsules."""
    B, T, D = hist_emb.shape
    K = cfg.n_interests
    low = hist_emb @ params["s_bilinear"]                    # (B,T,D)
    # fixed pseudo-random routing init (paper: random, not learned)
    b0 = jnp.asarray(np.random.default_rng(0).normal(size=(1, K, T)),
                     jnp.float32)
    b = jnp.broadcast_to(b0, (B, K, T))
    neg = -1e30 * (1.0 - hist_mask)[:, None, :]
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(b + neg, axis=1)                  # over K
        s = jnp.einsum("bkt,btd->bkd", w, jax.lax.stop_gradient(low))
        u = squash(s)
        b = b + jnp.einsum("bkd,btd->bkt", u, jax.lax.stop_gradient(low))
    # final pass lets gradients flow through the last aggregation
    w = jax.nn.softmax(b + neg, axis=1)
    u = squash(jnp.einsum("bkt,btd->bkd", w, low))
    u = mlp_tower_apply(params["interest_mlp"], u, final_act=False)
    return l2_normalize(u)


def _hist(params, batch, cfg):
    hist_ids = batch["user"]["hist"]                          # (B,T)
    mask = (hist_ids >= 0).astype(jnp.float32)
    table = params["tables"]["item_id"]
    emb = sharded_embedding_bag_2d(
        table, jnp.maximum(hist_ids, 0).reshape(-1, 1))       # (B*T, D)
    emb = emb.reshape(*hist_ids.shape, cfg.embed_dim) * mask[..., None]
    return emb, mask


def _target(params, item_ids, cfg):
    return sharded_embedding_bag_2d(params["tables"]["item_id"],
                                    item_ids["item_id"])


def loss_fn(params, batch: dict, cfg: RecsysConfig) -> jax.Array:
    emb, mask = _hist(params, batch, cfg)
    I = interests(params, emb, mask, cfg)                     # (B,K,D)
    tgt = l2_normalize(_target(params, batch["item"], cfg))   # (B,D)
    # label-aware attention, pow 2
    att = jax.nn.softmax(jnp.einsum("bkd,bd->bk", I, tgt) ** 2 * 8.0, axis=-1)
    u = jnp.einsum("bk,bkd->bd", att, I)
    return sampled_softmax_loss(l2_normalize(u), tgt)


def serve_scores(params, batch: dict, cfg: RecsysConfig) -> jax.Array:
    emb, mask = _hist(params, batch, cfg)
    I = interests(params, emb, mask, cfg)
    tgt = l2_normalize(_target(params, batch["item"], cfg))
    return jnp.max(jnp.einsum("bkd,bd->bk", I, tgt), axis=-1)


def retrieve(params, user_batch: dict, cand_ids: dict, cfg: RecsysConfig,
             top_k: int = 100):
    emb, mask = _hist(params, {"user": user_batch}, cfg)
    I = interests(params, emb, mask, cfg)[0]                  # (K,D)
    from repro.sparse.sharded import sharded_gather_a2a
    v = sharded_gather_a2a(params["tables"]["item_id"], cand_ids["item_id"])
    v = l2_normalize(runtime.shard(v, ("data", "model"), None))
    scores = jnp.max(v @ I.T, axis=-1).astype(jnp.float32)    # (C,)
    v, i = jax.lax.top_k(scores, top_k)
    return v, i
