"""DIEN [arXiv:1809.03672] — interest extraction (GRU) + interest evolution
(AUGRU: attentional update gate), plus the auxiliary next-behavior loss.

The AUGRU recurrence is the serving hot spot (seq scan per candidate); the
Pallas ``augru`` kernel fuses the full T-step recurrence in VMEM — this
module is its jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig
from repro.models.layers import mlp_tower_apply, mlp_tower_init
from repro.models.recsys.common import bce_loss, embed_fields, tables_init
from repro.sparse.sharded import sharded_embedding_bag_2d


def gru_init(key, d_in: int, h: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (d_in, 3 * h), jnp.float32) / np.sqrt(d_in),
            "u": jax.random.normal(k2, (h, 3 * h), jnp.float32) / np.sqrt(h),
            "b": jnp.zeros((3 * h,), jnp.float32)}


def _gates(p, x_t, h):
    gx = x_t @ p["w"] + p["b"]
    gh = h @ p["u"]
    H = h.shape[-1]
    r = jax.nn.sigmoid(gx[..., :H] + gh[..., :H])
    z = jax.nn.sigmoid(gx[..., H:2 * H] + gh[..., H:2 * H])
    n = jnp.tanh(gx[..., 2 * H:] + r * gh[..., 2 * H:])
    return z, n


def gru_apply(p, x: jax.Array) -> jax.Array:
    """x (B,T,D) → all hidden states (B,T,H)."""
    B, T, _ = x.shape
    H = p["u"].shape[0]

    def step(h, x_t):
        z, n = _gates(p, x_t, h)
        h_new = (1 - z) * n + z * h
        return h_new, h_new

    _, hs = jax.lax.scan(step, jnp.zeros((B, H), x.dtype),
                         x.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2)


def augru_apply(p, x: jax.Array, att: jax.Array) -> jax.Array:
    """AUGRU: att (B,T) scales the update gate. Returns final hidden (B,H)."""
    B, T, _ = x.shape
    H = p["u"].shape[0]

    def step(h, xs):
        x_t, a_t = xs
        z, n = _gates(p, x_t, h)
        z = z * a_t[:, None]
        h_new = (1 - z) * h + z * n
        return h_new, None

    h, _ = jax.lax.scan(step, jnp.zeros((B, H), x.dtype),
                        (x.transpose(1, 0, 2), att.T))
    return h


def init(key, cfg: RecsysConfig) -> dict:
    ks = jax.random.split(key, 6)
    D, H = cfg.embed_dim, cfg.gru_dim
    d_other = (len(cfg.user_fields) + len(cfg.item_fields) - 1) * D
    return {
        "tables": tables_init(ks[0], cfg),
        "gru": gru_init(ks[1], D, H),
        "augru": gru_init(ks[2], H, H),
        "att_w": jax.random.normal(ks[3], (H, D), jnp.float32) / np.sqrt(H),
        "mlp": mlp_tower_init(ks[4], H + D + d_other, cfg.mlp + (1,), jnp.float32),
        "aux_w": jax.random.normal(ks[5], (H, D), jnp.float32) / np.sqrt(H),
    }


def _hist_emb(params, hist_ids, cfg):
    mask = (hist_ids >= 0).astype(jnp.float32)
    emb = sharded_embedding_bag_2d(
        params["tables"]["item_id"], jnp.maximum(hist_ids, 0).reshape(-1, 1))
    emb = emb.reshape(*hist_ids.shape, cfg.embed_dim) * mask[..., None]
    return emb, mask


def _evolved_interest(params, hist, mask, target):
    """GRU states → attention vs target → AUGRU final state. (B,H)."""
    states = gru_apply(params["gru"], hist)                   # (B,T,H)
    att = jnp.einsum("bth,hd,bd->bt", states, params["att_w"], target)
    att = jax.nn.softmax(jnp.where(mask > 0, att, -1e30), axis=-1) * mask
    return states, augru_apply(params["augru"], states, att)


def logits_fn(params, batch: dict, cfg: RecsysConfig, return_aux=False):
    hist, mask = _hist_emb(params, batch["user"]["hist"], cfg)
    target = sharded_embedding_bag_2d(params["tables"]["item_id"],
                                      batch["item"]["item_id"])
    states, final = _evolved_interest(params, hist, mask, target)
    other_u = embed_fields(params["tables"], cfg.user_fields, batch["user"]["fields"])
    other_i = embed_fields(params["tables"],
                           tuple(f for f in cfg.item_fields if f.name != "item_id"),
                           batch["item"])
    x = jnp.concatenate([final, target, other_u, other_i], axis=-1)
    logits = mlp_tower_apply(params["mlp"], x, act="silu")[..., 0]
    if not return_aux:
        return logits
    # auxiliary loss: state_t should predict behavior t+1 (vs shuffled negative)
    pred = states[:, :-1] @ params["aux_w"]                   # (B,T-1,D)
    pos = jnp.sum(pred * hist[:, 1:], -1)
    neg = jnp.sum(pred * jnp.roll(hist[:, 1:], 1, axis=0), -1)
    m = mask[:, 1:]
    aux = -(jax.nn.log_sigmoid(pos) + jax.nn.log_sigmoid(-neg)) * m
    aux = aux.sum() / jnp.maximum(m.sum(), 1.0)
    return logits, aux


def loss_fn(params, batch: dict, cfg: RecsysConfig, aux_weight=0.5) -> jax.Array:
    logits, aux = logits_fn(params, batch, cfg, return_aux=True)
    return bce_loss(logits, batch["label"]) + aux_weight * aux


def serve_scores(params, batch: dict, cfg: RecsysConfig) -> jax.Array:
    return jax.nn.sigmoid(logits_fn(params, batch, cfg))


def score_candidates(params, user_batch: dict, cand_ids: dict,
                     cfg: RecsysConfig, top_k: int = 100):
    """Re-rank vs C candidates: GRU once, AUGRU per candidate."""
    C = cand_ids["item_id"].shape[0]
    hist, mask = _hist_emb(params, user_batch["hist"], cfg)   # (1,T,D)
    states = gru_apply(params["gru"], hist)                   # (1,T,H)
    from repro import runtime
    from repro.sparse.sharded import sharded_gather_a2a
    target = sharded_gather_a2a(params["tables"]["item_id"],
                                cand_ids["item_id"])           # (C,D)
    target = runtime.shard(target, ("data", "model"), None)
    states_b = runtime.shard(jnp.broadcast_to(states, (C, *states.shape[1:])),
                             ("data", "model"), None, None)
    mask_b = jnp.broadcast_to(mask, (C, mask.shape[1]))
    att = jnp.einsum("bth,hd,bd->bt", states_b, params["att_w"], target)
    att = jax.nn.softmax(jnp.where(mask_b > 0, att, -1e30), -1) * mask_b
    final = augru_apply(params["augru"], states_b, att)        # (C,H)
    other_u = embed_fields(params["tables"], cfg.user_fields, user_batch["fields"])
    other_u = jnp.broadcast_to(other_u, (C, other_u.shape[-1]))
    other_i = embed_fields(params["tables"],
                           tuple(f for f in cfg.item_fields if f.name != "item_id"),
                           cand_ids)
    x = jnp.concatenate([final, target, other_u, other_i], axis=-1)
    scores = mlp_tower_apply(params["mlp"], x, act="silu")[..., 0]
    v, i = jax.lax.top_k(scores.astype(jnp.float32), top_k)
    return v, i
