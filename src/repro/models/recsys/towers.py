"""Two-tower retrieval [Covington RecSys'16; Yi et al. RecSys'19]."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import runtime
from repro.configs.base import RecsysConfig
from repro.models.layers import mlp_tower_apply, mlp_tower_init
from repro.models.recsys.common import (embed_fields, l2_normalize,
                                        sampled_softmax_loss, tables_init)


def init(key, cfg: RecsysConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d_user = len(cfg.user_fields) * cfg.embed_dim
    d_item = len(cfg.item_fields) * cfg.embed_dim
    return {
        "tables": tables_init(k1, cfg),
        "user_tower": mlp_tower_init(k2, d_user, cfg.tower_mlp, jnp.float32),
        "item_tower": mlp_tower_init(k3, d_item, cfg.tower_mlp, jnp.float32),
    }


def user_vec(params, user_ids: dict, cfg: RecsysConfig) -> jax.Array:
    x = embed_fields(params["tables"], cfg.user_fields, user_ids)
    return l2_normalize(mlp_tower_apply(params["user_tower"], x))


def item_vec(params, item_ids: dict, cfg: RecsysConfig) -> jax.Array:
    x = embed_fields(params["tables"], cfg.item_fields, item_ids)
    return l2_normalize(mlp_tower_apply(params["item_tower"], x))


def loss_fn(params, batch: dict, cfg: RecsysConfig) -> jax.Array:
    u = user_vec(params, batch["user"]["fields"], cfg)
    v = item_vec(params, batch["item"], cfg)
    return sampled_softmax_loss(u, v, batch.get("log_q"))


def serve_scores(params, batch: dict, cfg: RecsysConfig) -> jax.Array:
    """Paired (user, item) relevance scores, (B,)."""
    u = user_vec(params, batch["user"]["fields"], cfg)
    v = item_vec(params, batch["item"], cfg)
    return jnp.sum(u * v, axis=-1)


def retrieve(params, user_ids: dict, cand_ids: dict, cfg: RecsysConfig,
             top_k: int = 100):
    """One query vs n_candidates (recall phase): batched dot, then top-k.
    Candidate embedding + tower is sharded over the full mesh."""
    u = user_vec(params, user_ids, cfg)                       # (1, D)
    # bag=1 fields: all-to-all exchange (each row moves ONCE — §Perf iter 5);
    # multi-hot bags: psum pooling with bf16 collectives (§Perf iter 4)
    from repro.sparse.sharded import (sharded_embedding_bag_2d,
                                      sharded_gather_a2a)
    cols = []
    for f in cfg.item_fields:
        if f.bag == 1:
            cols.append(sharded_gather_a2a(params["tables"][f.name],
                                           cand_ids[f.name]))
        else:
            # multi-hot: per-column a2a gathers + local pool still moves
            # each row once (k small) vs the dense-partial psum
            acc = sum(sharded_gather_a2a(params["tables"][f.name],
                                         cand_ids[f.name][:, j])
                      for j in range(f.bag))
            cols.append(acc / f.bag if f.combiner == "mean" else acc)
    x = jnp.concatenate(cols, axis=-1)
    # lookup emerges data-sharded; spread candidates over the whole mesh so
    # the tower MLP runs 256-way, not 16-way
    x = runtime.shard(x, ("data", "model"), None)
    v = l2_normalize(mlp_tower_apply(params["item_tower"], x))  # (C, D)
    scores = (v @ u[0]).astype(jnp.float32)                   # (C,)
    v, i = jax.lax.top_k(scores, top_k)
    return v, i
