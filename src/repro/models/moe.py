"""Expert-parallel MoE (DeepSeek-style: shared + fine-grained routed experts).

Dispatch is SORT-BASED (argsort by expert, rank-in-expert capacity, scatter
into (E_local, C, d) buffers) — linear memory and *actual* FLOPs, unlike the
GShard (T,E,C) one-hot einsum whose dispatch alone would dominate the
roofline at T=64k, E=256.

Distribution (inside one shard_map over the full mesh):
  * routed expert weights: experts over ``model``, d_ff over ``data``
    (2-D expert-weight sharding → deepseek-v3's 656B of expert weights cost
    5.2 GB/device, and dispatch never gathers a weight).
  * tokens: sharded over ("pod","data"); each MoE layer all-gathers tokens
    within its pod's data row, computes the f-slice of its local experts,
    then psum_scatter("data") + psum("model") combines f-partials and expert
    contributions back to token owners. MoE traffic never crosses pods.
  * shared experts are a plain dense GLU with standard TP (handled by the
    caller), not part of this file.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import runtime
from repro.models.layers import activation


def moe_expert_init(key, d_model: int, cfg, dtype) -> dict:
    """Routed experts + router. Weights stacked (E, d, f) / (E, f, d)."""
    E, f = cfg.n_routed, cfg.d_ff_expert
    ks = jax.random.split(key, 4)
    s_in, s_f = 1.0 / np.sqrt(d_model), 1.0 / np.sqrt(f)
    return {
        "router": (jax.random.normal(ks[0], (d_model, E), jnp.float32) * s_in
                   ).astype(jnp.float32),  # router kept fp32 (routing stability)
        "w1": (jax.random.normal(ks[1], (E, d_model, f), jnp.float32) * s_in).astype(dtype),
        "w3": (jax.random.normal(ks[2], (E, d_model, f), jnp.float32) * s_in).astype(dtype),
        "w2": (jax.random.normal(ks[3], (E, f, d_model), jnp.float32) * s_f).astype(dtype),
    }


def _capacity(tokens: int, cfg) -> int:
    c = int(np.ceil(cfg.capacity_factor * tokens * cfg.top_k / cfg.n_routed))
    return max(8, -(-c // 8) * 8)  # pad to sublane multiple


def _route(x, router_w, top_k: int):
    logits = (x.astype(jnp.float32) @ router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)                  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch-style), returned for the training loss
    T, E = logits.shape
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros(E).at[idx.reshape(-1)].add(1.0) / (T * top_k)
    aux = E * jnp.sum(me * ce)
    return gate, idx, aux


def _dispatch_compute_combine(xg, gate, idx, w1, w3, w2, *, e0: int, C: int, act: str):
    """Sort-based pack → grouped GEMM → combine, for experts [e0, e0+E_loc).

    xg (T, d); gate/idx (T, k); w* (E_loc, d, f_loc)/(E_loc, f_loc, d).
    Returns (T, d) partial output (partial over f-slices when f is sharded).

    Memory discipline: the naive gather-by-pair materializes (T·k, d) — at
    deepseek-v3 scale that is 7.5 GB per layer. Instead we build a
    slot→token index map and gather straight into the (E_loc·C, d) capacity
    buffer, and combine with k separate (T, d) gathers (dropped pairs point
    at a zero sentinel row, so no extra masking is needed).
    """
    T, d = xg.shape
    k = idx.shape[1]
    E_loc = w1.shape[0]
    N = T * k
    e_flat = idx.reshape(-1) - e0                            # (N,)
    mine = (e_flat >= 0) & (e_flat < E_loc)
    sort_key = jnp.where(mine, e_flat, E_loc).astype(jnp.int32)
    order = jnp.argsort(sort_key, stable=True)
    sorted_e = sort_key[order]
    counts = jax.ops.segment_sum(jnp.ones((N,), jnp.int32), sorted_e,
                                 num_segments=E_loc + 1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(N, dtype=jnp.int32) - starts[sorted_e]
    keep = (sorted_e < E_loc) & (pos < C)
    slot = jnp.where(keep, sorted_e * C + pos, E_loc * C)    # sentinel = last
    src_tok = (order // k).astype(jnp.int32)

    # slot → source token (occupancy via a parallel scatter of ones)
    idx_buf = jnp.zeros((E_loc * C + 1,), jnp.int32).at[slot].set(src_tok)
    occ = jnp.zeros((E_loc * C + 1,), xg.dtype).at[slot].max(
        keep.astype(xg.dtype))
    buf = jnp.take(xg, idx_buf[: E_loc * C], axis=0) \
        * occ[: E_loc * C, None]
    buf = buf.reshape(E_loc, C, d)

    h1 = jnp.einsum("ecd,edf->ecf", buf, w1)
    h3 = jnp.einsum("ecd,edf->ecf", buf, w3)
    h = activation(h1, act) * h3
    out_buf = jnp.einsum("ecf,efd->ecd", h, w2)              # f-partial
    flat = jnp.concatenate([out_buf.reshape(E_loc * C, d),
                            jnp.zeros((1, d), out_buf.dtype)])
    # token → its k slots (inverse permutation; dropped/foreign pairs hit
    # the zero sentinel row)
    slot_tok = jnp.zeros((N,), jnp.int32).at[order].set(
        jnp.where(keep, slot, E_loc * C)).reshape(T, k)
    out = jnp.zeros((T, d), xg.dtype)
    for j in range(k):                                       # k small (≤8)
        out = out + jnp.take(flat, slot_tok[:, j], axis=0) \
            * gate[:, j, None].astype(xg.dtype)
    return out


def moe_apply(p: dict, x: jax.Array, cfg, act: str = "silu"):
    """x (..., d) → (same, aux_loss). Token dims are flattened internally."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    mesh = runtime.current_mesh()
    ep = mesh is not None and mesh.shape.get("model", 1) > 1

    if not ep:
        gate, idx, aux = _route(xt, p["router"], cfg.top_k)
        out = _dispatch_compute_combine(
            xt, gate, idx, p["w1"], p["w3"], p["w2"],
            e0=0, C=_capacity(xt.shape[0], cfg), act=act)
        return out.reshape(*lead, d), aux

    n_model = mesh.shape["model"]
    n_data = mesh.shape.get("data", 1)
    assert cfg.n_routed % n_model == 0, "experts must divide the model axis"
    E_loc = cfg.n_routed // n_model
    batch_axes = runtime.batch_axes()
    T = xt.shape[0]
    # Tokens shard over the data axes when divisible (train/bulk serve);
    # tiny-token decode (e.g. batch-1 long-context) replicates tokens and the
    # psum over ("data","model") folds both the f-slice partials and the
    # expert contributions.
    tok_sharded = T % runtime.data_axis_size() == 0 and T >= runtime.data_axis_size()
    T_row = (T // runtime.data_axis_size()) * n_data if tok_sharded else T
    C = _capacity(T_row, cfg)
    f = cfg.d_ff_expert
    f_sharded = f % n_data == 0 and n_data > 1

    # chunk the gather+dispatch when the row buffer is large (v3: 940 MB/
    # layer): each chunk all-gathers T_row/n_ch tokens, dispatches into its
    # own capacity slice, computes, combines — MoE transients ÷ n_ch at the
    # cost of per-chunk (vs global) capacity drops [§Perf cell-1 iteration]
    d_model = xt.shape[-1]
    n_ch = 1
    while (T_row // n_ch) * d_model > (1 << 26) and \
            T_row % (n_ch * 2) == 0 and (T_row // (n_ch * 2)) % n_data == 0:
        n_ch *= 2
    C_ch = _capacity(T_row // n_ch, cfg)

    def local(xt_loc, router_w, w1, w3, w2):
        gate, idx, aux = _route(xt_loc, router_w, cfg.top_k)
        e0 = jax.lax.axis_index("model") * E_loc
        if tok_sharded and n_ch > 1:
            def chunk_fn(args):
                xc, gc, ic = args
                xg = jax.lax.all_gather(xc, "data", axis=0, tiled=True)
                gg = jax.lax.all_gather(gc, "data", axis=0, tiled=True)
                ig = jax.lax.all_gather(ic, "data", axis=0, tiled=True)
                return _dispatch_compute_combine(xg, gg, ig, w1, w3, w2,
                                                 e0=e0, C=C_ch, act=act)

            T_l = xt_loc.shape[0]
            outc = jax.lax.map(chunk_fn, (
                xt_loc.reshape(n_ch, T_l // n_ch, -1),
                gate.reshape(n_ch, T_l // n_ch, -1),
                idx.reshape(n_ch, T_l // n_ch, -1)))
            # each chunk's gather is (shard-major within the chunk); restore
            # the global gather order (shard, chunk, pos) for the combine
            out_full = outc.reshape(n_ch, n_data, T_l // n_ch, -1) \
                .transpose(1, 0, 2, 3).reshape(T_row, -1)
        elif tok_sharded:
            xg = jax.lax.all_gather(xt_loc, "data", axis=0, tiled=True)
            gg = jax.lax.all_gather(gate, "data", axis=0, tiled=True)
            ig = jax.lax.all_gather(idx, "data", axis=0, tiled=True)
            out_full = _dispatch_compute_combine(xg, gg, ig, w1, w3, w2,
                                                 e0=e0, C=C, act=act)
        else:
            out_full = _dispatch_compute_combine(xt_loc, gate, idx, w1, w3, w2,
                                                 e0=e0, C=C, act=act)
        if tok_sharded and T_row % (n_data * n_model) == 0:
            # combine = Σ over experts (model) and f-slices (data), then
            # return tokens to their data-shard owners. psum(model)+rs(data)
            # moves ≈2.9×|buf| on ICI; rs over BOTH axes then a small
            # all-gather(model) moves ≈1.06×|buf|  [§Perf iteration 2]
            out_tiny = jax.lax.psum_scatter(out_full, ("data", "model"),
                                            scatter_dimension=0, tiled=True)
            out_loc = jax.lax.all_gather(out_tiny, "model", axis=0,
                                         tiled=True)
        elif tok_sharded:
            out_full = jax.lax.psum(out_full, "model")
            out_loc = jax.lax.psum_scatter(out_full, "data",
                                           scatter_dimension=0, tiled=True)
        else:
            axes = ("data", "model") if f_sharded else ("model",)
            out_loc = jax.lax.psum(out_full, axes)
        return out_loc, jax.lax.pmean(aux, tuple(mesh.axis_names))

    w_spec_1 = P("model", None, "data" if f_sharded else None)
    w_spec_2 = P("model", "data" if f_sharded else None, None)
    tok_spec = P(batch_axes, None) if tok_sharded else P(None, None)
    fn = runtime.shard_map(
        local, mesh=mesh,
        in_specs=(tok_spec, P(None, None), w_spec_1, w_spec_1, w_spec_2),
        out_specs=(tok_spec, P()),
        check_vma=False)
    out, aux = fn(xt, p["router"], p["w1"], p["w3"], p["w2"])
    return out.reshape(*lead, d), aux


def moe_param_specs(cfg, f_sharded: bool) -> dict:
    """PartitionSpecs for one (unstacked) MoE layer's params."""
    fs = "data" if f_sharded else None
    return {"router": P(None, None),
            "w1": P("model", None, fs),
            "w3": P("model", None, fs),
            "w2": P("model", fs, None)}
