"""Model hot-loading (paper §7): serve new model generations without
interrupting the service — a monitor tracks the training cluster's output;
when a new generation appears (identified by generation timestamp), it is
pulled and swapped in via DOUBLE BUFFERING: in-flight requests finish on the
old buffer, new requests bind the new one.

Two watcher flavours share one polling skeleton (``PollWatcher``):

  * ``ModelMonitor`` — whole-generation swaps into a ``DoubleBuffer``
    (the §7 path: a full snapshot replaces the previous one).
  * ``repro.update.delta.DeltaWatcher`` — the streaming delta path
    (DESIGN.md §6): versioned delta batches applied into the live cube.

A failing loader/apply no longer stalls updates silently: the poll loop
catches the exception, LOGS it, and retries with exponential backoff
(reset on the next success), keeping the serving path alive while the
training side republishes a bad artifact.
"""
from __future__ import annotations

import logging
import os
import random
import threading
from dataclasses import dataclass
from typing import Any, Callable, List, Optional

from repro.obs.log import log_event

log = logging.getLogger(__name__)


@dataclass
class Generation:
    stamp: int
    payload: Any            # params pytree / jitted fns / cube handle


class DoubleBuffer:
    """Lock-free reads (python ref assignment is atomic); writers swap.

    ``on_swap`` callbacks fire after each successful publish — the cache-
    coherence hook: the query cache's scores were computed by the OLD
    generation, so `InferenceService` registers its
    ``QueryCache.bump_model_version`` here (DESIGN.md §6.4)."""

    def __init__(self, initial: Generation):
        self._active = initial
        self._standby: Optional[Generation] = None
        self._lock = threading.Lock()
        self.swaps = 0
        self.on_swap: List[Callable[[Generation], None]] = []

    @property
    def active(self) -> Generation:
        return self._active

    def load(self, gen: Generation):
        with self._lock:
            if gen.stamp <= self._active.stamp:
                return False             # stale generation — ignore
            self._standby = gen
            # atomically publish; old generation stays alive for in-flight
            # requests holding a reference (GC reclaims when they finish)
            self._active = gen
            self._standby = None
            self.swaps += 1
        for cb in self.on_swap:
            cb(gen)
        return True


class PollWatcher:
    """Thread that polls ``check_once()`` every ``poll_s`` seconds, with
    logged exponential backoff on failure.

    A loader exception used to be swallowed with a bare ``pass`` — the
    monitor would silently hammer the same broken artifact every tick with
    no operator signal. Now each consecutive failure doubles the wait (up
    to ``max_backoff_s``), the exception is logged, and ``failures`` /
    ``last_error`` expose the state to health checks; the first success
    resets the backoff.

    ``jitter`` (default on) decorrelates the retries: a fleet of watchers
    that all saw the same bad artifact would otherwise re-poll it in
    LOCKSTEP at 1s, 2s, 4s, ... — a synchronized thundering herd on the
    artifact store every power-of-two tick. Decorrelated jitter (sleep =
    uniform(poll_s, 3 × previous sleep), capped at ``max_backoff_s``)
    spreads them out while keeping the same growth rate and cap;
    ``jitter_seed`` pins the sequence for deterministic tests."""

    def __init__(self, poll_s: float = 1.0, max_backoff_s: float = 30.0,
                 jitter: bool = True, jitter_seed: Optional[int] = None):
        self.poll_s = poll_s
        self.max_backoff_s = max_backoff_s
        self.jitter = jitter
        self.failures = 0               # consecutive failures (resets on ok)
        self.total_failures = 0
        self.last_error: Optional[BaseException] = None
        self._rng = random.Random(jitter_seed)
        self._prev_backoff = 0.0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def check_once(self) -> bool:       # pragma: no cover - abstract
        raise NotImplementedError

    def _backoff_s(self) -> float:
        if not self.failures:
            self._prev_backoff = 0.0
            return self.poll_s
        # cap the exponent: 2.0**1024 raises OverflowError, which would
        # escape loop() (the wait runs outside the try) and silently kill
        # the watcher thread after ~1k consecutive failures
        exp = min(self.poll_s * (2.0 ** min(self.failures, 30)),
                  self.max_backoff_s)
        if not self.jitter:
            self._prev_backoff = exp
            return exp
        prev = self._prev_backoff if self._prev_backoff > 0 else self.poll_s
        hi = max(self.poll_s, min(self.max_backoff_s, prev * 3.0))
        self._prev_backoff = self._rng.uniform(self.poll_s, hi)
        return self._prev_backoff

    def start(self):
        def loop():
            while not self._stop.is_set():
                try:
                    self.check_once()
                    self.failures = 0
                    self.last_error = None
                    wait = self._backoff_s()
                except Exception as e:  # noqa: BLE001 — keep serving
                    self.failures += 1
                    self.total_failures += 1
                    self.last_error = e
                    # sample the (jittered) backoff ONCE per tick: the
                    # logged wait must be the wait actually slept
                    wait = self._backoff_s()
                    log_event(log, "watcher_poll_failed",
                              level=logging.WARNING,
                              watcher=type(self).__name__,
                              attempt=self.failures, retry_in_s=wait,
                              error=f"{type(e).__name__}: {e}")
                self._stop.wait(wait)
        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)


class ModelMonitor(PollWatcher):
    """Polls a 'remote address' (directory) for new generation stamps and
    hot-loads them. Thread-based; ``check_once`` is used by tests."""

    def __init__(self, watch_dir: str, buffer: DoubleBuffer,
                 loader: Callable[[str], Any], poll_s: float = 1.0,
                 max_backoff_s: float = 30.0, **kw):
        super().__init__(poll_s=poll_s, max_backoff_s=max_backoff_s, **kw)
        self.watch_dir = watch_dir
        self.buffer = buffer
        self.loader = loader

    def latest_stamp(self) -> Optional[int]:
        if not os.path.isdir(self.watch_dir):
            return None
        stamps = [int(d.split("_")[-1]) for d in os.listdir(self.watch_dir)
                  if d.startswith("gen_") and
                  os.path.exists(os.path.join(self.watch_dir, d, "DONE"))]
        return max(stamps) if stamps else None

    def check_once(self) -> bool:
        stamp = self.latest_stamp()
        if stamp is None or stamp <= self.buffer.active.stamp:
            return False
        path = os.path.join(self.watch_dir, f"gen_{stamp}")
        payload = self.loader(path)
        loaded = self.buffer.load(Generation(stamp, payload))
        if loaded:
            log_event(log, "model_hot_swap", watcher=type(self).__name__,
                      version=stamp, path=path)
        return loaded
