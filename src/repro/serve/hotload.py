"""Model hot-loading (paper §7): serve new model generations without
interrupting the service — a monitor tracks the training cluster's output;
when a new generation appears (identified by generation timestamp), it is
pulled and swapped in via DOUBLE BUFFERING: in-flight requests finish on the
old buffer, new requests bind the new one.
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional


@dataclass
class Generation:
    stamp: int
    payload: Any            # params pytree / jitted fns / cube handle


class DoubleBuffer:
    """Lock-free reads (python ref assignment is atomic); writers swap."""

    def __init__(self, initial: Generation):
        self._active = initial
        self._standby: Optional[Generation] = None
        self._lock = threading.Lock()
        self.swaps = 0

    @property
    def active(self) -> Generation:
        return self._active

    def load(self, gen: Generation):
        with self._lock:
            if gen.stamp <= self._active.stamp:
                return False             # stale generation — ignore
            self._standby = gen
            # atomically publish; old generation stays alive for in-flight
            # requests holding a reference (GC reclaims when they finish)
            self._active = gen
            self._standby = None
            self.swaps += 1
            return True


class ModelMonitor:
    """Polls a 'remote address' (directory) for new generation stamps and
    hot-loads them. Thread-based; ``check_once`` is used by tests."""

    def __init__(self, watch_dir: str, buffer: DoubleBuffer,
                 loader: Callable[[str], Any], poll_s: float = 1.0):
        self.watch_dir = watch_dir
        self.buffer = buffer
        self.loader = loader
        self.poll_s = poll_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def latest_stamp(self) -> Optional[int]:
        if not os.path.isdir(self.watch_dir):
            return None
        stamps = [int(d.split("_")[-1]) for d in os.listdir(self.watch_dir)
                  if d.startswith("gen_") and
                  os.path.exists(os.path.join(self.watch_dir, d, "DONE"))]
        return max(stamps) if stamps else None

    def check_once(self) -> bool:
        stamp = self.latest_stamp()
        if stamp is None or stamp <= self.buffer.active.stamp:
            return False
        path = os.path.join(self.watch_dir, f"gen_{stamp}")
        payload = self.loader(path)
        return self.buffer.load(Generation(stamp, payload))

    def start(self):
        def loop():
            while not self._stop.is_set():
                try:
                    self.check_once()
                except Exception:      # noqa: BLE001 — keep serving
                    pass
                self._stop.wait(self.poll_s)
        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)
