"""Request batching for the DNN serving stage.

Two disciplines, matching the two serving regimes in the paper's funnel:

  * ``MicroBatcher`` — recsys scoring: collect up to ``max_batch`` requests
    or ``max_wait_s``, whichever first (the per-stage batch knob of Table 6,
    as an online component rather than a SimExecutor parameter).
  * ``ContinuousBatcher`` — LM decode: fixed-width slot table; sequences
    join/leave between steps (vLLM-style continuous batching on a static
    XLA shape — slots are masked, not re-compiled).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np


@dataclass
class MicroBatcher:
    max_batch: int = 32
    max_wait_s: float = 0.002
    _buf: list = field(default_factory=list)
    _first_at: float = 0.0
    _min_deadline: Optional[float] = None

    def offer(self, item, now: Optional[float] = None,
              deadline_at: Optional[float] = None) -> Optional[list]:
        """``deadline_at``: the item's absolute request deadline, when it
        carries one — the batch's effective flush deadline becomes its
        TIGHTEST member's (a batching window must never be the reason an
        almost-expired request times out in the buffer)."""
        now = time.monotonic() if now is None else now
        if not self._buf:
            self._first_at = now
            self._min_deadline = None
        self._buf.append(item)
        if deadline_at is not None:
            self._min_deadline = (deadline_at if self._min_deadline is None
                                  else min(self._min_deadline, deadline_at))
        if len(self._buf) >= self.max_batch:
            return self.flush()
        return None

    def poll(self, now: Optional[float] = None) -> Optional[list]:
        now = time.monotonic() if now is None else now
        # compare against deadline() (the same expression the scheduler
        # sleeps on) — a recomputed subtraction form disagrees with it in
        # the last ulp at large clock values, making the boundary poll a
        # no-op
        if self._buf and now >= self.deadline():
            return self.flush()
        return None

    def flush(self) -> Optional[list]:
        if not self._buf:
            return None
        out, self._buf = self._buf, []
        self._min_deadline = None
        return out

    def deadline(self) -> float:
        """When the currently-buffered partial batch must flush: the
        batching-window close, pulled earlier to the tightest member's
        request deadline (undefined when empty — check ``len`` first)."""
        window = self._first_at + self.max_wait_s
        if self._min_deadline is not None:
            return min(window, self._min_deadline)
        return window

    def __len__(self) -> int:
        return len(self._buf)


@dataclass
class Slot:
    request_id: Optional[int] = None
    length: int = 0
    max_new: int = 0
    done: bool = False


class ContinuousBatcher:
    """Static (B_slots, S_max) decode table. join() claims a free slot after
    prefill; step() decodes every active slot; finished slots free up for
    waiting requests — throughput stays high without recompilation."""

    def __init__(self, n_slots: int, s_max: int):
        self.n_slots = n_slots
        self.s_max = s_max
        self.slots = [Slot() for _ in range(n_slots)]
        self.waiting: list[tuple[int, int, int]] = []   # (req, prompt_len, max_new)
        self.completed: list[int] = []

    def submit(self, request_id: int, prompt_len: int, max_new: int):
        self.waiting.append((request_id, prompt_len, max_new))
        self._admit()

    def _admit(self):
        for slot in self.slots:
            if slot.request_id is None and self.waiting:
                req, plen, mx = self.waiting.pop(0)
                slot.request_id, slot.length, slot.max_new = req, plen, mx
                slot.done = False

    @property
    def active_mask(self) -> np.ndarray:
        return np.array([s.request_id is not None and not s.done
                         for s in self.slots])

    def lengths(self) -> np.ndarray:
        return np.array([s.length for s in self.slots], np.int32)

    def step_complete(self, eos: np.ndarray):
        """Advance every active slot by one token; eos (B_slots,) bool marks
        sequences that just finished."""
        for i, slot in enumerate(self.slots):
            if slot.request_id is None or slot.done:
                continue
            slot.length += 1
            slot.max_new -= 1
            if bool(eos[i]) or slot.max_new <= 0 or slot.length >= self.s_max:
                self.completed.append(slot.request_id)
                self.slots[i] = Slot()
        self._admit()

    @property
    def utilization(self) -> float:
        return float(self.active_mask.mean())
