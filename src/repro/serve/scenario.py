"""Scenario API: declarative multi-scenario serving (DESIGN.md §7).

JiZHI serves twenty-plus heterogeneous recommendation services through ONE
staged-pipeline abstraction. This module is that surface for the repro:

  * ``ScenarioSpec`` — a declarative description of one serving scenario
    (arch id, pipeline shape, bucketing menus, cache/shed knobs). Adding a
    scenario is composition, not a fork of service.py.
  * ``ScenarioRuntime`` — the per-scenario model state (params buffer,
    jitted entry points, shape bucketers, cube feature groups) compiled
    from a spec against a shared :class:`ServingSubstrate`.
  * ``ServingSubstrate`` — ONE cube / cube-cache / query-cache / update
    subsystem shared by N scenario pipelines. Feature groups are keyed by
    ``(field_name, vocab)`` so scenarios with common fields share rows
    (paper §8.6: Service E's three tenants share >80% of feature groups).
  * ``PipelineBuilder`` — compiles specs into one SEDP DAG out of the
    typed stage processors (serve/stages.py), validating every stage's
    payload contract at BUILD time (`ContractError`), not mid-traffic.

``InferenceService`` (core/service.py) is a thin compatibility wrapper
over a single-scenario build; ``MultiScenarioService`` hosts N scenarios
behind the quota-aware multi-tenant fanout.
"""
from __future__ import annotations

import logging
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import numpy as np

from repro.configs import registry
from repro.obs.log import log_event
from repro.core import sedp as sedp_lib
from repro.core.cube import ParameterCube
from repro.core.cube_cache import TwoTierLFUCache, capacity_from_ratio
from repro.core.irm.shedding import (OnlineShedder, QuotaController,
                                     train_pruning_dnn)
from repro.core.query_cache import QueryCache
from repro.core.sedp import SEDP, Event, GraphError
from repro.serve.bucketing import (ShapeBucketer, TracedJit,
                                   bucketed_candidate_rerank, pow2_buckets,
                                   step_buckets)
from repro.serve.hotload import DoubleBuffer, Generation
from repro.serve.stages import (REQUEST_KEYS, CubeFetchStage,
                                FeatureHashStage, QueryCacheStage,
                                RerankStage, RespondStage, RetrievalStage,
                                Request, Response, ShedStage, Stage,
                                stage_of)
from repro.update import (DeltaWatcher, HBMHead, PromoteDemotePolicy,
                          UpdateManager)

log = logging.getLogger(__name__)

__all__ = [
    "Request", "Response", "ScenarioSpec", "ScenarioRuntime",
    "ServingSubstrate", "PipelineBuilder", "ContractError",
    "BoundedReverseMap", "SubstrateDeltaWatcher", "register_scenario",
    "get_scenario", "registered_scenarios", "make_request_events",
]


class ContractError(GraphError):
    """A stage's payload contract cannot be satisfied on every path that
    reaches it — raised at build time, never mid-traffic."""


# ------------------------------------------------------------------ spec

@dataclass(frozen=True)
class ScenarioSpec:
    """Declarative description of one serving scenario.

    ``pipeline`` picks the terminal model stage: ``"rerank"`` (pointwise
    scores + fused candidate re-rank — DIN/DIEN-style ranking) or
    ``"retrieval"`` (top-k against the candidate set, no pointwise score —
    MIND/two-tower recall). The data-plane stages (query cache, feature
    hashing, cube fetch, shedding) are toggled per scenario; every enabled
    stage runs against the shared substrate."""
    name: str
    arch_id: str
    pipeline: str = "rerank"              # "rerank" | "retrieval"
    query_cache: bool = True
    cube_fetch: bool = True
    shed: bool = True
    priority: int = 1                     # fanout tier; 0 = never shed
    batch_size: int = 16
    keep: int = 12                        # response top-k size
    batch_buckets: Optional[tuple] = None  # DNN batch dimension B
    cand_buckets: Optional[tuple] = None   # candidate count C
    hist_bucket_step: int = 8              # history length T menu step
    seed: int = 0

    def __post_init__(self):
        if self.pipeline not in ("rerank", "retrieval"):
            raise ValueError(f"scenario {self.name!r}: unknown pipeline "
                             f"{self.pipeline!r}")


_REGISTRY: dict[str, ScenarioSpec] = {}


def register_scenario(spec: ScenarioSpec, replace: bool = False) -> ScenarioSpec:
    if spec.name in _REGISTRY and not replace:
        raise ValueError(f"scenario {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_scenario(name: str) -> ScenarioSpec:
    # registrations live in configs/jizhi_service.py; import lazily so the
    # registry is populated on first lookup without an import cycle
    if name not in _REGISTRY:
        import repro.configs.jizhi_service  # noqa: F401  (registers)
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def registered_scenarios() -> tuple[ScenarioSpec, ...]:
    import repro.configs.jizhi_service  # noqa: F401
    return tuple(_REGISTRY.values())


# ------------------------------------------------------ bounded reverse map

class BoundedReverseMap:
    """Per-group hashed-bucket → raw-items reverse map with a bound.

    The unbounded version was a slow memory leak: a long-lived service
    accumulates one entry per distinct raw item ever seen (item churn
    never shrinks it). The bound prunes whole buckets once ``total`` items
    exceed ``max_items`` — coldest first when an LFU signal is available
    (``counts_fn``, fed by the cube cache's persistent counts), insertion
    order otherwise.

    Coherence: the map exists to find which query-cache items a delta
    invalidates, so FORGETTING a mapping silently would under-invalidate.
    ``maybe_prune`` therefore returns the dropped raw items and the caller
    must invalidate them from the query cache first — pruning can only
    over-invalidate (safe, mildly wasteful), never leave a stale score.

    Every accessor takes the lock: stage workers ``add`` and the update
    thread reads ``items_for`` concurrently with pruning — an unlocked
    add racing a prune could land an item in a just-popped set (a mapping
    silently lost WITHOUT invalidation — exactly the stale-score hole the
    prune contract exists to prevent), and an unlocked read could iterate
    a set mid-mutation. The critical sections are tiny (per-batch dict
    ops), so the lock is cheap next to the stage's model work."""

    def __init__(self, max_items: int = 65536, prune_fraction: float = 0.25,
                 counts_fn: Optional[Callable] = None):
        assert max_items > 0 and 0.0 < prune_fraction < 1.0
        self.max_items = max_items
        self.prune_fraction = prune_fraction
        self.counts_fn = counts_fn
        self.buckets: dict[int, set] = {}
        self.total = 0
        self._lock = threading.Lock()

    def add(self, bucket: int, item: int):
        with self._lock:
            s = self.buckets.get(bucket)
            if s is None:
                s = self.buckets.setdefault(bucket, set())
            if item not in s:
                s.add(item)
                self.total += 1

    def items_for(self, hashed_ids) -> list:
        out: list = []
        with self._lock:
            for h in hashed_ids:
                out.extend(self.buckets.get(int(h), ()))
        return out

    def export(self) -> dict:
        """Locked deep copy of bucket → items, for snapshot persistence
        (DESIGN.md §9: persisted reverse maps make warm-start invalidation
        exact after a restart)."""
        with self._lock:
            return {b: set(s) for b, s in self.buckets.items()}

    def maybe_prune(self) -> list:
        """Evict down to ``max_items * (1 - prune_fraction)`` once over the
        cap; returns the raw items whose mappings were dropped (the caller
        invalidates them — over-invalidation is safe)."""
        if self.total <= self.max_items:      # racy fast path: prune is
            return []                         # re-checked under the lock
        with self._lock:
            if self.total <= self.max_items:
                return []
            victims = list(self.buckets)
            if self.counts_fn is not None:
                counts = {b: self.counts_fn(b) for b in victims}
                victims.sort(key=counts.__getitem__)
            target = int(self.max_items * (1.0 - self.prune_fraction))
            dropped: list = []
            for b in victims:
                if self.total <= target:
                    break
                s = self.buckets.pop(b, None)
                if s:
                    self.total -= len(s)
                    dropped.extend(s)
            return dropped


# -------------------------------------------------------------- substrate

class ServingSubstrate:
    """The shared data plane: ONE parameter cube, cube cache, query cache,
    HBM head and update manager serving every scenario pipeline.

    Feature groups register through :meth:`group_for`, keyed by
    ``(field_name, vocab)`` — two scenarios naming the same field share the
    group's rows, cache entries and delta stream. Each registration loads
    the group's tail table, grows the cube-cache capacity, creates the
    group's bounded reverse map, and re-splits the HBM head budget across
    the per-group promote/demote policies."""

    def __init__(self, cube_cache_ratio: float = 1.0,
                 query_window_s: float = 120.0, tail_dim: int = 4,
                 n_servers: int = 4, replication: int = 2,
                 block_rows: int = 4096, head_slots: int = 0,
                 compact_after_blocks: int = 64,
                 compact_max_rows_per_pass: Optional[int] = None,
                 reverse_map_items: int = 65536, seed: int = 0,
                 mesh_shards: int = 0, mesh_hosts: int = 0,
                 mesh_replication: int = 2,
                 mesh_hedge_after_s: Optional[float] = None,
                 _cube: Optional[ParameterCube] = None):
        self.tail_dim = tail_dim
        self.cube_cache_ratio = cube_cache_ratio
        self.head_slots = head_slots
        self.reverse_map_items = reverse_map_items
        self.query_cache = QueryCache(window_s=query_window_s)
        self.cube_cache = TwoTierLFUCache(0, 0)
        # ``_cube`` is the recovery path's injection point (a cube rebuilt
        # from a snapshot replaces the fresh one) — :meth:`recover` is the
        # public surface. ``mesh_shards > 0`` builds the scale-out tier
        # instead (DESIGN.md §11): a MeshCube duck-types the cube surface,
        # so every stage/cache/update path below runs unchanged.
        if _cube is not None:
            self.cube = _cube
        elif mesh_shards > 0:
            from repro.mesh import MeshCube
            self.cube = MeshCube(
                n_shards=mesh_shards,
                n_hosts=mesh_hosts or mesh_shards,
                replication=mesh_replication, seed=seed,
                hedge_after_s=mesh_hedge_after_s,
                n_servers=n_servers, cube_replication=replication,
                block_rows=block_rows)
        else:
            self.cube = ParameterCube(
                n_servers=n_servers, replication=replication,
                block_rows=block_rows)
        # warm-up state (DESIGN.md §9): while True, CubeFetchStage floors
        # every fetch at the stale-cache degradation tier and the quota
        # controllers shed against the warm-up quota; cleared once delta
        # replay reaches ``recovery_target``
        self.recovering = False
        self.recovery_target = -1
        self.last_replay_s = 0.0     # duration of the last delta-log replay
        self._rng = np.random.default_rng(seed)
        self._groups: dict[tuple[str, int], int] = {}
        self.bucket_items: dict[int, BoundedReverseMap] = {}
        head = HBMHead(head_slots, dim=tail_dim) if head_slots else None
        self.updates = UpdateManager(
            self.cube, cube_cache=self.cube_cache,
            query_cache=self.query_cache, head=head,
            qcache_items_fn=self.items_for_buckets,
            compact_after_blocks=compact_after_blocks,
            compact_max_rows_per_pass=compact_max_rows_per_pass)

    # ---------------------------------------------------------- groups
    def cache_key(self, group: int, key: int):
        """Cube-cache key convention (must match the UpdateManager's
        ``cache_key_fn``): bare id for group 0, (group, id) otherwise."""
        return key if group == 0 else (group, key)

    def group_for(self, field_name: str, vocab: int) -> int:
        key = (field_name, int(vocab))
        if key in self._groups:
            return self._groups[key]
        g = len(self._groups)
        self._groups[key] = g
        self.cube.load_table(g, self._rng.normal(
            0, 0.01, (int(vocab), self.tail_dim)).astype(np.float32))
        mem, disk = capacity_from_ratio(int(vocab) * self.tail_dim,
                                        self.cube_cache_ratio)
        self.cube_cache.mem.capacity += mem
        self.cube_cache.disk.capacity += disk
        self.bucket_items[g] = BoundedReverseMap(
            max_items=self.reverse_map_items,
            counts_fn=lambda b, g=g: self._lfu_count(g, b))
        if self.updates.head is not None:
            # re-split the head budget: every registered group gets an
            # equal slice of the shared slot pool
            cap = max(1, self.head_slots // len(self._groups))
            self.updates.policies = {
                gid: PromoteDemotePolicy(capacity=cap)
                for gid in self._groups.values()}
        return g

    def _register_recovered_group(self, field_name: str, vocab: int,
                                  gid: int):
        """Everything :meth:`group_for` does EXCEPT loading the tail table
        and drawing from the rng: the recovered cube already holds the
        rows (base table + every applied delta), and re-drawing would both
        clobber them and desync the rng stream. Groups must be re-
        registered in their original (dense) id order."""
        key = (field_name, int(vocab))
        if self._groups.get(key) == gid:
            return
        if gid != len(self._groups):
            raise ValueError(
                f"recovered group {key} id {gid} out of order "
                f"(expected {len(self._groups)})")
        self._groups[key] = gid
        mem, disk = capacity_from_ratio(int(vocab) * self.tail_dim,
                                        self.cube_cache_ratio)
        self.cube_cache.mem.capacity += mem
        self.cube_cache.disk.capacity += disk
        self.bucket_items[gid] = BoundedReverseMap(
            max_items=self.reverse_map_items,
            counts_fn=lambda b, g=gid: self._lfu_count(g, b))
        if self.updates.head is not None:
            cap = max(1, self.head_slots // len(self._groups))
            self.updates.policies = {
                g: PromoteDemotePolicy(capacity=cap)
                for g in self._groups.values()}

    @classmethod
    def recover(cls, snapshot_dir: str, update_dir: Optional[str] = None,
                replay: bool = True, **kw) -> "ServingSubstrate":
        """Restart path (DESIGN.md §9): newest valid snapshot → cube
        rebuild → delta-log replay from ``snapshot_version + 1``. The
        returned substrate serves immediately — ``recovering`` stays True
        (degraded tiers + warm-up quota) until the delta cursor reaches
        the log head observed at recovery time.

        ``replay=True`` replays the pending suffix inline (bounded RTO:
        the caller knows the cube is caught up on return); ``replay=False``
        leaves the suffix to a ``SubstrateDeltaWatcher`` resumed at the
        snapshot cursor — the service serves degraded while replay streams
        in the background. Caches start cold; persisted reverse maps (aux
        state) make warm-start invalidation exact when available.

        Raises FileNotFoundError when no valid snapshot exists — cold
        boot is the caller's fallback, not an implicit default."""
        from repro.update.delta import list_deltas
        from repro.update.snapshot import (latest_valid_snapshot,
                                           load_aux_state,
                                           load_cube_snapshot)
        path = latest_valid_snapshot(snapshot_dir)
        if path is None:
            raise FileNotFoundError(
                f"no valid snapshot under {snapshot_dir}")
        cube, meta = load_cube_snapshot(path)
        kw.setdefault("tail_dim", int(meta.get("extra", {})
                                      .get("tail_dim", 4)))
        sub = cls(_cube=cube, **kw)
        for f, v, g in sorted(meta["groups"], key=lambda t: t[2]):
            sub._register_recovered_group(f, int(v), int(g))
        delta_ver = int(meta["delta_version"])
        aux = load_aux_state(path)
        if aux is not None:
            sub.updates.restore_state(delta_ver, aux["touched"],
                                      aux["touched_floor"])
            for g, buckets in aux["reverse_maps"].items():
                rmap = sub.bucket_items.get(g)
                if rmap is not None:
                    for b, items in buckets.items():
                        for item in items:
                            rmap.add(b, item)
        else:
            sub.updates.restore_state(delta_ver)
        sub.recovering = True
        sub.recovery_target = delta_ver
        if update_dir is not None:
            pending = list_deltas(update_dir, after_version=delta_ver)
            if pending:
                sub.recovery_target = pending[-1][0]
            if replay:
                sub.replay_update_log(update_dir)
        if sub.updates.stats.last_version >= sub.recovery_target:
            sub.finish_recovery()
        return sub

    def replay_update_log(self, update_dir: str) -> int:
        """Apply every published delta past the current cursor, strictly
        in version order (the recovery replay — same ``read_delta`` /
        ``apply`` path as live tailing, same idempotence under re-offer).
        Clears ``recovering`` once the cursor reaches the recovery target.
        Returns the number of deltas applied."""
        from repro.update.delta import list_deltas, read_delta, verify_delta
        t0 = time.perf_counter()
        n = 0
        for _ver, path in list_deltas(
                update_dir,
                after_version=self.updates.stats.last_version):
            verify_delta(path)
            self.updates.apply(read_delta(path))
            n += 1
        if n:
            self.last_replay_s = time.perf_counter() - t0
            log_event(log, "delta_log_replayed", n_deltas=n,
                      version=self.updates.stats.last_version,
                      duration_s=self.last_replay_s)
        if (self.recovering
                and self.updates.stats.last_version
                >= self.recovery_target):
            self.finish_recovery()
        return n

    def finish_recovery(self):
        """Replay caught up: leave warm-up mode (full tiers, full quota)."""
        self.recovering = False

    @property
    def groups(self) -> dict[tuple[str, int], int]:
        return dict(self._groups)

    def _lfu_count(self, group: int, bucket: int) -> int:
        k = self.cache_key(group, bucket)
        return max(self.cube_cache.mem.counts.get(k, 0),
                   self.cube_cache.disk.counts.get(k, 0))

    def items_for_buckets(self, group: int, hashed_ids) -> list:
        """Raw item ids whose cached scores embed the given cube rows —
        the UpdateManager's query-cache invalidation key set, per group."""
        rmap = self.bucket_items.get(group)
        return [] if rmap is None else rmap.items_for(hashed_ids)


class SubstrateDeltaWatcher(DeltaWatcher):
    """The live-update stage of a substrate: tail the delta log, apply
    through the shared UpdateManager, then run the off-hot-path
    maintenance a fresh batch warrants — overlay compaction, the
    per-group promote/demote pass, and (when a ``snapshotter`` is wired)
    the periodic durable snapshot.

    With a snapshotter, ``prune_applied`` is forced OFF: recovery must
    find the delta suffix past the newest snapshot on disk, so retention
    moves to the snapshotter's GC (which floors pruning on this watcher's
    cursor). The cursor starts at the substrate's delta cursor — on a
    recovered substrate the watcher resumes exactly where replay left
    off."""

    def __init__(self, substrate: ServingSubstrate, update_dir: str,
                 snapshotter=None, **kw):
        if snapshotter is not None:
            kw["prune_applied"] = False
        else:
            # the substrate is its delta log's only consumer → prune
            # applied deltas so the log directory (and each poll's scan)
            # stays bounded
            kw.setdefault("prune_applied", True)
        kw.setdefault("start_after_version",
                      substrate.updates.stats.last_version)
        super().__init__(update_dir, substrate.updates.apply, **kw)
        self._sub = substrate
        self.snapshotter = snapshotter
        if snapshotter is not None:
            snapshotter.register_watcher(self)

    def check_once(self) -> bool:
        applied = super().check_once()
        if applied:
            self._sub.updates.maybe_compact()
            if self._sub.updates.head is not None:
                self._sub.updates.rebalance_all()
            if self.snapshotter is not None:
                self.snapshotter.maybe_snapshot()
        if (self._sub.recovering
                and self._sub.updates.stats.last_version
                >= self._sub.recovery_target):
            self._sub.finish_recovery()
        return applied


# ---------------------------------------------------------------- runtime

class ScenarioRuntime:
    """Per-scenario model state compiled from a spec: params buffer,
    jitted entry points (trace-counted + shape-bucketed), and the
    scenario's cube feature groups on the shared substrate."""

    def __init__(self, spec: ScenarioSpec, substrate: ServingSubstrate,
                 qcache_scope: bool = False):
        self.spec = spec
        self.substrate = substrate
        arch = registry.get(spec.arch_id)
        self.model_cfg = arch.reduced(arch.config)
        from repro.launch.specs import REC_MODULES
        self.mod = REC_MODULES[self.model_cfg.model]
        params = self.mod.init(jax.random.PRNGKey(spec.seed), self.model_cfg)
        self.buffer = DoubleBuffer(Generation(0, params))
        # any scenario's generation swap bumps the shared query cache's
        # model version (over-invalidation across scenarios: safe)
        self.buffer.on_swap.append(substrate.updates.on_generation_swap)
        self.qcache_scope = spec.name if qcache_scope else None
        self.shedder: Optional[OnlineShedder] = None
        mc = self.model_cfg
        self.batch_buckets = ShapeBucketer(
            spec.batch_buckets or pow2_buckets(spec.batch_size))
        self.cand_buckets = ShapeBucketer(
            spec.cand_buckets or pow2_buckets(64, min_size=16))
        # step-8 history buckets (DESIGN.md §5.3): padded history rows
        # still pay the full attention MLP, so tight T buckets win
        self.hist_buckets = (ShapeBucketer(
            step_buckets(mc.seq_len, step=spec.hist_bucket_step))
            if mc.seq_len else None)
        self.serve = TracedJit(
            lambda p, b: self.mod.serve_scores(p, b, self.model_cfg))
        # fused one-user-many-candidates re-rank (kernels/rerank_score via
        # score_candidates): full ranking of each request's candidate set
        self.rerank = (TracedJit(
            lambda p, u, c: self.mod.score_candidates(
                p, u, c, self.model_cfg, top_k=c["item_id"].shape[0]))
            if hasattr(self.mod, "score_candidates") else None)
        retrieve_fn = getattr(self.mod, "retrieve", None)
        if retrieve_fn is None:
            self.retrieve = None
        elif mc.model == "two_tower":
            # towers.retrieve takes the bare user-fields dict
            self.retrieve = TracedJit(
                lambda p, u, c: retrieve_fn(
                    p, u["fields"], c, self.model_cfg,
                    top_k=c["item_id"].shape[0]))
        else:
            self.retrieve = TracedJit(
                lambda p, u, c: retrieve_fn(
                    p, u, c, self.model_cfg, top_k=c["item_id"].shape[0]))
        # every single-valued item field becomes a cube feature group on
        # the shared substrate (bag>1 fields have no single tail row)
        self.cube_groups = [
            (f.name, substrate.group_for(f.name, f.vocab), f.vocab)
            for f in mc.item_fields if f.bag == 1]

    # -------------------------------------------------------- helpers
    def user_key(self, payload):
        """Query-cache user key — scenario-scoped in a multi-scenario
        service so one scenario's score never answers another's probe."""
        uid = payload["user_id"]
        return (self.qcache_scope, uid) if self.qcache_scope else uid

    def pack_batch(self, payloads: list) -> dict:
        mc = self.model_cfg
        import jax.numpy as jnp
        user_fields = {f.name: np.stack([p["user_fields"][f.name]
                                         for p in payloads])
                       for f in mc.user_fields}
        item = {f.name: np.stack([p["item_fields"][f.name]
                                  for p in payloads])
                for f in mc.item_fields}
        batch = {"user": {"fields": jax.tree.map(jnp.asarray, user_fields)},
                 "item": jax.tree.map(jnp.asarray, item)}
        # cube output attached upstream becomes a model input: the primary
        # group's host-tier rows keep their historical ``cube_tail`` slot,
        # and the full multi-group fetch rides along concatenated
        if all("cube_rows" in p for p in payloads):
            batch["item"]["cube_tail"] = jnp.asarray(
                np.stack([p["cube_rows"] for p in payloads]))
        if all("cube_rows_all" in p for p in payloads) and payloads and \
                len(payloads[0]["cube_rows_all"]) > 1:
            names = sorted(payloads[0]["cube_rows_all"])
            batch["item"]["cube_tail_all"] = jnp.asarray(np.stack(
                [np.concatenate([p["cube_rows_all"][n] for n in names])
                 for p in payloads]))
        if mc.seq_len:
            batch["user"]["hist"] = jnp.asarray(
                np.stack([p["hist"] for p in payloads]))
        return batch

    def rerank_candidates(self, params, payload, keep: int = 12):
        """Full re-rank of the request's surviving candidate set through
        the fused shared-history scorer, every dimension bucketed."""
        mc = self.model_cfg
        cands = payload.get("candidates")
        if not cands or self.rerank is None or not mc.seq_len:
            return
        payload["topk"] = bucketed_candidate_rerank(
            self.rerank, params, payload["hist"],
            {f.name: payload["user_fields"][f.name] for f in mc.user_fields},
            cands, self.cand_buckets, self.hist_buckets,
            item_fields=[(f.name, f.bag) for f in mc.item_fields
                         if f.name != "item_id"], keep=keep)

    def retrieve_candidates(self, params, payload, keep: int = 12) -> list:
        """One query against the candidate set through the scenario's
        ``retrieve`` head (bucketed C and, when the model uses history,
        bucketed T)."""
        mc = self.model_cfg
        cands = payload.get("candidates")
        if not cands or self.retrieve is None:
            return []
        return bucketed_candidate_rerank(
            self.retrieve, params,
            payload["hist"] if mc.seq_len else None,
            {f.name: payload["user_fields"][f.name] for f in mc.user_fields},
            cands, self.cand_buckets, self.hist_buckets,
            item_fields=[(f.name, f.bag) for f in mc.item_fields
                         if f.name != "item_id"], keep=keep)


# ---------------------------------------------------------------- builder

def validate_contracts(plan, ingress_keys) -> dict:
    """Walk the compiled DAG in topo order and prove every typed stage's
    ``requires`` is available on EVERY path that can reach it (multi-pred
    stages take the intersection — an event may arrive from any one).
    Returns the per-stage available-key map; raises ContractError."""
    avail: dict[str, set] = {}
    for n in plan.order:
        if not plan.preds[n]:
            incoming = set(ingress_keys)
        else:
            sets = []
            for p in plan.preds[n]:
                ps = stage_of(plan.stages[p].op)
                sets.append(avail[p] | set(ps.provides if ps else ()))
            incoming = set.intersection(*sets)
        st = stage_of(plan.stages[n].op)
        if st is not None:
            missing = [k for k in st.requires if k not in incoming]
            if missing:
                raise ContractError(
                    f"stage {n!r} requires payload keys {missing} that are "
                    f"not guaranteed on every path into it "
                    f"(available: {sorted(incoming)})")
        avail[n] = incoming
    return avail


def _tag_entry(op, scenario: str):
    """Wrap a scenario's entry-stage op to stamp the scenario name on each
    event (fanout clones arrive untagged)."""
    def wrapped(batch, ctx):
        for ev in batch:
            ev.payload["scenario"] = scenario
            ev.meta["tenant"] = scenario
        return op(batch, ctx)
    wrapped._stage = stage_of(op)
    return wrapped


class PipelineBuilder:
    """Compiles ScenarioSpecs into one SEDP DAG over a shared substrate.

    ``add_scenario`` instantiates the spec's stage chain (namespaced
    ``<name>.<stage>`` in a multi-scenario graph, bare names otherwise —
    the InferenceService compatibility surface), wires it into the shared
    ``respond`` sink, and returns the ScenarioRuntime. ``compile``
    validates every payload contract and returns (graph, plan)."""

    def __init__(self, substrate: ServingSubstrate, max_queue: int = 512,
                 batch_wait_s: float = 0.002):
        self.substrate = substrate
        self.g = SEDP()
        self.kw = dict(max_queue=max_queue, max_wait_s=batch_wait_s)
        self.runtimes: dict[str, ScenarioRuntime] = {}
        self.entries: dict[str, str] = {}
        self.terminals: dict[str, str] = {}
        self._has_respond = False
        self._shed_dnn = None

    # ------------------------------------------------------- shared bits
    def ensure_respond(self) -> str:
        if not self._has_respond:
            st = RespondStage()
            self.g.add_stage("respond", st.op, batch_size=st.batch_size,
                             parallelism=st.parallelism, **self.kw)
            self._has_respond = True
        return "respond"

    def add_ingress(self, name: str = "ingress", op=None,
                    batch_size: int = 8, parallelism: int = 2) -> str:
        self.g.add_stage(name, op or sedp_lib.passthrough,
                         batch_size=batch_size, parallelism=parallelism,
                         **self.kw)
        return name

    def shed_dnn(self, seed: int = 0):
        """One pruning DNN shared by every scenario's shedder (the
        OnlineShedder state stays per scenario)."""
        if self._shed_dnn is None:
            self._shed_dnn, _ = train_pruning_dnn(n_samples=800, seed=seed)
        return self._shed_dnn

    # --------------------------------------------------------- scenarios
    def add_scenario(self, spec: ScenarioSpec, namespaced: bool = True,
                     shedder: Optional[OnlineShedder] = None
                     ) -> ScenarioRuntime:
        if spec.name in self.runtimes:
            raise GraphError(f"scenario {spec.name!r} already added")
        rt = ScenarioRuntime(spec, self.substrate, qcache_scope=namespaced)
        respond = self.ensure_respond()
        prefix = f"{spec.name}." if namespaced else ""
        terminal: Stage = (RerankStage(rt, keep=spec.keep)
                           if spec.pipeline == "rerank"
                           else RetrievalStage(rt, keep=spec.keep))
        terminal_name = prefix + terminal.name
        stages: list[Stage] = []
        if spec.query_cache:
            stages.append(QueryCacheStage(rt, hit_route=respond))
        stages.append(FeatureHashStage(rt))
        if spec.cube_fetch:
            stages.append(CubeFetchStage(rt))
        if spec.shed:
            # warmup_fn ties the controller to the substrate's recovery
            # state: while replay catches up, admission is clamped to the
            # warm-up quota (serve degraded, not saturated)
            rt.shedder = shedder or OnlineShedder(
                self.shed_dnn(seed=spec.seed), downstream=terminal_name,
                controller=QuotaController(
                    terminal_name, depth_capacity=64.0,
                    warmup_fn=lambda: self.substrate.recovering))
            stages.append(ShedStage(rt.shedder))
        stages.append(terminal)
        names = [prefix + st.name for st in stages]
        if spec.query_cache:
            stages[0].miss_route = names[1]
        for i, (st, nm) in enumerate(zip(stages, names)):
            op = _tag_entry(st.op, spec.name) if i == 0 else st.op
            bs = spec.batch_size if st is terminal else st.batch_size
            self.g.add_stage(nm, op, batch_size=bs,
                             parallelism=st.parallelism, **self.kw)
        for a, b in zip(names, names[1:]):
            self.g.add_edge(a, b)
        if spec.query_cache:
            self.g.add_edge(names[0], respond)
        self.g.add_edge(names[-1], respond)
        self.runtimes[spec.name] = rt
        self.entries[spec.name] = names[0]
        self.terminals[spec.name] = terminal_name
        return rt

    # ------------------------------------------------------------ compile
    def default_ingress_keys(self) -> set:
        keys = set(REQUEST_KEYS) | {"candidates"}
        if any(rt.model_cfg.seq_len for rt in self.runtimes.values()):
            keys.add("hist")
        return keys

    def compile(self, ingress_keys=None):
        plan = self.g.compile()
        validate_contracts(plan, ingress_keys if ingress_keys is not None
                           else self.default_ingress_keys())
        return self.g, plan


# ------------------------------------------------------------ request gen

def make_request_events(model_cfgs, n: int, seed: int = 0,
                        n_candidates: int = 64,
                        deadline_s: Optional[float] = None) -> list[Event]:
    """Synthetic typed Requests covering the UNION of the given model
    configs' feature fields — one request stream that every scenario in a
    multi-scenario service can consume (each pipeline reads only the
    fields its config names).

    ``deadline_s`` attaches a per-request latency budget
    (``meta["deadline_s"]``): the executor stamps an absolute deadline at
    ingress and sheds the event at any later dispatch once it expires
    (DESIGN.md §8.4)."""
    from repro.data import synthetic
    rng = np.random.default_rng(seed)
    user_fields: dict = {}
    item_fields: dict = {}
    for mc in model_cfgs:
        for f in mc.user_fields:
            user_fields.setdefault(f.name, f)
        for f in mc.item_fields:
            item_fields.setdefault(f.name, f)
    uf = synthetic.recsys_ids(rng, list(user_fields.values()), n)
    itf = synthetic.recsys_ids(rng, list(item_fields.values()), n)
    seq = max((mc.seq_len or 0) for mc in model_cfgs)
    hist = None
    if seq:
        h = synthetic.zipf_ids(rng, n * seq,
                               model_cfgs[0].item_fields[0].vocab
                               ).reshape(n, seq)
        lengths = rng.integers(1, seq + 1, n)
        mask = np.arange(seq)[None, :] < lengths[:, None]
        hist = np.where(mask, h, -1).astype(np.int32)
    uid_field = next(iter(user_fields.values()))
    evs = []
    for i in range(n):
        req = Request(
            user_id=(int(uf[uid_field.name][i]) if uid_field.bag == 1
                     else i),
            item_id=int(itf["item_id"][i]) if "item_id" in itf else i,
            user_fields={name: uf[name][i] for name in uf},
            item_fields={name: itf[name][i] for name in itf},
            hist=hist[i] if hist is not None else None,
            candidates=[(j, float(rng.random()))
                        for j in range(n_candidates)])
        ev = Event(payload=req)
        if deadline_s is not None:
            ev.meta["deadline_s"] = float(deadline_s)
        evs.append(ev)
    return evs
