"""Typed stage processors for the scenario API (DESIGN.md §7).

The old ``InferenceService`` hard-coded one DIN re-rank pipeline: stage
logic lived in closures inside ``_build()``, requests were raw payload
dicts with magic keys, and every cube/feature/invalidation path assumed
embedding group 0. This module is the decomposition: each stage is a
configurable class that

  * owns its piece of the serving-correctness machinery (version pinning,
    cache-aside guards, tombstone handling, reverse-map recording), and
  * DECLARES its payload contract — ``requires`` (keys it reads) and
    ``provides`` (keys it writes) — so ``PipelineBuilder`` (scenario.py)
    can reject a mis-wired pipeline at build time instead of letting it
    KeyError mid-traffic.

Stages are scenario-agnostic: they read everything model- or
deployment-specific off the ``ScenarioRuntime`` handed to them, so one
stage class serves DIN, DIEN and retrieval scenarios alike.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.obs.trace import add_child_spans, annotate, shard_fanout_spans
from repro.core.cube import (TIER_DEFAULT, TIER_PRIMARY, TIER_REPLICA,
                             TIER_STALE_CACHE)
from repro.sparse.hashing import hash_bucket_np

# ---------------------------------------------------------------- payloads

#: Keys every Request carries into the pipeline (the ingress contract).
#: ``hist`` and ``candidates`` are optional per scenario — the builder
#: includes them in the ingress key set only when the request generator
#: attaches them.
REQUEST_KEYS = ("user_id", "item_id", "user_fields", "item_fields",
                "scenario")

_CORE_FIELDS = ("user_id", "item_id", "user_fields", "item_fields",
                "hist", "candidates", "scenario")


@dataclass
class Request:
    """One inference request — the typed replacement for the raw payload
    dict. Core fields are declared; stage-attached intermediates (hashed
    ids, cube rows, scores, topk, ...) live in ``extras``.

    The mapping protocol (``req["hashed"]``, ``"score" in req``,
    ``req.get("candidates")``) is kept so generic SEDP machinery — the
    shedder, the multi-tenant fanout, existing tests — works on Requests
    and plain dicts interchangeably; an unset optional core field
    (``hist``/``candidates`` = None) behaves as an absent key."""
    user_id: int = 0
    item_id: int = 0
    user_fields: dict = field(default_factory=dict)
    item_fields: dict = field(default_factory=dict)
    hist: Optional[np.ndarray] = None
    candidates: Optional[list] = None
    scenario: str = ""
    extras: dict = field(default_factory=dict)

    # ------------------------------------------------- mapping protocol
    def __getitem__(self, key):
        if key in _CORE_FIELDS:
            v = getattr(self, key)
            if v is None:
                raise KeyError(key)
            return v
        return self.extras[key]

    def __setitem__(self, key, value):
        if key in _CORE_FIELDS:
            setattr(self, key, value)
        else:
            self.extras[key] = value

    def __contains__(self, key):
        try:
            self[key]
            return True
        except KeyError:
            return False

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def keys(self):
        return ([k for k in _CORE_FIELDS if getattr(self, k) is not None]
                + list(self.extras))

    def __iter__(self):
        return iter(self.keys())

    def copy(self) -> "Request":
        """Shallow clone with an independent extras dict — what the
        multi-tenant fanout uses so per-scenario stages never write into a
        sibling clone's payload."""
        return Request(user_id=self.user_id, item_id=self.item_id,
                       user_fields=self.user_fields,
                       item_fields=self.item_fields, hist=self.hist,
                       candidates=(list(self.candidates)
                                   if self.candidates is not None else None),
                       scenario=self.scenario, extras=dict(self.extras))


@dataclass
class Response:
    """Typed view of a served event, attached by ``RespondStage`` at
    ``event.meta["response"]``."""
    scenario: str
    req_id: int
    user_id: Optional[int] = None
    item_id: Optional[int] = None
    score: Optional[float] = None
    topk: Optional[list] = None
    generation: Optional[int] = None
    cube_version: Optional[int] = None
    from_cache: bool = False
    # graceful-degradation ladder rung this answer was served from
    # (DESIGN.md §8.5): 0 primary, 1 versioned replica, 2 stale-cache
    # row, 3 default embedding. 0 also for cache hits (they bypass the
    # cube stage entirely).
    degraded_tier: int = 0
    # the request blew its deadline budget: the event was short-circuited
    # to the sink without a score (DESIGN.md §8.4)
    timed_out: bool = False

    @classmethod
    def from_event(cls, ev) -> "Response":
        p = ev.payload
        get = p.get if hasattr(p, "get") else (lambda k, d=None: d)
        return cls(scenario=get("scenario", ""), req_id=ev.req_id,
                   user_id=get("user_id"), item_id=get("item_id"),
                   score=get("score"), topk=get("topk"),
                   generation=get("generation"),
                   cube_version=get("cube_version"),
                   from_cache=("score" in p and "generation" not in p),
                   degraded_tier=int(get("degraded_tier", 0) or 0),
                   timed_out=bool(ev.meta.get("timed_out")))


# ------------------------------------------------------------- stage base

class Stage:
    """One SEDP stage processor with a declared payload contract.

    ``op(batch, ctx)`` is handed to ``SEDP.add_stage``; ``requires`` /
    ``provides`` are validated by the builder against every path that can
    reach the stage. Class attributes carry the default tuning knobs
    (paper Table 6); the builder may override per scenario."""
    name: str = "stage"
    requires: tuple = ()
    provides: tuple = ()
    batch_size: int = 8
    parallelism: int = 2

    def op(self, batch, ctx):           # pragma: no cover - abstract
        raise NotImplementedError


def stage_of(op) -> Optional[Stage]:
    """Recover the Stage instance behind a stage op callable (bound method
    or a builder wrapper that stamped ``_stage``)."""
    st = getattr(op, "_stage", None)
    if isinstance(st, Stage):
        return st
    owner = getattr(op, "__self__", None)
    return owner if isinstance(owner, Stage) else None


# ----------------------------------------------------------------- stages

class QueryCacheStage(Stage):
    """HHS query cache probe: hits short-circuit straight to the respond
    stage with the cached score; misses continue down the pipeline.

    Scenario-scoped: in a multi-scenario service the user key is
    ``(scenario, user_id)`` so DIN's cached score can never answer a DIEN
    request (items stay raw so one delta invalidates every scenario's
    scores for the touched rows)."""
    name = "query_cache"
    requires = ("user_id", "item_id")
    provides = ()
    batch_size = 16
    parallelism = 2

    def __init__(self, rt, hit_route: str = "respond",
                 miss_route: Optional[str] = None):
        self.rt = rt
        self.hit_route = hit_route
        self.miss_route = miss_route

    def op(self, batch, ctx):
        now = ctx.now()     # executor clock: wall (Async) or virtual (Sim)
        scores = self.rt.substrate.query_cache.get_many(
            [self.rt.user_key(ev.payload) for ev in batch],
            [ev.payload["item_id"] for ev in batch], now)
        for ev, s in zip(batch, scores):
            if s is not None:
                ev.payload["score"] = s
                ev.route = self.hit_route
            else:
                ev.route = self.miss_route
            annotate(ev, cache_hit=s is not None)
        return batch


class FeatureHashStage(Stage):
    """Feature extraction: hash EVERY single-valued item field into its
    cube feature group (not just group 0) and record the per-group
    bucket → raw-items reverse map that makes query-cache invalidation
    targeted. The maps are bounded (``BoundedReverseMap``): pruning
    invalidates the dropped items first, so forgetting a mapping can only
    ever over-invalidate, never leave a stale score behind."""
    name = "features"
    requires = ("item_id", "item_fields")
    provides = ("hashed",)
    batch_size = 8
    parallelism = 2

    def __init__(self, rt):
        self.rt = rt

    def op(self, batch, ctx):
        sub = self.rt.substrate
        items = np.fromiter((ev.payload["item_id"] for ev in batch),
                            np.int64, len(batch))
        hashed_all = [dict() for _ in batch]
        for fname, group, vocab in self.rt.cube_groups:
            values = np.fromiter(
                (int(np.asarray(ev.payload["item_fields"][fname]).reshape(-1)[0])
                 for ev in batch), np.int64, len(batch))
            hashed = hash_bucket_np(group, values, vocab)
            rmap = sub.bucket_items[group]
            for hv, h, item in zip(hashed_all, hashed, items):
                hv[fname] = int(h)
                # reverse map for targeted query-cache invalidation (GIL-
                # atomic set/dict ops; bounded — see BoundedReverseMap)
                rmap.add(int(h), int(item))
            pruned = rmap.maybe_prune()
            if pruned:
                # invalidate-and-forget: the dropped mappings' items leave
                # the query cache NOW, so the bound never costs coherence
                sub.query_cache.invalidate_items(pruned)
        for ev, hv in zip(batch, hashed_all):
            ev.payload["hashed"] = hv
        return batch


class CubeFetchStage(Stage):
    """Parameter-cube resolve for ALL of the scenario's item-field groups
    under ONE pinned cube version.

    Per group: cache probe and misses happen inside the pin (probing
    before pinning would let a pre-delta cached row ride out stamped with
    the post-delta version, sneaking past both cache-aside guards); the
    HBM head tier answers promoted hot rows; tombstoned rows serve as the
    zero/default row (a delete is a legitimate serving state, not a
    KeyError that kills the stage worker); and the post-insert version
    check drops exactly the cache entries a racing delta touched.

    Pinning once for the whole group sweep gives every group's rows on
    one event a single version attribution: the cube publishes a
    multi-group delta batch as ONE atomic snapshot swap
    (``apply_batch``, DESIGN.md §6.6), so the single pin resolves EVERY
    group at exactly the pinned version — batch-atomic across groups,
    not merely coherent within each (the §7.3 cross-group relaxation is
    closed).

    Graceful degradation (DESIGN.md §8.5): the cube resolves misses via
    ``lookup_ex``, which walks the ladder healthy-primary → versioned
    replica (bit-identical at the pinned version) → TIER_DEFAULT when no
    holder is reachable. This stage inserts one more rung between those
    last two: a bounded stale-row side buffer (most recent authoritative
    row seen per key, ANY version) answers TIER_DEFAULT keys as
    TIER_STALE_CACHE before falling back to the default embedding. The
    event's worst rung is stamped into ``payload["degraded_tier"]`` (→
    ``Response.degraded_tier``) and counted in ``StageStats.degraded``
    via the ``_degraded`` meta marker."""
    name = "cube"
    requires = ("hashed",)
    provides = ("cube_rows", "cube_rows_all", "cube_version",
                "degraded_tier")
    batch_size = 8
    parallelism = 2

    def __init__(self, rt, stale_cap: int = 4096):
        self.rt = rt
        # stale-row side buffer: cache_key → last authoritative row. LRU-
        # bounded; deliberately NOT invalidated by deltas (its whole point
        # is answering when nothing current is reachable — staleness is
        # the contract, and the tier stamp declares it to the caller).
        self.stale_cap = stale_cap
        self._stale: OrderedDict = OrderedDict()
        self._stale_lock = threading.Lock()

    # ------------------------------------------- stale-row side buffer
    def _stale_get(self, ck):
        with self._stale_lock:
            row = self._stale.get(ck)
            if row is not None:
                self._stale.move_to_end(ck)
            return row

    def _stale_put(self, sub, group: int, rows: dict):
        if not rows:
            return
        with self._stale_lock:
            for k, r in rows.items():
                ck = sub.cache_key(group, k)
                self._stale[ck] = r
                self._stale.move_to_end(ck)
            while len(self._stale) > self.stale_cap:
                self._stale.popitem(last=False)

    def _fetch_group(self, group: int, keys: list, pv
                     ) -> tuple[dict, dict]:
        """Resolve one group's hashed keys at the pinned version; returns
        (key → row, key → degradation tier) for every key (cached rows
        included, tier 0)."""
        sub = self.rt.substrate
        cache_keys = [sub.cache_key(group, k) for k in keys]
        fetched: dict = {}
        tiers: dict = {}
        cached = sub.cube_cache.get_many(cache_keys)
        by_key = {k: c[0] for k, c in zip(keys, cached) if c is not None}
        tier_by_key = {k: TIER_PRIMARY for k in by_key}
        miss = sorted({k for k, c in zip(keys, cached) if c is None})
        if miss:
            pending = np.asarray(miss, np.int64)
            head = sub.updates.head
            if head is not None and head.resident_count:
                # HBM head tier first: promoted hot rows skip the host
                # cube entirely (updated in place at delta-apply)
                hrows, hfound = head.lookup(group, pending)
                for k, r, f in zip(pending.tolist(), hrows, hfound):
                    if f:
                        fetched[int(k)] = r
                        tiers[int(k)] = TIER_PRIMARY
                pending = pending[~hfound]
            if pending.size:
                live = sub.cube.contains(group, pending, version=pv)
                if not live.all():
                    dim = (sub.cube.row_shape(group) or (4,))[0]
                    zero = np.zeros(dim, np.float32)
                    for k in pending[~live].tolist():
                        # tombstone: the zero row IS the authoritative
                        # answer at this version — tier 0, not degraded
                        fetched[int(k)] = zero
                        tiers[int(k)] = TIER_PRIMARY
                    pending = pending[live]
            if pending.size:
                rows, row_tiers = sub.cube.lookup_ex(group, pending,
                                                     version=pv)
                for i, k in enumerate(pending.tolist()):
                    t = int(row_tiers[i])
                    if t == TIER_DEFAULT:
                        srow = self._stale_get(sub.cache_key(group, k))
                        if srow is not None:
                            fetched[k] = srow
                            tiers[k] = TIER_STALE_CACHE
                            continue
                    fetched[k] = rows[i]
                    tiers[k] = t
            # only version-accurate rows (primary/replica — bit-identical
            # at the pin) may enter the cube cache; stale/default rows
            # would poison later requests with silently-wrong tier-0 hits
            ok = {k: r for k, r in fetched.items()
                  if tiers[k] <= TIER_REPLICA}
            if ok and sub.cube.version != pv.version:
                # a delta already published since the pin: filter the
                # known-stale keys out BEFORE inserting — an insert-then-
                # drop would expose them to concurrent readers for the
                # window between put_many and the drop. A cold touched-key
                # log forces the conservative skip-all.
                touched = sub.updates.touched_since(pv.version)
                ok = ({} if touched is None else
                      {k: r for k, r in ok.items()
                       if sub.cache_key(group, k) not in touched[0]})
            if ok:
                sub.cube_cache.put_many(
                    [sub.cache_key(group, k) for k in ok],
                    [ok[k][None] for k in ok])
                # close the remaining cache-aside race: a delta may have
                # published (and run its targeted invalidation) between
                # the pre-insert check and the insert above, which would
                # resurrect pre-delta rows as fresh entries. Drop our own
                # inserts for exactly the keys deltas touched since the
                # pin; a cold touched-key log forces the conservative
                # full drop.
                if sub.cube.version != pv.version:
                    touched = sub.updates.touched_since(pv.version)
                    own = {sub.cache_key(group, k): k for k in ok}
                    drop = (list(own) if touched is None else
                            [ck for ck in own if ck in touched[0]])
                    if drop:
                        sub.cube_cache.invalidate_keys(drop)
            by_key.update(fetched)
            tier_by_key.update(tiers)
        # refresh the stale side buffer with every version-accurate row
        # this sweep resolved (cache hits included)
        self._stale_put(sub, group,
                        {k: by_key[k] for k in by_key
                         if tier_by_key[k] <= TIER_REPLICA})
        return by_key, tier_by_key

    def op(self, batch, ctx):
        sub = self.rt.substrate
        primary = self.rt.cube_groups[0][0] if self.rt.cube_groups else None
        worst = [TIER_PRIMARY] * len(batch)
        with sub.cube.pin() as pv:
            rows_all = [dict() for _ in batch]
            for fname, group, _vocab in self.rt.cube_groups:
                keys = [int(ev.payload["hashed"][fname]) for ev in batch]
                by_key, tier_by_key = self._fetch_group(group, keys, pv)
                for i, (out, k) in enumerate(zip(rows_all, keys)):
                    out[fname] = np.asarray(by_key[k], np.float32)
                    worst[i] = max(worst[i], tier_by_key[k])
            # recovery warm-up (DESIGN.md §9): while the substrate is
            # replaying its delta log, every row it serves may predate the
            # log head — honest answers, stale attribution. Floor the tier
            # at TIER_STALE_CACHE so responses declare it (the service
            # serves degraded rather than failing), without masking a
            # ladder rung that is already worse.
            if getattr(sub, "recovering", False):
                worst = [max(t, TIER_STALE_CACHE) for t in worst]
            for ev, out, tier in zip(batch, rows_all, worst):
                ev.payload["cube_rows_all"] = out
                if primary is not None:
                    # the primary group's row keeps its historical payload
                    # slot (and the packed batch's ``cube_tail``)
                    ev.payload["cube_rows"] = out[primary]
                ev.payload["cube_version"] = pv.version
                ev.payload["degraded_tier"] = int(tier)
                annotate(ev, cube_version=pv.version,
                         degraded_tier=int(tier))
                if tier > TIER_PRIMARY:
                    ev.meta["_degraded"] = True
            if getattr(sub.cube, "is_mesh", False):
                # attach this batch's shard scatter/gather as child spans
                # (one shard_fanout parent + one shard_fetch per shard
                # sub-batch) to every traced event — `critical_path` /
                # `shard_profile` then attribute the fetch tail to the
                # slowest shard. Inserted before the open exec span; each
                # event gets its own copies.
                fan = sub.cube.take_fanout()
                if fan:
                    proto = shard_fanout_spans(fan)
                    for ev in batch:
                        add_child_spans(ev, [dict(s, attrs=dict(s["attrs"]))
                                             for s in proto])
        # post-fetch deadline check: a fetch that burned the whole budget
        # on breaker probes / slow disk marks the event now, so the NEXT
        # dispatch sheds it before it ever occupies the model stage
        now = ctx.now() if ctx is not None and hasattr(ctx, "now") else None
        if now is not None:
            for ev in batch:
                if ev.deadline_at is not None and now >= ev.deadline_at:
                    ev.meta["timed_out"] = True
        return batch


class ShedStage(Stage):
    """Online load shedding: the IRM pruning DNN + live quota controller
    wrapped as a typed stage (the shedder also serves as the bounded-
    channel overflow policy — see ``OnlineShedder.on_overflow``)."""
    name = "shed"
    requires = ("candidates",)
    provides = ()
    batch_size = 8
    parallelism = 1

    def __init__(self, shedder):
        self.shedder = shedder

    def op(self, batch, ctx):
        return self.shedder.op(batch, ctx)


class RerankStage(Stage):
    """The DNN stage of a ranking scenario: pointwise scores for the whole
    micro-batch through the jitted ``serve_scores`` (batch padded to a
    bucket), plus the fused one-user-many-candidates re-rank of each
    request's surviving candidate set.

    Owns the query-cache insert and BOTH its staleness guards: scores are
    stamped with the model version captured before binding the generation
    (a racing hot swap can only over-invalidate), and the delta-side
    cache-aside guard drops exactly the batch items deltas touched since
    the events' pinned cube versions."""
    name = "rerank"
    requires = ("user_id", "item_id", "user_fields", "item_fields",
                "cube_rows")
    provides = ("score", "generation", "topk")
    batch_size = 16
    parallelism = 1

    def __init__(self, rt, keep: int = 12):
        self.rt = rt
        self.keep = keep
        if rt.model_cfg.seq_len:
            self.requires = self.requires + ("hist",)
        if rt.rerank is None or not rt.model_cfg.seq_len:
            self.provides = ("score", "generation")

    def op(self, batch, ctx):
        rt = self.rt
        sub = rt.substrate
        # capture the query-cache model version BEFORE binding the
        # generation: a hot swap racing this batch can only over-invalidate
        qv = sub.query_cache.model_version
        gen = rt.buffer.active          # ONE generation for the batch
        params = gen.payload
        B = len(batch)
        payloads = [ev.payload for ev in batch]
        # pad to the covering batch bucket (bounded jit-trace count);
        # scores are per-row, so slicing [:B] discards the filler exactly
        padded = rt.batch_buckets.pad_rows(payloads)
        b = rt.pack_batch(padded)
        scores = np.asarray(rt.serve(params, b))[:B]
        now = ctx.now() if ctx is not None else 0.0
        for ev, s in zip(batch, scores):
            ev.payload["score"] = float(s)
            ev.payload["generation"] = gen.stamp
            annotate(ev, batch_bucket=len(padded), generation=gen.stamp)
            rt.rerank_candidates(params, ev.payload, keep=self.keep)
        sub.query_cache.put_many(
            [rt.user_key(ev.payload) for ev in batch],
            [ev.payload["item_id"] for ev in batch],
            [float(s) for s in scores], now, version=qv)
        # delta-side cache-aside guard (the query-cache twin of the cube
        # stage's): these scores embed cube rows fetched at the events'
        # pinned versions — if a delta published since, its
        # invalidate_items may have run BEFORE our insert, resurrecting a
        # stale score. Drop exactly the batch items deltas touched since
        # the earliest pin; a cold touched-key log forces the drop.
        vmin = min((ev.payload.get("cube_version", 0) for ev in batch),
                   default=0)
        if sub.cube.version != vmin:
            items = {ev.payload["item_id"] for ev in batch}
            touched = sub.updates.touched_since(vmin)
            if touched is not None:
                items &= touched[1]
            if items:
                sub.query_cache.invalidate_items(items)
        return batch


class RetrievalStage(Stage):
    """Terminal stage of a retrieval scenario (MIND / two-tower): one
    query against the request's candidate set through the scenario's
    ``retrieve`` head, shape-bucketed like the fused re-rank. No
    pointwise score and no query-cache insert — retrieval responses are
    top-k lists, not (user, item) scores."""
    name = "retrieve"
    requires = ("user_fields", "candidates")
    provides = ("topk", "generation")
    batch_size = 8
    parallelism = 1

    def __init__(self, rt, keep: int = 12):
        self.rt = rt
        self.keep = keep
        if rt.model_cfg.seq_len:
            self.requires = self.requires + ("hist",)

    def op(self, batch, ctx):
        rt = self.rt
        gen = rt.buffer.active
        for ev in batch:
            ev.payload["topk"] = rt.retrieve_candidates(
                gen.payload, ev.payload, keep=self.keep)
            ev.payload["generation"] = gen.stamp
        return batch


class RespondStage(Stage):
    """Sink: stamps a typed ``Response`` onto every event's meta."""
    name = "respond"
    requires = ()
    provides = ()
    batch_size = 32
    parallelism = 1

    def op(self, batch, ctx):
        for ev in batch:
            ev.meta["response"] = Response.from_event(ev)
        return batch
