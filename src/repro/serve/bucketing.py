"""Shape bucketing for the jitted serving stages.

The SEDP micro-batcher hands the DNN stage whatever batch it collected and
the shedder hands the re-rank path whatever candidate set survived pruning —
so B, C and the user's history length all vary request to request. Every
distinct shape is a fresh XLA trace; left unchecked the compile cache grows
with the traffic mix and steady-state latency is spiked by mid-stream
compiles. The fix (TF-Serving / JiZHI practice) is to PAD each dimension up
to a small fixed set of buckets so the trace count is bounded by the bucket
count and flat after warm-up.

Three pieces:

  * ``ShapeBucketer`` — maps a runtime size to the smallest covering bucket
    (sizes above the top bucket round up to a multiple of it, so the cache
    stays bounded even under pathological inputs).
  * ``compact_history`` — the history-side twin: gathers the VALID (id >= 0)
    rows of a padded history to the front and re-pads to a bucket, so the
    fused re-rank scores only ``bucket(T_valid)`` rows instead of the full
    padded T. Exact: masked rows contribute zero attention weight.
  * ``TracedJit`` — a ``jax.jit`` wrapper that counts distinct compiled
    shapes; tests assert the count stays at the bucket-set size.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np


def pow2_buckets(max_size: int, min_size: int = 4) -> tuple[int, ...]:
    """Powers of two from ``min_size`` up to and including ``max_size``
    (``max_size`` itself is always a bucket, power of two or not)."""
    sizes = []
    b = min_size
    while b < max_size:
        sizes.append(b)
        b *= 2
    sizes.append(max_size)
    return tuple(sizes)


def step_buckets(max_size: int, step: int = 8) -> tuple[int, ...]:
    """Multiples of ``step`` up to and including ``max_size``: more traces
    than pow2 (max_size/step of them) but ≤ step−1 rows of padding per
    call. Worth it for the fused re-rank's history dimension, where padded
    rows still pay the full attention MLP."""
    sizes = list(range(step, max_size, step))
    sizes.append(max_size)
    return tuple(sizes)


@dataclass(frozen=True)
class ShapeBucketer:
    """Pads a varying dimension to a fixed menu of sizes."""
    sizes: tuple[int, ...]

    def __post_init__(self):
        if not self.sizes or any(s <= 0 for s in self.sizes):
            raise ValueError(f"bad bucket sizes {self.sizes}")
        object.__setattr__(self, "sizes", tuple(sorted(set(self.sizes))))

    def fit(self, n: int) -> int:
        """Smallest bucket >= n; beyond the top bucket, the next multiple of
        it (bounded cache: overflow shapes reuse one arithmetic family)."""
        for s in self.sizes:
            if n <= s:
                return s
        top = self.sizes[-1]
        return ((n + top - 1) // top) * top

    def pad_rows(self, xs: list, n: Optional[int] = None) -> list:
        """Pad a list of payload rows to the covering bucket by repeating the
        last row. Callers must slice stage outputs back to ``len(xs)`` so the
        filler rows never leak (per-row ops make them pure dead weight)."""
        n = len(xs) if n is None else n
        target = self.fit(n)
        return list(xs) + [xs[-1]] * (target - len(xs))


def compact_history(hist_ids: np.ndarray,
                    bucketer: Optional[ShapeBucketer] = None) -> np.ndarray:
    """(T,) int ids, -1 = padding → valid ids gathered to the front, padded
    with -1 to ``bucket(n_valid)`` (or to a multiple of 8 without a
    bucketer). Attention pooling is order-agnostic and masked rows carry
    zero weight, so scoring the compacted history is exact — the fused
    re-rank pays O(bucket(T_valid)) instead of O(T_padded)."""
    hist_ids = np.asarray(hist_ids)
    valid = hist_ids[hist_ids >= 0]
    n = max(1, len(valid))
    target = bucketer.fit(n) if bucketer is not None else ((n + 7) // 8) * 8
    out = np.full(target, -1, dtype=hist_ids.dtype)
    out[:len(valid)] = valid
    return out


def bucketed_candidate_rerank(score_fn, params, hist_ids, user_fields,
                              cands, cand_buckets: ShapeBucketer,
                              hist_buckets: ShapeBucketer,
                              item_fields=(), keep: int = 12) -> list:
    """One request's candidate set through a fused shared-history scorer,
    every varying dimension padded to a bucket.

    ``cands``: list of (item_id, recall_score). ``score_fn(params,
    user_batch, cand_ids)`` must return a FULL ranking of the padded set
    (top_k == padded C) as (values, indices) sorted best-first — the
    bucket filler repeats candidate 0's id and is dropped here by index,
    so top_k < padded C would let filler crowd out real candidates.
    ``item_fields``: (name, bag) pairs for the non-item_id candidate
    fields (zero-filled — recall output carries ids only).
    ``hist_ids=None`` serves history-free scorers (e.g. the two-tower
    retrieval head): the user batch carries fields only.
    Returns the top ``keep`` real candidates as [(item_id, score)], scores
    on the probability scale (sigmoid of the ranking logits — the same
    scale ``serve_scores`` puts in ``payload["score"]``; for retrieval
    similarities the sigmoid is monotone, so the ranking is unchanged).
    """
    import jax.numpy as jnp
    C = len(cands)
    Cp = cand_buckets.fit(C)
    ids = np.fromiter((c[0] for c in cands), np.int64, C)
    ids_p = np.concatenate([ids, np.full(Cp - C, ids[0])])
    user = {"fields": {k: jnp.asarray(np.asarray(v))[None]
                       for k, v in user_fields.items()}}
    if hist_ids is not None:
        hist = compact_history(np.asarray(hist_ids), hist_buckets)
        user["hist"] = jnp.asarray(hist)[None]
    cand_ids = {"item_id": jnp.asarray(ids_p)}
    for name, bag in item_fields:
        shape = (Cp,) if bag == 1 else (Cp, bag)
        cand_ids[name] = jnp.zeros(shape, jnp.int32)
    v, i = score_fn(params, user, cand_ids)
    v, i = np.asarray(v, np.float64), np.asarray(i)
    probs = 1.0 / (1.0 + np.exp(-v))            # monotone: ranking unchanged
    return [(int(ids_p[j]), float(s))
            for s, j in zip(probs, i) if j < C][:keep]


@dataclass
class TracedJit:
    """``jax.jit`` plus a distinct-shape-signature counter.

    ``n_traces`` reports the jit cache size when the running jax exposes it
    (ground truth); only when it does not are call signatures recorded —
    equivalent for shape-only retrace triggers, which is all the serving
    path has — so the hot path normally skips the pytree flatten."""
    fn: Callable
    static_argnames: tuple = ()
    signatures: set = field(default_factory=set)

    def __post_init__(self):
        kw = ({"static_argnames": self.static_argnames}
              if self.static_argnames else {})
        self._jit = jax.jit(self.fn, **kw)
        self._count_sigs = not callable(getattr(self._jit, "_cache_size",
                                                None))

    def __call__(self, *args, **kwargs):
        if self._count_sigs:
            sig = tuple(
                (tuple(leaf.shape), str(leaf.dtype)) if hasattr(leaf, "shape")
                else repr(leaf)
                for leaf in jax.tree_util.tree_leaves((args, kwargs)))
            self.signatures.add(sig)
        return self._jit(*args, **kwargs)

    @property
    def n_traces(self) -> int:
        if not self._count_sigs:
            try:
                return int(self._jit._cache_size())
            except Exception:
                pass
        return len(self.signatures)
