"""Host-side input pipeline: double-buffered prefetch + straggler-tolerant
shard leasing. Overlaps batch synthesis/IO with device compute (the training
analogue of SEDP's async stages)."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

from repro.train.elastic import ShardLease, lease_shards


class Prefetcher:
    def __init__(self, make_batch: Callable[[int], dict], depth: int = 2):
        self.make_batch = make_batch
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self):
        while not self._stop.is_set():
            b = self.make_batch(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self.q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self.q.get()

    def close(self):
        self._stop.set()


class LeasedShardReader:
    """Every shard has a primary and a backup worker; whoever reports first
    wins — a slow/dead reader cannot stall the epoch (backup-task pattern)."""

    def __init__(self, n_shards: int, worker_ids: list[int]):
        self.leases = lease_shards(n_shards, worker_ids)
        self._lock = threading.Lock()

    def assignments(self, worker: int) -> list[int]:
        return [l.shard_id for l in self.leases
                if worker in (l.primary, l.backup)]

    def try_complete(self, shard_id: int, worker: int) -> bool:
        with self._lock:
            lease = self.leases[shard_id]
            if lease.completed_by is not None:
                return False
            if worker not in (lease.primary, lease.backup):
                return False
            lease.completed_by = worker
            return True

    @property
    def remaining(self) -> int:
        return sum(1 for l in self.leases if l.completed_by is None)
