"""Neighbor sampler for sampled-training GNN shapes (minibatch_lg).

A real GraphSAGE-style fanout sampler over a CSR adjacency (numpy,
host-side): seeds → fanout₁ neighbors → fanout₂ neighbors, with padded
fixed-size outputs (XLA needs static shapes) and sentinel edges masked via
the model's sentinel-node convention.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray      # (N+1,)
    indices: np.ndarray     # (E,)
    n_nodes: int

    @staticmethod
    def random(rng: np.random.Generator, n_nodes: int, avg_degree: int,
               power_law: float = 1.5) -> "CSRGraph":
        # heavy-tailed degrees (capped), like real social/product graphs
        deg = np.minimum(
            rng.zipf(power_law, n_nodes) + avg_degree // 2,
            10 * avg_degree).astype(np.int64)
        indptr = np.concatenate([[0], np.cumsum(deg)])
        indices = rng.integers(0, n_nodes, indptr[-1], dtype=np.int64)
        return CSRGraph(indptr.astype(np.int64), indices, n_nodes)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]: self.indptr[v + 1]]


def sample_fanout(graph: CSRGraph, seeds: np.ndarray, fanouts: tuple,
                  rng: np.random.Generator):
    """Returns a padded subgraph:
      nodes     (N_sub,) original node ids (padded with -1)
      edges     (E_sub, 2) LOCAL indices [src=neighbor, dst=target]
                (padded edges point at the sentinel N_sub)
      edge_mask (E_sub,) bool
    Sizes are the static worst case: N = B + B·f1 + B·f1·f2; E = B·f1 + B·f1·f2.
    """
    B = len(seeds)
    layer_nodes = [np.asarray(seeds, np.int64)]
    edges_src_local, edges_dst_local, valid = [], [], []
    offset = 0
    next_offset = B
    for fan in fanouts:
        frontier = layer_nodes[-1]
        n_f = len(frontier)
        sampled = np.full((n_f, fan), -1, np.int64)
        for i, v in enumerate(frontier):
            if v < 0:
                continue
            nbrs = graph.neighbors(int(v))
            if len(nbrs) == 0:
                continue
            take = rng.choice(nbrs, size=fan, replace=len(nbrs) < fan)
            sampled[i] = take
        src_local = next_offset + np.arange(n_f * fan)
        dst_local = np.repeat(offset + np.arange(n_f), fan)
        ok = sampled.reshape(-1) >= 0
        edges_src_local.append(src_local)
        edges_dst_local.append(dst_local)
        valid.append(ok)
        layer_nodes.append(sampled.reshape(-1))
        offset = next_offset
        next_offset += n_f * fan
    nodes = np.concatenate(layer_nodes)
    src = np.concatenate(edges_src_local)
    dst = np.concatenate(edges_dst_local)
    mask = np.concatenate(valid)
    n_sub = len(nodes)
    edges = np.stack([np.where(mask, src, n_sub),
                      np.where(mask, dst, n_sub)], axis=1).astype(np.int32)
    return nodes.astype(np.int64), edges, mask


def subgraph_sizes(batch_nodes: int, fanouts: tuple) -> tuple[int, int]:
    n, e, frontier = batch_nodes, 0, batch_nodes
    for f in fanouts:
        e += frontier * f
        frontier *= f
        n += frontier
    return n, e
