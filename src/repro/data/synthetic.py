"""Synthetic data generators: web-scale traffic shapes without the web.

Zipf-distributed ids reproduce the paper's heavy-tailed access pattern
(Fig. 5a: 80% of lookups hit 1% of keys), which the cube-cache experiments
depend on. All generators are numpy + seeded (host-side data pipeline).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import GNNConfig, LMConfig, RecsysConfig, ShapeSpec


def zipf_ids(rng: np.random.Generator, n: int, vocab: int, a: float = 1.05) -> np.ndarray:
    """Zipf over [0, vocab) — heavy-tailed like production feature access."""
    z = rng.zipf(a, size=n).astype(np.int64)
    return ((z - 1) % vocab).astype(np.int32)


def lm_batch(rng: np.random.Generator, cfg: LMConfig, batch: int, seq: int) -> dict:
    return {"tokens": rng.integers(0, cfg.vocab, (batch, seq), dtype=np.int32)}


def recsys_ids(rng, fields, batch: int, zipf_a: float = 1.05) -> dict:
    out = {}
    for f in fields:
        shape = (batch,) if f.bag == 1 else (batch, f.bag)
        out[f.name] = zipf_ids(rng, int(np.prod(shape)), f.vocab, zipf_a).reshape(shape)
    return out


def recsys_batch(rng: np.random.Generator, cfg: RecsysConfig, batch: int) -> dict:
    b: dict = {"user": {"fields": recsys_ids(rng, cfg.user_fields, batch)},
               "item": recsys_ids(rng, cfg.item_fields, batch),
               "label": rng.binomial(1, 0.3, batch).astype(np.float32)}
    if cfg.seq_len:
        hist = zipf_ids(rng, batch * cfg.seq_len,
                        cfg.item_fields[0].vocab).reshape(batch, cfg.seq_len)
        lengths = rng.integers(1, cfg.seq_len + 1, batch)
        mask = np.arange(cfg.seq_len)[None, :] < lengths[:, None]
        b["user"]["hist"] = np.where(mask, hist, -1).astype(np.int32)
    return b


def _arrival_streams(rng: np.random.Generator):
    """Derive the three independent sub-streams the NHPP sampler uses:
    candidate gaps, burst-window starts, accept draws. Splitting them is
    what makes the vectorized and per-event implementations bit-identical:
    batched draws from one Generator equal the same draws made one at a
    time, and with separate streams the interleaving ORDER between
    candidates/bursts/accepts stops mattering — including the overshoot
    candidates a chunked sampler draws and discards."""
    seeds = rng.integers(0, np.iinfo(np.int64).max, size=3)
    return (np.random.default_rng(int(seeds[0])),
            np.random.default_rng(int(seeds[1])),
            np.random.default_rng(int(seeds[2])))


def diurnal_burst_arrivals(rng: np.random.Generator, n_events: int,
                           base_qps: float, peak_mult: float = 3.0,
                           day_s: float = 86400.0, start_frac: float = 0.5,
                           burst_rate_per_s: float = 0.0,
                           burst_mult: float = 3.0,
                           burst_dur_s: float = 0.5) -> np.ndarray:
    """Time-varying arrival process for closed-loop serving benchmarks
    (paper Fig. 2a: diurnal traffic; §6.2: bursts exceeding capacity).

    A non-homogeneous Poisson process sampled by Lewis thinning:

      * diurnal ramp — cosine day curve between ``base_qps`` (trough) and
        ``base_qps * peak_mult`` (peak); ``day_s`` compresses a day into the
        simulated horizon (e.g. day_s=60 sweeps a full diurnal cycle per
        simulated minute), ``start_frac`` picks where in the day t=0 falls
        (0.5 = mid-ramp);
      * Poisson bursts — burst windows open at rate ``burst_rate_per_s``,
        multiply the instantaneous rate by ``burst_mult`` for
        ``burst_dur_s`` seconds (flash-crowd spikes).

    Vectorized chunked thinning — candidate times, burst membership, and
    accept draws all evaluate as arrays, so the 100×-scale mesh bench can
    generate millions of arrivals in seconds. Bit-identical to the
    per-event reference (:func:`diurnal_burst_arrivals_loop`) at a fixed
    seed: both derive the same three sub-streams and consume each
    identically per candidate/burst/accept.

    Returns sorted arrival times (seconds, t=0 origin), seeded and
    deterministic per ``rng``.
    """
    arr_rng, burst_rng, acc_rng = _arrival_streams(rng)
    lam_max = base_qps * max(1.0, peak_mult) * (
        max(1.0, burst_mult) if burst_rate_per_s > 0 else 1.0)
    # accept probability averages lam_mean/lam_max — size chunks so the
    # expected number of rounds is ~1-2 even for burst-heavy configs
    mean_accept = max(1e-3, 0.5 * (1.0 + peak_mult) * base_qps / lam_max)
    out: list[np.ndarray] = []
    got = 0
    t0 = 0.0
    b_starts = np.empty(0)       # burst-window starts drawn so far
    b_cursor = 0.0               # sum of burst gaps drawn so far
    two_pi = 2.0 * np.pi
    while got < n_events:
        need = n_events - got
        chunk = max(1024, int(need / mean_accept * 1.1) + 16)
        gaps = arr_rng.exponential(1.0 / lam_max, size=chunk)
        # cumsum seeded with t0 reproduces the loop's ((t0+g1)+g2)+...
        # association exactly — `t0 + cumsum(gaps)` would round differently
        ts = np.cumsum(np.concatenate(([t0], gaps)))[1:]
        t0 = float(ts[-1])
        phase = np.cos((start_frac + ts / day_s) * two_pi)
        lam = base_qps * (1.0 + (peak_mult - 1.0) * 0.5 * (1.0 + phase))
        if burst_rate_per_s > 0:
            while b_cursor <= t0:    # extend burst starts past the chunk
                gaps = burst_rng.exponential(1.0 / burst_rate_per_s,
                                             size=max(chunk // 16, 64))
                ext = b_cursor + np.cumsum(gaps)
                b_starts = np.concatenate([b_starts, ext])
                b_cursor = float(ext[-1])
            # constant burst_dur_s ⇒ window ends increase with starts, so
            # the loop's running-max burst_end reduces to "the latest
            # start ≤ t still covers t"
            idx = np.searchsorted(b_starts, ts, side="right") - 1
            in_burst = (idx >= 0) & (ts < b_starts[np.maximum(idx, 0)]
                                     + burst_dur_s)
            lam = np.where(in_burst, lam * burst_mult, lam)
        accept = acc_rng.random(chunk) < lam / lam_max
        sel = ts[accept]
        out.append(sel[:need])
        got += min(len(sel), need)
    return np.concatenate(out)[:n_events]


def diurnal_burst_arrivals_loop(rng: np.random.Generator, n_events: int,
                                base_qps: float, peak_mult: float = 3.0,
                                day_s: float = 86400.0,
                                start_frac: float = 0.5,
                                burst_rate_per_s: float = 0.0,
                                burst_mult: float = 3.0,
                                burst_dur_s: float = 0.5) -> np.ndarray:
    """Per-event reference implementation of
    :func:`diurnal_burst_arrivals` (the original Lewis-thinning loop,
    restructured onto the same three derived sub-streams). Kept as the
    parity oracle: the vectorized sampler must match it bit-for-bit."""
    arr_rng, burst_rng, acc_rng = _arrival_streams(rng)
    lam_max = base_qps * max(1.0, peak_mult) * (
        max(1.0, burst_mult) if burst_rate_per_s > 0 else 1.0)
    times = np.empty(n_events)
    t = 0.0
    next_burst = (burst_rng.exponential(1.0 / burst_rate_per_s)
                  if burst_rate_per_s > 0 else np.inf)
    burst_end = -np.inf
    k = 0
    while k < n_events:
        t += arr_rng.exponential(1.0 / lam_max)
        while t >= next_burst:
            burst_end = max(burst_end, next_burst + burst_dur_s)
            next_burst += burst_rng.exponential(1.0 / burst_rate_per_s)
        phase = np.cos((start_frac + t / day_s) * 2.0 * np.pi)
        lam = base_qps * (1.0 + (peak_mult - 1.0) * 0.5 * (1.0 + phase))
        if t < burst_end:
            lam *= burst_mult
        if acc_rng.random() < lam / lam_max:
            times[k] = t
            k += 1
    return times


def random_graph(rng: np.random.Generator, n_nodes: int, n_edges: int,
                 d_feat: int | None = None) -> dict:
    """Random directed graph as (E,2) [src,dst] with synthetic edge lengths."""
    edges = rng.integers(0, n_nodes, (n_edges, 2), dtype=np.int32)
    g: dict = {"edges": edges,
               "edge_dist": rng.uniform(0.5, 9.5, n_edges).astype(np.float32)}
    if d_feat is not None:
        g["node_feat"] = rng.normal(0, 1, (n_nodes, d_feat)).astype(np.float32)
    return g


def molecule_batch(rng: np.random.Generator, cfg: GNNConfig, batch: int,
                   n_atoms: int, n_edges: int) -> dict:
    """Batched small molecules flattened into one disjoint graph."""
    N, E = batch * n_atoms, batch * n_edges
    atom_z = rng.integers(1, cfg.n_atom_types, N).astype(np.int32)
    pos = rng.normal(0, 2.0, (N, 3)).astype(np.float32)
    # intra-molecule random edges (offsets keep graphs disjoint)
    src = rng.integers(0, n_atoms, (batch, n_edges))
    dst = rng.integers(0, n_atoms, (batch, n_edges))
    off = (np.arange(batch) * n_atoms)[:, None]
    edges = np.stack([(src + off).reshape(-1), (dst + off).reshape(-1)],
                     axis=1).astype(np.int32)
    graph_ids = np.repeat(np.arange(batch), n_atoms).astype(np.int32)
    return {"atom_z": atom_z, "positions": pos, "edges": edges,
            "graph_ids": graph_ids, "n_graphs": batch,
            "targets": rng.normal(0, 1, batch).astype(np.float32)}
