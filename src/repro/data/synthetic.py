"""Synthetic data generators: web-scale traffic shapes without the web.

Zipf-distributed ids reproduce the paper's heavy-tailed access pattern
(Fig. 5a: 80% of lookups hit 1% of keys), which the cube-cache experiments
depend on. All generators are numpy + seeded (host-side data pipeline).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import GNNConfig, LMConfig, RecsysConfig, ShapeSpec


def zipf_ids(rng: np.random.Generator, n: int, vocab: int, a: float = 1.05) -> np.ndarray:
    """Zipf over [0, vocab) — heavy-tailed like production feature access."""
    z = rng.zipf(a, size=n).astype(np.int64)
    return ((z - 1) % vocab).astype(np.int32)


def lm_batch(rng: np.random.Generator, cfg: LMConfig, batch: int, seq: int) -> dict:
    return {"tokens": rng.integers(0, cfg.vocab, (batch, seq), dtype=np.int32)}


def recsys_ids(rng, fields, batch: int, zipf_a: float = 1.05) -> dict:
    out = {}
    for f in fields:
        shape = (batch,) if f.bag == 1 else (batch, f.bag)
        out[f.name] = zipf_ids(rng, int(np.prod(shape)), f.vocab, zipf_a).reshape(shape)
    return out


def recsys_batch(rng: np.random.Generator, cfg: RecsysConfig, batch: int) -> dict:
    b: dict = {"user": {"fields": recsys_ids(rng, cfg.user_fields, batch)},
               "item": recsys_ids(rng, cfg.item_fields, batch),
               "label": rng.binomial(1, 0.3, batch).astype(np.float32)}
    if cfg.seq_len:
        hist = zipf_ids(rng, batch * cfg.seq_len,
                        cfg.item_fields[0].vocab).reshape(batch, cfg.seq_len)
        lengths = rng.integers(1, cfg.seq_len + 1, batch)
        mask = np.arange(cfg.seq_len)[None, :] < lengths[:, None]
        b["user"]["hist"] = np.where(mask, hist, -1).astype(np.int32)
    return b


def random_graph(rng: np.random.Generator, n_nodes: int, n_edges: int,
                 d_feat: int | None = None) -> dict:
    """Random directed graph as (E,2) [src,dst] with synthetic edge lengths."""
    edges = rng.integers(0, n_nodes, (n_edges, 2), dtype=np.int32)
    g: dict = {"edges": edges,
               "edge_dist": rng.uniform(0.5, 9.5, n_edges).astype(np.float32)}
    if d_feat is not None:
        g["node_feat"] = rng.normal(0, 1, (n_nodes, d_feat)).astype(np.float32)
    return g


def molecule_batch(rng: np.random.Generator, cfg: GNNConfig, batch: int,
                   n_atoms: int, n_edges: int) -> dict:
    """Batched small molecules flattened into one disjoint graph."""
    N, E = batch * n_atoms, batch * n_edges
    atom_z = rng.integers(1, cfg.n_atom_types, N).astype(np.int32)
    pos = rng.normal(0, 2.0, (N, 3)).astype(np.float32)
    # intra-molecule random edges (offsets keep graphs disjoint)
    src = rng.integers(0, n_atoms, (batch, n_edges))
    dst = rng.integers(0, n_atoms, (batch, n_edges))
    off = (np.arange(batch) * n_atoms)[:, None]
    edges = np.stack([(src + off).reshape(-1), (dst + off).reshape(-1)],
                     axis=1).astype(np.int32)
    graph_ids = np.repeat(np.arange(batch), n_atoms).astype(np.int32)
    return {"atom_z": atom_z, "positions": pos, "edges": edges,
            "graph_ids": graph_ids, "n_graphs": batch,
            "targets": rng.normal(0, 1, batch).astype(np.float32)}
