"""Cell builder: (arch × shape × mesh) → step fn + abstract inputs +
shardings + analytic MODEL_FLOPS. The dry-run, roofline, and launcher all
consume Cells; nothing here allocates device memory (ShapeDtypeStruct only).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import GNNConfig, LMConfig, RecsysConfig, ShapeSpec
from repro.launch import sharding as shr
from repro.models import schnet, transformer
from repro.models.recsys import dien, din, mind, towers
from repro.train import optimizer as opt_lib
from repro.train.train_step import build_train_step

REC_MODULES = {"two_tower": towers, "mind": mind, "din": din, "dien": dien}

S32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
F32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float32)


@dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable                    # positional args match .args
    args: tuple                     # pytrees of ShapeDtypeStruct
    in_specs: tuple                 # pytrees of PartitionSpec
    out_specs: Any
    donate: tuple = ()
    meta: dict = field(default_factory=dict)

    def jitted(self, mesh: Mesh):
        return jax.jit(self.fn,
                       in_shardings=shr.to_named(mesh, self.in_specs),
                       out_shardings=shr.to_named(mesh, self.out_specs),
                       donate_argnums=self.donate)


def abstract_params(init_fn) -> Any:
    return jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0)))


# ------------------------------------------------------------------ LM

def _lm_micro(cfg: LMConfig, batch: int, mesh: Mesh) -> int:
    """Grad-accum microbatches: hold ~1-4 sequences per data shard."""
    per_shard = {"deepseek-v3-671b": 1, "qwen3-8b": 2, "starcoder2-7b": 2,
                 "deepseek-v2-lite-16b": 4, "smollm-135m": 2}.get(cfg.name, 2)
    ds = shr.data_size(mesh)
    n = max(1, batch // (per_shard * ds))
    while batch % n or (batch // n) % ds:
        n -= 1
    return max(1, n)


def lm_model_flops(cfg: LMConfig, shape: ShapeSpec) -> float:
    n_active = cfg.active_param_count()
    d = shape.dims
    if shape.kind == "train":
        return 6.0 * n_active * d["seq_len"] * d["global_batch"]
    if shape.kind == "prefill":
        return 2.0 * n_active * d["seq_len"] * d["global_batch"]
    return 2.0 * n_active * d["global_batch"]       # decode: 1 token/seq


def lm_model_bytes(cfg: LMConfig, shape: ShapeSpec, n_dev: int) -> float:
    """Analytic minimum HBM traffic per device per step (roofline floor):
    weights read once + KV cache read (decode) / activations (train)."""
    d = shape.dims
    B, S = d["global_batch"], d["seq_len"]
    bpp = 2 if cfg.param_dtype == "bfloat16" else 4
    w = cfg.active_param_count() * bpp
    if cfg.mla:
        per_tok = (cfg.mla.kv_lora + cfg.mla.d_rope) * bpp * cfg.n_layers
    else:
        per_tok = 2 * cfg.n_kv * cfg.d_head * bpp * cfg.n_layers
    if shape.kind in ("decode", "decode_long"):
        return (w + B * S * per_tok) / n_dev
    if shape.kind == "prefill":
        return (w + 3 * B * S * cfg.d_model * bpp * cfg.n_layers) / n_dev
    # train: params+grads+opt traffic (~3 weight passes) + layer activations
    return (3 * w * 3 + 4 * B * S * cfg.d_model * bpp * cfg.n_layers) / n_dev


def build_lm_cell(arch, shape: ShapeSpec, mesh: Mesh) -> Cell:
    cfg: LMConfig = arch.config
    dims = shape.dims
    B, S = dims["global_batch"], dims["seq_len"]
    params = abstract_params(lambda k: transformer.init(k, cfg))
    pspecs = shr.param_specs(params, cfg, mesh)
    meta = {"model_flops": lm_model_flops(cfg, shape),
            "model_bytes_per_device": lm_model_bytes(cfg, shape, mesh.size),
            "param_dtype": cfg.param_dtype,
            "params": cfg.param_count(), "active_params": cfg.active_param_count()}

    if shape.kind == "train":
        n_micro = _lm_micro(cfg, B, mesh)
        opt = opt_lib.for_family("lm", cfg.param_count())
        # ZeRO-2: grad accumulator + optimizer state pick up an extra `data`
        # sharding; updated params all-gather back to the compute sharding.
        zspecs = shr.zero_specs(params, pspecs, mesh)
        if getattr(cfg, "fsdp_params", False):
            # ZeRO-3: params themselves stay data-sharded; each layer
            # re-gathers its weights on use (GSPMD inserts the all-gather)
            pspecs = zspecs
        step, opt_init = build_train_step(
            lambda p, toks: transformer.lm_loss(p, toks, cfg), opt,
            n_micro=n_micro, grad_shardings=shr.to_named(mesh, zspecs))
        opt_state = jax.eval_shape(opt_init, params)
        ospecs = shr.opt_state_specs(opt_state, params, zspecs)
        toks = S32((B, S))
        tspec = shr.batched_spec(mesh, (B, S))
        meta["n_micro"] = n_micro
        return Cell(arch.arch_id, shape.name, step,
                    (params, opt_state, toks),
                    (pspecs, ospecs, tspec),
                    (pspecs, ospecs, P()),
                    donate=(0, 1), meta=meta)

    if shape.kind == "prefill":
        ca, cb, cl = shr.kv_cache_specs(cfg, B, mesh)
        fn = lambda p, toks: transformer.prefill(p, toks, cfg, smax=S)
        toks = S32((B, S))
        logits_spec = shr.batched_spec(mesh, (B, cfg.vocab))
        return Cell(arch.arch_id, shape.name, fn, (params, toks),
                    (pspecs, shr.batched_spec(mesh, (B, S))),
                    (logits_spec, transformer.KVCache(a=ca, b=cb, length=cl)),
                    meta=meta)

    # decode / decode_long: one new token against a seq_len KV cache
    cache = transformer.KVCache.shapes(cfg, B, S)
    ca, cb, cl = shr.kv_cache_specs(cfg, B, mesh)
    cache_specs = transformer.KVCache(a=ca, b=cb, length=cl)
    fn = lambda p, c, toks: transformer.decode_step(p, c, toks, cfg)
    toks = S32((B, 1))
    logits_spec = shr.batched_spec(mesh, (B, cfg.vocab))
    return Cell(arch.arch_id, shape.name, fn, (params, cache, toks),
                (pspecs, cache_specs, shr.batched_spec(mesh, (B, 1))),
                (logits_spec, cache_specs),
                donate=(1,), meta=meta)


# ------------------------------------------------------------------ GNN

def gnn_model_flops(cfg: GNNConfig, n_nodes: int, n_edges: int, d_in: int,
                    train: bool = True) -> float:
    h, r = cfg.d_hidden, cfg.n_rbf
    per_edge = 2 * (r * h + h * h) + 2 * h
    per_node = 2 * (2 * h * h)
    fwd = cfg.n_interactions * (n_edges * per_edge + n_nodes * per_node) \
        + 2 * n_nodes * d_in * h
    return (3.0 if train else 1.0) * fwd


def build_gnn_cell(arch, shape: ShapeSpec, mesh: Mesh) -> Cell:
    cfg: GNNConfig = arch.config
    d = shape.dims
    if shape.kind == "graph_batched":
        N = d["batch"] * d["n_nodes"]
        E = d["batch"] * d["n_edges"]
        n_graphs = d["batch"]
        inputs = {"atom_z": S32((N,)), "positions": F32((N, 3)),
                  "edges": S32((E, 2)), "edge_dist": F32((E,)),
                  "graph_ids": S32((N,))}
        targets = F32((d["batch"],))
        init_fn = lambda k: schnet.init(k, cfg)
        d_in = cfg.d_hidden
    else:
        if shape.kind == "graph_mini":
            f1, f2 = d["fanout"]
            bn = d["batch_nodes"]
            N = bn + bn * f1 + bn * f1 * f2
            E = bn * f1 + bn * f1 * f2
        else:
            N, E = d["n_nodes"], d["n_edges"]
        # pad the edge list to the multi-pod mesh multiple; sentinel edges
        # (src=dst=N) drain into the stripped sentinel node row
        E = -(-E // 512) * 512
        inputs = {"node_feat": F32((N, d["d_feat"])), "edges": S32((E, 2)),
                  "edge_dist": F32((E,)), "graph_ids": S32((N,))}
        n_graphs = 1
        targets = F32((1,))
        init_fn = lambda k: schnet.init(k, cfg, d_feat_in=d["d_feat"])
        d_in = d["d_feat"]

    params = abstract_params(init_fn)
    pspecs = shr.param_specs(params, cfg, mesh)
    opt = opt_lib.adamw()
    step, opt_init = build_train_step(
        lambda p, b: schnet.loss_fn(p, b["inputs"], b["targets"], cfg,
                                    n_graphs=n_graphs), opt)
    opt_state = jax.eval_shape(opt_init, params)
    ospecs = shr.opt_state_specs(opt_state, params, pspecs)

    in_spec = {k: (shr.edge_spec(mesh, v.ndim) if k in ("edges", "edge_dist")
                   else P(*(None,) * v.ndim))
               for k, v in inputs.items()}
    batch = {"inputs": inputs, "targets": targets}
    bspec = {"inputs": in_spec, "targets": P(None)}
    n_nodes_eff = N if shape.kind != "graph_batched" else N
    meta = {"model_flops": gnn_model_flops(cfg, n_nodes_eff, E, d_in),
            "model_bytes_per_device":
                (E * (cfg.n_rbf + 3 * cfg.d_hidden) * 4 * cfg.n_interactions
                 + N * (d_in + 4 * cfg.d_hidden) * 4) / mesh.size,
            "param_dtype": "float32",
            "params": sum(np.prod(l.shape) for l in jax.tree.leaves(params))}
    return Cell(arch.arch_id, shape.name, step, (params, opt_state, batch),
                (pspecs, ospecs, bspec), (pspecs, ospecs, P()),
                donate=(0, 1), meta=meta)


# --------------------------------------------------------------- recsys

def _rec_batch_specs(cfg: RecsysConfig, batch: int, mesh: Mesh, with_label=True):
    def fspec(f):
        shape = (batch,) if f.bag == 1 else (batch, f.bag)
        return S32(shape), shr.batched_spec(mesh, shape)

    user_fields, user_fspecs = {}, {}
    for f in cfg.user_fields:
        user_fields[f.name], user_fspecs[f.name] = fspec(f)
    item, item_specs = {}, {}
    for f in cfg.item_fields:
        item[f.name], item_specs[f.name] = fspec(f)
    user = {"fields": user_fields}
    uspec = {"fields": user_fspecs}
    if cfg.seq_len:
        user["hist"] = S32((batch, cfg.seq_len))
        uspec["hist"] = shr.batched_spec(mesh, (batch, cfg.seq_len))
    b = {"user": user, "item": item}
    bs = {"user": uspec, "item": item_specs}
    if with_label:
        b["label"] = F32((batch,))
        bs["label"] = shr.batched_spec(mesh, (batch,))
    return b, bs


def rec_dense_params(params) -> int:
    return int(sum(np.prod(l.shape) for path, l in
                   jax.tree_util.tree_flatten_with_path(params)[0]
                   if not any(getattr(k, "key", None) == "tables" for k in path)))


def build_rec_cell(arch, shape: ShapeSpec, mesh: Mesh) -> Cell:
    cfg: RecsysConfig = arch.config
    mod = REC_MODULES[cfg.model]
    params = abstract_params(lambda k: mod.init(k, cfg))
    pspecs = shr.param_specs(params, cfg, mesh)
    n_dense = rec_dense_params(params)
    n_table = int(sum(np.prod(l.shape) for l in jax.tree.leaves(params))) - n_dense
    d = shape.dims

    n_lookup_rows = sum(f.bag for f in cfg.user_fields + cfg.item_fields) \
        + (cfg.seq_len or 0)

    def rec_bytes(B):
        # embedding rows touched + dense params + activations (fp32)
        return (B * n_lookup_rows * cfg.embed_dim * 4 + n_dense * 4
                + B * n_lookup_rows * cfg.embed_dim * 4) / mesh.size

    if shape.kind == "rec_train":
        B = d["batch"]
        batch, bspec = _rec_batch_specs(cfg, B, mesh)
        opt = opt_lib.for_family("recsys")
        step, opt_init = build_train_step(lambda p, b: mod.loss_fn(p, b, cfg), opt)
        opt_state = jax.eval_shape(opt_init, params)
        ospecs = shr.opt_state_specs(opt_state, params, pspecs)
        meta = {"model_flops": 6.0 * n_dense * B, "params": n_dense + n_table,
                "model_bytes_per_device": 3 * rec_bytes(B),
                "param_dtype": "float32", "dense_params": n_dense}
        return Cell(arch.arch_id, shape.name, step, (params, opt_state, batch),
                    (pspecs, ospecs, bspec), (pspecs, ospecs, P()),
                    donate=(0, 1), meta=meta)

    if shape.kind == "rec_serve":
        B = d["batch"]
        batch, bspec = _rec_batch_specs(cfg, B, mesh, with_label=False)
        fn = lambda p, b: mod.serve_scores(p, b, cfg)
        meta = {"model_flops": 2.0 * n_dense * B, "params": n_dense + n_table,
                "model_bytes_per_device": rec_bytes(B),
                "param_dtype": "float32"}
        return Cell(arch.arch_id, shape.name, fn, (params, batch),
                    (pspecs, bspec), shr.batched_spec(mesh, (B,)), meta=meta)

    # rec_retrieval: 1 query vs n_candidates
    C = d["n_candidates"]
    user, uspec = {}, {}
    for f in cfg.user_fields:
        shp = (1,) if f.bag == 1 else (1, f.bag)
        user[f.name], uspec[f.name] = S32(shp), P(*(None,) * len(shp))
    cand, cspec = {}, {}
    for f in cfg.item_fields:
        shp = (C,) if f.bag == 1 else (C, f.bag)
        cand[f.name] = S32(shp)
        cspec[f.name] = shr.batched_spec(mesh, shp)
    meta = {"model_flops": 2.0 * n_dense * C, "params": n_dense + n_table,
            "model_bytes_per_device": rec_bytes(C), "param_dtype": "float32"}
    if cfg.model == "two_tower":
        fn = lambda p, u, c: towers.retrieve(p, u, c, cfg)
        return Cell(arch.arch_id, shape.name, fn, (params, user, cand),
                    (pspecs, uspec, cspec), (P(None), P(None)), meta=meta)
    ub = {"fields": user, "hist": S32((1, cfg.seq_len))}
    ubspec = {"fields": uspec, "hist": P(None, None)}
    if cfg.model == "mind":
        fn = lambda p, u, c: mind.retrieve(p, u, c, cfg)
    elif cfg.model == "din":
        # launch cells measure the MESH-SHARDED computation: pin the jnp
        # path, which carries the ("data","model") sharding constraints —
        # the fused Pallas path is the single-host serving fast path and
        # has no partitioning rule
        fn = lambda p, u, c: din.score_candidates(p, u, c, cfg, path="jnp")
    else:
        fn = lambda p, u, c: mod.score_candidates(p, u, c, cfg)
    return Cell(arch.arch_id, shape.name, fn, (params, ub, cand),
                (pspecs, ubspec, cspec), (P(None), P(None)), meta=meta)


def build_cell(arch_id: str, shape_name: str, mesh: Mesh) -> Cell:
    arch = registry.get(arch_id)
    shape = registry.get_shape(arch, shape_name)
    builder = {"lm": build_lm_cell, "gnn": build_gnn_cell,
               "recsys": build_rec_cell}[arch.family]
    return builder(arch, shape, mesh)
