"""PartitionSpec rules: params, optimizer state, inputs, KV caches.

Encodes the distribution design of DESIGN.md §5:
  * LM dense: batch → ("pod","data"); TP on ``model`` for d_ff / attention
    heads (replicated where head counts don't divide 16 — smollm fully,
    qwen3/starcoder2 kv projections); vocab (embed + head) on ``model``.
  * MLA: q_b/k_b/v_b shard the head dim (16 | H for both deepseeks); the
    latent projections (wkv_a, wq_a) replicate (tiny).
  * MoE: experts on ``model``, expert d_ff on ``data`` (2-D expert weights);
    router replicated.
  * RecSys tables: rows on flat ("data","model"); dense parts replicated.
  * KV caches: sequence dim on ``model`` (batch on data axes), or on
    ("data","model") for batch-1 long-context — distributed-softmax decode.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import GNNConfig, LMConfig, RecsysConfig


def _names(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return out


def _divides(n: int, mesh: Mesh, axis: str) -> bool:
    return n % mesh.shape.get(axis, 1) == 0


# ------------------------------------------------------------------ LM

def _lm_leaf_spec(names: list[str], leaf, cfg: LMConfig, mesh: Mesh) -> P:
    stacked = ("layers" in names or "dense_layers" in names) and "mtp" not in names
    pre = (None,) if stacked else ()
    name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    nm = mesh.shape.get("model", 1)
    H, Hkv, D = cfg.n_heads, cfg.n_kv, cfg.d_head

    def spec(*s):
        return P(*(pre + s))

    if "embed" in names:
        return P("model", None) if _divides(cfg.vocab, mesh, "model") else P(None, None)
    if "lm_head" in names:
        return P(None, "model") if _divides(cfg.vocab, mesh, "model") else P(None, None)
    if name in ("scale", "bias"):          # norms (incl. q_norm/k_norm/kv_norm)
        return spec(*(None,) * (leaf.ndim - len(pre)))
    if parent == "moe":
        f_ok = _divides(cfg.moe.d_ff_expert, mesh, "data")
        fs = "data" if f_ok else None
        return {"router": spec(None, None),
                "w1": spec("model", None, fs), "w3": spec("model", None, fs),
                "w2": spec("model", fs, None)}[name]
    if parent in ("mlp", "shared"):        # dense FFN / shared experts: TP on f
        d_ff = leaf.shape[-1] if name in ("w1", "w3") else leaf.shape[-2]
        ok = d_ff % nm == 0
        if name in ("w1", "w3"):
            return spec(None, "model") if ok else spec(None, None)
        return spec("model", None) if ok else spec(None, None)
    if parent == "attn" or name in ("wq", "wk", "wv", "wo", "wq_a", "wq_b",
                                    "wkv_a", "wk_b", "wv_b"):
        if cfg.mla:
            h_ok = H % nm == 0
            hs = "model" if h_ok else None
            return {"wq": spec(None, hs), "wq_a": spec(None, None),
                    "wq_b": spec(None, hs), "wkv_a": spec(None, None),
                    "wk_b": spec(None, hs), "wv_b": spec(None, hs),
                    "wo": spec(hs, None)}.get(name, spec(*(None,) * (leaf.ndim - len(pre))))
        q_ok = H % nm == 0
        kv_ok = Hkv % nm == 0
        return {"wq": spec(None, "model" if q_ok else None),
                "wk": spec(None, "model" if kv_ok else None),
                "wv": spec(None, "model" if kv_ok else None),
                "wo": spec("model" if q_ok else None, None)}.get(
                    name, spec(*(None,) * (leaf.ndim - len(pre))))
    if name == "proj":                     # mtp projection
        return P(None, None)
    return spec(*(None,) * (leaf.ndim - len(pre)))


def lm_param_specs(params_shape: Any, cfg: LMConfig, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _lm_leaf_spec(_names(path), leaf, cfg, mesh),
        params_shape)


# ------------------------------------------------------------- recsys/gnn

def recsys_param_specs(params_shape: Any, cfg: RecsysConfig, mesh: Mesh):
    n_shards = mesh.shape.get("data", 1) * mesh.shape.get("model", 1)

    def leaf_spec(path, leaf):
        names = _names(path)
        if "tables" in names and leaf.ndim == 2 and leaf.shape[0] % n_shards == 0:
            return P(("data", "model"), None)
        return P(*(None,) * leaf.ndim)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def gnn_param_specs(params_shape: Any, cfg: GNNConfig, mesh: Mesh):
    return jax.tree.map(lambda leaf: P(*(None,) * leaf.ndim), params_shape,
                        is_leaf=lambda x: hasattr(x, "ndim"))


def param_specs(params_shape, cfg, mesh: Mesh):
    if isinstance(cfg, LMConfig):
        return lm_param_specs(params_shape, cfg, mesh)
    if isinstance(cfg, RecsysConfig):
        return recsys_param_specs(params_shape, cfg, mesh)
    return gnn_param_specs(params_shape, cfg, mesh)


# ------------------------------------------------------------- ZeRO grads

def zero_specs(params_shape: Any, pspecs: Any, mesh: Mesh,
               min_size: int = 1 << 20) -> Any:
    """ZeRO-2 sharding for gradient accumulators + optimizer state: add the
    ``data`` axis to the largest unsharded, divisible dim of every big leaf
    whose spec doesn't already use it. Params keep their compute sharding;
    grads are reduce-scattered into this spec and the optimizer update runs
    sharded (GSPMD all-gathers the updated params once per step)."""
    nd = mesh.shape.get("data", 1)
    if nd <= 1:
        return pspecs

    def one(leaf, spec: P) -> P:
        if int(np.prod(leaf.shape)) < min_size:
            return spec
        used = set()
        for s in spec:
            if s is None:
                continue
            for a in (s if isinstance(s, tuple) else (s,)):
                used.add(a)
        if "data" in used:
            return spec
        entries = list(spec) + [None] * (leaf.ndim - len(tuple(spec)))
        cands = [i for i in range(leaf.ndim)
                 if entries[i] is None and leaf.shape[i] % nd == 0]
        if not cands:
            return spec
        dim = max(cands, key=lambda i: leaf.shape[i])
        entries[dim] = "data"
        return P(*entries)

    return jax.tree.map(one, params_shape, pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# -------------------------------------------------------- optimizer state

def opt_state_specs(opt_state_shape: Any, params_shape: Any, pspecs: Any):
    """Infer optimizer-state specs structurally: any state leaf whose shape
    matches a param's shape/prefix inherits the param spec (adamw m/v,
    adafactor vr/vc, rowwise accumulators); scalars replicate."""
    flat_params = {tuple(_names(p)): (leaf, spec) for (p, leaf), (_, spec) in zip(
        jax.tree_util.tree_flatten_with_path(params_shape)[0],
        jax.tree_util.tree_flatten_with_path(pspecs)[0])}

    by_shape: dict[tuple, P] = {}
    for shape_spec in flat_params.values():
        leaf, spec = shape_spec
        by_shape.setdefault(tuple(leaf.shape), spec)
        # factored / rowwise variants
        if leaf.ndim >= 2:
            sp = tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec)))
            by_shape.setdefault(tuple(leaf.shape[:-1]), P(*sp[:-1]))
            by_shape.setdefault(tuple(leaf.shape[:-2] + leaf.shape[-1:]),
                                P(*(sp[:-2] + sp[-1:])))
            by_shape.setdefault(tuple(leaf.shape[:1]), P(sp[0]))

    def leaf_spec(leaf):
        if leaf.ndim == 0:
            return P()
        return by_shape.get(tuple(leaf.shape), P(*(None,) * leaf.ndim))

    return jax.tree.map(leaf_spec, opt_state_shape,
                        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dtype"))


# ----------------------------------------------------------------- inputs

def batch_axes_of(mesh: Mesh) -> tuple:
    axes = tuple(a for a in ("pod", "data") if mesh.shape.get(a, 1) > 1)
    return axes or ("data",)


def data_size(mesh: Mesh) -> int:
    return mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)


def batched_spec(mesh: Mesh, shape: tuple, extra_axes: int | None = None) -> P:
    """Shard dim0 over the data axes when divisible, else replicate."""
    nd = len(shape) if extra_axes is None else extra_axes + 1
    if shape and shape[0] % data_size(mesh) == 0 and shape[0] >= data_size(mesh):
        return P(batch_axes_of(mesh), *(None,) * (nd - 1))
    return P(*(None,) * nd)


def edge_spec(mesh: Mesh, ndim: int) -> P:
    return P(("data", "model"), *(None,) * (ndim - 1))


def kv_cache_specs(cfg: LMConfig, batch: int, mesh: Mesh):
    """(a, b, length) specs — sequence-sharded decode caches."""
    if batch % data_size(mesh) == 0 and batch >= data_size(mesh):
        b_ax, s_ax = batch_axes_of(mesh), ("model",)
    else:
        b_ax, s_ax = (), tuple(a for a in ("pod", "data", "model")
                               if mesh.shape.get(a, 1) > 1)
    bspec = b_ax if b_ax else None
    if cfg.mla:
        a = P(None, bspec, s_ax, None)
        b = P(None, bspec, s_ax, None)
    else:
        a = P(None, bspec, s_ax, None, None)
        b = P(None, bspec, s_ax, None, None)
    return a, b, P()


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
