"""Serving launcher: LM decode service with continuous batching + hot-load,
or the recsys JiZHI service (examples/quickstart path), from one CLI.

  PYTHONPATH=src python -m repro.launch.serve --mode recsys --requests 96
  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch smollm-135m \
      --requests 6 --reduced
"""
import argparse
import time

import numpy as np


def serve_recsys(args):
    from repro.core.service import InferenceService, ServiceConfig
    cfg = ServiceConfig(
        arch_id=args.arch if args.arch != "smollm-135m" else "din",
        # crash safety (DESIGN.md §9): --snapshot-dir enables periodic
        # durable snapshots + SIGTERM final-snapshot; --recover boots from
        # the newest valid snapshot and replays the delta log
        snapshot_dir=args.snapshot_dir, recover=args.recover,
        live_updates=bool(args.update_dir), update_dir=args.update_dir)
    svc = InferenceService(cfg)
    if svc.snapshotter is not None:
        svc.install_shutdown_hook()
    if svc.update_watcher is not None:
        svc.start_updates()
    if args.recover and svc.substrate.recovering:
        print(f"recovering: serving degraded until delta replay reaches "
              f"v{svc.substrate.recovery_target}")
    rep = svc.run(n_requests=args.requests)
    print(f"served {len(rep.results)} requests; "
          f"avg {rep.avg_latency*1e3:.2f} ms, p99 "
          f"{rep.latency_percentile(0.99)*1e3:.2f} ms; "
          f"query-cache hit {100*svc.query_cache.stats.hit_ratio:.1f}%")
    if svc.snapshotter is not None:
        path = svc.shutdown()
        if path:
            print(f"final snapshot: {path}")


def serve_lm(args):
    import jax
    import jax.numpy as jnp
    from repro.configs import registry
    from repro.models import transformer
    from repro.serve.batcher import ContinuousBatcher
    from repro.serve.hotload import DoubleBuffer, Generation

    arch = registry.get(args.arch)
    cfg = arch.reduced(arch.config) if args.reduced else arch.config
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    buf = DoubleBuffer(Generation(0, params))
    n_slots, s_max = 4, 64
    batcher = ContinuousBatcher(n_slots, s_max)

    rng = np.random.default_rng(0)
    prompts = {i: rng.integers(0, cfg.vocab, (8,), dtype=np.int32)
               for i in range(args.requests)}
    for i, p in prompts.items():
        batcher.submit(i, len(p), max_new=8)

    # one shared cache table for the slot batch
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        transformer.KVCache.shapes(cfg, n_slots, s_max))
    cache = cache._replace(length=jnp.asarray(0, jnp.int32))
    # prefill each admitted slot (batch-1 prefill per join keeps it simple)
    toks = jnp.stack([jnp.asarray(prompts[s.request_id])
                      for s in batcher.slots if s.request_id is not None])
    logits, cache = transformer.prefill(buf.active.payload, toks, cfg,
                                        smax=s_max)
    decode = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, cfg))
    last = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    t0 = time.monotonic()
    steps = 0
    while batcher.active_mask.any() and steps < 32:
        logits, cache = decode(buf.active.payload, cache, last)
        last = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        eos = np.asarray(last[:, 0] % 97 == 0)       # toy EOS criterion
        batcher.step_complete(eos)
        steps += 1
    print(f"decoded {steps} steps for {args.requests} requests "
          f"({(time.monotonic()-t0)/max(1,steps)*1e3:.1f} ms/step, "
          f"slot utilization {batcher.utilization:.2f}, "
          f"completed {len(batcher.completed)})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["recsys", "lm"], default="recsys")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--snapshot-dir", default=None,
                    help="recsys: durable cube snapshots here (enables "
                         "periodic snapshot + SIGTERM final snapshot)")
    ap.add_argument("--recover", action="store_true",
                    help="recsys: boot from the newest valid snapshot and "
                         "replay the delta log (cold boot if none)")
    ap.add_argument("--update-dir", default=None,
                    help="recsys: tail this delta log (live updates)")
    args = ap.parse_args()
    if args.mode == "recsys":
        serve_recsys(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
