"""Serving launcher: LM decode service with continuous batching + hot-load,
or the recsys JiZHI service (examples/quickstart path), from one CLI.

  PYTHONPATH=src python -m repro.launch.serve --mode recsys --requests 96
  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch smollm-135m \
      --requests 6 --reduced

Telemetry (recsys mode): ``--metrics-port`` serves the registry live at
``/metrics`` (Prometheus text exposition) and ``/metrics.json``;
``--metrics-out DIR`` writes both files at shutdown; ``--history-dir``
runs a ``StatsRecorder`` sampling the registry into the windowed history
log the IRM's offline auto-search reads; ``--trace-out FILE`` exports the
run's tail-sampled traces as Chrome trace-event JSON (Perfetto-viewable).
"""
import argparse
import os
import threading
import time

import numpy as np


def start_metrics_server(registry, port: int):
    """Serve /metrics (Prometheus) + /metrics.json from a daemon thread.
    Returns the http.server instance (``.shutdown()`` to stop). Stdlib
    only — no new dependencies."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 — http.server API
            if self.path.startswith("/metrics.json"):
                body = registry.to_json().encode()
                ctype = "application/json"
            elif self.path.startswith("/metrics"):
                body = registry.to_prometheus().encode()
                ctype = "text/plain; version=0.0.4"
            else:
                self.send_response(404)
                self.end_headers()
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):       # quiet: metrics scrapes are noise
            pass

    srv = ThreadingHTTPServer(("127.0.0.1", port), Handler)
    threading.Thread(target=srv.serve_forever, daemon=True,
                     name="metrics-http").start()
    return srv


def write_metrics_files(registry, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "metrics.prom"), "w") as f:
        f.write(registry.to_prometheus())
    with open(os.path.join(out_dir, "metrics.json"), "w") as f:
        f.write(registry.to_json())


def serve_recsys(args):
    from repro import obs
    from repro.core.service import InferenceService, ServiceConfig
    cfg = ServiceConfig(
        arch_id=args.arch if args.arch != "smollm-135m" else "din",
        # crash safety (DESIGN.md §9): --snapshot-dir enables periodic
        # durable snapshots + SIGTERM final-snapshot; --recover boots from
        # the newest valid snapshot and replays the delta log
        snapshot_dir=args.snapshot_dir, recover=args.recover,
        live_updates=bool(args.update_dir), update_dir=args.update_dir)
    svc = InferenceService(cfg)
    registry = obs.get_registry()
    obs.bridge.register_service(svc, name="recsys", registry=registry)
    if svc.snapshotter is not None:
        obs.bridge.register_snapshotter(svc.snapshotter, registry=registry)
    metrics_srv = (start_metrics_server(registry, args.metrics_port)
                   if args.metrics_port else None)
    recorder = None
    if args.history_dir:
        recorder = obs.StatsRecorder(
            args.history_dir, registry,
            interval_s=args.history_interval_s).start()
    tracer = obs.Tracer() if args.trace_out else None
    if svc.snapshotter is not None:
        svc.install_shutdown_hook()
    if svc.update_watcher is not None:
        svc.start_updates()
    if args.recover and svc.substrate.recovering:
        print(f"recovering: serving degraded until delta replay reaches "
              f"v{svc.substrate.recovery_target}")
    rep = svc.run(n_requests=args.requests, tracer=tracer)
    registry.histogram("request_latency_s",
                       "end-to-end request latency").observe_many(
        rep.latencies)
    print(f"served {len(rep.results)} requests; "
          f"avg {rep.avg_latency*1e3:.2f} ms, p99 "
          f"{rep.latency_percentile(0.99)*1e3:.2f} ms; "
          f"query-cache hit {100*svc.query_cache.stats.hit_ratio:.1f}%")
    if recorder is not None:
        recorder.stop()
        print(f"history: {recorder.windows_published} window(s) in "
              f"{args.history_dir}")
    if tracer is not None:
        tracer.buffer.export_chrome(args.trace_out)
        print(f"traces: {len(tracer.buffer.traces())} retained "
              f"-> {args.trace_out}")
    if args.metrics_out:
        write_metrics_files(registry, args.metrics_out)
        print(f"metrics: {args.metrics_out}/metrics.prom + metrics.json")
    if metrics_srv is not None:
        metrics_srv.shutdown()
    if svc.snapshotter is not None:
        path = svc.shutdown()
        if path:
            print(f"final snapshot: {path}")


def serve_lm(args):
    import jax
    import jax.numpy as jnp
    from repro.configs import registry
    from repro.models import transformer
    from repro.serve.batcher import ContinuousBatcher
    from repro.serve.hotload import DoubleBuffer, Generation

    arch = registry.get(args.arch)
    cfg = arch.reduced(arch.config) if args.reduced else arch.config
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    buf = DoubleBuffer(Generation(0, params))
    n_slots, s_max = 4, 64
    batcher = ContinuousBatcher(n_slots, s_max)

    rng = np.random.default_rng(0)
    prompts = {i: rng.integers(0, cfg.vocab, (8,), dtype=np.int32)
               for i in range(args.requests)}
    for i, p in prompts.items():
        batcher.submit(i, len(p), max_new=8)

    # one shared cache table for the slot batch
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        transformer.KVCache.shapes(cfg, n_slots, s_max))
    cache = cache._replace(length=jnp.asarray(0, jnp.int32))
    # prefill each admitted slot (batch-1 prefill per join keeps it simple)
    toks = jnp.stack([jnp.asarray(prompts[s.request_id])
                      for s in batcher.slots if s.request_id is not None])
    logits, cache = transformer.prefill(buf.active.payload, toks, cfg,
                                        smax=s_max)
    decode = jax.jit(lambda p, c, t: transformer.decode_step(p, c, t, cfg))
    last = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    t0 = time.monotonic()
    steps = 0
    while batcher.active_mask.any() and steps < 32:
        logits, cache = decode(buf.active.payload, cache, last)
        last = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        eos = np.asarray(last[:, 0] % 97 == 0)       # toy EOS criterion
        batcher.step_complete(eos)
        steps += 1
    print(f"decoded {steps} steps for {args.requests} requests "
          f"({(time.monotonic()-t0)/max(1,steps)*1e3:.1f} ms/step, "
          f"slot utilization {batcher.utilization:.2f}, "
          f"completed {len(batcher.completed)})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["recsys", "lm"], default="recsys")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--snapshot-dir", default=None,
                    help="recsys: durable cube snapshots here (enables "
                         "periodic snapshot + SIGTERM final snapshot)")
    ap.add_argument("--recover", action="store_true",
                    help="recsys: boot from the newest valid snapshot and "
                         "replay the delta log (cold boot if none)")
    ap.add_argument("--update-dir", default=None,
                    help="recsys: tail this delta log (live updates)")
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="recsys: serve /metrics (Prometheus) + "
                         "/metrics.json on this localhost port")
    ap.add_argument("--metrics-out", default=None,
                    help="recsys: write metrics.prom + metrics.json into "
                         "this directory at shutdown")
    ap.add_argument("--history-dir", default=None,
                    help="recsys: record windowed registry history here "
                         "(the IRM offline auto-search input)")
    ap.add_argument("--history-interval-s", type=float, default=1.0)
    ap.add_argument("--trace-out", default=None,
                    help="recsys: export tail-sampled request traces as "
                         "Chrome trace-event JSON to this file")
    args = ap.parse_args()
    if args.mode == "recsys":
        serve_recsys(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
