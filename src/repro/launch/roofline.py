"""Roofline report: reads dry-run artifacts → per-(arch × shape) three-term
analysis (compute / memory / collective seconds on TPU v5e), dominant
bottleneck, MODEL_FLOPS ratio, and markdown for EXPERIMENTS.md.

  compute_s    = HLO_FLOPs_per_device / 197 TFLOP/s      (bf16 peak)
  memory_s     = HLO_bytes_per_device / 819 GB/s         (HBM)
  collective_s = ICI traffic per device (ring model) / 50 GB/s/link

HLO terms come from repro.launch.hlo_analysis (while-loop trip counts
included — XLA's own cost_analysis counts loop bodies once).

  PYTHONPATH=src python -m repro.launch.roofline [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

HINTS = {
    ("compute", "lm"): "raise MXU occupancy: larger per-device microbatch / "
                       "remove head-padding waste",
    ("memory", "lm"): "attention score traffic — Pallas flash kernel keeps "
                      "(Sq,C) blocks in VMEM; also bf16-normalize temps",
    ("collective", "lm"): "replace TP all-reduce with reduce-scatter+all-"
                          "gather (SP) / overlap collectives with GEMMs",
    ("memory", "recsys"): "fuse embedding pooling (Pallas embedding_bag) and "
                          "avoid dense-grad table traffic (sparse grads)",
    ("collective", "recsys"): "pool before psum (already); shrink psum dtype "
                              "to bf16 / quantized all-reduce",
    ("compute", "recsys"): "batch the MLP into fewer larger GEMMs",
    ("memory", "gnn"): "fuse gather×filter×scatter (segment ops) per edge "
                       "block; cast messages to bf16",
    ("collective", "gnn"): "edge-block locality: partition edges by dst so "
                           "scatter partials stay device-local",
    ("compute", "gnn"): "batch RBF+filter MLP across edge blocks",
}


def load(dirpath: str, mesh: str = "16x16") -> list[dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(dirpath, f"*__{mesh}.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def analyze_row(rec: dict) -> dict:
    hlo = rec.get("hlo", {})
    meta = rec.get("meta", {})
    n_dev = rec.get("n_devices", 256)
    f = hlo.get("flops_per_device", 0.0)
    b = hlo.get("bytes_per_device", 0.0)
    c = hlo.get("collective_bytes_per_device", 0.0)
    # XLA:CPU float-normalizes bf16 → f32 buffers; scale bytes-like terms
    # back toward the TPU lowering (factor measured via buffer dumps)
    bf16 = meta.get("param_dtype") == "bfloat16"
    adj = 0.55 if bf16 else 1.0
    compute_s = f / PEAK_FLOPS
    memory_s = b * adj / HBM_BW
    coll_s = c * adj / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get) if any(terms.values()) else "n/a"
    model_flops = meta.get("model_flops", 0.0)
    model_bytes = meta.get("model_bytes_per_device", 0.0)
    ratio = model_flops / (f * n_dev) if f else 0.0
    family = ("lm" if rec["arch"] in
              ("qwen3-8b", "smollm-135m", "starcoder2-7b",
               "deepseek-v2-lite-16b", "deepseek-v3-671b")
              else "gnn" if rec["arch"] == "schnet" else "recsys")
    # roofline fraction = analytic floor time / achieved (bottleneck) time:
    # floor = the slower of "must do these flops" and "must move these bytes"
    step_time = max(terms.values()) if any(terms.values()) else float("inf")
    ideal_s = max(model_flops / n_dev / PEAK_FLOPS, model_bytes / HBM_BW)
    useful_frac = (ideal_s / step_time
                   if step_time and step_time != float("inf") else 0.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "ok": rec.get("ok"),
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant, "model_flops": model_flops,
        "flops_ratio": ratio, "roofline_frac": useful_frac,
        "hbm_gb": rec.get("memory", {}).get("hbm_per_device", 0) / 2**30,
        "hbm_tpu_gb": rec.get("memory", {}).get(
            "hbm_per_device_tpu_est",
            rec.get("memory", {}).get("hbm_per_device", 0)) / 2**30,
        "hint": HINTS.get((dominant, family), ""),
    }


def markdown_table(rows: list[dict]) -> str:
    out = ["| arch | shape | compute s | memory s | coll s | dominant | "
           "MODEL/HLO flops | roofline frac | HBM/dev (TPU est) GB | "
           "what moves it |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['dominant']} | {r['flops_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {r['hbm_tpu_gb']:.1f} | "
            f"{r['hint']} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--json-out", default="artifacts/roofline.json")
    args = ap.parse_args()
    rows = [analyze_row(r) for r in load(args.dir, args.mesh)]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(markdown_table(rows))
    worst = sorted((r for r in rows if r["ok"]),
                   key=lambda r: r["roofline_frac"])[:5]
    print("\nworst roofline fractions:",
          [(r["arch"], r["shape"], round(r["roofline_frac"], 4))
           for r in worst])
    coll = sorted((r for r in rows if r["ok"]),
                  key=lambda r: -r["collective_s"])[:5]
    print("most collective-bound:",
          [(r["arch"], r["shape"], round(r["collective_s"], 3))
           for r in coll])


if __name__ == "__main__":
    main()
