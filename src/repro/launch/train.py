"""Production training launcher: mesh + cell + data pipeline + checkpoints +
elastic restart, in one driver.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --steps 50 \
      --devices 8 --mesh 2x4 --reduced

On a real pod, drop --devices/--reduced and run under your cluster runner;
the mesh comes from make_production_mesh(), restarts resume from the newest
generation in --ckpt-dir, and a changed device count re-plans the mesh
(repro.train.elastic.plan_mesh) before restore — the checkpoint reshards on
device_put.
"""
import os

if os.environ.get("REPRO_TRAIN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_TRAIN_DEVICES"])

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 (else production)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config + tiny batch (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro import runtime
    from repro.configs import registry
    from repro.data.pipeline import Prefetcher
    from repro.data import synthetic
    from repro.launch.mesh import make_mesh, make_production_mesh
    from repro.launch import sharding as shr
    from repro.models import transformer
    from repro.train import optimizer as opt_lib
    from repro.train.checkpoint import AsyncCheckpointer, restore
    from repro.train.train_step import build_train_step
    from repro.train.elastic import plan_mesh

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_mesh(dims, ("pod", "data", "model")[-len(dims):])
    elif args.reduced:
        mesh = make_mesh((1, 1), ("data", "model"))
    else:
        plan = plan_mesh(len(jax.devices()), 256)
        mesh = make_mesh(plan.shape, plan.axes)
    print(f"mesh: {dict(mesh.shape)} over {mesh.size} devices")

    arch = registry.get(args.arch)
    cfg = arch.reduced(arch.config) if args.reduced else arch.config
    batch, seq = (8, 64) if args.reduced else (256, 4096)

    rng = np.random.default_rng(0)
    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=3)

    with runtime.use_mesh(mesh):
        params = transformer.init(jax.random.PRNGKey(0), cfg)
        pspecs = shr.param_specs(params, cfg, mesh)
        zspecs = shr.zero_specs(params, pspecs, mesh)
        opt = opt_lib.for_family("lm", cfg.param_count())
        step_fn, opt_init = build_train_step(
            lambda p, t: transformer.lm_loss(p, t, cfg), opt,
            n_micro=1 if args.reduced else 8,
            grad_shardings=shr.to_named(mesh, zspecs))
        opt_state = opt_init(params)
        start_step = 0
        latest = ckpt.latest()
        if latest:
            params, start_step = restore(latest, params,
                                         shr.to_named(mesh, pspecs))
            print(f"resumed from {latest} (step {start_step})")
        jitted = jax.jit(step_fn, donate_argnums=(0, 1),
                         in_shardings=(shr.to_named(mesh, pspecs),
                                       None,
                                       shr.to_named(
                                           mesh, shr.batched_spec(
                                               mesh, (batch, seq)))),
                         )
        ckpt.install_sigterm_hook(lambda: params, lambda: step)

        pipe = Prefetcher(lambda s: synthetic.lm_batch(rng, cfg, batch, seq),
                          depth=2)
        t0 = time.monotonic()
        step = start_step
        for step in range(start_step, start_step + args.steps):
            tokens = jnp.asarray(next(pipe)["tokens"])
            params, opt_state, loss = jitted(params, opt_state, tokens)
            if step % 10 == 0:
                print(f"step {step:5d} loss {float(loss):.4f} "
                      f"({(time.monotonic()-t0)/max(1,step-start_step+1):.2f}s/step)",
                      flush=True)
            if step and step % args.ckpt_every == 0:
                ckpt.save(params, step)
        pipe.close()
        ckpt.save(params, step + 1, block=True)
        print(f"done; latest checkpoint: {ckpt.latest()}")


if __name__ == "__main__":
    main()
