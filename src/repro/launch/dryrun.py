import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (The two lines above MUST precede any jax import: jax locks the device
# count at first init. Tests may shrink the placeholder fleet:)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])
# the placeholder fleet only exists on the CPU platform; with libtpu present
# but no TPU attached, backend autodetection stalls in metadata probing
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell on
the production mesh and record memory/cost/collective analyses.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # subprocesses
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

V5E = {"peak_flops": 197e12, "hbm_gbps": 819e9, "ici_gbps": 50e9,
       "hbm_bytes": 16 * 1024**3}


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str, mesh_override=None, save_hlo: bool = False) -> dict:
    import jax
    from repro import runtime
    from repro.configs import registry
    from repro.launch import hlo_analysis
    from repro.launch.mesh import make_mesh, make_production_mesh
    from repro.launch.specs import build_cell

    if mesh_override:
        shape, axes = mesh_override
        mesh = make_mesh(shape, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "n_devices": n_dev, "ok": False}
    t0 = time.monotonic()
    try:
        with runtime.use_mesh(mesh):
            cell = build_cell(arch_id, shape_name, mesh)
            rec["meta"] = {k: (float(v) if isinstance(v, (int, float)) else v)
                           for k, v in cell.meta.items()}
            jitted = cell.jitted(mesh)
            lowered = jitted.lower(*cell.args)
            rec["t_lower_s"] = round(time.monotonic() - t0, 2)
            t1 = time.monotonic()
            compiled = lowered.compile()
            rec["t_compile_s"] = round(time.monotonic() - t1, 2)

            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            }
            hbm = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                   + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
            rec["memory"]["hbm_per_device"] = hbm
            rec["memory"]["fits_v5e"] = bool(hbm < V5E["hbm_bytes"])
            # XLA:CPU float-normalizes bf16 arithmetic to f32, so every
            # bf16 temp/carry doubles vs the TPU lowering (verified via
            # buffer-assignment dump: the dominant temps are f32 versions
            # of bf16 tensors). Report a TPU-side estimate alongside.
            cfgobj = registry.get(arch_id).config
            bf16 = getattr(cfgobj, "param_dtype", "float32") == "bfloat16"
            factor = 0.55 if bf16 else 1.0
            hbm_tpu = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                       - mem.alias_size_in_bytes
                       + mem.temp_size_in_bytes * factor)
            rec["memory"]["hbm_per_device_tpu_est"] = int(hbm_tpu)
            rec["memory"]["fits_v5e_tpu_est"] = bool(hbm_tpu < V5E["hbm_bytes"])

            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):     # jax 0.4.x: list per device
                ca = ca[0] if ca else {}
            rec["xla_cost_analysis"] = {k: float(v) for k, v in ca.items()
                                        if k in ("flops", "bytes accessed")}
            txt = compiled.as_text()
            rec["hlo"] = hlo_analysis.analyze_hlo(txt, n_dev)
            if save_hlo:
                with open(f"{out_dir}/{arch_id}__{shape_name}__{mesh_name}.hlo",
                          "w") as f:
                    f.write(txt)
            rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    rec["t_total_s"] = round(time.monotonic() - t0, 2)

    os.makedirs(out_dir, exist_ok=True)
    path = f"{out_dir}/{arch_id}__{shape_name}__{mesh_name}.json"
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def run_all(multi_pod: bool, out_dir: str, only=None, timeout=3600):
    """One subprocess per cell (isolates compile RAM; survives one bad cell)."""
    from repro.configs import registry
    results = []
    for arch in registry.ARCHS.values():
        for shape in arch.shapes:
            if only and arch.arch_id not in only:
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch.arch_id, "--shape", shape.name,
                   "--out", out_dir]
            if multi_pod:
                cmd.append("--multi-pod")
            t0 = time.monotonic()
            try:
                p = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=timeout)
                ok = p.returncode == 0
                tail = (p.stdout + p.stderr)[-400:] if not ok else ""
            except subprocess.TimeoutExpired:
                ok, tail = False, "TIMEOUT"
            results.append((arch.arch_id, shape.name, ok, round(time.monotonic() - t0, 1)))
            print(f"[{'OK' if ok else 'FAIL'}] {arch.arch_id} × {shape.name} "
                  f"({results[-1][3]}s) {tail}", flush=True)
    n_ok = sum(1 for r in results if r[2])
    print(f"\n{n_ok}/{len(results)} cells compiled "
          f"({'multi-pod 2x16x16' if multi_pod else 'single-pod 16x16'})")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--mesh", help="override, e.g. 2x4 (with pod: 2x2x4)")
    args = ap.parse_args()

    if args.all:
        run_all(args.multi_pod, args.out)
        return
    mesh_override = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh_override = (dims, axes)
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                   mesh_override=mesh_override, save_hlo=args.save_hlo)
    print(json.dumps({k: v for k, v in rec.items() if k != "traceback"},
                     indent=1, default=str))
    if not rec["ok"]:
        print(rec.get("traceback", ""), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
