"""Production meshes. A FUNCTION, not a module constant — importing this
module never touches jax device state (required by the dry-run contract)."""
from __future__ import annotations

import jax


def _axis_types_kw(n_axes: int) -> dict:
    """jax.sharding.AxisType landed after 0.4.x; Auto is the default there,
    so on older jax we simply omit the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic restarts, tests). shape/axes like above."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_types_kw(len(axes)))


def single_device_mesh():
    return make_mesh((1, 1), ("data", "model"))
