"""HLO-text analyzer for the roofline: FLOPs / bytes / collective traffic
with correct while-loop (lax.scan) trip-count multipliers.

Motivation: ``compiled.cost_analysis()`` counts a while body exactly ONCE
(verified empirically), so scan-over-layers models would be understated by
~n_layers×. We therefore parse the *partitioned* ``compiled.as_text()``
(per-device shapes), build the computation call graph, read each while op's
``known_trip_count`` backend config (fallback: max s32 constant in the
condition computation), and accumulate:

  * flops            — dot ops: 2 · prod(out) · prod(contracting dims)
  * bytes            — Σ over top-level ops of (output + operand bytes);
                       fusion internals excluded (a fusion reads its operands
                       from HBM and writes its output — the TPU model)
  * collective_bytes — per-device ICI traffic with ring-model factors:
                       all-reduce 2·b·(g-1)/g, all-gather/all-to-all b·(g-1)/g,
                       reduce-scatter b_out·(g-1), collective-permute b
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f4e2m1fn": 1,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^{]*\))?\s*->.*\{\s*$")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "iota", "while", "conditional",
                   "broadcast", "partition-id", "replica-id"}


def shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Op:
    name: str
    type_str: str
    kind: str
    rest: str          # everything after the opening paren (operands + attrs)
    operands: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    is_entry: bool
    ops: list = field(default_factory=list)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(2), bool(m.group(1)))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, kind, rest = m.groups()
        operands = re.findall(r"%([\w\.\-]+)", rest.split(" metadata=")[0])
        cur.ops.append(Op(name, type_str, kind, rest, operands))
    return comps


def _group_size(rest: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return default


def _trip_count(op: Op, comps: dict[str, Computation]) -> tuple[int, bool]:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
    if m:
        return int(m.group(1)), True
    mc = re.search(r"condition=%?([\w\.\-]+)", op.rest)
    if mc and mc.group(1) in comps:
        consts = []
        for o in comps[mc.group(1)].ops:
            mk = re.search(r"constant\((\d+)\)", o.rest)
            if o.kind == "constant" and mk:
                consts.append(int(mk.group(1)))
        if consts:
            return max(consts), False
    return 1, False


def _called(op: Op) -> list[str]:
    out = []
    for attr in ("calls", "body"):
        m = re.search(attr + r"=%?([\w\.\-]+)", op.rest)
        if m:
            out.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
    if m:
        out += re.findall(r"%?([\w\.\-]+)", m.group(1))
    return out


_COLL_RE = re.compile("^(" + "|".join(_COLLECTIVES) + r")(-start)?$")


def _collective_traffic(kind: str, out_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return float(out_bytes) * (g - 1)
    if kind in ("all-gather", "all-to-all"):
        return float(out_bytes) * (g - 1) / g
    return float(out_bytes)          # collective-permute


class Analyzer:
    def __init__(self, text: str, n_devices: int):
        self.comps = parse_module(text)
        self.n_devices = n_devices
        self.warnings: list[str] = []
        self._memo: dict[str, tuple] = {}
        # symbol table per computation: op name -> bytes
        self._sym: dict[str, dict[str, int]] = {
            c.name: {o.name: shape_bytes(o.type_str) for o in c.ops}
            for c in self.comps.values()}
        self._types: dict[str, dict[str, str]] = {
            c.name: {o.name: o.type_str for o in c.ops}
            for c in self.comps.values()}

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        _, out_dims = _shape_dims(op.type_str)
        out_prod = 1
        for d in out_dims:
            out_prod *= d
        mlhs = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        lhs_name = op.operands[0] if op.operands else None
        lhs_type = self._types[comp.name].get(lhs_name, "")
        _, lhs_dims = _shape_dims(lhs_type)
        k = 1
        if mlhs and lhs_dims:
            for d in mlhs.group(1).split(","):
                if d:
                    k *= lhs_dims[int(d)]
        return 2.0 * out_prod * k

    def analyze_comp(self, name: str, *, top_level: bool = True) -> tuple:
        """Returns (flops, bytes, coll_bytes, coll_by_kind) for ONE invocation."""
        memo_key = name
        if memo_key in self._memo:
            return self._memo[memo_key]
        comp = self.comps.get(name)
        if comp is None:
            return (0.0, 0.0, 0.0, {})
        flops = byts = coll = 0.0
        by_kind: dict[str, float] = {}
        sym = self._sym[comp.name]
        for op in comp.ops:
            mult = 1
            if op.kind == "while":
                mult, known = _trip_count(op, self.comps)
                if not known and mult == 1:
                    self.warnings.append(f"while {op.name}: trip count unknown")
            if op.kind == "dot":
                flops += self._dot_flops(comp, op)
            mcoll = _COLL_RE.match(op.kind)
            if mcoll:
                g = _group_size(op.rest, self.n_devices)
                ob = shape_bytes(op.type_str)
                if mcoll.group(2):           # -start returns (operand, result)
                    ob = ob / 2
                t = _collective_traffic(mcoll.group(1), ob, g)
                coll += t
                by_kind[mcoll.group(1)] = by_kind.get(mcoll.group(1), 0.0) + t
            # recurse into called computations
            for child in _called(op):
                f, b, c, bk = self.analyze_comp(child, top_level=False)
                is_fusion = op.kind in ("fusion", "call", "custom-call")
                flops += mult * f
                coll += mult * c
                for k, v in bk.items():
                    by_kind[k] = by_kind.get(k, 0.0) + mult * v
                if not is_fusion:            # while/conditional body bytes count
                    byts += mult * b
            # byte accounting at this computation's top level
            if op.kind not in _SKIP_BYTES_OPS and not op.kind.endswith("-done"):
                ob = shape_bytes(op.type_str)
                ib = sum(sym.get(o, 0) for o in op.operands)
                byts += ob + ib
        out = (flops, byts, coll, by_kind)
        self._memo[memo_key] = out
        return out

    def analyze(self) -> dict:
        entry = next((c for c in self.comps.values() if c.is_entry), None)
        if entry is None:
            return {"error": "no ENTRY computation"}
        f, b, c, bk = self.analyze_comp(entry.name)
        return {"flops_per_device": f, "bytes_per_device": b,
                "collective_bytes_per_device": c,
                "collectives_by_kind": bk,
                "warnings": self.warnings[:20]}


def analyze_hlo(text: str, n_devices: int) -> dict:
    return Analyzer(text, n_devices).analyze()
