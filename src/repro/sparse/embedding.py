"""EmbeddingBag and friends, in pure JAX.

JAX has no native ``nn.EmbeddingBag`` and no CSR/CSC sparse — the lookup
substrate here (``jnp.take`` + ``jax.ops.segment_sum``) IS part of the system
(kernel_taxonomy §RecSys). Three layouts are supported:

  * dense ids            — (..., ) int32 → (..., D)             (plain lookup)
  * padded multi-hot     — (B, K) ids + (B, K) weights/mask     (fixed-width bags)
  * ragged (segment)     — (N,) ids + (N,) segment_ids, B bags  (true EmbeddingBag)

The Pallas ``embedding_bag`` kernel (repro.kernels.embedding_bag) accelerates
the padded layout; these jnp paths are its oracle and the general substrate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class TableSpec:
    name: str
    vocab: int          # rows (hashed bucket count)
    dim: int
    combiner: str = "sum"   # sum | mean
    init_scale: float = 0.01

    @property
    def bytes_fp32(self) -> int:
        return self.vocab * self.dim * 4


def init_table(key: jax.Array, spec: TableSpec, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (spec.vocab, spec.dim), dtype=jnp.float32)
            * spec.init_scale).astype(dtype)


def lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Plain dense lookup: (...,) int → (..., D). mode='clip' keeps XLA
    gather in-bounds semantics explicit (matches TPU behaviour)."""
    return jnp.take(table, ids, axis=0, mode="clip")


def embedding_bag_padded(table: jax.Array, ids: jax.Array,
                         weights: Optional[jax.Array] = None,
                         combiner: str = "sum") -> jax.Array:
    """Fixed-width bags: ids (B, K) → (B, D). weights (B, K) doubles as the
    validity mask (0 for padding)."""
    vecs = lookup(table, ids)                      # (B, K, D)
    if weights is None:
        weights = jnp.ones(ids.shape, dtype=vecs.dtype)
    out = jnp.einsum("bk,bkd->bd", weights.astype(vecs.dtype), vecs)
    if combiner == "mean":
        denom = jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
        out = out / denom.astype(out.dtype)
    return out


def embedding_bag_ragged(table: jax.Array, ids: jax.Array, segment_ids: jax.Array,
                         num_bags: int, weights: Optional[jax.Array] = None,
                         combiner: str = "sum") -> jax.Array:
    """True EmbeddingBag: flat ids (N,) with segment_ids (N,) → (num_bags, D)."""
    vecs = lookup(table, ids)                      # (N, D)
    if weights is not None:
        vecs = vecs * weights[:, None].astype(vecs.dtype)
    out = jax.ops.segment_sum(vecs, segment_ids, num_segments=num_bags)
    if combiner == "mean":
        ones = jnp.ones((ids.shape[0],), vecs.dtype)
        if weights is not None:
            ones = weights.astype(vecs.dtype)
        cnt = jax.ops.segment_sum(ones, segment_ids, num_segments=num_bags)
        out = out / jnp.maximum(cnt, 1e-9)[:, None]
    return out


def cube_embedding_bag_padded(cube, group: int, ids: np.ndarray,
                              weights: Optional[np.ndarray] = None,
                              combiner: str = "sum") -> np.ndarray:
    """Host-side EmbeddingBag over the ParameterCube tail (DESIGN.md §2):
    one batched, deduplicated cube lookup for the whole (B, K) id block —
    never a per-row probe — then the same combine as
    ``embedding_bag_padded``. Returns (B, D) numpy."""
    ids = np.asarray(ids)
    rows = cube.lookup(group, ids.reshape(-1))            # (B*K, D), one gather
    rows = rows.reshape(ids.shape + (rows.shape[-1],))    # (B, K, D)
    if weights is None:
        weights = np.ones(ids.shape, rows.dtype)
    w = np.asarray(weights, dtype=rows.dtype)
    out = np.einsum("bk,bkd->bd", w, rows)
    if combiner == "mean":
        denom = np.maximum(w.sum(-1, keepdims=True), 1e-9)
        out = out / denom.astype(out.dtype)
    return out


def offsets_to_segment_ids(offsets: np.ndarray, total: int) -> np.ndarray:
    """torch-EmbeddingBag style offsets (B,) → segment_ids (N,). Host-side."""
    seg = np.zeros(total, dtype=np.int32)
    np.add.at(seg, offsets[1:][offsets[1:] < total], 1)
    return np.cumsum(seg).astype(np.int32)
