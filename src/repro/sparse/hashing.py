"""Feature signatures via universal hashing (paper §5.1).

The cube keys every sparse parameter by a *compact feature signature*: a
universally-unique identifier derived from (feature-group, raw id) via a
universal hash family (Carter & Wegman). We reproduce that exactly; the same
signature function is used host-side (ParameterCube) and device-side (hashed
embedding lookup), so cube contents and TPU-sharded tables agree.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# 64-bit universal multiply-shift family with fixed, documented constants.
_MUL = np.uint64(0x9E3779B97F4A7C15)  # golden-ratio odd multiplier
_XOR = np.uint64(0xBF58476D1CE4E5B9)


def signature_np(group: np.ndarray | int, raw_id: np.ndarray | int) -> np.ndarray:
    """uint64 feature signature, numpy (host / cube side)."""
    g = np.asarray(group, dtype=np.uint64)
    r = np.asarray(raw_id, dtype=np.uint64)
    h = (g * np.uint64(0xD1B54A32D192ED03) + r) & np.uint64(0xFFFFFFFFFFFFFFFF)
    h ^= h >> np.uint64(33)
    h = (h * _MUL) & np.uint64(0xFFFFFFFFFFFFFFFF)
    h ^= h >> np.uint64(29)
    h = (h ^ _XOR) & np.uint64(0xFFFFFFFFFFFFFFFF)
    return h


def hash_bucket_np(group, raw_id, vocab: int) -> np.ndarray:
    """Row index into a hashed embedding table (host side)."""
    return (signature_np(group, raw_id) % np.uint64(vocab)).astype(np.int64)


def hash_bucket(group: int, raw_ids: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """Row index into a hashed embedding table (device side, uint32 math).

    jnp lacks uint64 by default; we use a 2x32-bit mix with the same
    collision properties. Determinism across host/device is not required
    (tables are keyed consistently per side); tests assert determinism and
    near-uniform spread.
    """
    x = raw_ids.astype(jnp.uint32)
    g = jnp.uint32((group * 0x9E3779B9 + 0x85EBCA6B) & 0xFFFFFFFF)
    h = (x ^ g) * jnp.uint32(0xCC9E2D51)
    h = (h << 13) | (h >> 19)
    h = h * jnp.uint32(0x1B873593) + jnp.uint32(0xE6546B64)
    h ^= h >> 16
    h = h * jnp.uint32(0x85EBCA6B)
    h ^= h >> 13
    return (h % jnp.uint32(vocab)).astype(jnp.int32)
