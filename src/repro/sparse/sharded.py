"""Row-sharded embedding tables — the on-TPU distributed sparse parameter cube.

The paper's cube is a distributed read-only KV store over feature signatures
(§5.1). On a pod the same role is played by row-sharding each table over the
``model`` mesh axis; a lookup is a shard_map: every device takes the rows it
owns (masked take) and the results are summed over the axis (psum) — each row
lives on exactly one shard, so the psum reconstructs the gather. The
collective is only (batch × dim), never a table transfer.

Differentiable: grad w.r.t. the table is the masked scatter-add of the
incoming cotangents on the owning shard (psum's transpose is identity
broadcast), i.e. exactly the sparse gradient a parameter server would apply.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import runtime

SHARD_AXIS = "model"


def table_spec_sharded() -> P:
    return P(SHARD_AXIS, None)


def _local_lookup(table_shard: jax.Array, ids: jax.Array, rows_per_shard: int) -> jax.Array:
    shard_idx = jax.lax.axis_index(SHARD_AXIS)
    local = ids - shard_idx * rows_per_shard
    ok = (local >= 0) & (local < rows_per_shard)
    vecs = jnp.take(table_shard, jnp.where(ok, local, 0), axis=0, mode="clip")
    vecs = vecs * ok[..., None].astype(vecs.dtype)
    return jax.lax.psum(vecs, SHARD_AXIS)


def sharded_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """ids (...,) int32 → (..., D), table rows sharded over ``model``.

    Falls back to a dense take when no >1 ``model`` axis is installed, so the
    same model code runs in smoke tests (1 device) and on the pod.
    """
    mesh = runtime.current_mesh()
    if mesh is None or mesh.shape.get(SHARD_AXIS, 1) == 1:
        return jnp.take(table, ids, axis=0, mode="clip")
    n_shards = mesh.shape[SHARD_AXIS]
    vocab = table.shape[0]
    if vocab % n_shards != 0:
        # Small tables (e.g. SchNet atom types) are not worth sharding.
        return jnp.take(table, ids, axis=0, mode="clip")
    rows_per_shard = vocab // n_shards

    # Replicate ids when the leading dim can't shard the data axes (e.g.
    # batch-1 decode) — the psum('model') path is identical either way.
    shardable = (ids.ndim >= 1 and ids.shape[0] % runtime.data_axis_size() == 0
                 and ids.shape[0] >= runtime.data_axis_size())
    lead = P(runtime.batch_axes()) if shardable else P(None)
    id_spec = P(*(lead + (None,) * (ids.ndim - 1)))
    out_spec = P(*(lead + (None,) * ids.ndim))

    fn = runtime.shard_map(
        lambda t, i: _local_lookup(t, i, rows_per_shard),
        mesh=mesh,
        in_specs=(P(SHARD_AXIS, None), id_spec),
        out_specs=out_spec,
        check_vma=False,
    )
    return fn(table, ids)


def _donate_argnums() -> tuple:
    """Donate the table buffer where the backend can actually alias it (TPU/
    GPU); CPU donation is unimplemented in XLA and would only warn-spam."""
    return (0,) if jax.default_backend() in ("tpu", "gpu") else ()


@functools.lru_cache(maxsize=None)
def _row_update_fn(mesh, rows_per_shard: int):
    if mesh is None:
        return jax.jit(lambda t, i, r: t.at[i].set(r, mode="drop"),
                       donate_argnums=_donate_argnums())

    def local(t, i, r):
        shard_idx = jax.lax.axis_index(SHARD_AXIS)
        local_ids = i - shard_idx * rows_per_shard
        # mode="drop" alone is NOT the ownership mask: drop applies AFTER
        # negative-index normalization, so a row owned by an EARLIER shard
        # (negative local id) would wrap into this shard's tail and
        # silently overwrite another key's parameters. Push non-owned ids
        # past the end instead — those genuinely drop.
        ok = (local_ids >= 0) & (local_ids < rows_per_shard)
        safe = jnp.where(ok, local_ids, rows_per_shard)
        return t.at[safe].set(r, mode="drop")

    fn = runtime.shard_map(local, mesh=mesh,
                           in_specs=(P(SHARD_AXIS, None), P(None),
                                     P(None, None)),
                           out_specs=P(SHARD_AXIS, None), check_vma=False)
    return jax.jit(fn, donate_argnums=_donate_argnums())


def sharded_row_update(table: jax.Array, ids: jax.Array,
                       rows: jax.Array) -> jax.Array:
    """In-place row updates of the HBM head: scatter ``rows`` into ``table``
    at ``ids`` with the table buffer DONATED, so XLA writes the touched rows
    into the existing allocation — the streaming-update path (DESIGN.md §6)
    migrates hot rows from the cube tail into a live multi-GB head without
    a table rebuild or a second table's worth of HBM. Under a >1 ``model``
    mesh axis the scatter runs per shard inside shard_map (each device
    updates only the rows it owns; ids are replicated — they're int32 and
    tiny). Returns the updated table; the input reference is consumed where
    donation is in effect. Duplicate ids within one call are the caller's
    to resolve (the update policy dedups, last-wins, before calling)."""
    ids = jnp.asarray(ids, jnp.int32)
    rows = jnp.asarray(rows, table.dtype)
    if ids.size == 0:
        return table
    mesh = runtime.current_mesh()
    n_shards = 1 if mesh is None else mesh.shape.get(SHARD_AXIS, 1)
    vocab = table.shape[0]
    if mesh is None or n_shards == 1 or vocab % n_shards != 0:
        return _row_update_fn(None, 0)(table, ids, rows)
    return _row_update_fn(mesh, vocab // n_shards)(table, ids, rows)


def sharded_embedding_bag(table: jax.Array, ids: jax.Array,
                          weights: Optional[jax.Array] = None,
                          combiner: str = "sum") -> jax.Array:
    """Padded multi-hot bag over a row-sharded table: ids (B, K) → (B, D)."""
    vecs = sharded_lookup(table, ids)          # (B, K, D)
    if weights is None:
        w = jnp.ones(ids.shape, dtype=vecs.dtype)
    else:
        w = weights.astype(vecs.dtype)
    out = jnp.einsum("bk,bkd->bd", w, vecs)
    if combiner == "mean":
        out = out / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return out


# --------------------------------------------------------------------------
# 2-D row sharding: rows over the flattened ("data","model") axes — needed
# for TB-scale tables (JiZHI Table 1: 210–500 GB/service; our two-tower is
# ~0.5 TB fp32 → 2 GB/chip over 256 chips). The bag is POOLED LOCALLY before
# any collective, so comm is O(B×D) (a psum_scatter + psum), never O(B×K×D)
# and never a table transfer — this is the cube-access pattern on ICI.
# --------------------------------------------------------------------------

BIG_AXES = ("data", "model")


def sharded_gather_a2a(table: jax.Array, ids: jax.Array,
                       cap_factor: float = 4.0) -> jax.Array:
    """Single-id lookup over a 2-D row-sharded table via ALL-TO-ALL exchange.

    The psum-based path dense-sums (N, D) partials that are zero everywhere
    except each id's owner — ~2 orders of magnitude more ICI traffic than
    the information moved. This is the DLRM/TPU-embedding exchange instead:

      1. all-gather the int32 ids over both axes (N×4 bytes — tiny);
      2. every device packs the rows IT OWNS into per-destination buckets
         (destination = the id's position shard), capacity-padded;
      3. one all_to_all moves each row exactly once;
      4. receivers scatter rows into their (N_loc, D) output slice.

    Comm per device ≈ n_shards·cap·D ≈ cap_factor × the information-
    theoretic minimum, vs (g−1)·N_loc·D·g for the psum path.
    Capacity: ids here index positions uniformly across shards, so bucket
    occupancy is Poisson(N/g²); cap_factor=4 makes overflow vanishingly
    rare (overflowed rows fall back to zero — bound checked by tests).
    [§Perf iteration 5 — beyond-paper optimization]
    """
    mesh = runtime.current_mesh()
    if mesh is None or mesh.shape.get("model", 1) * mesh.shape.get("data", 1) == 1:
        return jnp.take(table, ids, axis=0, mode="clip")
    n_data = mesh.shape.get("data", 1)
    n_model = mesh.shape.get("model", 1)
    g = n_data * n_model
    vocab, D = table.shape
    if vocab % g:
        return sharded_embedding_bag_2d(table, ids[:, None])
    orig_n = ids.shape[0]
    pad = (-orig_n) % g
    if pad:
        ids = jnp.pad(ids, (0, pad))
    N = ids.shape[0]
    rows = vocab // g
    n_loc = N // g
    cap = max(8, int(np.ceil(cap_factor * N / (g * g) / 8)) * 8)

    def local(t, i):
        di = jax.lax.axis_index("data")
        mi = jax.lax.axis_index("model")
        shard = di * n_model + mi
        ig = jax.lax.all_gather(i, ("data", "model"), axis=0, tiled=True)
        local_ids = ig - shard * rows
        mine = (local_ids >= 0) & (local_ids < rows)
        dest = (jnp.arange(N, dtype=jnp.int32) // n_loc)
        # dest is MONOTONE in position, so rank-in-bucket is a block-wise
        # exclusive cumsum — no sort needed [§Perf iteration 6]
        mine_i = mine.astype(jnp.int32)
        excl = jnp.cumsum(mine_i) - mine_i              # exclusive count
        start_excl = jnp.take(excl, dest * n_loc)       # count before block
        pos = excl - start_excl
        keep = mine & (pos < cap)
        slot = jnp.where(keep, dest * cap + pos, g * cap)
        # slot → local row index, THEN gather straight into the buckets —
        # never materializes an (N, D) dense intermediate (same discipline
        # as the MoE dispatch)
        idx_buf = jnp.zeros((g * cap + 1,), jnp.int32).at[slot].set(
            jnp.clip(local_ids, 0, rows - 1).astype(jnp.int32))
        occ = jnp.zeros((g * cap + 1,), t.dtype).at[slot].max(
            keep.astype(t.dtype))
        buckets = jnp.take(t, idx_buf[: g * cap], axis=0) \
            * occ[: g * cap, None]
        posn = jnp.full((g * cap + 1,), -1, jnp.int32).at[slot].set(
            jnp.where(keep, jnp.arange(N, dtype=jnp.int32) % n_loc, -1))
        buckets = buckets.reshape(g, cap, D)
        posn = posn[: g * cap].reshape(g, cap)
        # one row moves exactly once
        recv = jax.lax.all_to_all(buckets, ("data", "model"), 0, 0,
                                  tiled=True)          # (g*cap, D)
        rpos = jax.lax.all_to_all(posn, ("data", "model"), 0, 0, tiled=True)
        out = jnp.zeros((n_loc + 1, D), t.dtype)
        out = out.at[jnp.where(rpos.reshape(-1) >= 0, rpos.reshape(-1),
                               n_loc)].add(recv.reshape(-1, D))
        return out[:n_loc]

    fn = runtime.shard_map(local, mesh=mesh,
                       in_specs=(P(BIG_AXES, None), P(BIG_AXES)),
                       out_specs=P(BIG_AXES, None), check_vma=False)
    out = fn(table, ids)
    return out[:orig_n] if pad else out


def table_spec_2d() -> P:
    return P(BIG_AXES, None)


def sharded_embedding_bag_2d(table: jax.Array, ids: jax.Array,
                             weights: Optional[jax.Array] = None,
                             combiner: str = "sum",
                             comm_dtype=None) -> jax.Array:
    """ids (B, K) → (B, D); table rows sharded over ("data","model").

    Inside shard_map: all-gather the (tiny, int32) ids over "data", pool each
    device's owned rows into a partial (B_row, D), then psum_scatter("data")
    + psum("model") reassembles exact bag sums on the batch owners.

    comm_dtype (e.g. bf16) downcasts the pooled partials before the
    collectives — halves ICI traffic on serving paths where bf16 pooled
    embeddings are ample precision [§Perf iteration 4].
    """
    mesh = runtime.current_mesh()
    squeeze = ids.ndim == 1
    if squeeze:
        ids = ids[:, None]
        weights = None if weights is None else weights[:, None]
    if mesh is None or mesh.shape.get("model", 1) * mesh.shape.get("data", 1) == 1:
        from repro.sparse.embedding import embedding_bag_padded
        return embedding_bag_padded(table, ids, weights, combiner)
    n_data = mesh.shape.get("data", 1)
    n_model = mesh.shape.get("model", 1)
    n_shards = n_data * n_model
    vocab = table.shape[0]
    assert vocab % n_shards == 0, f"vocab {vocab} vs {n_shards} shards"
    rows = vocab // n_shards
    B = ids.shape[0]
    batch_axes = runtime.batch_axes()
    scatterable = (B % runtime.data_axis_size()) == 0 and B >= runtime.data_axis_size()

    D = table.shape[1]
    K = ids.shape[1]

    def local(t, i, w):
        # flat shard index: data-major over ("data","model")
        di = jax.lax.axis_index("data")
        mi = jax.lax.axis_index("model")
        shard = di * n_model + mi
        if scatterable:
            i = jax.lax.all_gather(i, "data", axis=0, tiled=True)
            w = jax.lax.all_gather(w, "data", axis=0, tiled=True)

        def pool(iw):
            ic, wc = iw
            local_ids = ic - shard * rows
            ok = (local_ids >= 0) & (local_ids < rows)
            vecs = jnp.take(t, jnp.where(ok, local_ids, 0), axis=0,
                            mode="clip")
            wv = wc.astype(vecs.dtype) * ok.astype(vecs.dtype)
            return jnp.einsum("bk,bkd->bd", wv, vecs), wv.sum(-1)

        # the (B_row, K, D) gather can dominate peak memory at bulk-serving
        # batches (262k × 50 × 256 ≈ 13 GB) — chunk it through lax.map
        B_row = i.shape[0]
        if B_row * K * D > (1 << 26):
            n_ch = 1
            target = max(1, (1 << 24) // max(1, K * D))
            while B_row % (n_ch * 2) == 0 and B_row // n_ch > target:
                n_ch *= 2
            part, cnt = jax.lax.map(
                pool, (i.reshape(n_ch, -1, K), w.reshape(n_ch, -1, K)))
            part = part.reshape(B_row, -1)
            cnt = cnt.reshape(B_row)
        else:
            part, cnt = pool((i, w))
        out_dtype = part.dtype
        if comm_dtype is not None:
            part = part.astype(comm_dtype)
        if scatterable:
            part = jax.lax.psum_scatter(part, "data", scatter_dimension=0, tiled=True)
            part = jax.lax.psum(part, "model")
            cnt = jax.lax.psum_scatter(cnt, "data", scatter_dimension=0, tiled=True)
            cnt = jax.lax.psum(cnt, "model")
        else:
            part = jax.lax.psum(part, ("data", "model"))
            cnt = jax.lax.psum(cnt, ("data", "model"))
        part = part.astype(out_dtype)
        if combiner == "mean":
            part = part / jnp.maximum(cnt, 1e-9)[:, None]
        return part

    if weights is None:
        weights = jnp.ones(ids.shape, jnp.float32)
    id_spec = P(batch_axes, None) if scatterable else P(None, None)
    out_spec = P(batch_axes, None) if scatterable else P(None, None)
    fn = runtime.shard_map(local, mesh=mesh,
                       in_specs=(P(BIG_AXES, None), id_spec, id_spec),
                       out_specs=out_spec, check_vma=False)
    return fn(table, ids, weights)
