"""Per-server circuit breaker (DESIGN.md §8.2).

A dead cube server costs every lookup that routes to it one failed-probe
RPC before the replica path takes over. The breaker remembers: after
``failure_threshold`` consecutive failures it OPENS and the router treats
the server as down without probing; after ``cooldown_s`` it lets ONE
probe through (HALF-OPEN) — a success closes it, a failure re-opens it
and restarts the cooldown. States:

    closed ──(threshold consecutive failures)──► open
    open ──(cooldown elapsed)──► half-open
    half-open ──(probe ok)──► closed
    half-open ──(probe fails)──► open

Clock-agnostic: every transition takes ``now`` from the caller, so the
same breaker runs on wall time (AsyncExecutor) and on the SimExecutor's
virtual clock. Thread-safe: stage workers probe concurrently.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half_open"


class ServerHealth:
    """Circuit breaker for one cube server."""

    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 1.0):
        assert failure_threshold >= 1 and cooldown_s >= 0.0
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self.state = BREAKER_CLOSED
        self.consecutive_failures = 0
        self.opened_at = 0.0
        self._probe_out = False      # half-open: one probe in flight
        self._lock = threading.Lock()
        # observability counters
        self.opens = 0
        self.closes = 0
        self.skipped = 0             # requests the open breaker absorbed

    def allow_request(self, now: float) -> bool:
        """May the router probe this server at ``now``? An open breaker
        absorbs the request (False = route straight to the replica tier);
        after the cooldown exactly one caller gets True as the half-open
        probe until its success/failure lands."""
        with self._lock:
            if self.state == BREAKER_CLOSED:
                return True
            if self.state == BREAKER_OPEN:
                if now - self.opened_at < self.cooldown_s:
                    self.skipped += 1
                    return False
                self.state = BREAKER_HALF_OPEN
                self._probe_out = False
            # half-open: admit a single probe per transition
            if self._probe_out:
                self.skipped += 1
                return False
            self._probe_out = True
            return True

    def record_success(self, now: float):
        with self._lock:
            self.consecutive_failures = 0
            self._probe_out = False
            if self.state != BREAKER_CLOSED:
                self.closes += 1
                self.state = BREAKER_CLOSED

    def record_failure(self, now: float):
        with self._lock:
            self.consecutive_failures += 1
            self._probe_out = False
            if (self.state == BREAKER_HALF_OPEN
                    or self.consecutive_failures >= self.failure_threshold):
                if self.state != BREAKER_OPEN:
                    self.opens += 1
                self.state = BREAKER_OPEN
                self.opened_at = now

    def trip(self, now: float):
        """Force-open without paying ``failure_threshold`` probes — the
        fleet-wide verdict path: when a HOST is found dead, every breaker
        it backs opens at once (one strike total, DESIGN.md §11.5), not
        one failure-threshold run per shard."""
        with self._lock:
            self.consecutive_failures = max(self.consecutive_failures,
                                            self.failure_threshold)
            self._probe_out = False
            if self.state != BREAKER_OPEN:
                self.opens += 1
            self.state = BREAKER_OPEN
            self.opened_at = now


class HealthRegistry:
    """Breakers keyed by serving endpoint, plus the clock they share.

    Historically one breaker per in-process cube server, keyed by index
    (``n_servers=...``); the mesh generalizes keys to ``(host, server)``
    tuples (``keys=[...]``) so a host-level failure can open all of the
    host's breakers with ONE strike (``record_host_failure``). The
    positional ``servers`` list survives in key order — the cube's
    ``_alive_mask`` indexes it positionally.

    ``clock`` defaults to ``time.monotonic``; benchmarks running on a
    virtual clock pass their own callable (``lambda: sim_now``). Attach to
    a cube with ``ParameterCube.attach_health`` or a mesh with
    ``MeshCube.attach_health``."""

    def __init__(self, n_servers: Optional[int] = None,
                 clock: Optional[Callable] = None,
                 failure_threshold: int = 3, cooldown_s: float = 1.0,
                 keys: Optional[list] = None):
        assert (n_servers is None) != (keys is None), \
            "pass exactly one of n_servers / keys"
        self.clock = clock or time.monotonic
        self.keys = list(keys) if keys is not None else list(range(n_servers))
        self._breakers = {k: ServerHealth(failure_threshold, cooldown_s)
                          for k in self.keys}
        # positional view in key order — legacy int-keyed callers
        # (cube._alive_mask) index this directly
        self.servers = [self._breakers[k] for k in self.keys]

    @classmethod
    def for_mesh(cls, hosts, n_shards: int, **kw) -> "HealthRegistry":
        """One breaker per (host, shard) pair of a mesh topology."""
        return cls(keys=[(h, s) for h in hosts for s in range(n_shards)],
                   **kw)

    def __getitem__(self, key) -> ServerHealth:
        return self._breakers[key]

    def __len__(self) -> int:
        return len(self.servers)

    def record_host_failure(self, host, now: Optional[float] = None):
        """One dead host = one strike: trip every breaker whose key names
        ``host`` (tuple keys with ``key[0] == host``)."""
        now = self.clock() if now is None else now
        for k, b in self._breakers.items():
            if isinstance(k, tuple) and k and k[0] == host:
                b.trip(now)

    def host_states(self, host) -> dict:
        return {k: b.state for k, b in self._breakers.items()
                if isinstance(k, tuple) and k and k[0] == host}

    def states(self) -> list[str]:
        return [h.state for h in self.servers]

    @property
    def total_skipped(self) -> int:
        return sum(h.skipped for h in self.servers)
