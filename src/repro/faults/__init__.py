"""Failure-domain substrate (DESIGN.md §8): deterministic fault injection
for the parameter cube's server fleet plus the circuit-breaker health
model the router consults before paying for a probe.

  * ``FaultPlan`` / ``FaultInjector`` — a seedable, clock-agnostic schedule
    of per-server faults (latency spikes, transient unavailability, hard
    kills with later revival, slow-disk) applied mid-run by polling
    ``poll(now)`` from any clock: wall time in AsyncExecutor drills,
    the virtual clock in SimExecutor benchmarks.
  * ``ServerHealth`` / ``HealthRegistry`` — per-server circuit breaker
    (closed → open → half-open with probe requests). ``ParameterCube``
    consults it before routing so a dead server is skipped without paying
    the failed-probe RPC once the breaker opens.
  * ``crash_point`` / ``arm`` / ``SimulatedCrash`` — whole-process crash
    simulation for recovery drills (DESIGN.md §9): named abort points in
    durable-write paths that a drill arms to produce torn on-disk states.
"""
from repro.faults.crash import (SimulatedCrash, arm, crash_point,
                                disarm_all)
from repro.faults.health import (BREAKER_CLOSED, BREAKER_HALF_OPEN,
                                 BREAKER_OPEN, HealthRegistry, ServerHealth)
from repro.faults.plan import (FaultEvent, FaultInjector, FaultPlan,
                               HostFaultInjector)

__all__ = [
    "FaultEvent", "FaultInjector", "FaultPlan", "HostFaultInjector",
    "ServerHealth", "HealthRegistry",
    "BREAKER_CLOSED", "BREAKER_OPEN", "BREAKER_HALF_OPEN",
    "SimulatedCrash", "arm", "crash_point", "disarm_all",
]
