"""Simulated process crashes for recovery drills (DESIGN.md §9).

The chaos plane (``FaultPlan``/``FaultInjector``) models *intra-process*
faults: a server dies, a disk slows, a breaker opens — the process keeps
serving. Crash-safety needs the complement: the PROCESS dies at the worst
possible instant, mid-way through a multi-file publish, and a fresh
process must recover from whatever the filesystem holds.

``crash_point(name)`` is a named no-op sprinkled through durable-write
paths (delta emit, snapshot publish, chunked compaction). A drill ``arm``s
a point and the next hit raises :class:`SimulatedCrash` — the test/bench
catches it, DISCARDS the in-memory state (that is the crash), and runs
recovery against the torn on-disk state the abort left behind.

Unarmed, a crash point is one dict-emptiness check — cheap enough to live
inside writer loops. Points are process-global (the drills are
single-process by construction); ``disarm_all`` resets between cases.
"""
from __future__ import annotations

import threading

__all__ = ["SimulatedCrash", "arm", "disarm_all", "armed", "crash_point"]


class SimulatedCrash(RuntimeError):
    """Raised by an armed crash point: everything after this instant — in
    the aborted call stack AND in the process state the drill discards —
    simulates work a real crash would have lost."""


_lock = threading.Lock()
_armed: dict[str, int] = {}      # point → remaining hits before crash


def arm(point: str, at_hit: int = 1):
    """Arm ``point`` to crash on its ``at_hit``-th invocation (1 = next).
    The point disarms itself when it fires — one crash per arm."""
    assert at_hit >= 1
    with _lock:
        _armed[point] = at_hit


def disarm_all():
    with _lock:
        _armed.clear()


def armed() -> dict:
    with _lock:
        return dict(_armed)


def crash_point(point: str):
    """Durable-write paths call this at each torn-state boundary; a drill
    that armed ``point`` gets its simulated crash here."""
    if not _armed:                       # fast path: nothing armed anywhere
        return
    with _lock:
        n = _armed.get(point)
        if n is None:
            return
        if n > 1:
            _armed[point] = n - 1
            return
        del _armed[point]
    raise SimulatedCrash(point)
