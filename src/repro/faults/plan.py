"""Deterministic fault schedules (DESIGN.md §8.1).

A ``FaultPlan`` is a pure data schedule — (time, kind, server, amount)
tuples — built either explicitly (benchmark drills script the exact
scenario they gate) or sampled from a seeded RNG (``FaultPlan.random``:
same seed → same faults, so chaos results are reproducible). The
``FaultInjector`` walks the schedule against ANY clock: call
``poll(now)`` from a stage op (``ctx.now()``) or a drill loop and every
event whose time has come is applied to the cube.

Fault taxonomy (per cube server):

  * ``kill``          — hard kill (``alive = False``); optional later
                        revival. Lookups fail over to replicas.
  * ``unavailable``   — transient kill with a mandatory auto-revive
                        (network partition / GC pause flavour).
  * ``latency_spike`` — adds ``amount`` seconds to every RPC touching the
                        server for the duration.
  * ``slow_disk``     — multiplies the disk-block latency of the server's
                        memmapped blocks by ``amount`` for the duration.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

KINDS = ("kill", "revive", "unavailable", "latency_spike", "slow_disk")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled state change. ``until`` (absolute time) auto-schedules
    the recovery for transient kinds; ``amount`` is seconds for
    ``latency_spike`` and a multiplier for ``slow_disk``."""
    at: float
    kind: str
    server: int
    until: Optional[float] = None
    amount: float = 0.0


@dataclass
class FaultPlan:
    events: list = field(default_factory=list)

    # ------------------------------------------------------------ builders
    def kill(self, server: int, at: float,
             revive_at: Optional[float] = None) -> "FaultPlan":
        self.events.append(FaultEvent(at, "kill", server, until=revive_at))
        return self

    def unavailable(self, server: int, at: float,
                    duration_s: float) -> "FaultPlan":
        self.events.append(
            FaultEvent(at, "unavailable", server, until=at + duration_s))
        return self

    def latency_spike(self, server: int, at: float, duration_s: float,
                      add_s: float) -> "FaultPlan":
        self.events.append(FaultEvent(at, "latency_spike", server,
                                      until=at + duration_s, amount=add_s))
        return self

    def slow_disk(self, server: int, at: float, duration_s: float,
                  mult: float = 10.0) -> "FaultPlan":
        self.events.append(FaultEvent(at, "slow_disk", server,
                                      until=at + duration_s, amount=mult))
        return self

    @classmethod
    def random(cls, seed: int, n_servers: int, horizon_s: float,
               rate_per_s: float = 0.05, max_down_s: float = 2.0,
               spike_add_s: float = 2e-3, disk_mult: float = 10.0,
               allow_kill: bool = True) -> "FaultPlan":
        """Poisson-ish fault arrivals over [0, horizon): deterministic in
        ``seed``. Every sampled fault recovers within ``max_down_s`` so a
        random plan never leaves the fleet permanently degraded."""
        rng = np.random.default_rng(seed)
        plan = cls()
        t = 0.0
        kinds = ["unavailable", "latency_spike", "slow_disk"]
        if allow_kill:
            kinds.append("kill")
        while True:
            t += float(rng.exponential(1.0 / max(rate_per_s, 1e-9)))
            if t >= horizon_s:
                break
            sid = int(rng.integers(n_servers))
            dur = float(rng.uniform(0.1, 1.0) * max_down_s)
            kind = kinds[int(rng.integers(len(kinds)))]
            if kind == "kill":
                plan.kill(sid, t, revive_at=t + dur)
            elif kind == "unavailable":
                plan.unavailable(sid, t, dur)
            elif kind == "latency_spike":
                plan.latency_spike(sid, t, dur,
                                   float(rng.uniform(0.2, 1.0) * spike_add_s))
            else:
                plan.slow_disk(sid, t, dur, disk_mult)
        return plan

    # ------------------------------------------------------------ timeline
    def timeline(self) -> list:
        """Expand transient faults into (start, recover) pairs and return
        every state change sorted by time (recoveries after starts at the
        same instant)."""
        out = []
        for e in self.events:
            if e.kind not in KINDS:
                raise ValueError(f"unknown fault kind {e.kind!r}")
            out.append((e.at, 0, e))
            if e.until is not None:
                out.append((e.until, 1, e))
        out.sort(key=lambda x: (x[0], x[1]))
        return out


class FaultInjector:
    """Applies a plan's due events to a cube. Clock-agnostic: the caller
    owns time and calls ``poll(now)`` whenever it likes; every scheduled
    change with ``at <= now`` lands (idempotently — the walk index only
    moves forward). Recoveries restore the pre-fault state: revive for
    kills/unavailability, zero extra latency, unit disk multiplier."""

    def __init__(self, cube, plan: FaultPlan):
        self.cube = cube
        self.plan = plan
        self._timeline = plan.timeline()
        self._i = 0
        self.applied: list = []      # (t, phase, FaultEvent) audit log

    def poll(self, now: float) -> int:
        n = 0
        while self._i < len(self._timeline):
            t, phase, e = self._timeline[self._i]
            if t > now:
                break
            self._apply(e, recovering=bool(phase))
            self.applied.append((t, "recover" if phase else "start", e))
            self._i += 1
            n += 1
        return n

    def drain(self) -> int:
        """Apply everything left (end-of-drill cleanup)."""
        return self.poll(float("inf"))

    @property
    def exhausted(self) -> bool:
        return self._i >= len(self._timeline)

    def _apply(self, e: FaultEvent, recovering: bool):
        srv = self.cube.servers[e.server]
        if e.kind in ("kill", "unavailable"):
            if recovering:
                self.cube.revive_server(e.server)
            else:
                self.cube.kill_server(e.server)
        elif e.kind == "latency_spike":
            srv.extra_latency_s = 0.0 if recovering else e.amount
        elif e.kind == "slow_disk":
            srv.disk_latency_mult = 1.0 if recovering else e.amount


class HostFaultInjector(FaultInjector):
    """The same schedule machinery one level up: events target MESH HOSTS
    (``FaultEvent.server`` indexes ``mesh.host_list``) instead of
    in-process cube servers. Kills flip the host's ``alive`` flag —
    detection happens organically: the next lookup's failed probe raises
    ``HostDown``, the ShardClient records ONE host-level strike (opening
    every (host, *) breaker) and fails over along the topology's
    preference order. ``slow_disk`` has no host-level analogue and maps
    to a latency spike of ``amount`` milliseconds-scale seconds."""

    def __init__(self, mesh, plan: FaultPlan):
        super().__init__(mesh, plan)
        self.mesh = mesh

    def _apply(self, e: FaultEvent, recovering: bool):
        host = self.mesh.host_list[e.server]
        if e.kind in ("kill", "unavailable"):
            if recovering:
                self.mesh.revive_host(host.host_id)
            else:
                self.mesh.kill_host(host.host_id)
        elif e.kind in ("latency_spike", "slow_disk"):
            host.extra_latency_s = 0.0 if recovering else e.amount
