# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared kernel-dispatch policy.

Every ``ops.py`` wrapper takes ``interpret: bool | None = None`` and resolves
``None`` through :func:`default_interpret` at trace time — the Pallas
interpreter only when no TPU backend is attached (CPU containers, CI), the
compiled kernel on real hardware. ``REPRO_PALLAS_INTERPRET=0/1`` overrides
both ways (e.g. force-interpret on TPU while debugging a kernel).
"""
from __future__ import annotations

import os

import jax

_TRUTHY = ("1", "true", "True", "yes")


def tpu_present() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


def pad_axis(x, mult: int, axis: int):
    """Zero-pad one axis up to the next multiple of ``mult`` (shared by the
    kernel wrappers — padded rows are masked or sliced off by each op)."""
    import jax.numpy as jnp
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def default_interpret() -> bool:
    """True ⇒ run Pallas kernels in interpreter mode.

    Resolution happens when an op is traced; the decision is baked into that
    trace (it is a static argument), so flipping the env var mid-process only
    affects shapes not yet compiled.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env in _TRUTHY
    return not tpu_present()


def resolve_interpret(interpret) -> bool:
    return default_interpret() if interpret is None else bool(interpret)
