"""Pallas TPU embedding-bag: gather + weighted pool, HBM → VMEM row streaming.

TPU adaptation (vs. the GPU gather kernels the recsys literature assumes):
there is no per-lane random HBM access on TPU — the *grid pipeline* does the
gather instead. Ids live in SMEM via scalar prefetch
(PrefetchScalarGridSpec); the TABLE BlockSpec's index_map reads the
prefetched id for grid cell (b, k) and selects that ROW as the block, so the
pipeline emitter issues exactly one (1, D) HBM→VMEM DMA per bag member,
double-buffered across the sequential grid. The output block is revisited
for all k of one bag (TPU grids are sequential), accumulating the weighted
sum in VMEM; it is flushed to HBM once per bag.

Grid: (B, K). VMEM working set: 2×(1, D) table rows (double buffer) +
(1, D) accumulator — D up to ~8k rows fit trivially; MXU is not involved
(pure VPU multiply-add), which is correct for a bandwidth-bound op.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, w_ref, trow, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += trow[...] * w_ref[0, 0].astype(out_ref.dtype)


def embedding_bag_pallas(table: jax.Array, ids: jax.Array, weights: jax.Array,
                         *, interpret: bool = False) -> jax.Array:
    """table (V, D); ids (B, K) int32; weights (B, K) (0 ⇒ padding).
    Returns (B, D) weighted sums (combiner handling lives in ops.py)."""
    B, K = ids.shape
    V, D = table.shape

    def t_map(b, k, ids_ref):
        return (ids_ref[b, k], 0)

    def w_map(b, k, ids_ref):
        return (b, k)

    def o_map(b, k, ids_ref):
        return (b, 0)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, K),
            in_specs=[
                pl.BlockSpec((1, 1), w_map),          # weight scalar
                pl.BlockSpec((1, D), t_map),          # gathered table row
            ],
            out_specs=pl.BlockSpec((1, D), o_map),
        ),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(ids, weights, table)
