"""jit'd wrapper for the embedding_bag Pallas kernel (+combiner/vjp)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_ref


@functools.partial(jax.jit, static_argnames=("combiner", "interpret"))
def embedding_bag(table, ids, weights=None, combiner: str = "sum",
                  interpret: bool | None = None):
    """Drop-in EmbeddingBag. ``interpret=None`` → interpreter off-TPU
    (CPU containers), compiled kernel on TPU."""
    interpret = resolve_interpret(interpret)
    if weights is None:
        weights = jnp.ones(ids.shape, jnp.float32)
    ids = jnp.clip(ids, 0, table.shape[0] - 1).astype(jnp.int32)
    out = embedding_bag_pallas(table, ids, weights, interpret=interpret)
    if combiner == "mean":
        out = out / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9).astype(out.dtype)
    return out
