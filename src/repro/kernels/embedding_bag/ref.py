"""Pure-jnp oracle for the embedding_bag kernel."""
import jax.numpy as jnp


def embedding_bag_ref(table, ids, weights=None, combiner: str = "sum"):
    """table (V, D); ids (B, K) padded multi-hot; weights (B, K) doubles as
    the validity mask. → (B, D)."""
    vecs = jnp.take(table, ids, axis=0, mode="clip")           # (B, K, D)
    if weights is None:
        weights = jnp.ones(ids.shape, vecs.dtype)
    out = jnp.einsum("bk,bkd->bd", weights.astype(vecs.dtype), vecs)
    if combiner == "mean":
        out = out / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9).astype(out.dtype)
    return out
