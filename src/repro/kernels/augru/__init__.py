from repro.kernels.augru.ops import augru
from repro.kernels.augru.ref import augru_ref

__all__ = ["augru", "augru_ref"]
