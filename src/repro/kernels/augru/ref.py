"""Pure-jnp oracle for the fused AUGRU kernel (matches repro.models.recsys.dien)."""
import jax
import jax.numpy as jnp


def augru_ref(x, att, w, u, b):
    """x (B,T,Din), att (B,T), w (Din,3H), u (H,3H), b (3H,) → final h (B,H).
    Gate order [r | z | n]; AUGRU scales the update gate by attention."""
    B, T, _ = x.shape
    H = u.shape[0]

    def step(h, inputs):
        x_t, a_t = inputs
        gx = x_t @ w + b
        gh = h @ u
        r = jax.nn.sigmoid(gx[:, :H] + gh[:, :H])
        z = jax.nn.sigmoid(gx[:, H:2 * H] + gh[:, H:2 * H])
        n = jnp.tanh(gx[:, 2 * H:] + r * gh[:, 2 * H:])
        z = z * a_t[:, None]
        h = (1 - z) * h + z * n
        return h, None

    h, _ = jax.lax.scan(step, jnp.zeros((B, H), x.dtype),
                        (x.transpose(1, 0, 2), att.T))
    return h
