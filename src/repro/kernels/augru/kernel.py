"""Pallas TPU fused AUGRU: the whole T-step recurrence in one kernel.

Why fuse: a lax.scan AUGRU launches T tiny steps, each reading h (B,H) from
HBM, doing two small matmuls, and writing h back — latency-bound at DIEN's
H=108. Fused, the hidden state lives in a VMEM scratch for the entire
sequence; per grid step we DMA one (BT, T, Din) input tile + the (BT, T)
attention tile, precompute x·W for ALL T positions in one big MXU matmul
(T·Din × 3H — far better MXU utilization than T separate (Din×3H) GEMVs),
then run the T-step recurrence over VMEM-resident values.

Grid: (B // BT,). VMEM: x tile (8·128·128·4 ≈ 512 kB @ padded dims) +
weights (Din+H)·3H·4 + h scratch (BT, H). H is padded to 128 lanes by
ops.py; padded columns stay zero through the recurrence (sigmoid(0)·0 terms
are annihilated by the attention/update algebra since h starts at 0 and
z·n = σ(·)·tanh(0) = 0 on padded lanes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, att_ref, w_ref, u_ref, b_ref, out_ref, *, H: int):
    x = x_ref[...]                      # (BT, T, Din)
    att = att_ref[...]                  # (BT, T)
    BT, T, Din = x.shape
    # one big (BT·T, Din) @ (Din, 3H) MXU matmul for all gates' x-parts
    gx = (jnp.dot(x.reshape(BT * T, Din), w_ref[...],
                  preferred_element_type=jnp.float32)
          + b_ref[...]).reshape(BT, T, 3 * H)
    u = u_ref[...]

    def step(t, h):
        gxt = jax.lax.dynamic_slice_in_dim(gx, t, 1, axis=1)[:, 0]  # (BT,3H)
        gh = jnp.dot(h, u, preferred_element_type=jnp.float32)
        r = jax.nn.sigmoid(gxt[:, :H] + gh[:, :H])
        z = jax.nn.sigmoid(gxt[:, H:2 * H] + gh[:, H:2 * H])
        n = jnp.tanh(gxt[:, 2 * H:] + r * gh[:, 2 * H:])
        a_t = jax.lax.dynamic_slice_in_dim(att, t, 1, axis=1)       # (BT,1)
        z = z * a_t
        return (1 - z) * h + z * n

    h = jax.lax.fori_loop(0, T, step, jnp.zeros((BT, H), jnp.float32))
    out_ref[...] = h.astype(out_ref.dtype)


def augru_pallas(x, att, w, u, b, *, block_b: int = 8,
                 interpret: bool = False):
    B, T, Din = x.shape
    H = u.shape[0]
    assert B % block_b == 0

    return pl.pallas_call(
        lambda *refs: _kernel(*refs, H=H),
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, T, Din), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, T), lambda i: (i, 0)),
            pl.BlockSpec((Din, 3 * H), lambda i: (0, 0)),
            pl.BlockSpec((H, 3 * H), lambda i: (0, 0)),
            pl.BlockSpec((3 * H,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, H), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H), x.dtype),
        interpret=interpret,
    )(x, att, w, u, b)
