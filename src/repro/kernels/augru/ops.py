"""jit'd wrapper for the AUGRU kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.augru.kernel import augru_pallas


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def augru(x, att, w, u, b, interpret: bool | None = None, block_b: int = 8):
    """x (B,T,Din), att (B,T), GRU weights w (Din,3H) u (H,3H) b (3H,) →
    final hidden (B,H). Pads B to block_b (padded rows: h stays 0).
    ``interpret=None`` → interpreter off-TPU, compiled kernel on TPU."""
    interpret = resolve_interpret(interpret)
    B = x.shape[0]
    pad_b = (-B) % block_b
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0), (0, 0)))
        att = jnp.pad(att, ((0, pad_b), (0, 0)))
    out = augru_pallas(x, att, w, u, b, block_b=block_b, interpret=interpret)
    return out[:B]
