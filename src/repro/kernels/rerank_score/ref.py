"""Pure-jnp oracle for the fused re-rank scorer — the broadcast-everything
math of the pre-fusion serving path (din.attention_pool + score MLP), kept
as the parity contract for every fused impl."""
import jax
import jax.numpy as jnp


def rerank_score_ref(hist, mask, target, user_other, item_other,
                     a1, ab1, a2, ab2, a3, ab3,
                     m1, mb1, m2, mb2, m3, mb3):
    """hist (T,D), mask (T,), target (C,D), user_other (d_u,),
    item_other (C,d_i) → scores (C,). Materializes the (C,T,4D) feature
    block exactly like the jnp serving path it replaces."""
    C = target.shape[0]
    T, D = hist.shape
    h = jnp.broadcast_to(hist[None], (C, T, D))
    t = jnp.broadcast_to(target[:, None], (C, T, D))
    feat = jnp.concatenate([h, t, h - t, h * t], axis=-1)       # (C,T,4D)
    x = jax.nn.silu(feat.reshape(C * T, 4 * D) @ a1 + ab1)
    x = jax.nn.silu(x @ a2 + ab2)
    w = (x @ a3 + ab3).reshape(C, T) * mask[None]
    pooled = jnp.einsum("ct,td->cd", w, hist)                   # (C,D)
    xx = jnp.concatenate(
        [pooled, target, jnp.broadcast_to(user_other[None],
                                          (C, user_other.shape[0])),
         item_other], axis=-1)
    s = jax.nn.silu(xx @ m1 + mb1)
    s = jax.nn.silu(s @ m2 + mb2)
    return (s @ m3 + mb3)[:, 0]
