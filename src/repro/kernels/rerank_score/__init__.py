from repro.kernels.rerank_score.ops import rerank_score  # noqa: F401
