"""Pallas TPU fused one-user-many-candidates re-rank scorer.

The re-rank DNN (DIN local activation unit + score MLP) is the dominant
per-request cost of the funnel (paper §4, Table 2) and the serving path pays
it C times per request: the jnp path broadcasts the user's (T, D) history to
(C, T, D), materializes (C, T, 4D) concat features plus two MLP hiddens in
HBM, then runs a second MLP over the concat row — traffic O(C·T·D) for a
history that is SHARED by every candidate.

Fused, the shared state stays put: the (T, D) history tile, its mask and
both MLP weight stacks are resident in VMEM across the whole candidate grid
(their index maps are constant), candidates stream through in (BC, D) tiles,
and one pass per tile produces final scores. HBM traffic drops to
O(T·D + C·(D + d_i)) — the information-theoretic minimum for the problem.

Two algebraic fusions ride along (both exact, reproduced by the XLA fallback
in ops.py so every impl computes the same sums):

  * the 4-way feature block [h, t, h−t, h⊙t] @ W1 is never materialized:
    with W1 split into row blocks (Wa|Wb|Wc|Wd),
        feat @ W1 = h@(Wa+Wc) + t@(Wb−Wc) + (h⊙t)@Wd,
    and h@(Wa+Wc) is shared across candidates — first-layer MXU work falls
    from C·T·4D·H1 to C·T·D·H1 (+ O(T+C) shared terms);
  * the score MLP over [pooled, target, user_other, item_other] runs in the
    same grid step — the (C, D) pooled activations never round-trip to HBM.

Grid: (C // BC,). VMEM per step ≈ hist T·D·4 + weights + BC·(T·H1)·4 for the
attention hidden — BC=128, T=104, D=18, H1=80: ≈ 4.3 MB, comfortably inside
the ~16 MB budget (DESIGN.md §5 has the full table).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(hist_ref, mask_ref, tgt_ref, uo_ref, io_ref,
            a1_ref, ab1_ref, a2_ref, ab2_ref, a3_ref, ab3_ref,
            m1_ref, mb1_ref, m2_ref, mb2_ref, m3_ref, mb3_ref,
            out_ref):
    hist = hist_ref[...]                      # (T, D)   resident
    mask = mask_ref[...]                      # (T,)     resident
    tgt = tgt_ref[...]                        # (BC, D)  streaming
    io = io_ref[...]                          # (BC, d_i) streaming
    uo = uo_ref[...]                          # (d_u,)   resident
    T, D = hist.shape
    BC = tgt.shape[0]
    a1 = a1_ref[...]
    wa, wb, wc, wd = a1[:D], a1[D:2 * D], a1[2 * D:3 * D], a1[3 * D:]

    # local activation unit, first layer decomposed around the shared history
    ah = jnp.dot(hist, wa + wc,
                 preferred_element_type=jnp.float32) + ab1_ref[...]   # (T,H1)
    bt = jnp.dot(tgt, wb - wc, preferred_element_type=jnp.float32)    # (BC,H1)
    ht = hist[None, :, :] * tgt[:, None, :]                     # (BC,T,D)
    h1 = jnp.dot(ht.reshape(BC * T, D), wd,
                 preferred_element_type=jnp.float32)
    x = jax.nn.silu(h1.reshape(BC, T, -1) + ah[None] + bt[:, None])
    x = jax.nn.silu(jnp.dot(x.reshape(BC * T, -1), a2_ref[...],
                            preferred_element_type=jnp.float32) + ab2_ref[...])
    w = jnp.dot(x, a3_ref[...],
                preferred_element_type=jnp.float32) + ab3_ref[...]
    w = w.reshape(BC, T) * mask[None]
    pooled = jnp.dot(w, hist.astype(jnp.float32),
                     preferred_element_type=jnp.float32)        # (BC, D)

    # fused score MLP over [pooled, target, user_other, item_other]
    xx = jnp.concatenate(
        [pooled, tgt.astype(jnp.float32),
         jnp.broadcast_to(uo[None], (BC, uo.shape[0])).astype(jnp.float32),
         io.astype(jnp.float32)], axis=-1)
    s = jax.nn.silu(jnp.dot(xx, m1_ref[...],
                            preferred_element_type=jnp.float32) + mb1_ref[...])
    s = jax.nn.silu(jnp.dot(s, m2_ref[...],
                            preferred_element_type=jnp.float32) + mb2_ref[...])
    s = jnp.dot(s, m3_ref[...],
                preferred_element_type=jnp.float32) + mb3_ref[...]
    out_ref[...] = s.astype(out_ref.dtype)                      # (BC, 1)


def rerank_score_pallas(hist, mask, target, user_other, item_other,
                        a1, ab1, a2, ab2, a3, ab3,
                        m1, mb1, m2, mb2, m3, mb3,
                        *, block_c: int = 128, interpret: bool = False):
    """hist (T, D), mask (T,), target (C, D), user_other (d_u,),
    item_other (C, d_i); attention MLP (4D→H1→H2→1) and score MLP
    (2D+d_u+d_i→M1→M2→1) weight/bias pairs. Returns scores (C,)."""
    T, D = hist.shape
    C = target.shape[0]
    d_u, d_i = user_other.shape[0], item_other.shape[1]
    H1, H2 = a1.shape[1], a2.shape[1]
    M1, M2 = m1.shape[1], m2.shape[1]
    assert C % block_c == 0, (C, block_c)
    grid = (C // block_c,)

    def stream2(i):
        return (i, 0)

    def resident2(i):
        return (0, 0)

    def resident1(i):
        return (0,)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, D), resident2),          # hist — loaded once
            pl.BlockSpec((T,), resident1),            # mask
            pl.BlockSpec((block_c, D), stream2),      # target tile
            pl.BlockSpec((d_u,), resident1),          # user side features
            pl.BlockSpec((block_c, d_i), stream2),    # item side features
            pl.BlockSpec((4 * D, H1), resident2),
            pl.BlockSpec((H1,), resident1),
            pl.BlockSpec((H1, H2), resident2),
            pl.BlockSpec((H2,), resident1),
            pl.BlockSpec((H2, 1), resident2),
            pl.BlockSpec((1,), resident1),
            pl.BlockSpec((2 * D + d_u + d_i, M1), resident2),
            pl.BlockSpec((M1,), resident1),
            pl.BlockSpec((M1, M2), resident2),
            pl.BlockSpec((M2,), resident1),
            pl.BlockSpec((M2, 1), resident2),
            pl.BlockSpec((1,), resident1),
        ],
        out_specs=pl.BlockSpec((block_c, 1), stream2),
        out_shape=jax.ShapeDtypeStruct((C, 1), jnp.float32),
        interpret=interpret,
    )(hist, mask, target, user_other, item_other,
      a1, ab1, a2, ab2, a3, ab3, m1, mb1, m2, mb2, m3, mb3)
    return out[:, 0]
