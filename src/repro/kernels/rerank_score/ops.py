"""jit'd wrapper for the fused re-rank scorer: pads to tile multiples and
dispatches to one of three implementations of the SAME fused algorithm
(shared-history first-layer decomposition + candidate streaming):

  * ``impl="pallas"`` — the Pallas kernel (compiled on TPU; the interpreter
    when ``interpret`` resolves True — parity/debug only, it is slow);
  * ``impl="xla"``    — the fused algorithm as blocked jnp: identical sums,
    no (C,T,4D) materialization; the serving default off-TPU;
  * ``impl=None``     — auto: "pallas" when a TPU backend is attached,
    "xla" otherwise (see ``repro.kernels.default_interpret``).

Callers hand the history ALREADY compacted/bucketed (serve/bucketing.py):
masked rows are exact no-ops, so scoring ``bucket(T_valid)`` rows is
bit-equal to scoring the full padded history — but skips its cost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import pad_axis, resolve_interpret, tpu_present
from repro.kernels.rerank_score.kernel import rerank_score_pallas


def _fused_block_xla(hist, mask, tgt, uo, io,
                     a1, ab1, a2, ab2, a3, ab3, m1, mb1, m2, mb2, m3, mb3):
    """One candidate tile, same decomposition as the kernel body."""
    T, D = hist.shape
    BC = tgt.shape[0]
    wa, wb, wc, wd = a1[:D], a1[D:2 * D], a1[2 * D:3 * D], a1[3 * D:]
    ah = hist @ (wa + wc) + ab1                                 # (T,H1) shared
    bt = tgt @ (wb - wc)                                        # (BC,H1)
    ht = hist[None, :, :] * tgt[:, None, :]                     # (BC,T,D)
    h1 = (ht.reshape(BC * T, D) @ wd).reshape(BC, T, -1)
    x = jax.nn.silu(h1 + ah[None] + bt[:, None])
    x = jax.nn.silu(x.reshape(BC * T, -1) @ a2 + ab2)
    w = (x @ a3 + ab3).reshape(BC, T) * mask[None]
    pooled = w @ hist                                           # (BC,D)
    xx = jnp.concatenate(
        [pooled, tgt, jnp.broadcast_to(uo[None], (BC, uo.shape[0])), io], -1)
    s = jax.nn.silu(xx @ m1 + mb1)
    s = jax.nn.silu(s @ m2 + mb2)
    return (s @ m3 + mb3)[:, 0]


@functools.partial(jax.jit, static_argnames=("block_c", "impl", "interpret"))
def rerank_score(hist, mask, target, user_other, item_other,
                 attn_mlp, score_mlp, block_c: int = 128,
                 impl: str | None = None, interpret: bool | None = None):
    """Score C candidates against one user's shared history in one fused
    pass.

    hist (T, D) embedded history, mask (T,), target (C, D) candidate
    embeddings, user_other (d_u,) user side features (NOT pre-broadcast),
    item_other (C, d_i) per-candidate side features; attn_mlp / score_mlp:
    3-layer towers as produced by ``mlp_tower_init`` (two silu hiddens +
    linear out). Returns per-candidate scores (C,) float32.

    Zero-pads T to 8 (masked → exact). The Pallas grid additionally pads C
    to ``block_c`` (scored and discarded); the XLA impl streams blocks of
    AT MOST ``block_c`` and never pads C — a 16-candidate bucket costs 16
    rows of work, not 128.
    """
    assert len(attn_mlp) == 3 and len(score_mlp) == 3, \
        "fused path expects 2-hidden-layer towers (got " \
        f"{len(attn_mlp)}/{len(score_mlp)} layers)"
    if impl is None:
        # keyed on the hardware, NOT on default_interpret(): forcing
        # REPRO_PALLAS_INTERPRET=1 on a TPU must debug the Pallas kernel
        # (interpreted), not silently reroute to the XLA impl
        impl = "pallas" if tpu_present() else "xla"
    C = target.shape[0]
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    hist_p = pad_axis(f32(hist), 8, 0)
    mask_p = pad_axis(f32(mask), 8, 0)
    uo = f32(user_other)
    weights = [f32(p[k]) for p in (*attn_mlp, *score_mlp) for k in ("w", "b")]

    if impl == "pallas":
        target_p = pad_axis(f32(target), block_c, 0)
        io_p = pad_axis(f32(item_other), block_c, 0)
        out = rerank_score_pallas(
            hist_p, mask_p, target_p, uo, io_p, *weights,
            block_c=block_c, interpret=resolve_interpret(interpret))[:C]
    elif impl == "xla":
        target_p, io_p = f32(target), f32(item_other)
        blocks = [
            _fused_block_xla(hist_p, mask_p, target_p[s:s + block_c],
                             uo, io_p[s:s + block_c], *weights)
            for s in range(0, C, block_c)]
        out = blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks)
    else:
        raise ValueError(f"unknown impl {impl!r}")
    return out
