from repro.kernels.candidate_scorer.ops import candidate_scorer
from repro.kernels.candidate_scorer.ref import candidate_scorer_ref

__all__ = ["candidate_scorer", "candidate_scorer_ref"]
