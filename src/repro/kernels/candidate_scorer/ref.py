"""Pure-jnp oracle for the fused candidate scorer."""
import jax
import jax.numpy as jnp


def candidate_scorer_ref(cands, query, k: int):
    """cands (C, D), query (D,) → (topk values desc, topk indices)."""
    scores = (cands @ query).astype(jnp.float32)
    v, i = jax.lax.top_k(scores, k)
    return v, i
