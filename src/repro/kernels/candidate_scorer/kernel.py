"""Pallas TPU fused candidate scorer: blocked dot + in-kernel per-block
top-k (the recall phase's 1M-candidate hot loop).

Why fuse: scoring 1M candidates then lax.top_k writes the full (C,) score
vector to HBM and re-reads it for the sort (two extra sweeps). The kernel
streams (BC, D) candidate tiles through VMEM, scores them on the MXU
((BC, D) @ (D, 1)), and keeps only each block's top-k via k iterations of
masked-argmax IN REGISTERS (exact for k ≤ ~16; k·BC VPU work ≪ the dot).
HBM output shrinks from C floats to (C/BC)·k value+index pairs; the tiny
cross-block merge happens in ops.py.

Grid: (C // BC,). VMEM: candidate tile (BC·D·4 ≈ 1 MB @ BC 1024, D 256) +
query (D,) + (k, ) accumulators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(c_ref, q_ref, v_ref, i_ref, *, k: int, block_c: int, c_real: int):
    b = pl.program_id(0)
    scores = jnp.dot(c_ref[...], q_ref[...],
                     preferred_element_type=jnp.float32)      # (BC,)
    base = b * block_c
    # padding rows (last block) must never win a top-k slot
    scores = jnp.where(base + jnp.arange(block_c) < c_real, scores, NEG_INF)
    # exact top-k within the block: k rounds of masked argmax (unrolled)
    for j in range(k):
        m = jnp.max(scores)
        am = jnp.argmax(scores)
        v_ref[0, j] = m
        i_ref[0, j] = (base + am).astype(jnp.int32)
        scores = jnp.where(jnp.arange(block_c) == am, NEG_INF, scores)


def candidate_scorer_pallas(cands, query, *, k: int = 8, block_c: int = 1024,
                            c_real: int = None, interpret: bool = False):
    """cands (C, D), query (D,) → per-block (n_blocks, k) values + indices."""
    C, D = cands.shape
    assert C % block_c == 0, (C, block_c)
    n_blocks = C // block_c
    return pl.pallas_call(
        functools.partial(_kernel, k=k, block_c=block_c,
                          c_real=c_real if c_real is not None else C),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_c, D), lambda b: (b, 0)),
            pl.BlockSpec((D,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda b: (b, 0)),
            pl.BlockSpec((1, k), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, k), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, k), jnp.int32),
        ],
        interpret=interpret,
    )(cands, query)
