"""jit wrapper: pad → blocked kernel → tiny cross-block merge."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.candidate_scorer.kernel import candidate_scorer_pallas


@functools.partial(jax.jit, static_argnames=("k", "block_c", "interpret"))
def candidate_scorer(cands, query, k: int = 8, block_c: int = 1024,
                     interpret: bool | None = None):
    """cands (C, D), query (D,) → exact global (top-k values, indices).
    Exact because every block keeps its own top-k ≥ any global top-k member.
    ``interpret=None`` → interpreter off-TPU, compiled kernel on TPU."""
    interpret = resolve_interpret(interpret)
    C, D = cands.shape
    pad = (-C) % block_c
    if pad:
        cands = jnp.pad(cands, ((0, pad), (0, 0)))
    v, i = candidate_scorer_pallas(cands, query, k=k, block_c=block_c,
                                   c_real=C, interpret=interpret)
    vv, pos = jax.lax.top_k(v.reshape(-1), k)
    return vv, i.reshape(-1)[pos]
