"""Pure-jnp oracle for the fused DIN local-activation-unit kernel."""
import jax
import jax.numpy as jnp


def din_attention_ref(hist, mask, target, w1, b1, w2, b2, w3, b3):
    """hist (B,T,D), mask (B,T), target (B,D);
    attention MLP: 4D → H1 → H2 → 1 (silu), weights (4D,H1),(H1,H2),(H2,1).
    Returns (B, D): activation-weighted sum over history (no softmax —
    DIN paper §4.3 keeps raw weights)."""
    t = jnp.broadcast_to(target[:, None], hist.shape)
    feat = jnp.concatenate([hist, t, hist - t, hist * t], -1)   # (B,T,4D)
    h = jax.nn.silu(feat @ w1 + b1)
    h = jax.nn.silu(h @ w2 + b2)
    w = (h @ w3 + b3)[..., 0] * mask                            # (B,T)
    return jnp.einsum("bt,btd->bd", w, hist)
