from repro.kernels.din_attention.ops import din_attention
from repro.kernels.din_attention.ref import din_attention_ref

__all__ = ["din_attention", "din_attention_ref"]
