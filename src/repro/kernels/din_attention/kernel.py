"""Pallas TPU fused DIN target-attention (local activation unit).

Why fuse: the jnp path materializes (B,T,4D) concat features plus two MLP
intermediates in HBM — 5 round-trips of (B,T,·) for an op whose useful
output is (B,D). Fused, one pass: each grid step loads a (BT, T, D) tile of
history + its (BT, D) targets into VMEM, builds the 4-way feature blocks
IN REGISTERS, runs the tiny attention MLP on the MXU (weights resident in
VMEM — ~26 kB for the paper config 72→80→40→1), masks, and accumulates the
weighted sum. HBM traffic drops from ~(9·T·D + 2·T·H₁ + …) to (T·D + 2·D)
per row — a ~10× reduction for the paper shapes.

Grid: (B // BT,). VMEM: hist tile BT·T·D·4 ≈ 8·100·18·4 ≈ 58 kB + weights.
The T and feature dims are zero-padded to the 8×128 TPU tile grid by the
caller (ops.py) — zero rows produce zero attention weight contributions,
preserving exactness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(hist_ref, mask_ref, tgt_ref, w1_ref, b1_ref, w2_ref, b2_ref,
            w3_ref, b3_ref, out_ref):
    hist = hist_ref[...]                     # (BT, T, D)
    tgt = tgt_ref[...]                       # (BT, D)
    BT, T, D = hist.shape
    t = jnp.broadcast_to(tgt[:, None, :], (BT, T, D))
    feat = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    h = feat.reshape(BT * T, 4 * D)
    h = jax.nn.silu(jnp.dot(h, w1_ref[...],
                            preferred_element_type=jnp.float32) + b1_ref[...])
    h = jax.nn.silu(jnp.dot(h, w2_ref[...],
                            preferred_element_type=jnp.float32) + b2_ref[...])
    w = jnp.dot(h, w3_ref[...], preferred_element_type=jnp.float32) + b3_ref[...]
    w = w.reshape(BT, T) * mask_ref[...]
    out_ref[...] = jnp.einsum("bt,btd->bd", w, hist.astype(jnp.float32)
                              ).astype(out_ref.dtype)


def din_attention_pallas(hist, mask, target, w1, b1, w2, b2, w3, b3,
                         *, block_b: int = 8, interpret: bool = False):
    B, T, D = hist.shape
    H1, H2 = w1.shape[1], w2.shape[1]
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)

    def bmap(i):
        return (i, 0, 0)

    def bmap2(i):
        return (i, 0)

    def wmap(i):
        return (0, 0)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, T, D), bmap),
            pl.BlockSpec((block_b, T), bmap2),
            pl.BlockSpec((block_b, D), bmap2),
            pl.BlockSpec((4 * D, H1), wmap),
            pl.BlockSpec((H1,), lambda i: (0,)),
            pl.BlockSpec((H1, H2), wmap),
            pl.BlockSpec((H2,), lambda i: (0,)),
            pl.BlockSpec((H2, 1), wmap),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, D), bmap2),
        out_shape=jax.ShapeDtypeStruct((B, D), hist.dtype),
        interpret=interpret,
    )(hist, mask, target, w1, b1, w2, b2, w3, b3)
