"""jit'd wrapper: pads to TPU tile multiples, calls the fused kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import pad_axis, resolve_interpret
from repro.kernels.din_attention.kernel import din_attention_pallas


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def din_attention(hist, mask, target, w1, b1, w2, b2, w3, b3,
                  interpret: bool | None = None, block_b: int = 8):
    """Fused DIN local activation unit. Zero-pads T to 8 and B to block_b;
    padded history rows have mask 0 → zero contribution (exact).
    ``interpret=None`` → interpreter off-TPU, compiled kernel on TPU."""
    interpret = resolve_interpret(interpret)
    B, T, D = hist.shape
    hist_p = pad_axis(hist, 8, 1)
    mask_p = pad_axis(mask, 8, 1)
    pad_b = (-B) % block_b
    if pad_b:
        hist_p = jnp.pad(hist_p, ((0, pad_b), (0, 0), (0, 0)))
        mask_p = jnp.pad(mask_p, ((0, pad_b), (0, 0)))
        target = jnp.pad(target, ((0, pad_b), (0, 0)))
    out = din_attention_pallas(hist_p, mask_p, target, w1, b1, w2, b2, w3, b3,
                               block_b=block_b, interpret=interpret)
    return out[:B]
