"""Pure-jnp oracle for GQA flash-decode (matches models.attention.decode_attention)."""
import jax.numpy as jnp
import numpy as np


def flash_decode_ref(q, k_cache, v_cache, cache_len):
    """q (B,H,G,D); caches (B,S,H,D); cache_len scalar → (B,H,G,D)."""
    B, H, G, D = q.shape
    S = k_cache.shape[1]
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / np.sqrt(D)
    mask = jnp.arange(S) < cache_len
    s = jnp.where(mask[None, None, None, :], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhgs,bshd->bhgd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)
