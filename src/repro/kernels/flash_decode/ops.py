"""jit'd wrapper for flash-decode."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.flash_decode.kernel import flash_decode_pallas


@functools.partial(jax.jit, static_argnames=("interpret", "block_k"))
def flash_decode(q, k_cache, v_cache, cache_len, interpret: bool | None = None,
                 block_k: int = 512):
    """q (B,H,G,D) one new token per sequence; caches (B,S,H,D);
    cache_len: valid prefix. Pads S to block_k (masked).
    ``interpret=None`` → interpreter off-TPU, compiled kernel on TPU."""
    interpret = resolve_interpret(interpret)
    B, S, H, D = k_cache.shape
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return flash_decode_pallas(q, k_cache, v_cache, cache_len,
                               block_k=block_k, interpret=interpret)
