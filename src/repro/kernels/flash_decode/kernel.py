"""Pallas TPU flash-decode: split-K online-softmax decode attention.

One new token vs an S-long KV cache (decode_32k / long_500k serving shapes).
The jnp path materializes (B,H,G,S) scores in HBM; at S=512k that's the
whole HBM budget in traffic. This kernel streams the cache once:

  grid = (B, H, S // BK)   — sequential minor axis → running accumulation
  per step: K tile (BK, D) and V tile (BK, D) DMA into VMEM (double-
  buffered by the pipeline); scores for the G query heads of this kv head
  are computed on the MXU ((G, D) @ (D, BK)); an online-softmax carry
  (m, l, acc) lives in VMEM scratch across the S tiles; the final tile
  normalizes and writes (G, D) out.

Masking: tiles beyond cache_len are skipped entirely (pl.when on the
scalar-prefetched length) — decode cost is O(cache_len), not O(S_max).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_k: int, scale: float):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)
    cache_len = len_ref[0]

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = s_idx * block_k

    @pl.when(start < cache_len)
    def _step():
        q = q_ref[0, 0]                           # (G, D)
        k = k_ref[0, :, 0, :]                     # (BK, D)
        v = v_ref[0, :, 0, :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(pos < cache_len, s, NEG_INF)      # (G, BK)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_decode_pallas(q, k_cache, v_cache, cache_len, *, block_k: int = 512,
                        interpret: bool = False):
    """q (B,H,G,D); caches (B,S,H,D); cache_len scalar int32 → (B,H,G,D)."""
    B, H, G, D = q.shape
    S = k_cache.shape[1]
    assert S % block_k == 0, (S, block_k)
    grid = (B, H, S // block_k)
    scale = 1.0 / np.sqrt(D)

    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, s, L: (b, h, 0, 0)),
                pl.BlockSpec((1, block_k, 1, D), lambda b, h, s, L: (b, s, h, 0)),
                pl.BlockSpec((1, block_k, 1, D), lambda b, h, s, L: (b, s, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s, L: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, G, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(cache_len, jnp.int32).reshape(1), q, k_cache, v_cache)
