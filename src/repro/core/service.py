"""InferenceService: the full JiZHI stack around a REAL JAX ranking model.

This is the deployable composition (examples/serve_recsys.py): SEDP DAG +
query cache + cube cache/cube + online load shedding + a jitted recsys model
(DIN by default) as the DNN stage, with hot-loading via DoubleBuffer. The
benchmark suite uses the calibrated service_model instead (deterministic
latency); THIS class is the functional end-to-end path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import sedp as sedp_lib
from repro.core.cube import ParameterCube
from repro.core.cube_cache import TwoTierLFUCache, capacity_from_ratio
from repro.core.executors import AsyncExecutor, SimExecutor
from repro.core.irm.shedding import (OnlineShedder, QuotaController,
                                     train_pruning_dnn)
from repro.core.query_cache import QueryCache
from repro.core.sedp import SEDP, Event
from repro.data import synthetic
from repro.serve.bucketing import (ShapeBucketer, TracedJit,
                                   bucketed_candidate_rerank, pow2_buckets,
                                   step_buckets)
from repro.serve.hotload import DoubleBuffer, Generation
from repro.sparse.hashing import hash_bucket_np
from repro.update import (DeltaWatcher, HBMHead, PromoteDemotePolicy,
                          UpdateManager)


@dataclass
class ServiceConfig:
    arch_id: str = "din"
    batch_size: int = 16
    cube_cache_ratio: float = 1.0
    query_window_s: float = 120.0
    shed: bool = True
    seed: int = 0
    # closed-loop serving knobs: bounded stage channels (backpressure) and
    # the per-stage micro-batching window (collect batch_size or wait)
    max_queue: int = 512
    batch_wait_s: float = 0.002
    # shape buckets for the jitted rerank stage: the micro-batcher hands it
    # whatever batch it collected and the shedder whatever candidate set
    # survived, so without padding every distinct (B, C, T_hist) is a fresh
    # XLA trace. None → powers of two up to the relevant maximum.
    rerank_buckets: Optional[tuple] = None     # batch dimension B
    cand_buckets: Optional[tuple] = None       # per-request candidate count C
    # live-update stage (DESIGN.md §6): tail a delta log and apply versioned
    # parameter deltas to the cube/caches/head while traffic flows
    live_updates: bool = False
    update_dir: Optional[str] = None
    update_poll_s: float = 0.1
    compact_after_blocks: int = 64
    head_slots: int = 0            # >0 → HBM head tier for promoted hot rows


class _ServiceDeltaWatcher(DeltaWatcher):
    """The service's live-update stage: tail the delta log, apply through
    the UpdateManager, then run the off-hot-path maintenance a fresh batch
    warrants — overlay compaction and the promote/demote pass."""

    def __init__(self, svc: "InferenceService", **kw):
        # the service is its delta log's only consumer → prune applied
        # deltas so the log directory (and each poll's scan) stays bounded
        kw.setdefault("prune_applied", True)
        super().__init__(svc.cfg.update_dir, svc.updates.apply, **kw)
        self._svc = svc

    def check_once(self) -> bool:
        applied = super().check_once()
        if applied:
            self._svc.updates.maybe_compact()
            if self._svc.updates.head is not None:
                self._svc.updates.rebalance(0)
        return applied


class InferenceService:
    def __init__(self, cfg: ServiceConfig = ServiceConfig()):
        self.cfg = cfg
        arch = registry.get(cfg.arch_id)
        self.model_cfg = arch.reduced(arch.config)
        from repro.launch.specs import REC_MODULES
        self.mod = REC_MODULES[self.model_cfg.model]
        params = self.mod.init(jax.random.PRNGKey(cfg.seed), self.model_cfg)
        self.buffer = DoubleBuffer(Generation(0, params))
        self.rerank_buckets = ShapeBucketer(
            cfg.rerank_buckets or pow2_buckets(cfg.batch_size))
        self.cand_buckets = ShapeBucketer(
            cfg.cand_buckets or pow2_buckets(64, min_size=16))
        # step-8 history buckets (DESIGN.md §5.3): padded history rows still
        # pay the full attention MLP, so tight T buckets beat a small menu
        self.hist_buckets = (ShapeBucketer(
            step_buckets(self.model_cfg.seq_len, step=8))
            if self.model_cfg.seq_len else None)
        self._serve = TracedJit(
            lambda p, b: self.mod.serve_scores(p, b, self.model_cfg))
        # fused one-user-many-candidates re-rank (kernels/rerank_score via
        # score_candidates): full ranking of each request's candidate set
        self._rerank = (TracedJit(
            lambda p, u, c: self.mod.score_candidates(
                p, u, c, self.model_cfg, top_k=c["item_id"].shape[0]))
            if hasattr(self.mod, "score_candidates") else None)

        vocab = self.model_cfg.item_fields[0].vocab
        self.query_cache = QueryCache(window_s=cfg.query_window_s)
        mem, disk = capacity_from_ratio(vocab * 4, cfg.cube_cache_ratio)
        self.cube_cache = TwoTierLFUCache(mem, disk)
        self.cube = ParameterCube(n_servers=4, replication=2, block_rows=4096)
        rng = np.random.default_rng(cfg.seed)
        for g, field in enumerate(self.model_cfg.item_fields):
            self.cube.load_table(g, rng.normal(
                0, 0.01, (field.vocab, 4)).astype(np.float32))
        # streaming-update subsystem: one manager keeps the cube, both
        # caches and the optional HBM head coherent per delta batch, and a
        # generation swap bumps the caches' model version — previously a
        # hot swap kept serving the OLD generation's scores out of the
        # query cache for up to its TTL window (DESIGN.md §6.4)
        head = (HBMHead(cfg.head_slots, dim=4) if cfg.head_slots else None)
        # the cube is keyed by HASHED item ids while the query cache scores
        # RAW item ids — op_features records the bucket → raw-items reverse
        # map so a delta invalidates exactly the raw items whose rows it
        # touched (a hash collision over-invalidates a sibling: safe)
        self._bucket_items: dict[int, set] = {}
        self.updates = UpdateManager(
            self.cube, cube_cache=self.cube_cache,
            query_cache=self.query_cache, head=head,
            policy=(PromoteDemotePolicy(capacity=cfg.head_slots)
                    if head else None),
            qcache_items_fn=self._items_for_buckets,
            compact_after_blocks=cfg.compact_after_blocks)
        self.buffer.on_swap.append(self.updates.on_generation_swap)
        self.update_watcher = None
        if cfg.live_updates and cfg.update_dir:
            self.update_watcher = _ServiceDeltaWatcher(
                self, poll_s=cfg.update_poll_s)
        self.shedder = None
        if cfg.shed:
            dnn, _ = train_pruning_dnn(n_samples=800, seed=cfg.seed)
            # live controller: re-rank queue depth + utilization → quota
            self.shedder = OnlineShedder(
                dnn, downstream="rerank",
                controller=QuotaController("rerank", depth_capacity=64.0))
        self.graph, self.plan = self._build()

    # ------------------------------------------------------------- stages
    def _build(self):
        g = SEDP()
        mc = self.model_cfg

        def op_qcache(batch, ctx):
            now = ctx.now()        # executor clock: wall (Async) or virtual (Sim)
            scores = self.query_cache.get_many(
                [ev.payload["user_id"] for ev in batch],
                [ev.payload["item_id"] for ev in batch], now)
            for ev, s in zip(batch, scores):
                if s is not None:
                    ev.payload["score"] = s
                    ev.route = "respond"
                else:
                    ev.route = "features"
            return batch

        def op_features(batch, ctx):
            items = np.fromiter((ev.payload["item_id"] for ev in batch),
                                np.int64, len(batch))
            hashed = hash_bucket_np(0, items, mc.item_fields[0].vocab)
            bucket_items = self._bucket_items
            for ev, h, item in zip(batch, hashed, items):
                ev.payload["hashed"] = {"item_id": h}
                # reverse map for targeted query-cache invalidation (GIL-
                # atomic set/dict ops; bounded by vocab × items-per-bucket)
                bucket_items.setdefault(int(h), set()).add(int(item))
            return batch

        def op_cube(batch, ctx):
            keys = [int(ev.payload["hashed"]["item_id"]) for ev in batch]
            fetched = {}
            # version-pinned resolve: cache probe AND misses happen under
            # ONE pinned cube version, stamped on each event — probing the
            # cache before pinning would let a pre-delta cached row ride
            # out stamped with the post-delta version, sneaking past both
            # cache-aside guards
            with self.cube.pin() as pv:
                cached = self.cube_cache.get_many(keys)
                miss = sorted({k for k, v in zip(keys, cached) if v is None})
                if miss:
                    pending = np.asarray(miss, np.int64)
                    head = self.updates.head
                    if head is not None and head.resident_count:
                        # HBM head tier first: promoted hot rows skip the
                        # host cube entirely (freshness: the head is
                        # updated in place at delta-apply, DESIGN.md §6.3)
                        hrows, hfound = head.lookup(0, pending)
                        for k, r, f in zip(pending.tolist(), hrows, hfound):
                            if f:
                                fetched[int(k)] = r
                        pending = pending[~hfound]
                    if pending.size:
                        # delta deletes leave tombstones: a deleted row is
                        # a legitimate serving state (the feature fell out
                        # of the model), served as the zero/default row —
                        # NOT a KeyError that would kill the stage worker
                        live = self.cube.contains(0, pending, version=pv)
                        if not live.all():
                            dim = (self.cube.row_shape(0) or (4,))[0]
                            zero = np.zeros(dim, np.float32)
                            for k in pending[~live].tolist():
                                fetched[int(k)] = zero
                            pending = pending[live]
                    if pending.size:
                        rows = self.cube.lookup(0, pending, version=pv)
                        for i, k in enumerate(pending.tolist()):
                            fetched[int(k)] = rows[i]
                    self.cube_cache.put_many(
                        list(fetched), [fetched[k][None] for k in fetched])
                    # close the cache-aside race: a delta may have published
                    # (and run its targeted invalidation) between our pinned
                    # fetch and the insert above, which would resurrect
                    # pre-delta rows as fresh entries. Drop our own inserts
                    # for exactly the keys deltas touched since the pin
                    # (batch-wide dropping would fire on nearly every batch
                    # under a continuous stream); the touched-key log going
                    # cold forces the conservative full drop.
                    if self.cube.version != pv.version:
                        touched = self.updates.touched_since(pv.version)
                        drop = (list(fetched) if touched is None else
                                [k for k in fetched if k in touched[0]])
                        if drop:
                            self.cube_cache.invalidate_keys(drop)
                # the gathered rows ride on the event: the rerank stage
                # consumes cube output from the payload instead of
                # re-touching the cube
                for ev, k, c in zip(batch, keys, cached):
                    row = fetched[k] if c is None else c[0]
                    ev.payload["cube_rows"] = np.asarray(row, np.float32)
                    ev.payload["cube_version"] = pv.version
            return batch

        def op_dnn(batch, ctx):
            # capture the query-cache model version BEFORE binding the
            # generation: scores are stamped with qv at insert, so a hot
            # swap racing this batch can only over-invalidate (fresh scores
            # stamped pre-bump), never mark old-generation scores as fresh
            qv = self.query_cache.model_version
            gen = self.buffer.active       # ONE generation for the batch
            params = gen.payload
            B = len(batch)
            payloads = [ev.payload for ev in batch]
            # pad to the covering batch bucket (bounded jit-trace count);
            # scores are per-row, so slicing [:B] discards the filler exactly
            b = self._pack_batch(self.rerank_buckets.pad_rows(payloads))
            scores = np.asarray(self._serve(params, b))[:B]
            now = ctx.now()
            for ev, s in zip(batch, scores):
                ev.payload["score"] = float(s)
                ev.payload["generation"] = gen.stamp
                self._rerank_candidates(params, ev.payload)
            self.query_cache.put_many(
                [ev.payload["user_id"] for ev in batch],
                [ev.payload["item_id"] for ev in batch],
                [float(s) for s in scores], now, version=qv)
            # close the delta-side cache-aside race (the query-cache twin of
            # op_cube's guard): these scores embed cube rows fetched at the
            # events' pinned versions — if a delta published since, its
            # invalidate_items may have run BEFORE our insert, resurrecting
            # a stale score. Drop exactly the batch items deltas actually
            # touched since the earliest pin (the pipeline latency between
            # cube fetch and score insert usually spans a delta interval
            # under a continuous stream, so a batch-wide drop would gut the
            # cache); a cold touched-key log forces the conservative drop.
            vmin = min((ev.payload.get("cube_version", 0) for ev in batch),
                       default=0)
            if self.cube.version != vmin:
                items = {ev.payload["item_id"] for ev in batch}
                touched = self.updates.touched_since(vmin)
                if touched is not None:
                    items &= touched[1]
                if items:
                    self.query_cache.invalidate_items(items)
            return batch

        kw = dict(max_queue=self.cfg.max_queue,
                  max_wait_s=self.cfg.batch_wait_s)
        g.add_stage("ingress", sedp_lib.passthrough, batch_size=8,
                    parallelism=2, **kw)
        g.add_stage("query_cache", op_qcache, batch_size=16, parallelism=2,
                    **kw)
        g.add_stage("features", op_features, batch_size=8, parallelism=2, **kw)
        g.add_stage("cube", op_cube, batch_size=8, parallelism=2, **kw)
        if self.shedder:
            g.add_stage("shed", self.shedder.op, batch_size=8, parallelism=1,
                        **kw)
        g.add_stage("rerank", op_dnn, batch_size=self.cfg.batch_size,
                    parallelism=1, **kw)
        g.add_stage("respond", sedp_lib.passthrough, batch_size=32,
                    parallelism=1, **kw)
        g.chain("ingress", "query_cache")
        g.add_edge("query_cache", "respond")
        g.chain("query_cache", "features", "cube")
        if self.shedder:
            g.chain("cube", "shed", "rerank")
        else:
            g.add_edge("cube", "rerank")
        g.add_edge("rerank", "respond")
        return g, g.compile()

    def _pack_batch(self, payloads: list[dict]) -> dict:
        mc = self.model_cfg
        user_fields = {f.name: np.stack([p["user_fields"][f.name]
                                         for p in payloads])
                       for f in mc.user_fields}
        item = {f.name: np.stack([p["item_fields"][f.name] for p in payloads])
                for f in mc.item_fields}
        batch = {"user": {"fields": jax.tree.map(jnp.asarray, user_fields)},
                 "item": jax.tree.map(jnp.asarray, item)}
        # cube output attached upstream (op_cube) becomes a model input: the
        # item's host-tier tail features enter the packed batch here rather
        # than being re-derived by another cube round-trip
        if all("cube_rows" in p for p in payloads):
            batch["item"]["cube_tail"] = jnp.asarray(
                np.stack([p["cube_rows"] for p in payloads]))
        if mc.seq_len:
            batch["user"]["hist"] = jnp.asarray(
                np.stack([p["hist"] for p in payloads]))
        return batch

    def _rerank_candidates(self, params, payload: dict, keep: int = 12):
        """Full re-rank of the request's surviving candidate set through the
        fused shared-history scorer. C and the history length are padded to
        buckets so the jit cache stays at |cand_buckets| × |hist_buckets|."""
        mc = self.model_cfg
        cands = payload.get("candidates")
        if not cands or self._rerank is None or not mc.seq_len:
            return
        payload["topk"] = bucketed_candidate_rerank(
            self._rerank, params, payload["hist"],
            {f.name: payload["user_fields"][f.name] for f in mc.user_fields},
            cands, self.cand_buckets, self.hist_buckets,
            item_fields=[(f.name, f.bag) for f in mc.item_fields
                         if f.name != "item_id"], keep=keep)

    # ------------------------------------------------------- live updates
    def _items_for_buckets(self, group: int, hashed_ids) -> list:
        """Raw item ids whose scores embed the given cube (hashed) rows —
        the UpdateManager's query-cache invalidation key set."""
        if group != 0:
            return []
        out: list = []
        for h in hashed_ids:
            out.extend(self._bucket_items.get(int(h), ()))
        return out

    def start_updates(self):
        """Start the live-update stage (requires cfg.live_updates +
        cfg.update_dir): a watcher thread tails the delta log and applies
        each published version while traffic keeps flowing."""
        if self.update_watcher is None:
            raise RuntimeError("live updates not configured "
                               "(set live_updates=True and update_dir)")
        self.update_watcher.start()

    def stop_updates(self):
        if self.update_watcher is not None:
            self.update_watcher.stop()

    # --------------------------------------------------------------- run
    def make_requests(self, n: int, seed: int = 0) -> list[Event]:
        rng = np.random.default_rng(seed)
        mc = self.model_cfg
        evs = []
        raw = synthetic.recsys_batch(rng, mc, n)
        for i in range(n):
            payload = {
                "user_id": int(raw["user"]["fields"][mc.user_fields[0].name][i]
                               if mc.user_fields[0].bag == 1 else i),
                "item_id": int(raw["item"][mc.item_fields[0].name][i]),
                "user_fields": {f.name: raw["user"]["fields"][f.name][i]
                                for f in mc.user_fields},
                "item_fields": {f.name: raw["item"][f.name][i]
                                for f in mc.item_fields},
                "candidates": [(j, float(rng.random())) for j in range(64)],
            }
            if mc.seq_len:
                payload["hist"] = raw["user"]["hist"][i]
            evs.append(Event(payload=payload))
        return evs

    def run(self, n_requests: int = 64, executor: str = "async",
            rate_qps: float = 500.0):
        """Serve n_requests end to end. ``executor="async"`` is the real
        threaded path (bounded channels block upstream — backpressure);
        ``executor="sim"`` runs the identical DAG on the virtual clock with
        the shedder as the bounded-channel overflow policy."""
        reqs = self.make_requests(n_requests, seed=self.cfg.seed)
        if executor == "async":
            return AsyncExecutor(self.plan).run(reqs)
        if executor != "sim":
            raise ValueError(f"unknown executor {executor!r}")
        ex = SimExecutor(
            self.plan,
            overflow_policy=self.shedder.on_overflow if self.shedder else None)
        return ex.run([(i / rate_qps, ev) for i, ev in enumerate(reqs)])
