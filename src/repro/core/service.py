"""The deployable JiZHI services, composed from the scenario API.

Two surfaces (DESIGN.md §7):

  * :class:`MultiScenarioService` — the Model-as-a-Service composition:
    N declaratively-registered scenarios (DIN re-rank, DIEN sequential
    scoring, MIND/two-tower retrieval, ...) compiled into ONE SEDP DAG
    behind the quota-aware multi-tenant fanout, all sharing one
    cube / cube-cache / query-cache / streaming-update substrate.
  * :class:`InferenceService` — the original single-scenario surface,
    kept as a thin compatibility wrapper: ``InferenceService(cfg)``
    builds one scenario from a :class:`ServiceConfig` with the historic
    stage names (ingress → query_cache → features → cube → shed →
    rerank → respond) and attribute layout, so existing examples,
    benchmarks and tests keep working unchanged.

The stage logic itself lives in ``repro.serve.stages`` (typed processors
owning version pinning and cache-aside guards) and ``repro.serve.scenario``
(specs, substrate, pipeline builder, build-time payload-contract checks).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

from repro.core.executors import AsyncExecutor, SimExecutor
from repro.core.irm.shedding import QuotaController
from repro.core.multitenant import make_fanout_op
from repro.core.sedp import Event
from repro.serve.scenario import (PipelineBuilder, ScenarioSpec,
                                  ServingSubstrate, SubstrateDeltaWatcher,
                                  get_scenario, make_request_events)


@dataclass
class ServiceConfig:
    arch_id: str = "din"
    batch_size: int = 16
    cube_cache_ratio: float = 1.0
    query_window_s: float = 120.0
    shed: bool = True
    seed: int = 0
    # closed-loop serving knobs: bounded stage channels (backpressure) and
    # the per-stage micro-batching window (collect batch_size or wait)
    max_queue: int = 512
    batch_wait_s: float = 0.002
    # shape buckets for the jitted rerank stage: the micro-batcher hands it
    # whatever batch it collected and the shedder whatever candidate set
    # survived, so without padding every distinct (B, C, T_hist) is a fresh
    # XLA trace. None → powers of two up to the relevant maximum.
    rerank_buckets: Optional[tuple] = None     # batch dimension B
    cand_buckets: Optional[tuple] = None       # per-request candidate count C
    # live-update stage (DESIGN.md §6): tail a delta log and apply versioned
    # parameter deltas to the cube/caches/head while traffic flows
    live_updates: bool = False
    update_dir: Optional[str] = None
    update_poll_s: float = 0.1
    compact_after_blocks: int = 64
    head_slots: int = 0            # >0 → HBM head tier for promoted hot rows
    # bound on the per-group bucket → raw-items reverse map (entries over
    # the cap are invalidated-and-forgotten — over-invalidation is safe)
    reverse_map_items: int = 65536
    # crash safety (DESIGN.md §9): periodic durable cube snapshots + the
    # snapshot-then-replay restart path. ``recover=True`` boots from the
    # newest valid snapshot under ``snapshot_dir`` when one exists (cold
    # boot otherwise); with live updates configured, replay streams
    # through the watcher while the service serves degraded.
    snapshot_dir: Optional[str] = None
    snapshot_every_deltas: int = 8
    snapshot_keep: int = 2
    recover: bool = False

    def to_scenario_spec(self) -> ScenarioSpec:
        """The ServiceConfig → ScenarioSpec migration mapping (DESIGN.md
        §7.5): model/pipeline knobs move onto the spec; substrate knobs
        (caches, live updates, head) configure the ServingSubstrate."""
        return ScenarioSpec(
            name=self.arch_id, arch_id=self.arch_id, pipeline="rerank",
            shed=self.shed, batch_size=self.batch_size,
            batch_buckets=self.rerank_buckets,
            cand_buckets=self.cand_buckets, seed=self.seed)

    def make_substrate(self) -> ServingSubstrate:
        kw = dict(
            cube_cache_ratio=self.cube_cache_ratio,
            query_window_s=self.query_window_s,
            head_slots=self.head_slots,
            compact_after_blocks=self.compact_after_blocks,
            reverse_map_items=self.reverse_map_items, seed=self.seed)
        return _recover_or_build(self, kw)


@dataclass
class MultiServiceConfig:
    """Knobs of the multi-scenario composition. ``scenarios`` may hold
    ScenarioSpec objects or names registered in configs/jizhi_service.py;
    empty → the default 3-scenario surface (DIN + DIEN + MIND)."""
    scenarios: tuple = ()
    cube_cache_ratio: float = 1.0
    query_window_s: float = 120.0
    seed: int = 0
    max_queue: int = 512
    batch_wait_s: float = 0.002
    # fanout quota gate: below this, only priority-0 scenarios get clones
    min_quota: float = 0.5
    live_updates: bool = False
    update_dir: Optional[str] = None
    update_poll_s: float = 0.1
    compact_after_blocks: int = 64
    head_slots: int = 0
    reverse_map_items: int = 65536
    # crash safety (DESIGN.md §9) — same contract as ServiceConfig
    snapshot_dir: Optional[str] = None
    snapshot_every_deltas: int = 8
    snapshot_keep: int = 2
    recover: bool = False


def _recover_or_build(cfg, substrate_kw: dict) -> ServingSubstrate:
    """Boot a substrate per config: from the newest valid snapshot when
    ``cfg.recover`` asks for it and one exists, cold otherwise. With live
    updates configured, replay is left to the watcher (the service serves
    degraded while the suffix streams in); without one, the pending deltas
    replay inline so the substrate is caught up on return."""
    if getattr(cfg, "recover", False) and cfg.snapshot_dir:
        from repro.update.snapshot import latest_valid_snapshot
        if latest_valid_snapshot(cfg.snapshot_dir) is not None:
            return ServingSubstrate.recover(
                cfg.snapshot_dir, update_dir=cfg.update_dir,
                replay=not (cfg.live_updates and cfg.update_dir),
                **substrate_kw)
    return ServingSubstrate(**substrate_kw)


class _ServiceBase:
    """Shared run/update machinery of both service surfaces."""

    substrate: ServingSubstrate
    cfg = None
    plan = None

    # ------------------------------------------------------- properties
    @property
    def query_cache(self):
        return self.substrate.query_cache

    @property
    def cube_cache(self):
        return self.substrate.cube_cache

    @property
    def cube(self):
        return self.substrate.cube

    @property
    def updates(self):
        return self.substrate.updates

    # ------------------------------------------------------ live updates
    def _make_watcher(self):
        self.snapshotter = None
        if getattr(self.cfg, "snapshot_dir", None):
            from repro.update.snapshot import CubeSnapshotter
            self.snapshotter = CubeSnapshotter(
                self.substrate, self.cfg.snapshot_dir,
                every_deltas=self.cfg.snapshot_every_deltas,
                keep=self.cfg.snapshot_keep,
                delta_log_dir=getattr(self.cfg, "update_dir", None))
        if getattr(self.cfg, "live_updates", False) and self.cfg.update_dir:
            return SubstrateDeltaWatcher(
                self.substrate, self.cfg.update_dir,
                poll_s=self.cfg.update_poll_s,
                snapshotter=self.snapshotter)
        return None

    # ------------------------------------------------- graceful shutdown
    def shutdown(self):
        """Planned restart (DESIGN.md §9): quiesce the update watcher and
        take a final snapshot at the quiescent cursor, so the next boot
        with ``recover=True`` replays ZERO deltas. Returns the snapshot
        path (None when nothing advanced since the last snapshot, or no
        snapshotter is configured)."""
        self.stop_updates()
        if self.snapshotter is not None:
            return self.snapshotter.graceful_shutdown()
        return None

    def install_shutdown_hook(self, chain: bool = True):
        """SIGTERM → :meth:`shutdown` (preemption notice → final
        snapshot), chaining to the previous handler like the training
        side's emergency checkpoint hook."""
        if self.snapshotter is None:
            raise RuntimeError("no snapshotter configured "
                               "(set snapshot_dir)")
        return self.snapshotter.install_sigterm_hook(chain=chain)

    def start_updates(self):
        """Start the live-update stage (requires cfg.live_updates +
        cfg.update_dir): a watcher thread tails the delta log and applies
        each published version while traffic keeps flowing."""
        if self.update_watcher is None:
            raise RuntimeError("live updates not configured "
                               "(set live_updates=True and update_dir)")
        self.update_watcher.start()

    def stop_updates(self):
        if self.update_watcher is not None:
            self.update_watcher.stop()

    # --------------------------------------------------------------- run
    def _overflow_policy(self):
        raise NotImplementedError

    def run(self, n_requests: int = 64, executor: str = "async",
            rate_qps: float = 500.0, deadline_s: Optional[float] = None,
            tracer=None, exact_latencies: bool = True):
        """Serve n_requests end to end. ``executor="async"`` is the real
        threaded path (bounded channels block upstream — backpressure);
        ``executor="sim"`` runs the identical DAG on the virtual clock with
        the shedders as the bounded-channel overflow policy.

        ``deadline_s`` gives every request a latency budget: an event that
        outlives it is shed at the next stage dispatch and finishes as a
        timed-out terminal (``Response.timed_out``, DESIGN.md §8.4).

        ``tracer`` (an ``obs.Tracer``) records per-request span trees on
        either executor; ``exact_latencies=False`` drops the raw latency
        list from the report (the log-bucketed histogram remains)."""
        reqs = self.make_requests(n_requests, seed=self.cfg.seed,
                                  deadline_s=deadline_s)
        if executor == "async":
            rep = AsyncExecutor(self.plan, tracer=tracer,
                                exact_latencies=exact_latencies).run(reqs)
        elif executor == "sim":
            ex = SimExecutor(self.plan,
                             overflow_policy=self._overflow_policy(),
                             tracer=tracer, exact_latencies=exact_latencies)
            rep = ex.run([(i / rate_qps, ev) for i, ev in enumerate(reqs)])
        else:
            raise ValueError(f"unknown executor {executor!r}")
        # expired/errored events short-circuit past RespondStage — give
        # them a typed Response too so callers see ONE result surface
        from repro.serve.stages import Response
        for ev in rep.results:
            if "response" not in ev.meta:
                ev.meta["response"] = Response.from_event(ev)
        return rep


class InferenceService(_ServiceBase):
    """Single-scenario compatibility wrapper over the scenario API: the
    full JiZHI stack around a REAL JAX ranking model (SEDP DAG + query
    cache + cube cache/cube + online load shedding + a jitted recsys model
    as the DNN stage, with hot-loading via DoubleBuffer). The benchmark
    suite uses the calibrated service_model instead; THIS class is the
    functional end-to-end path."""

    def __init__(self, cfg: ServiceConfig = ServiceConfig()):
        self.cfg = cfg
        self.substrate = cfg.make_substrate()
        builder = PipelineBuilder(self.substrate, max_queue=cfg.max_queue,
                                  batch_wait_s=cfg.batch_wait_s)
        builder.add_ingress("ingress")
        rt = builder.add_scenario(cfg.to_scenario_spec(), namespaced=False)
        builder.g.add_edge("ingress", builder.entries[rt.spec.name])
        self.graph, self.plan = builder.compile()
        self._rt = rt
        # historic attribute surface (tests/examples poke these directly)
        self.model_cfg = rt.model_cfg
        self.mod = rt.mod
        self.buffer = rt.buffer
        self.shedder = rt.shedder
        self.rerank_buckets = rt.batch_buckets
        self.cand_buckets = rt.cand_buckets
        self.hist_buckets = rt.hist_buckets
        self._serve = rt.serve
        self._rerank = rt.rerank
        self._pack_batch = rt.pack_batch
        self.update_watcher = self._make_watcher()

    @property
    def _bucket_items(self):
        """Primary group's bucket → raw-items reverse map (bounded)."""
        return self.substrate.bucket_items[self._rt.cube_groups[0][1]].buckets

    def make_requests(self, n: int, seed: int = 0,
                      deadline_s: Optional[float] = None) -> list[Event]:
        return make_request_events([self.model_cfg], n, seed=seed,
                                   deadline_s=deadline_s)

    def _overflow_policy(self):
        return self.shedder.on_overflow if self.shedder else None


class MultiScenarioService(_ServiceBase):
    """N scenario pipelines behind the quota-aware multi-tenant fanout,
    one shared substrate (paper §4 multi-tenant extension + §8.6 Service
    E: several models share the upstream data plane and >80% of feature
    groups).

    DAG shape::

        ingress → fanout ──→ <s1>.query_cache → ... → <s1>.rerank ──→ respond
                         └─→ <s2>...                                ↗
                         └─→ <s3>...                                ↗

    The fanout clones each request to every scenario (payloads cloned so
    per-scenario stages never write into a sibling's view); under
    overload the quota controller gates secondary scenarios first —
    priority-0 scenarios keep serving while the rest ride out the spike.
    """

    def __init__(self, cfg: Union[MultiServiceConfig, Sequence, None] = None):
        if cfg is None:
            cfg = MultiServiceConfig()
        elif not isinstance(cfg, MultiServiceConfig):
            cfg = MultiServiceConfig(scenarios=tuple(cfg))
        self.cfg = cfg
        specs = []
        names = cfg.scenarios or _default_scenario_names()
        for s in names:
            specs.append(s if isinstance(s, ScenarioSpec)
                         else get_scenario(s))
        if not specs:
            raise ValueError("MultiScenarioService needs ≥1 scenario")
        self.substrate = _recover_or_build(cfg, dict(
            cube_cache_ratio=cfg.cube_cache_ratio,
            query_window_s=cfg.query_window_s, head_slots=cfg.head_slots,
            compact_after_blocks=cfg.compact_after_blocks,
            reverse_map_items=cfg.reverse_map_items, seed=cfg.seed))
        builder = PipelineBuilder(self.substrate, max_queue=cfg.max_queue,
                                  batch_wait_s=cfg.batch_wait_s)
        builder.add_ingress("ingress")
        for spec in specs:
            builder.add_scenario(spec, namespaced=True)
        # quota signal: the primary (lowest-priority-number) scenario's
        # terminal queue — the stage overload hits first
        primary = min(specs, key=lambda s: (s.priority, specs.index(s)))
        self.fanout_controller = QuotaController(
            builder.terminals[primary.name], depth_capacity=64.0)
        targets = [builder.entries[s.name] for s in specs]
        priorities = {builder.entries[s.name]: s.priority for s in specs}
        fan = make_fanout_op(targets, priorities=priorities,
                             quota_fn=self.fanout_controller.observe,
                             min_quota=cfg.min_quota)
        builder.g.add_stage("fanout", fan, batch_size=8, parallelism=1,
                            max_queue=cfg.max_queue,
                            max_wait_s=cfg.batch_wait_s)
        builder.g.add_edge("ingress", "fanout")
        for t in targets:
            builder.g.add_edge("fanout", t)
        self.graph, self.plan = builder.compile()
        self.specs = tuple(specs)
        self.runtimes = builder.runtimes
        self.entries = builder.entries
        self.terminals = builder.terminals
        self.update_watcher = self._make_watcher()

    # ------------------------------------------------------------ traffic
    def make_requests(self, n: int, seed: int = 0,
                      deadline_s: Optional[float] = None) -> list[Event]:
        return make_request_events(
            [rt.model_cfg for rt in self.runtimes.values()], n, seed=seed,
            deadline_s=deadline_s)

    def _overflow_policy(self):
        def policy(stage, ev, ctx):
            name = stage.split(".", 1)[0]
            rt = self.runtimes.get(name)
            if rt is not None and rt.shedder is not None:
                return rt.shedder.on_overflow(stage, ev, ctx)
            return ev
        return policy

    # ------------------------------------------------------------ results
    @staticmethod
    def by_scenario(report) -> dict:
        """Completed events grouped by the scenario that served them."""
        out: dict = {}
        for ev in report.results:
            get = ev.payload.get if hasattr(ev.payload, "get") else None
            name = (get("scenario", "?") if get else "?") or "?"
            out.setdefault(name, []).append(ev)
        return out

    @staticmethod
    def responses(report) -> list:
        """Typed Response objects (stamped by RespondStage)."""
        return [ev.meta["response"] for ev in report.results
                if "response" in ev.meta]


def _default_scenario_names() -> tuple:
    from repro.configs import jizhi_service
    return jizhi_service.DEFAULT_SCENARIOS
