"""InferenceService: the full JiZHI stack around a REAL JAX ranking model.

This is the deployable composition (examples/serve_recsys.py): SEDP DAG +
query cache + cube cache/cube + online load shedding + a jitted recsys model
(DIN by default) as the DNN stage, with hot-loading via DoubleBuffer. The
benchmark suite uses the calibrated service_model instead (deterministic
latency); THIS class is the functional end-to-end path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import sedp as sedp_lib
from repro.core.cube import ParameterCube
from repro.core.cube_cache import TwoTierLFUCache, capacity_from_ratio
from repro.core.executors import AsyncExecutor, SimExecutor
from repro.core.irm.shedding import (OnlineShedder, QuotaController,
                                     train_pruning_dnn)
from repro.core.query_cache import QueryCache
from repro.core.sedp import SEDP, Event
from repro.data import synthetic
from repro.serve.bucketing import (ShapeBucketer, TracedJit,
                                   bucketed_candidate_rerank, pow2_buckets,
                                   step_buckets)
from repro.serve.hotload import DoubleBuffer, Generation
from repro.sparse.hashing import hash_bucket_np


@dataclass
class ServiceConfig:
    arch_id: str = "din"
    batch_size: int = 16
    cube_cache_ratio: float = 1.0
    query_window_s: float = 120.0
    shed: bool = True
    seed: int = 0
    # closed-loop serving knobs: bounded stage channels (backpressure) and
    # the per-stage micro-batching window (collect batch_size or wait)
    max_queue: int = 512
    batch_wait_s: float = 0.002
    # shape buckets for the jitted rerank stage: the micro-batcher hands it
    # whatever batch it collected and the shedder whatever candidate set
    # survived, so without padding every distinct (B, C, T_hist) is a fresh
    # XLA trace. None → powers of two up to the relevant maximum.
    rerank_buckets: Optional[tuple] = None     # batch dimension B
    cand_buckets: Optional[tuple] = None       # per-request candidate count C


class InferenceService:
    def __init__(self, cfg: ServiceConfig = ServiceConfig()):
        self.cfg = cfg
        arch = registry.get(cfg.arch_id)
        self.model_cfg = arch.reduced(arch.config)
        from repro.launch.specs import REC_MODULES
        self.mod = REC_MODULES[self.model_cfg.model]
        params = self.mod.init(jax.random.PRNGKey(cfg.seed), self.model_cfg)
        self.buffer = DoubleBuffer(Generation(0, params))
        self.rerank_buckets = ShapeBucketer(
            cfg.rerank_buckets or pow2_buckets(cfg.batch_size))
        self.cand_buckets = ShapeBucketer(
            cfg.cand_buckets or pow2_buckets(64, min_size=16))
        # step-8 history buckets (DESIGN.md §5.3): padded history rows still
        # pay the full attention MLP, so tight T buckets beat a small menu
        self.hist_buckets = (ShapeBucketer(
            step_buckets(self.model_cfg.seq_len, step=8))
            if self.model_cfg.seq_len else None)
        self._serve = TracedJit(
            lambda p, b: self.mod.serve_scores(p, b, self.model_cfg))
        # fused one-user-many-candidates re-rank (kernels/rerank_score via
        # score_candidates): full ranking of each request's candidate set
        self._rerank = (TracedJit(
            lambda p, u, c: self.mod.score_candidates(
                p, u, c, self.model_cfg, top_k=c["item_id"].shape[0]))
            if hasattr(self.mod, "score_candidates") else None)

        vocab = self.model_cfg.item_fields[0].vocab
        self.query_cache = QueryCache(window_s=cfg.query_window_s)
        mem, disk = capacity_from_ratio(vocab * 4, cfg.cube_cache_ratio)
        self.cube_cache = TwoTierLFUCache(mem, disk)
        self.cube = ParameterCube(n_servers=4, replication=2, block_rows=4096)
        rng = np.random.default_rng(cfg.seed)
        for g, field in enumerate(self.model_cfg.item_fields):
            self.cube.load_table(g, rng.normal(
                0, 0.01, (field.vocab, 4)).astype(np.float32))
        self.shedder = None
        if cfg.shed:
            dnn, _ = train_pruning_dnn(n_samples=800, seed=cfg.seed)
            # live controller: re-rank queue depth + utilization → quota
            self.shedder = OnlineShedder(
                dnn, downstream="rerank",
                controller=QuotaController("rerank", depth_capacity=64.0))
        self.graph, self.plan = self._build()

    # ------------------------------------------------------------- stages
    def _build(self):
        g = SEDP()
        mc = self.model_cfg

        def op_qcache(batch, ctx):
            now = ctx.now()        # executor clock: wall (Async) or virtual (Sim)
            scores = self.query_cache.get_many(
                [ev.payload["user_id"] for ev in batch],
                [ev.payload["item_id"] for ev in batch], now)
            for ev, s in zip(batch, scores):
                if s is not None:
                    ev.payload["score"] = s
                    ev.route = "respond"
                else:
                    ev.route = "features"
            return batch

        def op_features(batch, ctx):
            items = np.fromiter((ev.payload["item_id"] for ev in batch),
                                np.int64, len(batch))
            hashed = hash_bucket_np(0, items, mc.item_fields[0].vocab)
            for ev, h in zip(batch, hashed):
                ev.payload["hashed"] = {"item_id": h}
            return batch

        def op_cube(batch, ctx):
            keys = [int(ev.payload["hashed"]["item_id"]) for ev in batch]
            cached = self.cube_cache.get_many(keys)
            miss = sorted({k for k, v in zip(keys, cached) if v is None})
            fetched = {}
            if miss:
                rows = self.cube.lookup(0, np.asarray(miss, np.int64))
                self.cube_cache.put_many(
                    miss, [rows[i:i + 1] for i in range(len(miss))])
                fetched = {k: rows[i] for i, k in enumerate(miss)}
            # the gathered rows ride on the event: the rerank stage consumes
            # cube output from the payload instead of re-touching the cube
            for ev, k, c in zip(batch, keys, cached):
                row = fetched[k] if c is None else c[0]
                ev.payload["cube_rows"] = np.asarray(row, np.float32)
            return batch

        def op_dnn(batch, ctx):
            params = self.buffer.active.payload
            B = len(batch)
            payloads = [ev.payload for ev in batch]
            # pad to the covering batch bucket (bounded jit-trace count);
            # scores are per-row, so slicing [:B] discards the filler exactly
            b = self._pack_batch(self.rerank_buckets.pad_rows(payloads))
            scores = np.asarray(self._serve(params, b))[:B]
            now = ctx.now()
            for ev, s in zip(batch, scores):
                ev.payload["score"] = float(s)
                self._rerank_candidates(params, ev.payload)
            self.query_cache.put_many(
                [ev.payload["user_id"] for ev in batch],
                [ev.payload["item_id"] for ev in batch],
                [float(s) for s in scores], now)
            return batch

        kw = dict(max_queue=self.cfg.max_queue,
                  max_wait_s=self.cfg.batch_wait_s)
        g.add_stage("ingress", sedp_lib.passthrough, batch_size=8,
                    parallelism=2, **kw)
        g.add_stage("query_cache", op_qcache, batch_size=16, parallelism=2,
                    **kw)
        g.add_stage("features", op_features, batch_size=8, parallelism=2, **kw)
        g.add_stage("cube", op_cube, batch_size=8, parallelism=2, **kw)
        if self.shedder:
            g.add_stage("shed", self.shedder.op, batch_size=8, parallelism=1,
                        **kw)
        g.add_stage("rerank", op_dnn, batch_size=self.cfg.batch_size,
                    parallelism=1, **kw)
        g.add_stage("respond", sedp_lib.passthrough, batch_size=32,
                    parallelism=1, **kw)
        g.chain("ingress", "query_cache")
        g.add_edge("query_cache", "respond")
        g.chain("query_cache", "features", "cube")
        if self.shedder:
            g.chain("cube", "shed", "rerank")
        else:
            g.add_edge("cube", "rerank")
        g.add_edge("rerank", "respond")
        return g, g.compile()

    def _pack_batch(self, payloads: list[dict]) -> dict:
        mc = self.model_cfg
        user_fields = {f.name: np.stack([p["user_fields"][f.name]
                                         for p in payloads])
                       for f in mc.user_fields}
        item = {f.name: np.stack([p["item_fields"][f.name] for p in payloads])
                for f in mc.item_fields}
        batch = {"user": {"fields": jax.tree.map(jnp.asarray, user_fields)},
                 "item": jax.tree.map(jnp.asarray, item)}
        # cube output attached upstream (op_cube) becomes a model input: the
        # item's host-tier tail features enter the packed batch here rather
        # than being re-derived by another cube round-trip
        if all("cube_rows" in p for p in payloads):
            batch["item"]["cube_tail"] = jnp.asarray(
                np.stack([p["cube_rows"] for p in payloads]))
        if mc.seq_len:
            batch["user"]["hist"] = jnp.asarray(
                np.stack([p["hist"] for p in payloads]))
        return batch

    def _rerank_candidates(self, params, payload: dict, keep: int = 12):
        """Full re-rank of the request's surviving candidate set through the
        fused shared-history scorer. C and the history length are padded to
        buckets so the jit cache stays at |cand_buckets| × |hist_buckets|."""
        mc = self.model_cfg
        cands = payload.get("candidates")
        if not cands or self._rerank is None or not mc.seq_len:
            return
        payload["topk"] = bucketed_candidate_rerank(
            self._rerank, params, payload["hist"],
            {f.name: payload["user_fields"][f.name] for f in mc.user_fields},
            cands, self.cand_buckets, self.hist_buckets,
            item_fields=[(f.name, f.bag) for f in mc.item_fields
                         if f.name != "item_id"], keep=keep)

    # --------------------------------------------------------------- run
    def make_requests(self, n: int, seed: int = 0) -> list[Event]:
        rng = np.random.default_rng(seed)
        mc = self.model_cfg
        evs = []
        raw = synthetic.recsys_batch(rng, mc, n)
        for i in range(n):
            payload = {
                "user_id": int(raw["user"]["fields"][mc.user_fields[0].name][i]
                               if mc.user_fields[0].bag == 1 else i),
                "item_id": int(raw["item"][mc.item_fields[0].name][i]),
                "user_fields": {f.name: raw["user"]["fields"][f.name][i]
                                for f in mc.user_fields},
                "item_fields": {f.name: raw["item"][f.name][i]
                                for f in mc.item_fields},
                "candidates": [(j, float(rng.random())) for j in range(64)],
            }
            if mc.seq_len:
                payload["hist"] = raw["user"]["hist"][i]
            evs.append(Event(payload=payload))
        return evs

    def run(self, n_requests: int = 64, executor: str = "async",
            rate_qps: float = 500.0):
        """Serve n_requests end to end. ``executor="async"`` is the real
        threaded path (bounded channels block upstream — backpressure);
        ``executor="sim"`` runs the identical DAG on the virtual clock with
        the shedder as the bounded-channel overflow policy."""
        reqs = self.make_requests(n_requests, seed=self.cfg.seed)
        if executor == "async":
            return AsyncExecutor(self.plan).run(reqs)
        if executor != "sim":
            raise ValueError(f"unknown executor {executor!r}")
        ex = SimExecutor(
            self.plan,
            overflow_policy=self.shedder.on_overflow if self.shedder else None)
        return ex.run([(i / rate_qps, ev) for i, ev in enumerate(reqs)])
