"""Multi-tenant extension (paper §4): multiple DNNs in ONE pipeline.

Two production uses:
  * multi-objective / multi-phase inference — several models share the
    upstream data processing + sparse parameter access (Service E: CTR, FR,
    CMT share >80% of feature groups);
  * A/B testing — a dispatch stage splits traffic between test groups, each
    an independent SEDP branch on shared infrastructure (no per-variant
    service deployments, no manual traffic splitting).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.sedp import Event, propagate_trace


@dataclass
class TrafficSplit:
    """Deterministic hash-based splitting (stable per user — the standard
    requirement for A/B assignment)."""
    groups: dict[str, float]                 # stage-name → traffic fraction

    def __post_init__(self):
        total = sum(self.groups.values())
        self._cum = []
        acc = 0.0
        for name, frac in self.groups.items():
            acc += frac / total
            self._cum.append((acc, name))

    def assign(self, user_id: int) -> str:
        u = (hash(("ab", user_id)) % 10_000) / 10_000.0
        for edge, name in self._cum:
            if u < edge:
                return name
        return self._cum[-1][1]


def _clone_payload(payload):
    """Per-tenant payload clone via the payload's own ``copy()`` — a
    shallow copy for plain dicts, an independent-extras clone for the
    scenario API's typed Requests."""
    return payload.copy()


def make_dispatch_op(split: TrafficSplit, key: str = "user") -> Callable:
    """SEDP stage op routing each event to its test-group branch.
    ``key`` names the payload field carrying the stable A/B unit (the
    scenario API's typed Requests use ``"user_id"``)."""
    def op(batch: list[Event], ctx):
        for ev in batch:
            ev.route = split.assign(ev.payload[key])
            ev.meta["tenant"] = ev.route
        return batch
    return op


def make_balance_op(pick: Callable, on_unroutable: str = "error") -> Callable:
    """Replica-fleet dispatch (DESIGN.md §11.4): route each event to the
    entry stage chosen by ``pick(ev, ctx) -> Optional[str]`` — the fleet
    balancer's least-loaded/health-aware policy. ``pick`` returning None
    means no live replica: the event is terminal-errored (``error``) or
    left on its default route (``passthrough``) per ``on_unroutable``."""
    def op(batch: list[Event], ctx):
        out = []
        for ev in batch:
            target = pick(ev, ctx)
            if target is None:
                if on_unroutable == "error":
                    ev.meta["error"] = "no live replica"
                    ev.meta["_terminal"] = True
                out.append(ev)
                continue
            ev.route = target
            ev.meta["replica"] = target
            out.append(ev)
        return out
    return op


def make_fanout_op(targets: list[str],
                   priorities: Optional[dict[str, int]] = None,
                   quota_fn: Optional[Callable] = None,
                   min_quota: float = 0.5) -> Callable:
    """Multi-objective: clone each event to every tenant DNN (they share the
    already-computed features in the payload by reference).

    Closed-loop extension: under overload, secondary objectives are the
    first thing to shed. ``quota_fn(ctx) -> float`` is the live quota signal
    (e.g. ``QuotaController.observe``); when it drops below ``min_quota``,
    only priority-0 tenants (``priorities``, default: first target) receive
    clones — CTR keeps serving while FR/CMT ride out the spike."""
    priorities = priorities or {t: (0 if i == 0 else 1)
                                for i, t in enumerate(targets)}

    def op(batch: list[Event], ctx):
        live = targets
        if quota_fn is not None:
            q = quota_fn(ctx)
            if q < min_quota:
                live = [t for t in targets if priorities.get(t, 1) == 0]
                if not live:
                    # a priorities dict with no 0-rank entry must not shed
                    # EVERY tenant (events would vanish / Async would hang
                    # waiting on them): keep the best-ranked tier instead
                    best = min(priorities.get(t, 1) for t in targets)
                    live = [t for t in targets
                            if priorities.get(t, 1) == best]
        out = []
        for ev in batch:
            if len(live) < len(targets):
                ev.meta["tenants_shed"] = [t for t in targets
                                           if t not in live]
            for i, t in enumerate(live):
                if i == 0:
                    e = ev
                else:
                    e = Event(payload=_clone_payload(ev.payload),
                              req_id=ev.req_id, born_at=ev.born_at)
                    # clones keep the request's trace identity so each
                    # tenant branch records a complete span tree
                    propagate_trace(ev, e)
                e.route = t
                out.append(e)
        return out
    return op
