"""Query cache (paper §5.2): LRU + TTL cache of user-item SCORES.

Insight: the user-item score is stable over a short window (Fig. 5b: ≥60% of
scores invariant within 2 minutes), so a recently computed score can be
reused — a hit eliminates the WHOLE downstream inference computation.

  * purely in-memory, LRU (recency matters here, unlike the cube cache)
  * entries expire after a tunable window (Table 6: [60, 600] s; default
    120 s, offline-tuned to 143 s in the paper's Service A)
  * any user feedback (click, unlike, …) invalidates that user's entries —
    preference just changed
  * conditioned insertion: only scores worth reusing (e.g. high-relevance
    items) are cached, via an admission predicate
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Optional


@dataclass
class QueryCacheStats:
    hits: int = 0
    misses: int = 0
    expirations: int = 0
    invalidations: int = 0

    @property
    def hit_ratio(self):
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class QueryCache:
    def __init__(self, capacity: int = 1_000_000, window_s: float = 120.0,
                 admit: Optional[Callable[[float], bool]] = None):
        self.capacity = capacity
        self.window_s = window_s
        self.admit = admit or (lambda score: True)
        self._data: OrderedDict[tuple, tuple[float, float]] = OrderedDict()
        self._by_user: dict[Any, set] = {}
        self.stats = QueryCacheStats()

    def get(self, user: Any, item: Any, now: float) -> Optional[float]:
        key = (user, item)
        hit = self._data.get(key)
        if hit is None:
            self.stats.misses += 1
            return None
        score, stamp = hit
        if now - stamp > self.window_s:
            self._evict(key)
            self.stats.expirations += 1
            self.stats.misses += 1
            return None
        self._data.move_to_end(key)          # LRU touch
        self.stats.hits += 1
        return score

    def put(self, user: Any, item: Any, score: float, now: float):
        if not self.admit(score):
            return
        key = (user, item)
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = (score, now)
        self._by_user.setdefault(user, set()).add(item)
        while len(self._data) > self.capacity:
            old_key, _ = self._data.popitem(last=False)
            self._by_user.get(old_key[0], set()).discard(old_key[1])

    # ------------------------------------------------------------ batched
    def get_many(self, users, items, now: float) -> list:
        """Vectorized multi-get for one event batch: single pass over the
        store with locally-bound dict methods, stats folded in once. Returns
        a list of Optional[float] aligned with the inputs."""
        data = self._data
        out = []
        hits = misses = expired = 0
        for user, item in zip(users, items):
            key = (user, item)
            entry = data.get(key)
            if entry is None:
                misses += 1
                out.append(None)
                continue
            score, stamp = entry
            if now - stamp > self.window_s:
                self._evict(key)
                expired += 1
                misses += 1
                out.append(None)
                continue
            data.move_to_end(key)                # LRU touch
            hits += 1
            out.append(score)
        self.stats.hits += hits
        self.stats.misses += misses
        self.stats.expirations += expired
        return out

    def put_many(self, users, items, scores, now: float):
        """Vectorized multi-put: admission filter + insert for a whole batch,
        deferring capacity trimming to one pass at the end."""
        data, by_user, admit = self._data, self._by_user, self.admit
        for user, item, score in zip(users, items, scores):
            if not admit(score):
                continue
            key = (user, item)
            if key in data:
                data.move_to_end(key)
            data[key] = (score, now)
            by_user.setdefault(user, set()).add(item)
        while len(data) > self.capacity:
            old_key, _ = data.popitem(last=False)
            by_user.get(old_key[0], set()).discard(old_key[1])

    def user_feedback(self, user: Any):
        """Click/unlike/… → the user's cached scores are stale (paper §5.2)."""
        items = self._by_user.pop(user, set())
        for it in items:
            self._data.pop((user, it), None)
        self.stats.invalidations += len(items)

    def _evict(self, key):
        self._data.pop(key, None)
        self._by_user.get(key[0], set()).discard(key[1])

    def __len__(self):
        return len(self._data)
