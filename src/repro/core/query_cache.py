"""Query cache (paper §5.2): LRU + TTL cache of user-item SCORES.

Insight: the user-item score is stable over a short window (Fig. 5b: ≥60% of
scores invariant within 2 minutes), so a recently computed score can be
reused — a hit eliminates the WHOLE downstream inference computation.

  * purely in-memory, LRU (recency matters here, unlike the cube cache)
  * entries expire after a tunable window (Table 6: [60, 600] s; default
    120 s, offline-tuned to 143 s in the paper's Service A)
  * any user feedback (click, unlike, …) invalidates that user's entries —
    preference just changed
  * conditioned insertion: only scores worth reusing (e.g. high-relevance
    items) are cached, via an admission predicate

Coherence with the streaming-update subsystem (DESIGN.md §6): a cached
score embeds the MODEL that produced it, so every entry carries the
``model_version`` current at insert. ``bump_model_version`` (wired to the
hot-swap double buffer) lazily invalidates everything computed by the old
generation — previously a hot swap kept serving old-model scores out of
this cache for up to ``window_s`` seconds. ``invalidate_items`` is the
targeted form for parameter deltas: exactly the items whose rows a delta
touched drop, via a reverse item → users index.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Optional


@dataclass
class QueryCacheStats:
    hits: int = 0
    misses: int = 0
    expirations: int = 0
    invalidations: int = 0
    stale_version: int = 0     # entries dropped by model-version coherence

    @property
    def hit_ratio(self):
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class QueryCache:
    def __init__(self, capacity: int = 1_000_000, window_s: float = 120.0,
                 admit: Optional[Callable[[float], bool]] = None):
        self.capacity = capacity
        self.window_s = window_s
        self.admit = admit or (lambda score: True)
        # key → (score, insert_time, model_version)
        self._data: OrderedDict[tuple, tuple[float, float, int]] = OrderedDict()
        self._by_user: dict[Any, set] = {}
        self._by_item: dict[Any, set] = {}
        self.stats = QueryCacheStats()
        self.model_version = 0
        self._min_valid = 0

    @staticmethod
    def _unlink(index: dict, key, member):
        """Drop ``member`` from a reverse-index set, removing the key when
        the set empties — bare .discard() would leak one empty set per
        distinct user/item ever cached (unbounded on a large catalog)."""
        s = index.get(key)
        if s is not None:
            s.discard(member)
            if not s:
                del index[key]

    @staticmethod
    def _link(index: dict, key, member):
        """Add ``member`` to a reverse-index set, re-checking the set is
        still INSTALLED afterwards: an invalidation (update thread) can pop
        the set between our setdefault and our add, which would strand the
        member in an orphaned set — the cached entry would then be
        unreachable by every future targeted invalidation (including the
        serving op's own post-insert race guard) and serve stale until
        TTL. Each step is GIL-atomic; invalidations are rare, so the loop
        converges immediately in practice."""
        while True:
            s = index.setdefault(key, set())
            s.add(member)
            if index.get(key) is s:
                return

    # ------------------------------------------------------- invalidation
    def bump_model_version(self) -> int:
        """A new model generation was hot-swapped in: every cached score was
        computed by the OLD model — raise the validity floor so they all
        miss (and drop) on their next probe. O(1); no sweep."""
        self.model_version += 1
        self._min_valid = self.model_version
        return self.model_version

    def invalidate_items(self, items) -> int:
        """Targeted coherence for a parameter delta: scores for exactly
        these items are stale (their sparse rows just changed); everyone
        else's cache entries survive. Returns entries dropped."""
        n = 0
        for item in items:
            users = self._by_item.pop(item, None)
            if not users:
                continue
            for user in users:
                if self._data.pop((user, item), None) is not None:
                    self._unlink(self._by_user, user, item)
                    n += 1
        self.stats.invalidations += n
        return n

    # ------------------------------------------------------------- access
    def get(self, user: Any, item: Any, now: float) -> Optional[float]:
        key = (user, item)
        hit = self._data.get(key)
        if hit is None:
            self.stats.misses += 1
            return None
        score, stamp, ver = hit
        if ver < self._min_valid:
            self._evict(key)
            self.stats.stale_version += 1
            self.stats.misses += 1
            return None
        if now - stamp > self.window_s:
            self._evict(key)
            self.stats.expirations += 1
            self.stats.misses += 1
            return None
        self._data.move_to_end(key)          # LRU touch
        self.stats.hits += 1
        return score

    def put(self, user: Any, item: Any, score: float, now: float,
            version: Optional[int] = None):
        """``version``: the model_version the score was COMPUTED at (capture
        it before binding the generation); defaults to the current one. A
        swap racing the insert then leaves the entry stamped pre-bump —
        lazily dropped, never a stale score marked fresh."""
        if not self.admit(score):
            return
        key = (user, item)
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = (score, now,
                           self.model_version if version is None else version)
        self._link(self._by_user, user, item)
        self._link(self._by_item, item, user)
        while len(self._data) > self.capacity:
            old_key, _ = self._data.popitem(last=False)
            self._unlink(self._by_user, old_key[0], old_key[1])
            self._unlink(self._by_item, old_key[1], old_key[0])

    # ------------------------------------------------------------ batched
    def get_many(self, users, items, now: float) -> list:
        """Vectorized multi-get for one event batch: single pass over the
        store with locally-bound dict methods, stats folded in once. Returns
        a list of Optional[float] aligned with the inputs."""
        data = self._data
        out = []
        hits = misses = expired = stale = 0
        for user, item in zip(users, items):
            key = (user, item)
            entry = data.get(key)
            if entry is None:
                misses += 1
                out.append(None)
                continue
            score, stamp, ver = entry
            if ver < self._min_valid:
                self._evict(key)
                stale += 1
                misses += 1
                out.append(None)
                continue
            if now - stamp > self.window_s:
                self._evict(key)
                expired += 1
                misses += 1
                out.append(None)
                continue
            data.move_to_end(key)                # LRU touch
            hits += 1
            out.append(score)
        self.stats.hits += hits
        self.stats.misses += misses
        self.stats.expirations += expired
        self.stats.stale_version += stale
        return out

    def put_many(self, users, items, scores, now: float,
                 version: Optional[int] = None):
        """Vectorized multi-put: admission filter + insert for a whole batch,
        deferring capacity trimming to one pass at the end. ``version`` as
        in put(): stamp with the model version the scores were computed at."""
        data, by_user, by_item = self._data, self._by_user, self._by_item
        admit = self.admit
        ver = self.model_version if version is None else version
        for user, item, score in zip(users, items, scores):
            if not admit(score):
                continue
            key = (user, item)
            if key in data:
                data.move_to_end(key)
            data[key] = (score, now, ver)
            self._link(by_user, user, item)
            self._link(by_item, item, user)
        while len(data) > self.capacity:
            old_key, _ = data.popitem(last=False)
            self._unlink(by_user, old_key[0], old_key[1])
            self._unlink(by_item, old_key[1], old_key[0])

    def user_feedback(self, user: Any):
        """Click/unlike/… → the user's cached scores are stale (paper §5.2)."""
        items = self._by_user.pop(user, set())
        for it in items:
            self._data.pop((user, it), None)
            self._unlink(self._by_item, it, user)
        self.stats.invalidations += len(items)

    def _evict(self, key):
        self._data.pop(key, None)
        self._unlink(self._by_user, key[0], key[1])
        self._unlink(self._by_item, key[1], key[0])

    def __len__(self):
        return len(self._data)
