"""Staged Event-Driven Pipeline (paper §4).

  Definition 1: stage processor p = ⟨op, c⟩ — op: unit primitive for one
  execution stage; c: channel queuing events from upstream processors.
  Definition 2: SEDP = DAG G = (P, E); all edges into a stage SHARE one
  channel (join/aggregation semantics).

``SEDP.compile()`` validates the DAG, builds the shared channels, and
returns an execution plan (topological order + routing table) that the
executors (repro.core.executors) run fully asynchronously.

Events carry an optional ``route`` so an op can steer each event to a subset
of its successors — this is how the query cache short-circuits to the
response stage and how the multi-tenant dispatcher fans traffic to test
groups (§4 "multi-tenant extension").
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

_uid = itertools.count()


@dataclass
class Event:
    """One inference task (user-item pair / request) flowing through the DAG.

    ``meta`` doubles as the telemetry carrier (DESIGN.md §10): when a
    ``Tracer`` is attached to the executor, ``meta["trace_id"]`` holds the
    request's trace id and ``meta["spans"]`` the span list the executors
    append to on every stage visit. Ops that clone events (fanout) must
    call ``propagate_trace`` so the clone's span tree stays complete."""
    payload: Any
    req_id: int = field(default_factory=lambda: next(_uid))
    route: Optional[str] = None        # next-stage override (None = all succs)
    born_at: float = 0.0               # set by the executor clock
    done_at: float = 0.0
    # absolute deadline on the executor clock (None = no budget). Stamped
    # at ingress from meta["deadline_s"] (born_at + budget); every stage
    # dispatch checks it — an expired event short-circuits to a timed-out
    # terminal instead of occupying downstream stages (DESIGN.md §8.4)
    deadline_at: Optional[float] = None
    meta: dict = field(default_factory=dict)

    @property
    def trace_id(self) -> Optional[int]:
        return self.meta.get("trace_id")


def propagate_trace(parent: "Event", clone: "Event") -> "Event":
    """Carry the parent's trace identity onto a cloned event: same trace
    id, a branched copy of the span history (the closed prefix is shared
    structurally; each branch appends to its own list). No-op when the
    parent is untraced."""
    spans = parent.meta.get("spans")
    if spans is not None:
        clone.meta["trace_id"] = parent.meta["trace_id"]
        clone.meta["spans"] = list(spans)
    return clone


@dataclass
class StageProcessor:
    """op(batch: list[Event], ctx) -> list[Event]. Tunables (batch size,
    parallelism, batching window, channel bound) are exactly the paper's
    per-stage knobs (Table 6)."""
    name: str
    op: Callable
    batch_size: int = 1
    parallelism: int = 1
    # bounded channel: when the stage's queue holds max_queue events the
    # upstream either blocks (AsyncExecutor) or offers the event to the
    # load-shedding policy (SimExecutor) instead of growing without bound
    max_queue: int = 100_000
    # micro-batching window: a partial batch is held up to max_wait_s for
    # more arrivals before it is flushed (None = executor default)
    max_wait_s: Optional[float] = None
    # offline-tunable service-time model (used by SimExecutor):
    # seconds = base + per_item * n  (amortization is what batch tuning buys)
    sim_base_s: float = 0.0
    sim_per_item_s: float = 0.0

    def __post_init__(self):
        # a non-positive bound would mean "unbounded" to queue.Queue but
        # "overflow every event" to SimExecutor — reject it at the shared
        # knob instead of diverging per executor
        if self.max_queue <= 0:
            raise GraphError(
                f"stage {self.name!r}: max_queue must be positive "
                f"(got {self.max_queue})")


class GraphError(ValueError):
    pass


@dataclass
class Plan:
    stages: dict[str, StageProcessor]
    succs: dict[str, list[str]]
    preds: dict[str, list[str]]
    order: list[str]
    sources: list[str]
    sinks: list[str]


class SEDP:
    def __init__(self):
        self.stages: dict[str, StageProcessor] = {}
        self.edges: list[tuple[str, str]] = []

    def add_stage(self, name: str, op: Callable, **kw) -> StageProcessor:
        if name in self.stages:
            raise GraphError(f"duplicate stage {name!r}")
        sp = StageProcessor(name, op, **kw)
        self.stages[name] = sp
        return sp

    def add_edge(self, src: str, dst: str):
        for s in (src, dst):
            if s not in self.stages:
                raise GraphError(f"unknown stage {s!r}")
        if (src, dst) in self.edges:
            raise GraphError(f"duplicate edge {src}->{dst}")
        self.edges.append((src, dst))

    def chain(self, *names: str):
        for a, b in zip(names, names[1:]):
            self.add_edge(a, b)

    def compile(self) -> Plan:
        """Validate DAG + topo-sort. One channel per stage, shared by all
        in-edges (Definition 2)."""
        succs = {n: [] for n in self.stages}
        preds = {n: [] for n in self.stages}
        for a, b in self.edges:
            succs[a].append(b)
            preds[b].append(a)
        # Kahn topo sort → cycle detection
        indeg = {n: len(p) for n, p in preds.items()}
        frontier = [n for n, d in indeg.items() if d == 0]
        order = []
        while frontier:
            n = frontier.pop()
            order.append(n)
            for m in succs[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    frontier.append(m)
        if len(order) != len(self.stages):
            cyc = [n for n, d in indeg.items() if d > 0]
            raise GraphError(f"cycle through {cyc}")
        sources = [n for n in self.stages if not preds[n]]
        sinks = [n for n in self.stages if not succs[n]]
        if not sources or not sinks:
            raise GraphError("SEDP needs at least one source and one sink")
        # route targets must be real successors
        return Plan(self.stages, succs, preds, order, sources, sinks)


# ------------------------------------------------------------------ helpers

def passthrough(batch: list[Event], ctx) -> list[Event]:
    return batch


def map_op(fn: Callable[[Any], Any]) -> Callable:
    """Lift an item-level function to a batch op."""
    def op(batch: list[Event], ctx):
        for ev in batch:
            ev.payload = fn(ev.payload)
        return batch
    return op
