"""Executors for a compiled SEDP plan.

  * AsyncExecutor  — real threads, one worker pool + shared channel per stage;
    fully asynchronous event-driven execution (the production path; wraps
    jitted JAX steps in the DNN stage, JAX's async dispatch overlaps host
    stages with device compute).
  * SimExecutor    — deterministic discrete-event simulation with a virtual
    clock. Ops EXECUTE functionally (so caches/shedding change routing), but
    time advances by each stage's service-time model + queueing at
    ``parallelism`` servers. All latency/throughput numbers in benchmarks
    come from here (reproducible; no wall-clock noise).
  * LegacyExecutor — the paper's §2 baseline: synchronous batch pipeline with
    a barrier per stage (pipeline stalls on long-tail items — exactly the
    behaviour SEDP removes).
"""
from __future__ import annotations

import heapq
import queue
import threading
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.sedp import Event, Plan, StageProcessor


@dataclass
class StageStats:
    events: int = 0
    batches: int = 0
    busy_s: float = 0.0
    queue_wait_s: float = 0.0

    @property
    def avg_batch(self):
        return self.events / max(1, self.batches)


@dataclass
class RunReport:
    latencies: list = field(default_factory=list)       # per finished event
    stage_stats: dict = field(default_factory=dict)
    makespan_s: float = 0.0
    results: list = field(default_factory=list)

    @property
    def throughput(self):
        return len(self.latencies) / max(1e-9, self.makespan_s)

    def latency_percentile(self, q: float) -> float:
        if not self.latencies:
            return 0.0
        xs = sorted(self.latencies)
        return xs[min(len(xs) - 1, int(q * len(xs)))]

    @property
    def avg_latency(self):
        return sum(self.latencies) / max(1, len(self.latencies))


class ExecContext:
    """Passed to every op: executor-wide shared state + system feedback
    (queue depths → the load-shedder's 'quota' feature, Table 7)."""

    def __init__(self, executor):
        self.executor = executor
        self.shared: dict = {}

    def queue_depth(self, stage: str) -> int:
        try:
            return self.executor._depth(stage)
        except KeyError:
            return 0

    def now(self) -> float:
        return self.executor._now()


# --------------------------------------------------------------- Async

class AsyncExecutor:
    def __init__(self, plan: Plan, batch_timeout_s: float = 0.002):
        self.plan = plan
        self.batch_timeout_s = batch_timeout_s
        self.channels = {n: queue.Queue() for n in plan.stages}
        self.out_q: queue.Queue = queue.Queue()
        self.stats = defaultdict(StageStats)
        self.ctx = ExecContext(self)
        self._stop = threading.Event()
        self._pending = 0
        self._pending_lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._gen = 0          # run() generation; stale workers must not emit

    def _now(self):
        return time.monotonic()

    def _depth(self, stage):
        return self.channels[stage].qsize()

    def _worker(self, sp: StageProcessor, gen: int):
        ch = self.channels[sp.name]
        while not self._stop.is_set() and self._gen == gen:
            batch = []
            try:
                batch.append(ch.get(timeout=0.05))
            except queue.Empty:
                continue
            t_dead = time.monotonic() + self.batch_timeout_s
            while len(batch) < sp.batch_size:
                try:
                    batch.append(ch.get(timeout=max(0, t_dead - time.monotonic())))
                except queue.Empty:
                    break
            t0 = time.monotonic()
            out = sp.op(batch, self.ctx) or []
            if self._gen != gen:
                return       # a newer run() started: don't touch its state
            st = self.stats[sp.name]
            st.events += len(batch)
            st.batches += 1
            st.busy_s += time.monotonic() - t0
            self._emit(sp.name, out)

    def _emit(self, stage: str, events):
        succs = self.plan.succs[stage]
        for ev in events:
            targets = ([ev.route] if ev.route in succs else succs)
            ev.route = None
            if not targets:
                ev.done_at = time.monotonic()
                self.out_q.put(ev)
                with self._pending_lock:
                    self._pending -= 1
                continue
            if len(targets) > 1:
                with self._pending_lock:
                    self._pending += len(targets) - 1
            for t in targets:
                self.channels[t].put(ev)

    def run(self, events: list[Event], source: Optional[str] = None) -> RunReport:
        source = source or self.plan.sources[0]
        # fresh lifecycle per run: bump the generation and clear the stop
        # flag/stats left by a previous run() so the executor is reusable
        # (no stale-stop hang, no double-counted stats, and any worker that
        # outlived the join below exits on the generation mismatch instead
        # of stealing this run's events)
        self._gen += 1
        gen = self._gen
        self._stop.clear()
        self.stats = defaultdict(StageStats)
        for sp in self.plan.stages.values():
            for _ in range(sp.parallelism):
                th = threading.Thread(target=self._worker, args=(sp, gen),
                                      daemon=True)
                th.start()
                self._threads.append(th)
        t_start = time.monotonic()
        with self._pending_lock:
            self._pending = len(events)
        for ev in events:
            ev.born_at = time.monotonic()
            self.channels[source].put(ev)
        done = []
        while True:
            with self._pending_lock:
                if self._pending <= 0 and all(q.empty() for q in self.channels.values()):
                    if self.out_q.qsize() >= len(done):
                        pass
            try:
                ev = self.out_q.get(timeout=0.2)
                done.append(ev)
            except queue.Empty:
                with self._pending_lock:
                    if self._pending <= 0:
                        break
        self._stop.set()
        for th in self._threads:        # workers exit within their poll tick
            th.join(timeout=2.0)
        self._threads = [th for th in self._threads if th.is_alive()]
        rep = RunReport(
            latencies=[ev.done_at - ev.born_at for ev in done],
            stage_stats=dict(self.stats),
            makespan_s=time.monotonic() - t_start,
            results=done)
        return rep


# ----------------------------------------------------------------- Sim

@dataclass(order=True)
class _SimItem:
    t: float
    seq: int
    kind: str = field(compare=False)
    data: Any = field(compare=False)


class SimExecutor:
    """Discrete-event simulation: each stage = FIFO + ``parallelism`` servers;
    service time = sim_base_s + sim_per_item_s * len(batch) (per batch).
    Deterministic: same inputs → same report."""

    def __init__(self, plan: Plan, service_time: Optional[Callable] = None):
        self.plan = plan
        self.service_time = service_time or self._default_service_time
        self.stats = defaultdict(StageStats)
        self.ctx = ExecContext(self)
        # deques: stage dispatch pops from the head; list.pop(0) would be
        # O(n) per event and O(n²) in queue depth under heavy traffic
        self._queues: dict[str, deque[Event]] = {n: deque() for n in plan.stages}
        self._free_at: dict[str, list[float]] = {
            n: [0.0] * sp.parallelism for n, sp in plan.stages.items()}
        self._clock = 0.0
        self._done: list[Event] = []

    @staticmethod
    def _default_service_time(sp: StageProcessor, batch):
        return sp.sim_base_s + sp.sim_per_item_s * len(batch)

    def _now(self):
        return self._clock

    def _depth(self, stage):
        return len(self._queues[stage])

    def run(self, arrivals: list[tuple[float, Event]],
            source: Optional[str] = None) -> RunReport:
        source = source or self.plan.sources[0]
        pq: list[_SimItem] = []
        seq = 0
        for t, ev in arrivals:
            ev.born_at = t
            heapq.heappush(pq, _SimItem(t, seq, "arrive", (source, ev)))
            seq += 1
        while pq:
            item = heapq.heappop(pq)
            self._clock = max(self._clock, item.t)
            if item.kind == "arrive":
                stage, ev = item.data
                self._queues[stage].append(ev)
                seq = self._try_dispatch(stage, pq, seq)
            else:  # ("finish", stage, server_idx, batch, out_events)
                stage, si, batch, out = item.data
                st = self.stats[stage]
                st.events += len(batch)
                st.batches += 1
                self._emit(stage, out, pq)
                seq = self._try_dispatch(stage, pq, seq)
                for other in self.plan.stages:
                    seq = self._try_dispatch(other, pq, seq)
        rep = RunReport(
            latencies=[ev.done_at - ev.born_at for ev in self._done],
            stage_stats=dict(self.stats),
            makespan_s=self._clock - (arrivals[0][0] if arrivals else 0.0),
            results=self._done)
        return rep

    def _try_dispatch(self, stage: str, pq, seq: int) -> int:
        sp = self.plan.stages[stage]
        q = self._queues[stage]
        frees = self._free_at[stage]
        while q:
            si = min(range(len(frees)), key=frees.__getitem__)
            if frees[si] > self._clock:
                break
            batch = [q.popleft() for _ in range(min(sp.batch_size, len(q)))]
            t0 = self._clock
            out = sp.op(batch, self.ctx) or []
            dt = self.service_time(sp, batch)
            for e in batch:                     # cost consumed by THIS stage
                e.meta.pop("cost_s", None)
            frees[si] = t0 + dt
            self.stats[stage].busy_s += dt
            heapq.heappush(pq, _SimItem(t0 + dt, seq, "finish",
                                        (stage, si, batch, out)))
            seq += 1
        return seq

    def _emit(self, stage: str, events, pq):
        succs = self.plan.succs[stage]
        for ev in events:
            targets = ([ev.route] if ev.route in succs else succs)
            ev.route = None
            if not targets:
                ev.done_at = self._clock
                self._done.append(ev)
                continue
            for t in targets:
                self._queues[t].append(ev)


# -------------------------------------------------------------- Legacy

class LegacyExecutor:
    """§2 baseline: data-parallel batches; batches run in parallel across
    the fleet, but WITHIN a batch every stage is a BARRIER — the batch moves
    at the pace of its slowest item (pipeline stall on long-tail candidates),
    with zero cross-stage overlap. Caches/routing shortcuts don't exist in
    the legacy design, so ops still execute but `route` shortcuts are
    ignored (every event pays the full stage list)."""

    def __init__(self, plan: Plan, service_time: Optional[Callable] = None,
                 batch_size: int = 8):
        self.plan = plan
        self.batch_size = batch_size
        self.service_time = service_time or SimExecutor._default_service_time
        self.ctx = ExecContext(self)
        self._clock = 0.0
        self.stats = defaultdict(StageStats)

    def _now(self):
        return self._clock

    def _depth(self, stage):
        return 0

    def run(self, arrivals: list[tuple[float, Event]], source=None) -> RunReport:
        done: list[Event] = []
        order = self.plan.order
        t_first = arrivals[0][0] if arrivals else 0.0
        t_last = t_first
        for start in range(0, len(arrivals), self.batch_size):
            chunk = arrivals[start:start + self.batch_size]
            evs = []
            for t, ev in chunk:
                ev.born_at = t
                evs.append(ev)
            # batch can't start until it has filled
            t = chunk[-1][0]
            self._clock = t
            for stage in order:
                sp = self.plan.stages[stage]
                out = sp.op(list(evs), self.ctx) or []
                # barrier: parallel workers amortize the bulk, but the batch
                # leaves only when the SLOWEST item does
                bulk = self.service_time(sp, evs) / max(1, sp.parallelism)
                tail = max((e.meta.get("cost_s", sp.sim_per_item_s)
                            for e in evs), default=0.0)
                dt = sp.sim_base_s + bulk + tail
                for e in evs:                   # cost consumed by THIS stage
                    e.meta.pop("cost_s", None)
                t += dt
                st = self.stats[stage]
                st.events += len(evs)
                st.batches += 1
                st.busy_s += dt * max(1, sp.parallelism)   # workers held idle
                evs = out
                for e in evs:
                    e.route = None                          # no shortcuts
            for ev in evs:
                ev.done_at = t
                done.append(ev)
            t_last = max(t_last, t)
        return RunReport(latencies=[e.done_at - e.born_at for e in done],
                         stage_stats=dict(self.stats),
                         makespan_s=t_last - t_first, results=done)
