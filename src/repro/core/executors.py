"""Executors for a compiled SEDP plan.

  * AsyncExecutor  — real threads, one worker pool + shared channel per stage;
    fully asynchronous event-driven execution (the production path; wraps
    jitted JAX steps in the DNN stage, JAX's async dispatch overlaps host
    stages with device compute).
  * SimExecutor    — deterministic discrete-event simulation with a virtual
    clock. Ops EXECUTE functionally (so caches/shedding change routing), but
    time advances by each stage's service-time model + queueing at
    ``parallelism`` servers. All latency/throughput numbers in benchmarks
    come from here (reproducible; no wall-clock noise).
  * LegacyExecutor — the paper's §2 baseline: synchronous batch pipeline with
    a barrier per stage (pipeline stalls on long-tail items — exactly the
    behaviour SEDP removes).
"""
from __future__ import annotations

import heapq
import logging
import math
import queue
import threading
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.sedp import Event, Plan, StageProcessor
from repro.obs.metrics import Histogram
from repro.serve.batcher import MicroBatcher

log = logging.getLogger(__name__)


def _stamp_deadline(ev: Event, born_at: float):
    """Ingress deadline stamping: a request carrying a ``deadline_s``
    budget gets its absolute deadline fixed on the executor clock the
    moment it enters the pipeline."""
    if ev.deadline_at is None:
        budget = ev.meta.get("deadline_s")
        if budget is not None:
            ev.deadline_at = born_at + float(budget)


@dataclass
class StageStats:
    events: int = 0
    batches: int = 0
    busy_s: float = 0.0
    queue_wait_s: float = 0.0
    max_depth: int = 0        # deepest the stage's channel ever got
    overflows: int = 0        # enqueue attempts that found the channel full
    dropped: int = 0          # events shed AT this channel (overflow policy)
    expired: int = 0          # events past their deadline at dispatch — shed
    errors: int = 0           # events whose stage op raised (error-terminal)
    degraded: int = 0         # events this stage served off the ladder's
    #                           non-primary tiers (replica/stale/default)

    @property
    def avg_batch(self):
        return self.events / max(1, self.batches)


@dataclass
class RunReport:
    latencies: list = field(default_factory=list)       # per finished event
    stage_stats: dict = field(default_factory=dict)
    makespan_s: float = 0.0
    results: list = field(default_factory=list)
    offered: int = 0          # events injected at the source
    dropped: int = 0          # events shed by overflow policy (never finish)
    expired: int = 0          # deadline-expired events (finish timed-out)
    errors: int = 0           # events terminated by a stage-op exception
    completed: int = 0        # events that reached the sink (incl. expired/
    #                           errored terminals) — authoritative even when
    #                           exact latency retention is off
    # log-bucketed latency histogram: ALWAYS populated; the default
    # accounting path when ``exact_latencies=False`` drops the raw list
    # (bounded memory on long-running serving loops)
    latency_hist: Optional[Histogram] = None

    @property
    def throughput(self):
        n = self.completed or len(self.latencies)
        return n / max(1e-9, self.makespan_s)

    @property
    def goodput(self):
        """Completed (non-shed) requests per second of makespan."""
        return self.throughput

    @property
    def drop_ratio(self):
        return self.dropped / max(1, self.offered)

    def latency_percentile(self, q: float) -> float:
        """Ceil-based nearest-rank percentile: the smallest x with at least
        ``ceil(q*n)`` samples ≤ x. (The old ``int(q*n)`` index read one
        rank high on exact fractions and under-indexed small samples.)
        Falls back to the log-bucketed histogram when exact samples were
        not retained."""
        if self.latencies:
            xs = sorted(self.latencies)
            return xs[max(0, math.ceil(q * len(xs)) - 1)]
        if self.latency_hist is not None and self.latency_hist.count:
            return self.latency_hist.percentile(q)
        return 0.0

    @property
    def avg_latency(self):
        if self.latencies:
            return sum(self.latencies) / len(self.latencies)
        if self.latency_hist is not None and self.latency_hist.count:
            return self.latency_hist.sum / self.latency_hist.count
        return 0.0


class ExecContext:
    """Passed to every op: executor-wide shared state + intermediate system
    feedback — queue depths and per-stage stats feed the load-shedder's
    'quota' feature (Table 7)."""

    def __init__(self, executor):
        self.executor = executor
        self.shared: dict = {}

    def queue_depth(self, stage: str) -> int:
        try:
            return self.executor._depth(stage)
        except KeyError:
            return 0

    def stage_stats(self, stage: str) -> StageStats:
        return self.executor.stats[stage]

    def utilization(self, stage: str) -> float:
        """busy-server-seconds / available-server-seconds since run start;
        >1 means the offered work exceeds the stage's service capacity."""
        ex = self.executor
        sp = ex.plan.stages.get(stage)
        if sp is None:
            return 0.0
        elapsed = max(ex._now() - getattr(ex, "_t_start", 0.0), 1e-9)
        return ex.stats[stage].busy_s / (sp.parallelism * elapsed)

    def now(self) -> float:
        return self.executor._now()

    def total_expired(self) -> int:
        """Deadline expirations across every stage so far — the expiry-rate
        shedding signal (``QuotaController`` folds its growth into quota)."""
        return sum(st.expired for st in self.executor.stats.values())


# --------------------------------------------------------------- Async

class AsyncExecutor:
    """Channels are bounded (``StageProcessor.max_queue``): a full downstream
    queue BLOCKS the upstream worker's put — real backpressure that
    propagates toward the source instead of letting queues grow without
    bound. Batching follows the MicroBatcher discipline: a worker collects
    up to ``batch_size`` events or ``max_wait_s`` (whichever first)."""

    def __init__(self, plan: Plan, batch_timeout_s: float = 0.002,
                 tracer=None, exact_latencies: bool = True):
        self.plan = plan
        self.batch_timeout_s = batch_timeout_s
        self.tracer = tracer
        self.exact_latencies = exact_latencies
        self.channels = {n: queue.Queue(maxsize=sp.max_queue)
                         for n, sp in plan.stages.items()}
        self.out_q: queue.Queue = queue.Queue()
        self.stats = defaultdict(StageStats)
        self.ctx = ExecContext(self)
        self._stop = threading.Event()
        self._pending = 0
        self._pending_lock = threading.Lock()
        # StageStats mutations come from every worker thread concurrently;
        # bare += on the dataclass fields loses increments under contention
        self._stats_lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._gen = 0          # run() generation; stale workers must not emit
        self._t_start = 0.0

    def _now(self):
        return time.monotonic()

    def _depth(self, stage):
        return self.channels[stage].qsize()

    def _worker(self, sp: StageProcessor, gen: int):
        ch = self.channels[sp.name]
        wait_s = (sp.max_wait_s if sp.max_wait_s is not None
                  else self.batch_timeout_s)
        mb = MicroBatcher(max_batch=sp.batch_size, max_wait_s=wait_s)
        while not self._stop.is_set() and self._gen == gen:
            # idle poll tick when empty; otherwise sleep only to the window
            timeout = 0.05 if not len(mb) else min(0.05, max(
                1e-4, mb.deadline() - time.monotonic()))
            batch = None
            try:
                ev = ch.get(timeout=timeout)
                if self.tracer is not None:
                    self.tracer.dequeued(ev, sp.name, time.monotonic())
                batch = mb.offer(ev, deadline_at=ev.deadline_at)
            except queue.Empty:
                pass
            if batch is None:
                batch = mb.poll()
            if batch is None:
                continue
            if self._gen != gen:
                return       # a newer run() started: don't touch its state
            # deadline gate at dispatch: an expired event short-circuits to
            # a timed-out terminal instead of occupying this stage (and
            # everything downstream of it)
            now = time.monotonic()
            expired = [e for e in batch if e.deadline_at is not None
                       and now > e.deadline_at]
            if expired:
                with self._stats_lock:
                    self.stats[sp.name].expired += len(expired)
                for e in expired:
                    e.meta["timed_out"] = True
                    e.meta["_terminal"] = True
                    if self.tracer is not None:
                        self.tracer.expired(e, sp.name, now)
                self._emit(sp.name, expired, gen)
                batch = [e for e in batch if not e.meta.get("timed_out")]
                if not batch:
                    continue
            t0 = time.monotonic()
            if self.tracer is not None:
                self.tracer.exec_begin(batch, sp.name, t0)
            try:
                out = sp.op(batch, self.ctx) or []
                failed = False
            except Exception as e:  # noqa: BLE001 — a poisoned op must
                # become an error-terminal response, never a dead worker
                log.exception("stage %r op raised; failing its batch "
                              "terminally", sp.name)
                failed = True
                out = list(batch)
                for ev in out:
                    ev.meta["error"] = f"{type(e).__name__}: {e}"
                    ev.meta["_terminal"] = True
            t1 = time.monotonic()
            if self.tracer is not None:
                if failed:
                    self.tracer.exec_end(batch, sp.name, t1,
                                         error=batch[0].meta.get("error"))
                else:
                    self.tracer.exec_end(batch, sp.name, t1)
            if self._gen != gen:
                return       # a newer run() started: don't touch its state
            n_degraded = sum(1 for e in batch
                             if e.meta.pop("_degraded", None))
            with self._stats_lock:
                st = self.stats[sp.name]
                st.events += len(batch)
                st.batches += 1
                st.busy_s += t1 - t0
                if failed:
                    st.errors += len(batch)
                st.degraded += n_degraded
            # ops may CREATE events (multi-tenant fanout clones) or DROP
            # them (filters): the completion count must track the actual
            # in-flight population or run() would return early / hang
            if len(out) != len(batch):
                with self._pending_lock:
                    self._pending += len(out) - len(batch)
            self._emit(sp.name, out, gen)
        # a worker only exits once run() saw _pending == 0, so its batcher
        # buffer is necessarily empty here — nothing to drain

    def _put_blocking(self, stage: str, ev: Event, gen: int):
        """Bounded-channel put: blocks while the downstream queue is full
        (backpressure), bailing out only on shutdown/generation change."""
        ch = self.channels[stage]
        st = self.stats[stage]
        # queue span opens BEFORE the put: a consumer may pop the event the
        # instant it lands, and the span deliberately includes any
        # backpressure stall spent blocked on a full channel
        if self.tracer is not None:
            self.tracer.enqueued(ev, stage, time.monotonic())
        blocked = False
        while self._gen == gen:
            try:
                ch.put(ev, block=blocked, timeout=0.05)
                with self._stats_lock:
                    st.max_depth = max(st.max_depth, ch.qsize())
                return
            except queue.Full:
                if not blocked:             # count each backpressure stall once
                    with self._stats_lock:
                        st.overflows += 1
                    blocked = True
                continue

    def _emit(self, stage: str, events, gen: int):
        succs = self.plan.succs[stage]
        for ev in events:
            targets = ([ev.route] if ev.route in succs else succs)
            ev.route = None
            if ev.meta.pop("_terminal", False):
                targets = []     # expired/errored: straight to the sink
            if not targets:
                ev.done_at = time.monotonic()
                if self.tracer is not None:
                    self.tracer.finish(ev, ev.done_at)
                self.out_q.put(ev)
                with self._pending_lock:
                    self._pending -= 1
                continue
            if len(targets) > 1:
                with self._pending_lock:
                    self._pending += len(targets) - 1
            for t in targets:
                self._put_blocking(t, ev, gen)

    def run(self, events: list[Event], source: Optional[str] = None) -> RunReport:
        source = source or self.plan.sources[0]
        # fresh lifecycle per run: bump the generation and clear the stop
        # flag/stats left by a previous run() so the executor is reusable
        # (no stale-stop hang, no double-counted stats, and any worker that
        # outlived the join below exits on the generation mismatch instead
        # of stealing this run's events)
        self._gen += 1
        gen = self._gen
        self._stop.clear()
        self.stats = defaultdict(StageStats)
        for sp in self.plan.stages.values():
            for _ in range(sp.parallelism):
                th = threading.Thread(target=self._worker, args=(sp, gen),
                                      daemon=True)
                th.start()
                self._threads.append(th)
        t_start = time.monotonic()
        self._t_start = t_start
        with self._pending_lock:
            self._pending = len(events)
        for ev in events:
            ev.born_at = time.monotonic()
            _stamp_deadline(ev, ev.born_at)
            if self.tracer is not None:
                self.tracer.begin(ev, ev.born_at)
            # bounded ingress: a full source channel pushes back on the
            # injector exactly like any other upstream
            self._put_blocking(source, ev, gen)
        done = []
        while True:
            try:
                ev = self.out_q.get(timeout=0.2)
                done.append(ev)
            except queue.Empty:
                with self._pending_lock:
                    if self._pending <= 0:
                        break
        self._stop.set()
        for th in self._threads:        # workers exit within their poll tick
            th.join(timeout=2.0)
        self._threads = [th for th in self._threads if th.is_alive()]
        hist = Histogram("latency_s", "end-to-end request latency")
        for ev in done:
            hist.observe(ev.done_at - ev.born_at)
        rep = RunReport(
            latencies=([ev.done_at - ev.born_at for ev in done]
                       if self.exact_latencies else []),
            stage_stats=dict(self.stats),
            makespan_s=time.monotonic() - t_start,
            results=done, offered=len(events), completed=len(done),
            latency_hist=hist,
            expired=sum(st.expired for st in self.stats.values()),
            errors=sum(st.errors for st in self.stats.values()))
        return rep


# ----------------------------------------------------------------- Sim

@dataclass(order=True)
class _SimItem:
    t: float
    seq: int
    kind: str = field(compare=False)
    data: Any = field(compare=False)


class SimExecutor:
    """Discrete-event simulation: each stage = FIFO + ``parallelism`` servers;
    service time = sim_base_s + sim_per_item_s * len(batch) (per batch).
    Deterministic: same inputs → same report.

    Batching follows the MicroBatcher discipline on the virtual clock: a
    stage with ``max_wait_s`` set holds a partial batch until the window
    closes (a scheduled "poll" event flushes it); the default window of 0
    dispatches greedily, matching the pre-closed-loop behaviour the offline
    calibration was tuned against.

    Channels are bounded by ``max_queue``. On overflow the event is offered
    to ``overflow_policy(stage, event, ctx)`` — e.g. the online shedder's
    ``on_overflow``, which prunes the candidate set (admitting a cheaper
    event) or drops the request outright (returns None). Without a policy
    the queue keeps growing and only ``overflows`` is counted: exactly the
    unbounded blow-up the closed loop exists to prevent."""

    def __init__(self, plan: Plan, service_time: Optional[Callable] = None,
                 overflow_policy: Optional[Callable] = None,
                 default_max_wait_s: float = 0.0,
                 tracer=None, exact_latencies: bool = True):
        self.plan = plan
        self.service_time = service_time or self._default_service_time
        self.overflow_policy = overflow_policy
        self.default_max_wait_s = default_max_wait_s
        self.tracer = tracer
        self.exact_latencies = exact_latencies
        self.stats = defaultdict(StageStats)
        self.ctx = ExecContext(self)
        # deques of (enqueue_time, event): stage dispatch pops from the head;
        # list.pop(0) would be O(n) per event and O(n²) in queue depth under
        # heavy traffic. The timestamp drives queue-wait accounting and the
        # micro-batch window.
        self._queues: dict[str, deque] = {n: deque() for n in plan.stages}
        self._free_at: dict[str, list[float]] = {
            n: [0.0] * sp.parallelism for n, sp in plan.stages.items()}
        self._poll_at: dict[str, float] = {}    # one outstanding poll/stage
        self._clock = 0.0
        self._t_start = 0.0
        self._done: list[Event] = []
        self._dropped = 0

    @staticmethod
    def _default_service_time(sp: StageProcessor, batch):
        return sp.sim_base_s + sp.sim_per_item_s * len(batch)

    def _now(self):
        return self._clock

    def _depth(self, stage):
        return len(self._queues[stage])

    def _wait_window(self, sp: StageProcessor) -> float:
        return (sp.max_wait_s if sp.max_wait_s is not None
                else self.default_max_wait_s)

    def run(self, arrivals: list[tuple[float, Event]],
            source: Optional[str] = None) -> RunReport:
        source = source or self.plan.sources[0]
        # fresh lifecycle per run (same contract as AsyncExecutor): no
        # leftover events, clock, server busy-times or counters from a
        # previous run() on this instance
        self.stats = defaultdict(StageStats)
        self._queues = {n: deque() for n in self.plan.stages}
        self._free_at = {n: [0.0] * sp.parallelism
                         for n, sp in self.plan.stages.items()}
        self._poll_at = {}
        self._clock = 0.0
        self._done = []
        self._dropped = 0
        self._t_start = arrivals[0][0] if arrivals else 0.0
        pq: list[_SimItem] = []
        seq = 0
        for t, ev in arrivals:
            ev.born_at = t
            _stamp_deadline(ev, t)
            if self.tracer is not None:
                self.tracer.begin(ev, t)
            heapq.heappush(pq, _SimItem(t, seq, "arrive", (source, ev)))
            seq += 1
        while pq:
            item = heapq.heappop(pq)
            if item.kind == "poll":             # micro-batch window closed
                stage = item.data
                if self._poll_at.get(stage) != item.t:
                    continue                    # superseded by a later poll
                self._poll_at.pop(stage)
                if not self._queues[stage]:
                    # batch already went out on the size trigger: a stale
                    # poll must not advance the clock (it would inflate the
                    # makespan to the unused window deadline)
                    continue
                self._clock = max(self._clock, item.t)
                seq = self._try_dispatch(stage, pq, seq)
                continue
            self._clock = max(self._clock, item.t)
            if item.kind == "arrive":
                stage, ev = item.data
                self._enqueue(stage, ev)
                seq = self._try_dispatch(stage, pq, seq)
            else:  # ("finish", stage, server_idx, batch, out_events)
                stage, si, batch, out = item.data
                st = self.stats[stage]
                st.events += len(batch)
                st.batches += 1
                self._emit(stage, out)
                seq = self._try_dispatch(stage, pq, seq)
                for other in self.plan.stages:
                    seq = self._try_dispatch(other, pq, seq)
        hist = Histogram("latency_s", "end-to-end request latency")
        for ev in self._done:
            hist.observe(ev.done_at - ev.born_at)
        rep = RunReport(
            latencies=([ev.done_at - ev.born_at for ev in self._done]
                       if self.exact_latencies else []),
            stage_stats=dict(self.stats),
            makespan_s=self._clock - self._t_start,
            results=self._done, offered=len(arrivals),
            completed=len(self._done), latency_hist=hist,
            dropped=self._dropped,
            expired=sum(st.expired for st in self.stats.values()),
            errors=sum(st.errors for st in self.stats.values()))
        return rep

    def _try_dispatch(self, stage: str, pq, seq: int) -> int:
        sp = self.plan.stages[stage]
        wait = self._wait_window(sp)
        q = self._queues[stage]
        frees = self._free_at[stage]
        while q:
            si = min(range(len(frees)), key=frees.__getitem__)
            if frees[si] > self._clock:
                break
            if len(q) < sp.batch_size and wait > 0.0:
                t_flush = q[0][0] + wait
                # the window never outwaits the tightest member's request
                # deadline (MicroBatcher discipline on the virtual clock)
                dls = [e.deadline_at for _, e in q if e.deadline_at is not None]
                if dls:
                    t_flush = min(t_flush, min(dls))
                if t_flush > self._clock:
                    # partial batch inside its window: hold it and schedule
                    # ONE flush poll at window close
                    if self._poll_at.get(stage, float("inf")) > t_flush:
                        self._poll_at[stage] = t_flush
                        heapq.heappush(pq, _SimItem(t_flush, seq, "poll",
                                                    stage))
                        seq += 1
                    break
            entries = [q.popleft() for _ in range(min(sp.batch_size, len(q)))]
            batch = [e for _, e in entries]
            st = self.stats[stage]
            st.queue_wait_s += sum(self._clock - t for t, _ in entries)
            if self.tracer is not None:
                for e in batch:
                    self.tracer.dequeued(e, stage, self._clock)
            # deadline gate at dispatch: expired events finish timed-out
            # NOW, consuming no server time here or downstream
            expired = [e for e in batch if e.deadline_at is not None
                       and self._clock > e.deadline_at]
            if expired:
                st.expired += len(expired)
                for e in expired:
                    e.meta["timed_out"] = True
                    e.meta.pop("cost_s", None)
                    e.done_at = self._clock
                    if self.tracer is not None:
                        self.tracer.expired(e, stage, self._clock)
                        self.tracer.finish(e, self._clock)
                self._done.extend(expired)
                batch = [e for e in batch if not e.meta.get("timed_out")]
                if not batch:
                    continue
            t0 = self._clock
            if self.tracer is not None:
                self.tracer.exec_begin(batch, stage, t0)
            try:
                out = sp.op(batch, self.ctx) or []
                op_error = None
            except Exception as e:  # noqa: BLE001 — error-terminal, not a
                # wedged simulated server
                log.exception("stage %r op raised; failing its batch "
                              "terminally", stage)
                st.errors += len(batch)
                op_error = f"{type(e).__name__}: {e}"
                out = list(batch)
                for ev in out:
                    ev.meta["error"] = op_error
                    ev.meta["_terminal"] = True
            for e in batch:
                if e.meta.pop("_degraded", None):
                    st.degraded += 1
            dt = self.service_time(sp, batch)
            if self.tracer is not None:
                if op_error is not None:
                    self.tracer.exec_end(batch, stage, t0 + dt,
                                         error=op_error)
                else:
                    self.tracer.exec_end(batch, stage, t0 + dt)
            for e in batch:                     # cost consumed by THIS stage
                e.meta.pop("cost_s", None)
            frees[si] = t0 + dt
            st.busy_s += dt
            heapq.heappush(pq, _SimItem(t0 + dt, seq, "finish",
                                        (stage, si, batch, out)))
            seq += 1
        return seq

    def _enqueue(self, stage: str, ev: Event):
        q = self._queues[stage]
        st = self.stats[stage]
        if len(q) >= self.plan.stages[stage].max_queue:
            st.overflows += 1
            if self.overflow_policy is not None:
                dropped_ev = ev
                ev = self.overflow_policy(stage, ev, self.ctx)
                if ev is None:                  # request shed at the channel
                    st.dropped += 1
                    self._dropped += 1
                    if self.tracer is not None:
                        self.tracer.dropped(dropped_ev, stage, self._clock)
                    return
        if self.tracer is not None:
            self.tracer.enqueued(ev, stage, self._clock)
        q.append((self._clock, ev))
        st.max_depth = max(st.max_depth, len(q))

    def _emit(self, stage: str, events):
        succs = self.plan.succs[stage]
        for ev in events:
            targets = ([ev.route] if ev.route in succs else succs)
            ev.route = None
            if ev.meta.pop("_terminal", False):
                targets = []     # expired/errored: straight to the sink
            if not targets:
                ev.done_at = self._clock
                if self.tracer is not None:
                    self.tracer.finish(ev, self._clock)
                self._done.append(ev)
                continue
            for t in targets:
                self._enqueue(t, ev)


# -------------------------------------------------------------- Legacy

class LegacyExecutor:
    """§2 baseline: data-parallel batches; batches run in parallel across
    the fleet, but WITHIN a batch every stage is a BARRIER — the batch moves
    at the pace of its slowest item (pipeline stall on long-tail candidates),
    with zero cross-stage overlap. Caches/routing shortcuts don't exist in
    the legacy design, so ops still execute but `route` shortcuts are
    ignored (every event pays the full stage list)."""

    def __init__(self, plan: Plan, service_time: Optional[Callable] = None,
                 batch_size: int = 8):
        self.plan = plan
        self.batch_size = batch_size
        self.service_time = service_time or SimExecutor._default_service_time
        self.ctx = ExecContext(self)
        self._clock = 0.0
        self.stats = defaultdict(StageStats)

    def _now(self):
        return self._clock

    def _depth(self, stage):
        return 0

    def run(self, arrivals: list[tuple[float, Event]], source=None) -> RunReport:
        done: list[Event] = []
        order = self.plan.order
        t_first = arrivals[0][0] if arrivals else 0.0
        t_last = t_first
        for start in range(0, len(arrivals), self.batch_size):
            chunk = arrivals[start:start + self.batch_size]
            evs = []
            for t, ev in chunk:
                ev.born_at = t
                evs.append(ev)
            # batch can't start until it has filled
            t = chunk[-1][0]
            self._clock = t
            for stage in order:
                sp = self.plan.stages[stage]
                out = sp.op(list(evs), self.ctx) or []
                # barrier: parallel workers amortize the bulk, but the batch
                # leaves only when the SLOWEST item does
                bulk = self.service_time(sp, evs) / max(1, sp.parallelism)
                tail = max((e.meta.get("cost_s", sp.sim_per_item_s)
                            for e in evs), default=0.0)
                dt = sp.sim_base_s + bulk + tail
                for e in evs:                   # cost consumed by THIS stage
                    e.meta.pop("cost_s", None)
                t += dt
                st = self.stats[stage]
                st.events += len(evs)
                st.batches += 1
                st.busy_s += dt * max(1, sp.parallelism)   # workers held idle
                evs = out
                for e in evs:
                    e.route = None                          # no shortcuts
            for ev in evs:
                ev.done_at = t
                done.append(ev)
            t_last = max(t_last, t)
        hist = Histogram("latency_s", "end-to-end request latency")
        for e in done:
            hist.observe(e.done_at - e.born_at)
        return RunReport(latencies=[e.done_at - e.born_at for e in done],
                         stage_stats=dict(self.stats),
                         makespan_s=t_last - t_first, results=done,
                         offered=len(arrivals), completed=len(done),
                         latency_hist=hist)
