"""Modelled online-inference services — the substrate for reproducing the
paper's experiments (Tables 2-5, Figs 7-9) deterministically on CPU.

A service = SEDP of stages with calibrated service-time models + the REAL
HHS components (ParameterCube-like latency mix via TwoTierLFUCache +
QueryCache) running functionally inside the ops, so cache hits actually
change routing/time, and the IRM knobs (Table 6) actually move the numbers.

Scale note: we simulate O(10³-10⁴) requests and report latency directly;
"instances" are derived from stage utilization as
   instances_j = ceil(rate · busy_time_j / (duration · util_target))
— the paper's own capacity accounting (instance = fixed-size VM).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional

import numpy as np

from repro.core.cube_cache import TwoTierLFUCache, capacity_from_ratio
from repro.core.executors import LegacyExecutor, RunReport, SimExecutor
from repro.core.query_cache import QueryCache
from repro.core.sedp import SEDP, Event
from repro.sparse.hashing import signature_np


# Table 6 knobs, with paper defaults ("noOpt" column of Table 4)
@dataclass(frozen=True)
class Knobs:
    user_batch: int = 30
    item_extractor_batch: int = 4
    item_processor_batch: int = 6
    cube_batch: int = 10
    dnn_batch: int = 15
    cube_cache_ratio: float = 1.0        # percent
    query_cache_window: float = 120.0    # seconds
    arenas: int = 500
    max_active_extent: int = 6
    huge_page: bool = False              # False=Default, True=Always

    BOUNDS = (
        ("user_batch", 10, 45), ("item_extractor_batch", 2, 45),
        ("item_processor_batch", 2, 45), ("cube_batch", 1, 20),
        ("dnn_batch", 10, 45), ("cube_cache_ratio", 0.1, 5.0),
        ("query_cache_window", 60.0, 600.0), ("arenas", 350, 700),
        ("max_active_extent", 5, 40), ("huge_page", 0, 1),
    )

    def to_vector(self) -> np.ndarray:
        return np.array([getattr(self, n) if n != "huge_page"
                         else float(self.huge_page)
                         for n, _, _ in self.BOUNDS], float)

    @classmethod
    def from_vector(cls, x) -> "Knobs":
        kv = {}
        for (name, lo, hi), v in zip(cls.BOUNDS, x):
            v = min(max(float(v), lo), hi)
            if name == "huge_page":
                kv[name] = v >= 0.5
            elif name in ("cube_cache_ratio", "query_cache_window"):
                kv[name] = v
            else:
                kv[name] = int(round(v))
        return cls(**kv)


@dataclass(frozen=True)
class ServiceSpec:
    """Per-service workload profile (Table 1 spread)."""
    name: str
    n_features: int = 300            # feature groups per request
    item_vocab: int = 200_000
    user_vocab: int = 1_000_000
    cands_per_req: int = 24          # items scored per request (funnel out)
    dnn_ms: float = 1.1              # per-item DNN fwd cost at batch=1
    cube_us_local: float = 3.0
    cube_us_remote: float = 110.0
    user_ms: float = 0.35
    item_ms: float = 0.5
    zipf_a: float = 1.25
    user_zipf_a: float = 1.07
    dnn_parallel: int = 16
    rate_qps: float = 1500.0
    multi_tenant: tuple = ()         # e.g. ("ctr","fr","cmt") for Service E
    shared_feature_frac: float = 0.8


# zipf_a ≈ 1.3 puts ~85-90% of accesses on the top 1% of keys — the measured
# production concentration of Fig. 5a
SERVICES = {
    "A": ServiceSpec("A", n_features=379, dnn_ms=3.5, item_ms=2.2, user_ms=1.4, zipf_a=1.3),
    "B": ServiceSpec("B", n_features=430, dnn_ms=3.8, item_ms=2.4, user_ms=1.5, zipf_a=1.29),
    "C": ServiceSpec("C", n_features=270, dnn_ms=6.5, item_ms=3.0, user_ms=1.8, zipf_a=1.22,
                     cands_per_req=32, rate_qps=850.0),
    "D": ServiceSpec("D", n_features=106, dnn_ms=2.2, item_ms=1.4, user_ms=0.9, zipf_a=1.32),
    "E": ServiceSpec("E", n_features=968, dnn_ms=3.2, item_ms=2.0, user_ms=1.3, zipf_a=1.29,
                     multi_tenant=("ctr", "fr", "cmt")),
}


def alloc_factor(k: Knobs) -> float:
    """jemalloc-knob model: more arenas → less contention; huge pages →
    fewer TLB misses; extents sweet spot ~25 (matches Table 4's Opt).
    Multiplies CPU-stage service times."""
    arena = 1.0 + 0.18 / (1.0 + math.exp((k.arenas - 450) / 60.0))
    huge = 1.0 if k.huge_page else 1.06
    extent = 1.0 + 0.04 * abs(k.max_active_extent - 25) / 35.0
    return arena * huge * extent


def cube_hit_model(cache_ratio_pct: float, zipf_a: float) -> float:
    """Zipf CDF mass of the top r% keys — ~84% at 1% for a≈1.08 (Fig 5a)."""
    r = max(cache_ratio_pct, 1e-3) / 100.0
    s = zipf_a
    # mass of top-r fraction of a zipf(s) over large vocab ≈ r^(1-1/s) … use
    # calibrated smooth form anchored at (1%, 84%)
    return float(min(0.97, 0.84 * (r / 0.01) ** (0.12 / s)))


def query_hit_model(window_s: float) -> float:
    """Fig 5b: ≥60% of scores invariant at 2 min; cacheable-and-recurrent
    fraction gives ~19.26% hit at 120 s (paper §8.4)."""
    return float(0.1926 * (1 - math.exp(-window_s / 110.0))
                 / (1 - math.exp(-120.0 / 110.0)))


@dataclass
class ServiceRuntime:
    spec: ServiceSpec
    knobs: Knobs
    query_cache: QueryCache = None
    cube_cache: TwoTierLFUCache = None
    tenants: tuple = ()

    def __post_init__(self):
        self.query_cache = QueryCache(window_s=self.knobs.query_cache_window)
        # key space ≈ items × hot feature groups per request
        n_hot = max(4, self.spec.n_features // 12)
        mem, disk = capacity_from_ratio(self.spec.item_vocab * n_hot,
                                        self.knobs.cube_cache_ratio)
        self.cube_cache = TwoTierLFUCache(mem, disk)
        self.tenants = self.spec.multi_tenant or ("main",)


def build_service(spec: ServiceSpec, knobs: Knobs,
                  shedder=None) -> tuple[SEDP, ServiceRuntime]:
    rt = ServiceRuntime(spec, knobs)
    g = SEDP()
    af = alloc_factor(knobs)
    ms = 1e-3
    us = 1e-6
    mt = len(rt.tenants)

    def op_ingress(batch, ctx):
        for ev in batch:
            ev.meta["cost_s"] = 0.02 * ms
        return batch

    def op_query_cache(batch, ctx):
        now = ctx.now()
        scores = rt.query_cache.get_many(
            [ev.payload["user"] for ev in batch],
            [ev.payload["item"] for ev in batch], now)
        for ev, score in zip(batch, scores):
            ev.meta["cost_s"] = 0.03 * ms
            if score is not None:
                ev.payload["score"] = score
                ev.payload["from_cache"] = True
                ev.route = "respond"        # hit: skip the whole pipeline
            else:
                ev.route = "user_proc"      # miss: full path (no fan-out)
        return batch

    def op_user(batch, ctx):
        for ev in batch:
            ev.meta["cost_s"] = spec.user_ms * ms * af \
                / (1 + 0.12 * (knobs.user_batch - 1) ** 0.7)
        return batch

    def op_item_extract(batch, ctx):
        for ev in batch:
            ev.meta["cost_s"] = 0.4 * spec.item_ms * ms * af \
                / (1 + 0.12 * (knobs.item_extractor_batch - 1) ** 0.7)
        return batch

    def op_item_proc(batch, ctx):
        for ev in batch:
            ev.meta["cost_s"] = 0.6 * spec.item_ms * ms * af \
                / (1 + 0.12 * (knobs.item_processor_batch - 1) ** 0.7)
        return batch

    def op_cube(batch, ctx):
        # batched HHS access: the whole event batch's feature keys go through
        # the cube cache in one deduplicated multi-get/multi-put pass
        amort = 1 + 0.08 * (knobs.cube_batch - 1) ** 0.6
        feats_per_ev = [ev.payload["features"] for ev in batch]
        uniq: list = []
        index: dict = {}
        for feats in feats_per_ev:
            for k in feats:
                if k not in index:
                    index[k] = len(uniq)
                    uniq.append(k)
        got = rt.cube_cache.get_many(uniq)
        miss = [k for k, v in zip(uniq, got) if v is None]
        rt.cube_cache.put_many(miss, [1] * len(miss))
        # per-event cost keeps the old per-occurrence accounting: the first
        # occurrence of a missed key pays the remote fetch, every later
        # occurrence in the batch is a local hit (it was just installed)
        hit = [v is not None for v in got]
        seen: set = set()
        for ev, feats in zip(batch, feats_per_ev):
            t = 0.0
            for k in feats:
                if hit[index[k]] or k in seen:
                    t += spec.cube_us_local * us
                else:
                    t += spec.cube_us_remote * us
                    seen.add(k)
            ev.meta["cost_s"] = t * af / amort
        return batch

    def make_op_dnn(tenant):
        def op_dnn(batch, ctx):
            now = ctx.now()
            amort = 1 + 0.10 * (knobs.dnn_batch - 1) ** 0.75
            for ev in batch:
                n_c = max(1, len(ev.payload.get("candidates", [1] * 1)))
                ev.meta["cost_s"] = spec.dnn_ms * ms * n_c / spec.cands_per_req / amort
                ev.payload["score"] = float(
                    (hash((ev.payload["user"], ev.payload["item"], tenant))
                     % 1000) / 1000.0)
            rt.query_cache.put_many(
                [ev.payload["user"] for ev in batch],
                [ev.payload["item"] for ev in batch],
                [ev.payload["score"] for ev in batch], now)
            return batch
        return op_dnn

    def op_respond(batch, ctx):
        for ev in batch:
            ev.meta["cost_s"] = 0.02 * ms
        return batch

    sb = knobs
    g.add_stage("ingress", op_ingress, batch_size=8, parallelism=4,
                sim_base_s=0.01 * ms)
    g.add_stage("query_cache", op_query_cache, batch_size=16, parallelism=4,
                sim_base_s=0.01 * ms)
    g.add_stage("user_proc", op_user, batch_size=sb.user_batch, parallelism=6,
                sim_base_s=0.05 * ms)
    g.add_stage("item_extract", op_item_extract,
                batch_size=sb.item_extractor_batch, parallelism=6,
                sim_base_s=0.05 * ms)
    g.add_stage("item_proc", op_item_proc, batch_size=sb.item_processor_batch,
                parallelism=6, sim_base_s=0.05 * ms)
    g.add_stage("feature_join", _op_feature_join(spec), batch_size=16,
                parallelism=4, sim_base_s=0.02 * ms)
    g.add_stage("cube_access", op_cube, batch_size=sb.cube_batch,
                parallelism=8, sim_base_s=0.05 * ms)
    if shedder is not None:
        shedder.downstream = f"dnn_{rt.tenants[0]}"
        g.add_stage("shed", shedder.op, batch_size=16, parallelism=2,
                    sim_base_s=0.01 * ms)
    for t in rt.tenants:
        g.add_stage(f"dnn_{t}", make_op_dnn(t), batch_size=sb.dnn_batch,
                    parallelism=spec.dnn_parallel, sim_base_s=0.08 * ms)
    g.add_stage("respond", op_respond, batch_size=32, parallelism=2,
                sim_base_s=0.01 * ms)

    g.add_edge("ingress", "query_cache")
    g.add_edge("query_cache", "user_proc")
    g.add_edge("query_cache", "respond")       # cache-hit shortcut
    g.add_edge("user_proc", "item_extract")
    g.add_edge("item_extract", "item_proc")
    g.add_edge("item_proc", "feature_join")
    g.add_edge("feature_join", "cube_access")
    nxt = "shed" if shedder is not None else None
    if shedder is not None:
        g.add_edge("cube_access", "shed")
    prev = nxt or "cube_access"
    for t in rt.tenants:
        g.add_edge(prev, f"dnn_{t}")
        g.add_edge(f"dnn_{t}", "respond")
    return g, rt


def _op_feature_join(spec: ServiceSpec):
    n_hot = max(4, spec.n_features // 12)      # non-zero groups per request

    def op(batch, ctx):
        for ev in batch:
            rng = np.random.default_rng(ev.payload["item"] * 2654435761 % (2**32))
            groups = rng.integers(0, spec.n_features, n_hot)
            ids = np.full(n_hot, ev.payload["item"])
            ev.payload["features"] = [int(s) for s in
                                      signature_np(groups, ids)]
            ev.meta["cost_s"] = 0.02e-3
        return batch
    return op


# ------------------------------------------------------------- traffic

def diurnal_rate(t_hours: float, base: float, peak_mult: float = 3.0) -> float:
    """Fig 2a/7c-style daily curve: trough ~4am, evening peak ~21h."""
    phase = math.cos((t_hours - 21.0) / 24.0 * 2 * math.pi)
    return base * (1.0 + (peak_mult - 1.0) * 0.5 * (1 + phase))


def make_traffic(spec: ServiceSpec, n_events: int, rate_qps: float,
                 seed: int = 0, start_hour: float = 12.0,
                 feedback_frac: float = 0.02) -> list[tuple[float, Event]]:
    rng = np.random.default_rng(seed)
    users = ((rng.zipf(spec.user_zipf_a, n_events) - 1) % spec.user_vocab)
    items = ((rng.zipf(spec.zipf_a, n_events) - 1) % spec.item_vocab)
    t = 0.0
    arrivals = []
    # heavy-tailed candidate counts — the "long-tail candidates" whose
    # access+compute latency stalls the legacy pipeline (§2)
    n_cands = np.clip(rng.lognormal(np.log(spec.cands_per_req), 0.45,
                                    n_events), 4, 6 * spec.cands_per_req
                      ).astype(int)
    for i in range(n_events):
        hours = start_hour + t / 3600.0
        r = diurnal_rate(hours, rate_qps)
        t += float(rng.exponential(1.0 / r))
        cands = [(int(items[i]) + j, float(rng.random()))
                 for j in range(int(n_cands[i]))]
        ev = Event(payload={"user": int(users[i]), "item": int(items[i]),
                            "candidates": cands})
        arrivals.append((t, ev))
    return arrivals


def service_time_model(sp, batch):
    """SimExecutor hook: base + the per-event costs the ops recorded."""
    return sp.sim_base_s + sum(ev.meta.get("cost_s", sp.sim_per_item_s)
                               for ev in batch)


# --------------------------------------------------------- capacity model

UTIL_TARGET = 0.55          # paper-era prod fleets run ~50-60% utilization
INSTANCE_SCALE = 55.0      # sim-qps → production-qps scale (Table 1 loads)


def derive_instances(report: RunReport, rate_qps: float) -> int:
    """Little's law: a fleet must hold λ·W in-flight requests; each 4-core
    instance sustains a fixed concurrency at target utilization. Synchronous
    pipelines pay their stall time in concurrency — exactly why the paper's
    legacy fleet was 2-3× larger at equal traffic."""
    concurrent = rate_qps * INSTANCE_SCALE * report.avg_latency
    slots_per_instance = 4.0 / UTIL_TARGET
    return int(math.ceil(concurrent / slots_per_instance))


def run_service(spec: ServiceSpec, knobs: Knobs, n_events: int = 4000,
                rate_qps: float = None, seed: int = 0, legacy: bool = False,
                shedder=None) -> tuple[RunReport, ServiceRuntime, int]:
    rate_qps = rate_qps if rate_qps is not None else spec.rate_qps
    graph, rt = build_service(spec, knobs, shedder=shedder)
    plan = graph.compile()
    arrivals = make_traffic(spec, n_events, rate_qps, seed)
    if legacy:
        ex = LegacyExecutor(plan, service_time=service_time_model, batch_size=32)
    else:
        ex = SimExecutor(plan, service_time=service_time_model)
    rep = ex.run(arrivals)
    inst = derive_instances(rep, rate_qps)
    return rep, rt, inst
