"""The Distributed Sparse Parameter Cube (paper §5.1 + §7).

A distributed KV store for the sparse sub-network:
  * key    — compact feature signature (universal hash; repro.sparse.hashing)
  * value  — model weights (+ feedback statistics) for that sparse feature
  * keys live purely in memory (to hide hash-probe latency); values are
    grouped into ~1 GB blocks placed in MEMORY or DISK (SSD) — a tunable
    latency/hardware trade-off (the "cube cache ratio" knob moves it)
  * sharded over servers by key hash; every block replicated ``replication``
    ways → fault tolerant (server failure reroutes to replicas)
  * generation-stamped (model hot-loading swaps whole generations)

Lookups are **batch-native** (DESIGN.md §3): a request's signatures are
deduplicated once (`np.unique`), grouped by shard with a single argsort,
probed against each server's *sorted signature index* with one
`np.searchsorted`, and each touched block is gathered with a single
fancy-index. Latency is accounted per *block touch* + per *server RPC*,
not per row — batching is exactly what amortizes those costs.

Streaming updates (DESIGN.md §6): the cube is MVCC-versioned. The publish
unit is the WHOLE delta batch (``apply_batch``; ``apply_delta`` is the
single-group convenience): every touched group's upserts land in fresh
in-memory *overlay blocks* (plus tombstone index entries for deletes)
staged under one writer-lock hold, then published by an atomic swap of
the ONE ``(version, sigs, srv, blk, off)`` snapshot tuple — one version
bump covering ALL groups, so a pinned reader provably sees every group of
a multi-group batch at the same version (the DESIGN.md §7.3 cross-group
torn-read window is closed at the cube layer). Blocks are append-only, so
a reader that grabbed the snapshot at entry — or pinned a version with
``pin()`` — keeps reading exactly the state it started on while new
versions publish underneath it. ``compact()`` folds accumulated overlays
back into consolidated base blocks off the hot path — in ONE writer-lock
hold, or incrementally across many short holds with a
``max_rows_per_pass`` budget (DESIGN.md §6.6) so a TB-scale fold never
pauses the writer path for the whole rebuild; superseded blocks are freed
only once no pinned reader can still see them.
"""
from __future__ import annotations

import contextlib
import os
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.faults.crash import crash_point
from repro.sparse.hashing import signature_np

# serving-tier codes for lookup_ex (DESIGN.md §8.3): the graceful-
# degradation ladder. 2 (stale cache) is assigned by CubeFetchStage —
# the cube itself cannot see the cache tier.
TIER_PRIMARY = 0
TIER_REPLICA = 1
TIER_STALE_CACHE = 2
TIER_DEFAULT = 3


def _merge_last_wins(sigs: np.ndarray, *arrays: np.ndarray):
    """Sort parallel index arrays by signature, resolving duplicate
    signatures to the LAST insertion — THE dedup rule for every cube index
    (primary snapshot, per-server indexes, within-delta dedup). One
    implementation: which copy of a duplicate wins is correctness-critical
    and must never diverge between the folds."""
    order = np.argsort(sigs, kind="stable")
    sigs = sigs[order]
    arrays = tuple(a[order] for a in arrays)
    if sigs.size > 1:
        last = np.ones(sigs.size, bool)
        last[:-1] = sigs[1:] != sigs[:-1]
        sigs = sigs[last]
        arrays = tuple(a[last] for a in arrays)
    return (sigs,) + arrays


@dataclass
class CubeMetrics:
    lookups: int = 0
    mem_block_hits: int = 0      # batched path: distinct mem blocks touched
    disk_block_hits: int = 0     # batched path: distinct disk blocks touched
    failovers: int = 0
    replica_rows: int = 0        # rows served from a replica snapshot
    unavailable_rows: int = 0    # rows no live replica could serve
    simulated_latency_s: float = 0.0
    # streaming-update subsystem
    deltas_applied: int = 0
    rows_upserted: int = 0
    rows_deleted: int = 0
    compactions: int = 0
    compact_passes: int = 0          # writer-lock holds spent compacting
    compact_max_hold_s: float = 0.0  # longest single compaction lock hold
    blocks_freed: int = 0


class _Block:
    """One value block: contiguous (n, dim) array, in RAM or memmapped."""

    def __init__(self, values: np.ndarray, on_disk: bool, tmpdir: str, bid: str):
        self.on_disk = on_disk
        self.path: Optional[str] = None
        if on_disk:
            self.path = os.path.join(tmpdir, f"block_{bid}.npy")
            mm = np.lib.format.open_memmap(self.path, mode="w+",
                                           dtype=values.dtype, shape=values.shape)
            mm[:] = values
            mm.flush()
            self.values = mm
        else:
            self.values = values
        # plain-ndarray view for gathers: same mapped pages for disk blocks,
        # but skips np.memmap's per-__getitem__ subclass machinery
        self.view = np.asarray(self.values)


class _FreedBlock:
    """Sentinel left where a compacted-away block used to be: any access is
    a routing bug (an index referenced a block past its retire version)."""

    on_disk = False

    @property
    def view(self):
        raise RuntimeError("touched a freed (compacted) cube block — "
                           "a reader escaped its version pin")

    values = view


class CubeServer:
    """One shard holder. The key index is three parallel arrays sorted by
    signature — ``sigs`` (uint64), ``blk``/``off`` (block id, row offset) —
    probed with np.searchsorted; no per-key Python dict. The index is held
    as ONE tuple swapped atomically (readers run concurrently with delta
    ingestion from the update thread), with a fold lock serializing merges."""

    def __init__(self, server_id: int, tmpdir: str):
        self.server_id = server_id
        self.tmpdir = tmpdir
        self.blocks: list = []       # _Block | _FreedBlock, append-only slots
        self.alive = True
        # fault-injection dials (repro.faults): per-RPC latency added while
        # a spike is active; multiplier on this server's disk-block latency
        self.extra_latency_s = 0.0
        self.disk_latency_mult = 1.0
        self._index = (np.empty(0, np.uint64), np.empty(0, np.int32),
                       np.empty(0, np.int32))
        self._pending: list[tuple[np.ndarray, int]] = []   # ingested, unsorted
        self._idx_lock = threading.Lock()
        # versioned index snapshots: (version, (sigs, blk, off)) appended by
        # ``publish_version`` at every cube version bump that touched this
        # server. A pinned reader failing over probes the newest snapshot
        # ≤ its pinned version — the DESIGN.md §6.2 exact-failover contract
        # (replica reads are bit-identical to the primary's at that
        # version, never the replica's freshest row). Append-only between
        # prunes; readers capture the list reference lock-free.
        self._snaps: list[tuple[int, tuple]] = [(0, self._index)]
        # slot ids whose blocks were reclaimed: reused by the next ingest
        # so a perpetual delta stream + compaction cadence doesn't grow the
        # block list (and its _FreedBlock sentinels) without bound. Safe:
        # a slot only reaches this list once no pinned snapshot can route
        # to it, and writers (the only add_block/reclaim callers) serialize
        # on the cube's writer lock.
        self.free_slots: list[int] = []
        self._slot_seq = 0          # unique suffix for memmap filenames

    def add_block(self, sigs: np.ndarray, values: np.ndarray, on_disk: bool,
                  index: bool = True) -> int:
        # filename carries the server id — servers share a tmpdir; the
        # sequence number keeps reused slots from colliding on disk
        self._slot_seq += 1
        block = _Block(values, on_disk, self.tmpdir,
                       f"s{self.server_id}_{self._slot_seq}")
        if self.free_slots:
            bid = self.free_slots.pop()
            self.blocks[bid] = block
        else:
            bid = len(self.blocks)
            self.blocks.append(block)
        if index:
            with self._idx_lock:
                self._pending.append((np.asarray(sigs, dtype=np.uint64), bid))
        return bid

    def install_index(self, sigs: np.ndarray, blk: np.ndarray,
                      off: np.ndarray):
        """Replace the whole index (compactor): entries must be dup-free;
        sorts by signature and swaps atomically, dropping any pending."""
        order = np.argsort(sigs, kind="stable")
        with self._idx_lock:
            self._index = (sigs[order], blk[order].astype(np.int32),
                           off[order].astype(np.int32))
            self._pending.clear()

    def _ensure_index(self):
        """Merge pending ingests into the sorted index (lazy: load_table may
        add many blocks back-to-back; sort once at first probe). Returns one
        consistent (sigs, blk, off) tuple."""
        if not self._pending:
            return self._index
        with self._idx_lock:
            if not self._pending:
                return self._index
            isigs, iblk, ioff = self._index
            sigs = np.concatenate([isigs] + [s for s, _ in self._pending])
            blk = np.concatenate([iblk] + [
                np.full(s.size, b, np.int32) for s, b in self._pending])
            off = np.concatenate([ioff] + [
                np.arange(s.size, dtype=np.int32) for s, _ in self._pending])
            # last insertion wins on duplicate signatures, so overlay rows
            # shadow the base rows they supersede.
            # swap BEFORE clearing: a concurrent reader's lock-free fast
            # path is "pending empty → use _index" — clearing first would
            # let it read the PRE-fold index for already-cleared ingests
            self._index = _merge_last_wins(sigs, blk, off)
            self._pending.clear()
            return self._index

    # ------------------------------------------------ versioned snapshots
    def publish_version(self, version: int):
        """Record the server's index as of cube ``version``: folds pending
        ingests and appends a (version, index) snapshot. Called by every
        cube writer at its version bump; appending nothing when the index
        is unchanged keeps the snapshot list proportional to the versions
        that actually touched this server."""
        idx = self._ensure_index()
        with self._idx_lock:
            last_ver, last_idx = self._snaps[-1]
            if last_idx is idx:
                return                         # nothing new landed here
            if last_ver == version:            # same-version re-publish
                self._snaps[-1] = (version, idx)
            else:
                self._snaps.append((version, idx))

    def _index_at(self, version: int) -> tuple:
        """Newest snapshot ≤ ``version`` (lock-free: capture the list
        reference once; publishers only append)."""
        snaps = self._snaps
        lo, hi = 0, len(snaps)
        while lo < hi:
            mid = (lo + hi) // 2
            if snaps[mid][0] <= version:
                lo = mid + 1
            else:
                hi = mid
        if lo == 0:          # pinned before this server's first publish
            return (np.empty(0, np.uint64), np.empty(0, np.int32),
                    np.empty(0, np.int32))
        return snaps[lo - 1][1]

    def prune_snapshots(self, min_version: int):
        """Drop snapshots no pinned reader can reach: keep the newest one
        ≤ ``min_version`` (still the answer for a reader pinned there) and
        everything newer. Writer-driven, like block reclaim."""
        with self._idx_lock:
            snaps = self._snaps
            keep_from = 0
            for i, (ver, _) in enumerate(snaps):
                if ver <= min_version:
                    keep_from = i
                else:
                    break
            if keep_from:
                self._snaps = snaps[keep_from:]

    # ------------------------------------------------------------ probing
    def get(self, sig: int) -> Optional[tuple[np.ndarray, bool]]:
        """Scalar probe (debugging)."""
        sigs, blk_a, off_a = self._ensure_index()
        s = np.uint64(sig)
        pos = int(np.searchsorted(sigs, s))
        if pos >= sigs.size or sigs[pos] != s:
            return None
        blk = self.blocks[int(blk_a[pos])]
        return np.asarray(blk.values[int(off_a[pos])]), blk.on_disk

    def get_batch(self, sigs: np.ndarray, version: Optional[int] = None
                  ) -> tuple[Optional[np.ndarray], np.ndarray, int, int]:
        """Vectorized probe. Returns (rows, found, mem_touches, disk_touches):
        ``found`` is a boolean mask over ``sigs``; ``rows`` holds the values
        of the found signatures in order (one fancy-index gather per touched
        block); touch counts are DISTINCT blocks read, for latency accounting.

        ``version``: resolve against the index snapshot published at the
        newest cube version ≤ it (exact failover for pinned readers);
        None probes the latest index.
        """
        isigs, iblk, ioff = (self._ensure_index() if version is None
                             else self._index_at(version))
        m = sigs.size
        if isigs.size == 0:
            return None, np.zeros(m, bool), 0, 0
        pos = np.searchsorted(isigs, sigs)
        pos = np.minimum(pos, isigs.size - 1)
        found = isigs[pos] == sigs
        if not found.any():
            return None, found, 0, 0
        fpos = pos[found]
        fblk, foff = iblk[fpos], ioff[fpos]
        # group rows by block with one argsort, then slice-gather per block
        order = np.argsort(fblk, kind="stable")
        sblk, soff = fblk[order], foff[order]
        starts = np.concatenate(([0], np.flatnonzero(sblk[1:] != sblk[:-1]) + 1,
                                 [sblk.size]))
        # one probe batch is always single-group (lookup hashes one group),
        # so every touched block shares the first one's row shape — blocks[0]
        # may belong to a DIFFERENT group with another dim/dtype
        first = self.blocks[int(sblk[0])].view
        gathered = np.empty((fpos.size, first.shape[1]), first.dtype)
        mem_t = disk_t = 0
        for lo, hi in zip(starts[:-1], starts[1:]):
            block = self.blocks[int(sblk[lo])]
            gathered[lo:hi] = block.view[soff[lo:hi]]  # one gather per block
            if block.on_disk:
                disk_t += 1
            else:
                mem_t += 1
        rows = np.empty_like(gathered)
        rows[order] = gathered
        return rows, found, mem_t, disk_t


class PinnedVersion:
    """Handle returned by ``ParameterCube.pin()``: every lookup made with it
    sees exactly the cube state published as ``version``, regardless of
    deltas/compactions landing concurrently."""

    __slots__ = ("snap",)

    def __init__(self, snap):
        self.snap = snap

    @property
    def version(self) -> int:
        return self.snap[0]


class ParameterCube:
    """Build from feature-group embedding tables; serve batched lookups;
    ingest streaming delta updates with version-consistent reads."""

    def __init__(self, n_servers: int = 4, replication: int = 2,
                 block_rows: int = 65536, mem_block_fraction: float = 0.5,
                 mem_latency_s: float = 2e-6, disk_latency_s: float = 50e-6,
                 net_latency_s: float = 300e-6, generation: int = 0,
                 tmpdir: Optional[str] = None):
        assert replication <= n_servers
        self.n_servers = n_servers
        self.replication = replication
        self.block_rows = block_rows
        self.mem_block_fraction = mem_block_fraction
        self.lat = {"mem": mem_latency_s, "disk": disk_latency_s,
                    "net": net_latency_s}
        self.generation = generation
        self.tmpdir = tmpdir or tempfile.mkdtemp(prefix="cube_")
        self.servers = [CubeServer(i, self.tmpdir) for i in range(n_servers)]
        self.metrics = CubeMetrics()
        self._dim: Optional[int] = None
        self._dtype = np.float32
        self._shapes: dict[int, tuple[int, np.dtype]] = {}  # per-group row shape
        # cube-wide PRIMARY index: every r=0 placement, sorted by signature.
        # Keys are all-in-memory per the paper, so the router can resolve a
        # whole batch (sig → primary server, block, offset) with ONE
        # searchsorted; replicas are only probed for misses/dead primaries.
        # MVCC: the index is published as ONE (version, sigs, srv, blk, off)
        # tuple swapped atomically — a reader must never see sigs from one
        # version with srv/blk/off from another (that routes to the wrong
        # block — silent corruption), and a version-pinned reader must keep
        # resolving against exactly the tuple it pinned. srv == -1 marks a
        # TOMBSTONE (the signature was deleted by a delta).
        self._snap = (0, np.empty(0, np.uint64), np.empty(0, np.int32),
                      np.empty(0, np.int32), np.empty(0, np.int32))
        self._p_pending: list[tuple[np.ndarray, int, int]] = []
        # RLock: writers (load_table / apply_delta / compact) fold the
        # pending list while already holding the lock
        self._p_lock = threading.RLock()
        # version pinning: version → count of readers inside that version.
        # Compaction retires blocks at a version; a retired block is freed
        # only once min(pinned) reaches its retire version.
        self._pins: dict[int, int] = {}
        self._pin_lock = threading.Lock()
        self._garbage: list[tuple[int, int, int]] = []  # (retire_ver, sid, bid)
        # chunked compaction releases the writer lock BETWEEN passes, so a
        # second compactor could interleave with a half-drained one — this
        # outer lock serializes whole compactions (writers still only wait
        # per-pass: apply_delta/apply_batch never take it)
        self._compact_lock = threading.Lock()
        self.overlay_blocks = 0       # blocks added by deltas since compact()
        # optional circuit-breaker registry (repro.faults.HealthRegistry):
        # when attached, routing consults it before probing a server — an
        # open breaker skips the server without paying the failed probe
        self.health = None

    def attach_health(self, registry):
        """Attach a ``repro.faults.HealthRegistry`` (one breaker per
        server) that routing consults before touching a server."""
        assert len(registry) == self.n_servers
        self.health = registry
        return registry

    # ------------------------------------------------------------- build
    @property
    def version(self) -> int:
        return self._snap[0]

    def row_shape(self, group: int) -> Optional[tuple]:
        """(dim, dtype) of a group's rows, or None if the group is unknown
        — the update manager's pre-apply validation hook."""
        return self._shapes.get(group)

    def _place_shard(self, sid: int, s_sigs: np.ndarray, s_rows: np.ndarray,
                     fresh_index: bool):
        """THE single block-placement implementation (load_table and the
        compactor must never diverge — a floor-vs-ceil mismatch here once
        sent tail blocks to disk at mem_block_fraction=1.0): split one
        primary shard's rows into block_rows-sized blocks, place the first
        mem_block_fraction of them in memory and the rest on disk, and add
        every block to the shard's ``replication`` servers. Returns
        (primary, per_server): ``primary`` = [(blk_sigs, bid)] for the r=0
        copies; ``per_server`` = [(server_id, blk_sigs, bid)] for EVERY
        copy when ``fresh_index`` (the compactor builds indexes from
        scratch; otherwise copies register in each server's pending
        index)."""
        n_blocks = max(1, -(-len(s_sigs) // self.block_rows))   # ceil
        primary, per_server = [], []
        for start in range(0, len(s_sigs), self.block_rows):
            blk_s = s_sigs[start:start + self.block_rows]
            blk_v = s_rows[start:start + self.block_rows]
            on_disk = (start // self.block_rows) >= max(
                1, int(n_blocks * self.mem_block_fraction))
            for r in range(self.replication):
                hsid = (sid + r) % self.n_servers
                bid = self.servers[hsid].add_block(
                    blk_s, blk_v, on_disk, index=not fresh_index)
                if fresh_index:
                    per_server.append((hsid, blk_s, bid))
                if r == 0:
                    primary.append((blk_s, bid))
        return primary, per_server

    def load_table(self, group: int, table: np.ndarray,
                   raw_ids: Optional[np.ndarray] = None):
        """Ingest rows of one feature group. Values are the rows; keys are
        signature(group, row_id)."""
        ids = raw_ids if raw_ids is not None else np.arange(table.shape[0])
        sigs = signature_np(group, ids)
        order = np.argsort(sigs % np.uint64(self.n_servers), kind="stable")
        sigs, rows = sigs[order], table[order]
        shard = (sigs % np.uint64(self.n_servers)).astype(np.int64)
        self._dim, self._dtype = table.shape[1], table.dtype
        self._shapes[group] = (table.shape[1], table.dtype)
        # the WHOLE placement runs under the writer lock: a compact()
        # concurrent with an unlocked load would enumerate the half-placed
        # blocks into its retire list and wipe their replica-index
        # registrations — the folded primary index would then route to
        # blocks the next reclaim frees. (Also: a concurrent index fold
        # iterates and clears _p_pending — an unlocked append could be
        # wiped before ever being folded.)
        with self._p_lock:
            for sid in range(self.n_servers):
                sel = shard == sid
                primary, _ = self._place_shard(sid, sigs[sel], rows[sel],
                                               fresh_index=False)
                for blk_s, bid in primary:
                    self._p_pending.append((blk_s, sid, bid))

    # ------------------------------------------------------------ lookup
    def _ensure_primary_index(self):
        """Fold pending placements into the index and return a consistent
        (version, sigs, srv, blk, off) snapshot. Thread-safe: concurrent
        stage workers serialize on the build lock; the double-check inside
        keeps the common no-pending call lock-free-ish and cheap. Folding
        bumps the version: newly ingested rows become visible only at the
        bumped snapshot, never half-way."""
        if not self._p_pending:
            return self._snap
        with self._p_lock:
            if not self._p_pending:
                return self._snap
            ver, psigs, psrv, pblk, poff = self._snap
            sigs = np.concatenate([psigs] + [s for s, _, _ in self._p_pending])
            srv = np.concatenate([psrv] + [
                np.full(s.size, sid, np.int32) for s, sid, _ in self._p_pending])
            blk = np.concatenate([pblk] + [
                np.full(s.size, b, np.int32) for s, _, b in self._p_pending])
            off = np.concatenate([poff] + [
                np.arange(s.size, dtype=np.int32) for s, _, _ in self._p_pending])
            # publish BEFORE clearing pending: a concurrent reader's
            # lock-free fast path is "pending empty → use _snap"; clearing
            # first opens a window where it reads the PRE-fold snapshot
            # server snapshots FIRST: a reader that pins ver+1 the instant
            # _snap swaps may immediately fail over — the replica index at
            # ver+1 must already exist (at ≤ ver it is unreachable: no
            # reader can pin ver+1 before the swap below)
            for srv_ in self.servers:
                srv_.publish_version(ver + 1)
            self._snap = (ver + 1,) + _merge_last_wins(sigs, srv, blk, off)
            self._p_pending.clear()
            return self._snap

    # ------------------------------------------------------------ pinning
    def _pin_current(self):
        """Atomically (snapshot read + pin registration under ONE _pin_lock
        hold) pin the published version. Reading _snap outside the lock and
        pinning after would race the compactor's garbage collection: it
        could free the snapshot's blocks in the unpinned window."""
        self._ensure_primary_index()          # fold pending placements first
        with self._pin_lock:
            snap = self._snap                 # publishers swap the whole tuple
            self._pins[snap[0]] = self._pins.get(snap[0], 0) + 1
        return snap

    def _pin_release(self, ver: int):
        # NOTE: no garbage collection here — this runs on READER threads
        # (every lookup unpins), and freeing blocks means os.remove plus
        # dirty-memmap flushes: filesystem latency injected straight into
        # the serving path. Writers reclaim instead (apply_delta/compact
        # entry), so deferred garbage is freed within one stream tick.
        with self._pin_lock:
            n = self._pins.get(ver, 0) - 1
            if n <= 0:
                self._pins.pop(ver, None)
            else:
                self._pins[ver] = n

    @contextlib.contextmanager
    def pin(self):
        """Pin the currently published version for a sequence of lookups:
        ``with cube.pin() as v: cube.lookup(g, ids, version=v)`` — every
        lookup inside the block reads the same snapshot even while deltas
        publish and the compactor folds overlays concurrently."""
        snap = self._pin_current()
        try:
            yield PinnedVersion(snap)
        finally:
            self._pin_release(snap[0])

    def lookup(self, group: int, raw_ids: np.ndarray,
               version: Optional[PinnedVersion] = None) -> np.ndarray:
        """Batched gather: (...,) raw ids → (N, dim) rows (inputs are
        flattened; callers reshape). Deduplicates repeated ids before any
        server is touched and re-scatters afterwards, so a dup-heavy batch
        pays each distinct row once. The whole batch is routed with one
        probe of the cube-wide primary index; only misses and signatures on
        dead primaries take the per-server replica path.

        ``version``: a ``pin()`` handle — the lookup resolves against that
        snapshot. Without one, the call pins the current version for its own
        duration (an in-flight lookup never sees a half-published delta or
        loses a block to the compactor mid-gather)."""
        raw = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
        sigs = signature_np(group, raw)
        n_req = sigs.size
        if n_req == 0:
            dim, dtype = self._shapes.get(group, (self._dim or 0, self._dtype))
            return np.empty((0, dim), dtype)
        if version is not None:
            return self._lookup_pinned(group, sigs, version.snap)
        snap = self._pin_current()
        try:
            return self._lookup_pinned(group, sigs, snap)
        finally:
            self._pin_release(snap[0])

    def lookup_ex(self, group: int, raw_ids: np.ndarray,
                  version: Optional[PinnedVersion] = None
                  ) -> tuple[np.ndarray, np.ndarray]:
        """Degradation-aware batched gather (DESIGN.md §8.3): like
        ``lookup`` but NEVER raises on a fault — returns ``(rows, tiers)``
        where ``tiers[i]`` says how row i was served: ``TIER_PRIMARY``
        (healthy primary, HBM-adjacent, or an authoritative tombstone
        zero), ``TIER_REPLICA`` (versioned failover — bit-identical to the
        primary at the pinned version), or ``TIER_DEFAULT`` (no live
        replica could serve it; the row is zeros and the caller decides
        whether a stale cache entry beats it)."""
        raw = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
        sigs = signature_np(group, raw)
        if sigs.size == 0:
            dim, dtype = self._shapes.get(group, (self._dim or 0, self._dtype))
            return np.empty((0, dim), dtype), np.empty(0, np.int8)
        if version is not None:
            return self._lookup_pinned_ex(group, sigs, version.snap,
                                          strict=False)
        snap = self._pin_current()
        try:
            return self._lookup_pinned_ex(group, sigs, snap, strict=False)
        finally:
            self._pin_release(snap[0])

    def _alive_mask(self) -> tuple[np.ndarray, float]:
        """Effective server availability for one routing decision, and the
        latency the decision itself cost. Without a health registry this is
        the raw ``alive`` flags for free (the historical behaviour). With
        one, each CLOSED/HALF-OPEN breaker admits a probe — a dead server's
        failed probe costs one net RPC and is recorded (opening the breaker
        after enough failures) — while an OPEN breaker reroutes instantly
        and for free."""
        if self.health is None:
            return np.fromiter((s.alive for s in self.servers), bool,
                               self.n_servers), 0.0
        now = self.health.clock()
        out = np.empty(self.n_servers, bool)
        cost = 0.0
        for i, s in enumerate(self.servers):
            h = self.health.servers[i]
            if not h.allow_request(now):
                out[i] = False               # open breaker: free reroute
            elif s.alive:
                h.record_success(now)
                out[i] = True
            else:
                h.record_failure(now)        # paid probe, found it dead
                out[i] = False
                cost += self.lat["net"]
        return out, cost

    def _lookup_pinned(self, group: int, sigs: np.ndarray, snap) -> np.ndarray:
        return self._lookup_pinned_ex(group, sigs, snap, strict=True)[0]

    def _lookup_pinned_ex(self, group: int, sigs: np.ndarray, snap,
                          strict: bool = True
                          ) -> tuple[np.ndarray, np.ndarray]:
        """Resolve ``sigs`` against one pinned snapshot. Returns
        (rows, tiers) aligned with ``sigs``; tiers are the ladder codes
        (TIER_PRIMARY / TIER_REPLICA / TIER_DEFAULT). ``strict`` keeps the
        historical ``lookup`` contract — KeyError on a deleted or
        unavailable signature; non-strict (``lookup_ex``) zero-fills and
        stamps TIER_DEFAULT instead, so a fetch stage can degrade rather
        than error."""
        _, psigs, psrv, pblk, poff = snap
        n_req = sigs.size
        uniq, inverse = np.unique(sigs, return_inverse=True)
        nu = uniq.size
        dim, dtype = self._shapes.get(group, (self._dim or 0, self._dtype))
        rows = np.empty((nu, dim), dtype)
        tiers = np.zeros(nu, np.int8)
        primary = (uniq % np.uint64(self.n_servers)).astype(np.int64)
        t = 0.0

        # ---- fast path: one searchsorted over the primary index
        alive, probe_cost = self._alive_mask()
        t += probe_cost
        pos = np.searchsorted(psigs, uniq)
        np.minimum(pos, max(0, psigs.size - 1), out=pos)
        found = (psigs[pos] == uniq) if psigs.size else \
            np.zeros(nu, bool)
        # tombstones: deleted signatures are KNOWN-missing at this version —
        # they must not fall through to the replica path (replica indexes
        # still hold the pre-delete row)
        tomb = found & (psrv[pos] == -1) if psigs.size else found
        dead_primary = ~alive[primary]
        if dead_primary.any():
            # failover accounted at batch granularity: every distinct
            # signature rerouted off its dead primary
            self.metrics.failovers += int(dead_primary.sum())
        served = found & ~tomb & ~dead_primary
        sidx = np.flatnonzero(served)
        if sidx.size:
            spos = pos[sidx]
            gsrv, gblk, goff = psrv[spos], pblk[spos], poff[spos]
            # group by (server, block) with one argsort → one fancy-index
            # gather per touched block, one RPC per touched server
            comp = (gsrv.astype(np.int64) << 32) | gblk
            order = np.argsort(comp, kind="stable")
            scomp, soff = comp[order], goff[order]
            starts = np.concatenate(
                ([0], np.flatnonzero(scomp[1:] != scomp[:-1]) + 1,
                 [scomp.size]))
            gathered = np.empty((sidx.size, dim), dtype)
            touched_srv = set()
            mem_t = disk_t = 0
            disk_lat = 0.0
            for lo, hi in zip(starts[:-1], starts[1:]):
                c = int(scomp[lo])
                srv_id, blk_id = c >> 32, c & 0xFFFFFFFF
                block = self.servers[srv_id].blocks[blk_id]
                gathered[lo:hi] = block.view[soff[lo:hi]]
                touched_srv.add(srv_id)
                if block.on_disk:
                    disk_t += 1
                    # slow-disk fault: the owning server's memmap reads
                    # pay a multiplied latency for the fault's duration
                    disk_lat += (self.lat["disk"]
                                 * self.servers[srv_id].disk_latency_mult)
                else:
                    mem_t += 1
            rows[sidx[order]] = gathered
            self.metrics.mem_block_hits += mem_t
            self.metrics.disk_block_hits += disk_t
            t += (len(touched_srv) * self.lat["net"]
                  + mem_t * self.lat["mem"] + disk_lat
                  + sum(self.servers[s].extra_latency_s
                        for s in touched_srv))

        # ---- slow path: replica probing for misses / dead primaries.
        # Replica indexes ARE versioned (the DESIGN.md §6.2 relaxation is
        # closed): the probe resolves against the snapshot published at the
        # pinned version, so a failover read is bit-identical to what the
        # primary would have served at that version — never the replica's
        # fresher row, never a torn or freed one.
        pinned_ver = snap[0]
        pending = np.flatnonzero(~served & ~tomb)
        for r in range(1, self.replication):
            if pending.size == 0:
                break
            srv_of = (primary[pending] + r) % self.n_servers
            order = np.argsort(srv_of, kind="stable")   # group by shard, once
            sp, so = pending[order], srv_of[order]
            bounds = np.searchsorted(so, np.arange(self.n_servers + 1))
            missed: list[np.ndarray] = []
            for sid in range(self.n_servers):
                lo, hi = bounds[sid], bounds[sid + 1]
                if lo == hi:
                    continue
                idxs = sp[lo:hi]
                srv = self.servers[sid]
                if not alive[sid]:
                    missed.append(idxs)
                    continue
                got, fmask, mem_t, disk_t = srv.get_batch(
                    uniq[idxs], version=pinned_ver)
                t += self.lat["net"] + srv.extra_latency_s  # one RPC/server
                if got is not None:
                    rows[idxs[fmask]] = got
                    tiers[idxs[fmask]] = TIER_REPLICA
                    self.metrics.replica_rows += int(fmask.sum())
                self.metrics.mem_block_hits += mem_t
                self.metrics.disk_block_hits += disk_t
                t += (mem_t * self.lat["mem"]
                      + disk_t * self.lat["disk"] * srv.disk_latency_mult)
                if not fmask.all():
                    missed.append(idxs[~fmask])
            pending = (np.concatenate(missed) if missed
                       else np.empty(0, np.int64))
        if pending.size:
            if strict:
                raise KeyError(
                    f"signature {uniq[pending[0]]} unavailable "
                    f"(group {group})")
            rows[pending] = 0
            tiers[pending] = TIER_DEFAULT
            self.metrics.unavailable_rows += int(pending.size)
        if tomb.any():
            if strict:
                raise KeyError(
                    f"signature {uniq[np.flatnonzero(tomb)[0]]} deleted "
                    f"(group {group})")
            # a tombstone is an authoritative answer at this version — the
            # zero row IS the value, not a degradation
            rows[tomb] = 0
        self.metrics.lookups += n_req
        self.metrics.simulated_latency_s += t
        return rows[inverse], tiers[inverse]

    def contains(self, group: int, raw_ids: np.ndarray,
                 version: Optional[PinnedVersion] = None) -> np.ndarray:
        """Vectorized membership against the primary index (tombstones count
        as absent). Used by update tooling to split upserts from inserts."""
        raw = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
        sigs = signature_np(group, raw)
        snap = version.snap if version is not None \
            else self._ensure_primary_index()
        _, psigs, psrv, _, _ = snap
        if psigs.size == 0:
            return np.zeros(sigs.size, bool)
        pos = np.searchsorted(psigs, sigs)
        np.minimum(pos, psigs.size - 1, out=pos)
        return (psigs[pos] == sigs) & (psrv[pos] != -1)

    # ---------------------------------------------------- streaming deltas
    def apply_delta(self, group: int, raw_ids: Optional[np.ndarray] = None,
                    rows: Optional[np.ndarray] = None,
                    delete_ids: Optional[np.ndarray] = None) -> int:
        """Single-group convenience over :meth:`apply_batch`: one group's
        upserts/deletes published with one atomic version bump."""
        return self.apply_batch([(group, raw_ids, rows, delete_ids)])

    def apply_batch(self, parts) -> int:
        """Apply one delta batch — ``parts`` is an iterable of
        ``(group, raw_ids, rows, delete_ids)`` — and publish ALL of it with
        ONE atomic version bump. This is THE publish unit (DESIGN.md §6.6):
        every group's upserts land in fresh in-memory overlay blocks
        (replicated like base blocks) and deletes become tombstone entries,
        all staged under the writer lock, then the primary snapshot swaps
        once. A reader pinning any version therefore sees every group of
        the batch at that same version — never group g new and group g+1
        old (the former §7.3 cross-group torn-read window). Within one
        batch, a group's deletes apply AFTER its upserts. Returns the newly
        published version. In-flight/pinned readers keep the snapshot they
        started on — nothing is mutated in place."""
        parts = list(parts)
        with self._p_lock:
            self.reclaim()          # writer-side: free drained-pin garbage
            snap = self._ensure_primary_index()
            ver, psigs, psrv, pblk, poff = snap
            # ---- validate EVERY part before placing ANY block: a shape
            # error surfacing after an earlier group placed its overlays
            # would leak replica-probeable blocks for rows that never
            # publish — a torn state the batch API exists to rule out
            norm: list[tuple] = []
            shapes = dict(self._shapes)
            for group, raw_ids, rows, delete_ids in parts:
                ids = vals = dels = None
                if raw_ids is not None and np.asarray(raw_ids).size:
                    ids = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
                    vals = np.asarray(rows)
                    if vals.ndim != 2 or vals.shape[0] != ids.size:
                        raise ValueError(
                            f"rows shape {vals.shape} does not match "
                            f"{ids.size} upsert ids")
                    dim, dtype = shapes.get(
                        group, (vals.shape[1], vals.dtype))
                    if vals.shape[1] != dim:
                        raise ValueError(
                            f"group {group} rows are dim {dim}, delta has "
                            f"{vals.shape[1]}")
                    shapes[group] = (dim, dtype)
                if delete_ids is not None and np.asarray(delete_ids).size:
                    dels = np.atleast_1d(np.asarray(delete_ids)).reshape(-1)
                norm.append((group, ids, vals, dels))
            # ---- stage: overlay blocks + index entries for every group
            add_sigs: list[np.ndarray] = []
            add_srv: list[np.ndarray] = []
            add_blk: list[np.ndarray] = []
            add_off: list[np.ndarray] = []
            n_up = n_del = 0
            for group, ids, vals, dels in norm:
                if ids is not None:
                    dim, dtype = self._shapes.get(
                        group, (vals.shape[1], vals.dtype))
                    self._shapes[group] = (dim, dtype)
                    if self._dim is None:
                        self._dim, self._dtype = dim, dtype
                    vals = vals.astype(dtype, copy=False)
                    sigs = signature_np(group, ids)
                    shard = (sigs % np.uint64(self.n_servers)) \
                        .astype(np.int64)
                    order = np.argsort(shard, kind="stable")
                    sigs, vals, shard = sigs[order], vals[order], shard[order]
                    bounds = np.searchsorted(shard,
                                             np.arange(self.n_servers + 1))
                    for sid in range(self.n_servers):
                        lo, hi = bounds[sid], bounds[sid + 1]
                        if lo == hi:
                            continue
                        s_sigs, s_rows = sigs[lo:hi], vals[lo:hi]
                        # overlay blocks are memory-resident: fresh rows
                        # are hot
                        for r in range(self.replication):
                            bid = self.servers[(sid + r) % self.n_servers] \
                                .add_block(s_sigs, s_rows, on_disk=False)
                            if r == 0:
                                add_sigs.append(s_sigs)
                                add_srv.append(
                                    np.full(s_sigs.size, sid, np.int32))
                                add_blk.append(
                                    np.full(s_sigs.size, bid, np.int32))
                                add_off.append(
                                    np.arange(s_sigs.size, dtype=np.int32))
                        self.overlay_blocks += self.replication
                    n_up += ids.size
                if dels is not None:
                    d_sigs = signature_np(group, dels)
                    add_sigs.append(d_sigs)
                    add_srv.append(np.full(d_sigs.size, -1, np.int32))
                    add_blk.append(np.full(d_sigs.size, -1, np.int32))
                    add_off.append(np.full(d_sigs.size, -1, np.int32))
                    n_del += dels.size
            if not add_sigs:                       # empty batch: still a bump
                self._snap = (ver + 1, psigs, psrv, pblk, poff)
                self.metrics.deltas_applied += 1
                return ver + 1
            dsigs = np.concatenate(add_sigs)
            dsrv = np.concatenate(add_srv)
            dblk = np.concatenate(add_blk)
            doff = np.concatenate(add_off)
            # last-wins dedup WITHIN the batch (per group, upserts precede
            # tombstones; cross-group signatures never collide by key)
            dsigs, dsrv, dblk, doff = _merge_last_wins(
                dsigs, dsrv, dblk, doff)
            # STREAMING merge into the sorted base: a delta touches a tiny
            # slice of a huge index, so never re-sort the whole thing —
            # copy the base (readers share the old arrays; MVCC forbids
            # in-place), overwrite matched positions, np.insert the rest:
            # O(base memcpy + delta log delta) vs O(base log base)
            if psigs.size:
                pos = np.searchsorted(psigs, dsigs)
                posc = np.minimum(pos, psigs.size - 1)
                match = psigs[posc] == dsigs
                nsigs, nsrv = psigs.copy(), psrv.copy()
                nblk, noff = pblk.copy(), poff.copy()
                if match.any():
                    mp = posc[match]
                    nsrv[mp], nblk[mp], noff[mp] = \
                        dsrv[match], dblk[match], doff[match]
                if not match.all():
                    ins, m = pos[~match], ~match
                    nsigs = np.insert(nsigs, ins, dsigs[m])
                    nsrv = np.insert(nsrv, ins, dsrv[m])
                    nblk = np.insert(nblk, ins, dblk[m])
                    noff = np.insert(noff, ins, doff[m])
            else:
                nsigs, nsrv, nblk, noff = dsigs, dsrv, dblk, doff
            # replica indexes at ver+1 must exist before any reader can pin
            # ver+1 (same ordering rule as _ensure_primary_index)
            for srv_ in self.servers:
                srv_.publish_version(ver + 1)
            self._snap = (ver + 1, nsigs, nsrv, nblk, noff)
            self.metrics.deltas_applied += 1
            self.metrics.rows_upserted += n_up
            self.metrics.rows_deleted += n_del
            return ver + 1

    # ---------------------------------------------------------- compaction
    def compact(self, max_rows_per_pass: Optional[int] = None) -> int:
        """Fold overlay blocks (and tombstones) back into consolidated base
        blocks, off the hot path. ``max_rows_per_pass=None`` is the
        monolithic fold: one writer-lock hold rebuilds every block — fine
        at bench scale, a stop-the-world pause risk at TB scale. With a
        budget, the fold is INCREMENTAL (DESIGN.md §6.6): each pass drains
        whole source blocks up to ~``max_rows_per_pass`` primary rows
        under one short lock hold and publishes its own version bump;
        between passes, pinned readers keep serving and delta batches
        land freely. Either way, every pre-compaction block is retired and
        its storage freed once no reader pins an older version; per-pass
        lock holds are recorded in ``metrics.compact_max_hold_s`` (the
        bench gate for the pause bound). Returns the final published
        version."""
        # serialize whole compactions: chunked mode releases the writer
        # lock between passes, and a second compactor interleaving with a
        # half-drained one would retire each other's fresh blocks
        with self._compact_lock:
            if max_rows_per_pass is None:
                return self._compact_monolithic()
            return self._compact_chunked(max(1, int(max_rows_per_pass)))

    def _hold_finished(self, t0: float):
        """Record one compaction writer-lock hold (call BEFORE release)."""
        hold = time.monotonic() - t0
        self.metrics.compact_passes += 1
        self.metrics.compact_max_hold_s = max(
            self.metrics.compact_max_hold_s, hold)

    def _compact_monolithic(self) -> int:
        """One-pass fold: gather every live row from the current snapshot,
        redistribute into fresh block_rows-sized blocks with the same
        placement policy as load_table, install fresh per-server indexes,
        and publish with a version bump."""
        with self._p_lock:
            t_hold = time.monotonic()
            snap = self._ensure_primary_index()
            ver, psigs, psrv, pblk, poff = snap
            new_ver = ver + 1
            live = psrv >= 0
            lsigs, lsrv = psigs[live], psrv[live]
            lblk, loff = pblk[live], poff[live]
            # group live entries by source block, gather once per block, and
            # bucket rows into (dim, dtype) families — block shapes differ
            # across feature groups and a consolidated block is single-family
            families: dict[tuple, list] = {}
            comp = (lsrv.astype(np.int64) << 32) | lblk
            order = np.argsort(comp, kind="stable")
            scomp, soff, ssigs = comp[order], loff[order], lsigs[order]
            # zero live entries (fresh cube / everything tombstoned):
            # starts collapses to a single bound so the gather loop runs
            # zero times and the cube compacts to empty instead of
            # indexing into an empty array
            starts = np.concatenate(
                ([0], np.flatnonzero(scomp[1:] != scomp[:-1]) + 1,
                 [scomp.size])) if scomp.size else np.array([0])
            for lo, hi in zip(starts[:-1], starts[1:]):
                c = int(scomp[lo])
                block = self.servers[c >> 32].blocks[c & 0xFFFFFFFF]
                fam = (block.view.shape[1], block.view.dtype)
                families.setdefault(fam, []).append(
                    (ssigs[lo:hi], block.view[soff[lo:hi]]))
            # retire EVERY current block slot (old base + overlays) — except
            # slots a previous compact already queued while a pin held them:
            # re-adding those would double-free and double-count blocks_freed
            with self._pin_lock:
                already = {(s, b) for _, s, b in self._garbage}
            retired = [(sid, bid)
                       for sid, srv_ in enumerate(self.servers)
                       for bid, b in enumerate(srv_.blocks)
                       if isinstance(b, _Block) and (sid, bid) not in already]
            new_entries: list[tuple[np.ndarray, int, int]] = []
            per_server: dict[int, list] = {s: [] for s in range(self.n_servers)}
            for (dim, dtype), parts in families.items():
                fsigs = np.concatenate([p[0] for p in parts])
                frows = np.concatenate([p[1] for p in parts])
                shard = (fsigs % np.uint64(self.n_servers)).astype(np.int64)
                order = np.argsort(shard, kind="stable")
                fsigs, frows, shard = fsigs[order], frows[order], shard[order]
                bounds = np.searchsorted(shard,
                                         np.arange(self.n_servers + 1))
                for sid in range(self.n_servers):
                    lo, hi = bounds[sid], bounds[sid + 1]
                    if lo == hi:
                        continue
                    primary, per_srv = self._place_shard(
                        sid, fsigs[lo:hi], frows[lo:hi], fresh_index=True)
                    for blk_s, bid in primary:
                        new_entries.append((blk_s, sid, bid))
                    for hsid, blk_s, bid in per_srv:
                        per_server[hsid].append(
                            (blk_s, np.full(blk_s.size, bid, np.int32),
                             np.arange(blk_s.size, dtype=np.int32)))
            # install fresh per-server indexes referencing ONLY new blocks —
            # no stale entry can ever route a replica probe to a freed block
            for sid, parts in per_server.items():
                if parts:
                    self.servers[sid].install_index(
                        np.concatenate([p[0] for p in parts]),
                        np.concatenate([p[1] for p in parts]),
                        np.concatenate([p[2] for p in parts]))
                else:
                    self.servers[sid].install_index(
                        np.empty(0, np.uint64), np.empty(0, np.int32),
                        np.empty(0, np.int32))
            # snapshot the fresh replica indexes at new_ver before the
            # primary swap makes new_ver pinnable; older snapshots stay
            # for readers still pinned behind the compaction
            for srv_ in self.servers:
                srv_.publish_version(new_ver)
            if new_entries:
                nsigs = np.concatenate([s for s, _, _ in new_entries])
                nsrv = np.concatenate([
                    np.full(s.size, sid, np.int32)
                    for s, sid, _ in new_entries])
                nblk = np.concatenate([
                    np.full(s.size, b, np.int32) for s, _, b in new_entries])
                noff = np.concatenate([
                    np.arange(s.size, dtype=np.int32)
                    for s, _, _ in new_entries])
                self._snap = (new_ver,) + _merge_last_wins(
                    nsigs, nsrv, nblk, noff)
            else:
                self._snap = (new_ver, np.empty(0, np.uint64),
                              np.empty(0, np.int32), np.empty(0, np.int32),
                              np.empty(0, np.int32))
            with self._pin_lock:
                self._garbage.extend(
                    (new_ver, sid, bid) for sid, bid in retired)
            self.overlay_blocks = 0
            self.metrics.compactions += 1
            # reclaim under the writer lock (RLock): slot reuse must not
            # race a concurrent writer's add_block
            self.reclaim()
            self._hold_finished(t_hold)
        return new_ver

    def _compact_chunked(self, max_rows_per_pass: int) -> int:
        """Incremental fold. Plan: snapshot the set of pre-compaction
        blocks once; each pass re-homes the live primary entries of a few
        source blocks (≈``max_rows_per_pass`` rows, always ≥1 whole block)
        into fresh consolidated blocks and re-points the primary snapshot
        at them — the rows are bit-identical, so a reader pinned at ANY
        intermediate version reads the same values whichever copy its
        index routes to. The final pass rebuilds each server's index
        without entries routing to pre-compaction blocks, drops tombstones
        whose pre-delete rows no index can reach any more, retires every
        pre-compaction block, and publishes. Overlay blocks created by
        deltas landing BETWEEN passes are left for the next compaction —
        they are not in the plan's retire set."""
        with self._p_lock:
            self._ensure_primary_index()
            with self._pin_lock:
                already = {(s, b) for _, s, b in self._garbage}
            # every live block right now — old base + overlays, replica
            # copies included — is the retire set; (sid, bid) identifies
            # a copy, and sid<<32|bid matches the primary's routing code
            initial = {(sid, bid)
                       for sid, srv_ in enumerate(self.servers)
                       for bid, b in enumerate(srv_.blocks)
                       if isinstance(b, _Block) and (sid, bid) not in already}
            init_codes = np.sort(np.fromiter(
                ((sid << 32) | bid for sid, bid in initial),
                np.int64, len(initial))) if initial else np.empty(0, np.int64)
            overlay_start = self.overlay_blocks

        while True:
            # recovery-drill abort boundary (DESIGN.md §9): a crash here —
            # after some passes re-homed rows and published intermediate
            # versions — loses only IN-MEMORY state; compaction never
            # touches the durable snapshot/delta artifacts, so a restarted
            # node replays the same deltas onto uncompacted blocks and
            # serves the identical rows
            crash_point("cube.compact_pass")
            with self._p_lock:
                t_hold = time.monotonic()
                ver, psigs, psrv, pblk, poff = self._ensure_primary_index()
                live = psrv >= 0
                comp = np.where(
                    live, (psrv.astype(np.int64) << 32) | pblk, -1)
                in_src = np.isin(comp, init_codes) if init_codes.size \
                    else np.zeros(comp.shape, bool)
                if not in_src.any():
                    final_ver = self._compact_finish(
                        ver, psigs, psrv, pblk, poff, initial, overlay_start)
                    self._hold_finished(t_hold)
                    return final_ver
                # group the remaining source entries by source block and
                # drain whole blocks until the pass budget is spent
                spos = np.flatnonzero(in_src)
                order = np.argsort(comp[spos], kind="stable")
                spos = spos[order]
                scomp = comp[spos]
                starts = np.concatenate(
                    ([0], np.flatnonzero(scomp[1:] != scomp[:-1]) + 1,
                     [scomp.size]))
                take = 1
                while (take < starts.size - 1
                       and starts[take] < max_rows_per_pass):
                    take += 1
                chosen = spos[:starts[take]]
                cstarts = starts[:take + 1]
                # gather the chosen entries once per source block, bucketed
                # into (dim, dtype) families — consolidated blocks are
                # single-family (block shapes differ across feature groups)
                families: dict[tuple, list] = {}
                ccomp, csigs, coff = comp[chosen], psigs[chosen], poff[chosen]
                for lo, hi in zip(cstarts[:-1], cstarts[1:]):
                    c = int(ccomp[lo])
                    block = self.servers[c >> 32].blocks[c & 0xFFFFFFFF]
                    fam = (block.view.shape[1], block.view.dtype)
                    families.setdefault(fam, []).append(
                        (csigs[lo:hi], block.view[coff[lo:hi]]))
                # re-place per family; fresh_index=False registers every
                # copy in its server's pending index, so replica failover
                # at this pass's version resolves the moved rows
                moved: list[tuple[np.ndarray, int, int]] = []
                for (dim, dtype), parts in families.items():
                    fsigs = np.concatenate([p[0] for p in parts])
                    frows = np.concatenate([p[1] for p in parts])
                    shard = (fsigs % np.uint64(self.n_servers)) \
                        .astype(np.int64)
                    order = np.argsort(shard, kind="stable")
                    fsigs, frows, shard = (fsigs[order], frows[order],
                                           shard[order])
                    bounds = np.searchsorted(
                        shard, np.arange(self.n_servers + 1))
                    for sid in range(self.n_servers):
                        lo, hi = bounds[sid], bounds[sid + 1]
                        if lo == hi:
                            continue
                        primary, _ = self._place_shard(
                            sid, fsigs[lo:hi], frows[lo:hi],
                            fresh_index=False)
                        moved.extend((blk_s, sid, bid)
                                     for blk_s, bid in primary)
                # re-point the drained entries: their sigs are unchanged,
                # so this is a pure overwrite of copied routing arrays
                msigs = np.concatenate([s for s, _, _ in moved])
                msrv = np.concatenate([np.full(s.size, sid, np.int32)
                                       for s, sid, _ in moved])
                mblk = np.concatenate([np.full(s.size, b, np.int32)
                                       for s, _, b in moved])
                moff = np.concatenate([np.arange(s.size, dtype=np.int32)
                                       for s, _, _ in moved])
                morder = np.argsort(msigs, kind="stable")
                msigs = msigs[morder]
                pos = np.searchsorted(psigs, msigs)
                nsrv, nblk, noff = psrv.copy(), pblk.copy(), poff.copy()
                nsrv[pos] = msrv[morder]
                nblk[pos] = mblk[morder]
                noff[pos] = moff[morder]
                for srv_ in self.servers:
                    srv_.publish_version(ver + 1)
                self._snap = (ver + 1, psigs, nsrv, nblk, noff)
                self._hold_finished(t_hold)
            # lock released: readers pin, deltas land, then the next pass

    def _compact_finish(self, ver, psigs, psrv, pblk, poff,
                        initial: set, overlay_start: int) -> int:
        """Last chunked pass (caller holds the writer lock, no source
        entries left): rebuild per-server indexes without retired routes,
        clear unreachable tombstones, retire the plan's blocks, publish."""
        new_ver = ver + 1
        retired_by_sid: dict[int, set] = {}
        for sid, bid in initial:
            retired_by_sid.setdefault(sid, set()).add(bid)
        # install each server's folded index minus entries routing to a
        # block this compaction retires — after this, no replica probe at
        # ≥ new_ver can reach a pre-compaction block (older pinned
        # versions keep their snapshots, and their blocks stay allocated
        # until those pins drain)
        for sid, srv_ in enumerate(self.servers):
            isigs, iblk, ioff = srv_._ensure_index()
            dead = retired_by_sid.get(sid)
            if dead and isigs.size:
                keep = ~np.isin(iblk, np.fromiter(dead, np.int64, len(dead)))
                isigs, iblk, ioff = isigs[keep], iblk[keep], ioff[keep]
            srv_.install_index(isigs, iblk, ioff)
        # a tombstone must survive as long as ANY server's current index
        # still holds the pre-delete row (dropping it early would let the
        # replica path resurrect the deleted value); after the filter
        # above, that is exactly "the sig still appears in some index" —
        # e.g. a concurrent delta upserted-then-re-deleted it, leaving the
        # row in a fresh overlay block this compaction does not retire
        tomb = psrv == -1
        if tomb.any():
            tsigs = psigs[tomb]
            reachable = np.zeros(tsigs.size, bool)
            for srv_ in self.servers:
                isigs = srv_._index[0]
                if isigs.size:
                    pos = np.searchsorted(isigs, tsigs)
                    pos = np.minimum(pos, isigs.size - 1)
                    reachable |= isigs[pos] == tsigs
            drop = tomb.copy()
            drop[tomb] = ~reachable
            if drop.any():
                keep = ~drop
                psigs, psrv = psigs[keep], psrv[keep]
                pblk, poff = pblk[keep], poff[keep]
        for srv_ in self.servers:
            srv_.publish_version(new_ver)
        self._snap = (new_ver, psigs, psrv, pblk, poff)
        with self._pin_lock:
            self._garbage.extend((new_ver, sid, bid) for sid, bid in initial)
        self.overlay_blocks = max(0, self.overlay_blocks - overlay_start)
        self.metrics.compactions += 1
        self.reclaim()
        return new_ver

    def reclaim(self):
        """Free retired blocks no pinned reader can still reference: a block
        retired at version v is reachable only through snapshots < v, so it
        frees once every active pin is ≥ v. Called from writer paths (and
        available to maintenance loops) — never from readers, whose unpin
        must stay free of filesystem work."""
        freed = []
        with self._pin_lock:
            min_pinned = min(self._pins) if self._pins else self._snap[0]
            keep = []
            for retire_ver, sid, bid in self._garbage:
                if min_pinned >= retire_ver:
                    freed.append((sid, bid))
                else:
                    keep.append((retire_ver, sid, bid))
            self._garbage = keep
        # versioned replica snapshots age out with the same min-pin rule
        # as retired blocks (writer-driven; readers never prune)
        for srv in self.servers:
            srv.prune_snapshots(min_pinned)
        for sid, bid in freed:
            block = self.servers[sid].blocks[bid]
            if not isinstance(block, _Block):
                continue                      # defensively skip double-frees
            self.servers[sid].blocks[bid] = _FreedBlock()
            self.servers[sid].free_slots.append(bid)
            if getattr(block, "path", None):
                try:
                    os.remove(block.path)
                except OSError:
                    pass
            self.metrics.blocks_freed += 1

    # ----------------------------------------------------- fault injection
    def kill_server(self, sid: int):
        self.servers[sid].alive = False

    def revive_server(self, sid: int):
        self.servers[sid].alive = True
