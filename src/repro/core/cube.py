"""The Distributed Sparse Parameter Cube (paper §5.1).

A READ-ONLY distributed KV store for the sparse sub-network:
  * key    — compact feature signature (universal hash; repro.sparse.hashing)
  * value  — model weights (+ feedback statistics) for that sparse feature
  * keys live purely in memory (to hide hash-probe latency); values are
    grouped into ~1 GB blocks placed in MEMORY or DISK (SSD) — a tunable
    latency/hardware trade-off (the "cube cache ratio" knob moves it)
  * sharded over servers by key hash; every block replicated ``replication``
    ways → fault tolerant (server failure reroutes to replicas)
  * generation-stamped (model hot-loading swaps whole generations)

Lookups are **batch-native** (DESIGN.md §3): a request's signatures are
deduplicated once (`np.unique`), grouped by shard with a single argsort,
probed against each server's *sorted signature index* with one
`np.searchsorted`, and each touched block is gathered with a single
fancy-index. Latency is accounted per *block touch* + per *server RPC*,
not per row — batching is exactly what amortizes those costs.

The legacy per-row scalar path survives behind ``use_scalar_path=True``
(or ``lookup_scalar``) as a benchmark baseline for one release; see
DESIGN.md §3.3 for the deprecation schedule.

Host-side numpy implementation: this tier backs the >HBM tail of the model;
the HBM-resident head is the row-sharded table (repro.sparse.sharded) — see
DESIGN.md §2 for how the two compose on a pod.
"""
from __future__ import annotations

import os
import tempfile
import threading
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.sparse.hashing import signature_np


@dataclass
class CubeMetrics:
    lookups: int = 0
    mem_block_hits: int = 0      # batched path: distinct mem blocks touched
    disk_block_hits: int = 0     # batched path: distinct disk blocks touched
    failovers: int = 0
    simulated_latency_s: float = 0.0


class _Block:
    """One value block: contiguous (n, dim) array, in RAM or memmapped."""

    def __init__(self, values: np.ndarray, on_disk: bool, tmpdir: str, bid: str):
        self.on_disk = on_disk
        if on_disk:
            path = os.path.join(tmpdir, f"block_{bid}.npy")
            mm = np.lib.format.open_memmap(path, mode="w+",
                                           dtype=values.dtype, shape=values.shape)
            mm[:] = values
            mm.flush()
            self.values = mm
        else:
            self.values = values
        # plain-ndarray view for gathers: same mapped pages for disk blocks,
        # but skips np.memmap's per-__getitem__ subclass machinery
        self.view = np.asarray(self.values)


class CubeServer:
    """One shard holder. The key index is three parallel arrays sorted by
    signature — ``_sigs`` (uint64), ``_blk``/``_off`` (block id, row offset) —
    probed with np.searchsorted; no per-key Python dict."""

    def __init__(self, server_id: int, tmpdir: str):
        self.server_id = server_id
        self.tmpdir = tmpdir
        self.blocks: list[_Block] = []
        self.alive = True
        self._sigs = np.empty(0, np.uint64)
        self._blk = np.empty(0, np.int32)
        self._off = np.empty(0, np.int32)
        self._pending: list[tuple[np.ndarray, int]] = []   # ingested, unsorted

    def add_block(self, sigs: np.ndarray, values: np.ndarray, on_disk: bool) -> int:
        bid = len(self.blocks)
        # filename carries the server id — servers share a tmpdir
        self.blocks.append(_Block(values, on_disk, self.tmpdir,
                                  f"s{self.server_id}_{bid}"))
        self._pending.append((np.asarray(sigs, dtype=np.uint64), bid))
        return bid

    def _ensure_index(self):
        """Merge pending ingests into the sorted index (lazy: load_table may
        add many blocks back-to-back; sort once at first probe)."""
        if not self._pending:
            return
        sigs = np.concatenate([self._sigs] + [s for s, _ in self._pending])
        blk = np.concatenate([self._blk] + [
            np.full(s.size, b, np.int32) for s, b in self._pending])
        off = np.concatenate([self._off] + [
            np.arange(s.size, dtype=np.int32) for s, _ in self._pending])
        self._pending.clear()
        order = np.argsort(sigs, kind="stable")
        sigs, blk, off = sigs[order], blk[order], off[order]
        if sigs.size > 1:
            # duplicate signature (re-ingest): last insertion wins, matching
            # the old dict overwrite semantics
            last = np.ones(sigs.size, bool)
            last[:-1] = sigs[1:] != sigs[:-1]
            sigs, blk, off = sigs[last], blk[last], off[last]
        self._sigs, self._blk, self._off = sigs, blk, off

    # ------------------------------------------------------------ probing
    def get(self, sig: int) -> Optional[tuple[np.ndarray, bool]]:
        """Scalar probe (legacy path + debugging)."""
        self._ensure_index()
        s = np.uint64(sig)
        pos = int(np.searchsorted(self._sigs, s))
        if pos >= self._sigs.size or self._sigs[pos] != s:
            return None
        blk = self.blocks[int(self._blk[pos])]
        return np.asarray(blk.values[int(self._off[pos])]), blk.on_disk

    def get_batch(self, sigs: np.ndarray
                  ) -> tuple[Optional[np.ndarray], np.ndarray, int, int]:
        """Vectorized probe. Returns (rows, found, mem_touches, disk_touches):
        ``found`` is a boolean mask over ``sigs``; ``rows`` holds the values
        of the found signatures in order (one fancy-index gather per touched
        block); touch counts are DISTINCT blocks read, for latency accounting.
        """
        self._ensure_index()
        m = sigs.size
        if self._sigs.size == 0:
            return None, np.zeros(m, bool), 0, 0
        pos = np.searchsorted(self._sigs, sigs)
        pos = np.minimum(pos, self._sigs.size - 1)
        found = self._sigs[pos] == sigs
        if not found.any():
            return None, found, 0, 0
        fpos = pos[found]
        fblk, foff = self._blk[fpos], self._off[fpos]
        # group rows by block with one argsort, then slice-gather per block
        order = np.argsort(fblk, kind="stable")
        sblk, soff = fblk[order], foff[order]
        starts = np.concatenate(([0], np.flatnonzero(sblk[1:] != sblk[:-1]) + 1,
                                 [sblk.size]))
        # one probe batch is always single-group (lookup hashes one group),
        # so every touched block shares the first one's row shape — blocks[0]
        # may belong to a DIFFERENT group with another dim/dtype
        first = self.blocks[int(sblk[0])].view
        gathered = np.empty((fpos.size, first.shape[1]), first.dtype)
        mem_t = disk_t = 0
        for lo, hi in zip(starts[:-1], starts[1:]):
            block = self.blocks[int(sblk[lo])]
            gathered[lo:hi] = block.view[soff[lo:hi]]  # one gather per block
            if block.on_disk:
                disk_t += 1
            else:
                mem_t += 1
        rows = np.empty_like(gathered)
        rows[order] = gathered
        return rows, found, mem_t, disk_t


class ParameterCube:
    """Build from feature-group embedding tables; serve batched lookups."""

    def __init__(self, n_servers: int = 4, replication: int = 2,
                 block_rows: int = 65536, mem_block_fraction: float = 0.5,
                 mem_latency_s: float = 2e-6, disk_latency_s: float = 50e-6,
                 net_latency_s: float = 300e-6, generation: int = 0,
                 tmpdir: Optional[str] = None, use_scalar_path: bool = False):
        assert replication <= n_servers
        self.n_servers = n_servers
        self.replication = replication
        self.block_rows = block_rows
        self.mem_block_fraction = mem_block_fraction
        self.lat = {"mem": mem_latency_s, "disk": disk_latency_s,
                    "net": net_latency_s}
        self.generation = generation
        self.tmpdir = tmpdir or tempfile.mkdtemp(prefix="cube_")
        self.servers = [CubeServer(i, self.tmpdir) for i in range(n_servers)]
        self.metrics = CubeMetrics()
        # DEPRECATED escape hatch (one release): route lookup() through the
        # per-row legacy path so deployments can A/B the rollout.
        self.use_scalar_path = use_scalar_path
        self._dim: Optional[int] = None
        self._dtype = np.float32
        self._shapes: dict[int, tuple[int, np.dtype]] = {}  # per-group row shape
        # cube-wide PRIMARY index: every r=0 placement, sorted by signature.
        # Keys are all-in-memory per the paper, so the router can resolve a
        # whole batch (sig → primary server, block, offset) with ONE
        # searchsorted; replicas are only probed for misses/dead primaries.
        # Held as ONE (sigs, srv, blk, off) tuple swapped atomically: lookup
        # runs concurrently from parallel SEDP stage workers, and a reader
        # must never see sigs from one generation with srv/blk/off from
        # another (that routes to the wrong block — silent corruption).
        self._pindex = (np.empty(0, np.uint64), np.empty(0, np.int32),
                        np.empty(0, np.int32), np.empty(0, np.int32))
        self._p_pending: list[tuple[np.ndarray, int, int]] = []
        self._p_lock = threading.Lock()

    # ------------------------------------------------------------- build
    def load_table(self, group: int, table: np.ndarray,
                   raw_ids: Optional[np.ndarray] = None):
        """Ingest rows of one feature group. Values are the rows; keys are
        signature(group, row_id)."""
        ids = raw_ids if raw_ids is not None else np.arange(table.shape[0])
        sigs = signature_np(group, ids)
        order = np.argsort(sigs % np.uint64(self.n_servers), kind="stable")
        sigs, rows = sigs[order], table[order]
        shard = (sigs % np.uint64(self.n_servers)).astype(np.int64)
        self._dim, self._dtype = table.shape[1], table.dtype
        self._shapes[group] = (table.shape[1], table.dtype)
        for sid in range(self.n_servers):
            sel = shard == sid
            s_sigs, s_rows = sigs[sel], rows[sel]
            for start in range(0, len(s_sigs), self.block_rows):
                blk_s = s_sigs[start:start + self.block_rows]
                blk_v = s_rows[start:start + self.block_rows]
                n_blocks = max(1, len(s_sigs) // self.block_rows)
                on_disk = (start // self.block_rows) >= max(
                    1, int(n_blocks * self.mem_block_fraction))
                for r in range(self.replication):
                    bid = self.servers[(sid + r) % self.n_servers].add_block(
                        blk_s, blk_v, on_disk)
                    if r == 0:
                        # under the build lock: a concurrent index fold
                        # iterates and clears _p_pending — an unlocked
                        # append could be wiped before ever being folded
                        with self._p_lock:
                            self._p_pending.append((blk_s, sid, bid))

    # ------------------------------------------------------------ lookup
    def _ensure_primary_index(self):
        """Fold pending placements into the index and return a consistent
        (sigs, srv, blk, off) snapshot. Thread-safe: concurrent stage
        workers serialize on the build lock; the double-check inside keeps
        the common no-pending call lock-free-ish and cheap."""
        if not self._p_pending:
            return self._pindex
        with self._p_lock:
            if not self._p_pending:
                return self._pindex
            psigs, psrv, pblk, poff = self._pindex
            sigs = np.concatenate([psigs] + [s for s, _, _ in self._p_pending])
            srv = np.concatenate([psrv] + [
                np.full(s.size, sid, np.int32) for s, sid, _ in self._p_pending])
            blk = np.concatenate([pblk] + [
                np.full(s.size, b, np.int32) for s, _, b in self._p_pending])
            off = np.concatenate([poff] + [
                np.arange(s.size, dtype=np.int32) for s, _, _ in self._p_pending])
            self._p_pending.clear()
            order = np.argsort(sigs, kind="stable")
            sigs, srv, blk, off = sigs[order], srv[order], blk[order], off[order]
            if sigs.size > 1:
                last = np.ones(sigs.size, bool)     # duplicate sig: last wins
                last[:-1] = sigs[1:] != sigs[:-1]
                sigs, srv, blk, off = (sigs[last], srv[last], blk[last],
                                       off[last])
            self._pindex = (sigs, srv, blk, off)
            return self._pindex

    def lookup(self, group: int, raw_ids: np.ndarray) -> np.ndarray:
        """Batched gather: (...,) raw ids → (N, dim) rows (inputs are
        flattened; callers reshape). Deduplicates repeated ids before any
        server is touched and re-scatters afterwards, so a dup-heavy batch
        pays each distinct row once. The whole batch is routed with one
        probe of the cube-wide primary index; only misses and signatures on
        dead primaries take the per-server replica path."""
        if self.use_scalar_path:
            return self.lookup_scalar(group, raw_ids)
        raw = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
        sigs = signature_np(group, raw)
        n_req = sigs.size
        if n_req == 0:
            dim, dtype = self._shapes.get(group, (self._dim or 0, self._dtype))
            return np.empty((0, dim), dtype)
        psigs, psrv, pblk, poff = self._ensure_primary_index()
        uniq, inverse = np.unique(sigs, return_inverse=True)
        nu = uniq.size
        dim, dtype = self._shapes.get(group, (self._dim or 0, self._dtype))
        rows = np.empty((nu, dim), dtype)
        primary = (uniq % np.uint64(self.n_servers)).astype(np.int64)
        t = 0.0

        # ---- fast path: one searchsorted over the primary index
        alive = np.fromiter((s.alive for s in self.servers), bool,
                            self.n_servers)
        pos = np.searchsorted(psigs, uniq)
        np.minimum(pos, max(0, psigs.size - 1), out=pos)
        found = (psigs[pos] == uniq) if psigs.size else \
            np.zeros(nu, bool)
        dead_primary = ~alive[primary]
        if dead_primary.any():
            # failover accounted at batch granularity: every distinct
            # signature rerouted off its dead primary
            self.metrics.failovers += int(dead_primary.sum())
        served = found & ~dead_primary
        sidx = np.flatnonzero(served)
        if sidx.size:
            spos = pos[sidx]
            gsrv, gblk, goff = psrv[spos], pblk[spos], poff[spos]
            # group by (server, block) with one argsort → one fancy-index
            # gather per touched block, one RPC per touched server
            comp = (gsrv.astype(np.int64) << 32) | gblk
            order = np.argsort(comp, kind="stable")
            scomp, soff = comp[order], goff[order]
            starts = np.concatenate(
                ([0], np.flatnonzero(scomp[1:] != scomp[:-1]) + 1,
                 [scomp.size]))
            gathered = np.empty((sidx.size, dim), dtype)
            touched_srv = set()
            mem_t = disk_t = 0
            for lo, hi in zip(starts[:-1], starts[1:]):
                c = int(scomp[lo])
                srv_id, blk_id = c >> 32, c & 0xFFFFFFFF
                block = self.servers[srv_id].blocks[blk_id]
                gathered[lo:hi] = block.view[soff[lo:hi]]
                touched_srv.add(srv_id)
                if block.on_disk:
                    disk_t += 1
                else:
                    mem_t += 1
            rows[sidx[order]] = gathered
            self.metrics.mem_block_hits += mem_t
            self.metrics.disk_block_hits += disk_t
            t += (len(touched_srv) * self.lat["net"]
                  + mem_t * self.lat["mem"] + disk_t * self.lat["disk"])

        # ---- slow path: replica probing for misses / dead primaries
        pending = np.flatnonzero(~served)
        for r in range(1, self.replication):
            if pending.size == 0:
                break
            srv_of = (primary[pending] + r) % self.n_servers
            order = np.argsort(srv_of, kind="stable")   # group by shard, once
            sp, so = pending[order], srv_of[order]
            bounds = np.searchsorted(so, np.arange(self.n_servers + 1))
            missed: list[np.ndarray] = []
            for sid in range(self.n_servers):
                lo, hi = bounds[sid], bounds[sid + 1]
                if lo == hi:
                    continue
                idxs = sp[lo:hi]
                srv = self.servers[sid]
                if not srv.alive:
                    missed.append(idxs)
                    continue
                got, fmask, mem_t, disk_t = srv.get_batch(uniq[idxs])
                t += self.lat["net"]                    # one RPC per server
                if got is not None:
                    rows[idxs[fmask]] = got
                self.metrics.mem_block_hits += mem_t
                self.metrics.disk_block_hits += disk_t
                t += mem_t * self.lat["mem"] + disk_t * self.lat["disk"]
                if not fmask.all():
                    missed.append(idxs[~fmask])
            pending = (np.concatenate(missed) if missed
                       else np.empty(0, np.int64))
        if pending.size:
            raise KeyError(
                f"signature {uniq[pending[0]]} unavailable (group {group})")
        self.metrics.lookups += n_req
        self.metrics.simulated_latency_s += t
        return rows[inverse]

    def lookup_scalar(self, group: int, raw_ids: np.ndarray) -> np.ndarray:
        """DEPRECATED legacy per-row path (per-row latency accounting, no
        dedup). Kept one release as the benchmark baseline — see DESIGN.md."""
        sigs = signature_np(group, np.asarray(raw_ids))
        out = []
        t = 0.0
        for s in np.atleast_1d(sigs).reshape(-1):
            primary = int(s % np.uint64(self.n_servers))
            row = None
            for r in range(self.replication):
                srv = self.servers[(primary + r) % self.n_servers]
                if not srv.alive:
                    if r == 0:
                        self.metrics.failovers += 1
                    continue
                got = srv.get(int(s))
                if got is not None:
                    row, on_disk = got
                    t += self.lat["net"] / 64 + (
                        self.lat["disk"] if on_disk else self.lat["mem"])
                    if on_disk:
                        self.metrics.disk_block_hits += 1
                    else:
                        self.metrics.mem_block_hits += 1
                    break
            if row is None:
                raise KeyError(f"signature {s} unavailable (group {group})")
            out.append(row)
        self.metrics.lookups += len(out)
        self.metrics.simulated_latency_s += t
        return np.stack(out)

    # ----------------------------------------------------- fault injection
    def kill_server(self, sid: int):
        self.servers[sid].alive = False

    def revive_server(self, sid: int):
        self.servers[sid].alive = True
