"""The Distributed Sparse Parameter Cube (paper §5.1).

A READ-ONLY distributed KV store for the sparse sub-network:
  * key    — compact feature signature (universal hash; repro.sparse.hashing)
  * value  — model weights (+ feedback statistics) for that sparse feature
  * keys live purely in memory (to hide hash-probe latency); values are
    grouped into ~1 GB blocks placed in MEMORY or DISK (SSD) — a tunable
    latency/hardware trade-off (the "cube cache ratio" knob moves it)
  * sharded over servers by key hash; every block replicated ``replication``
    ways → fault tolerant (server failure reroutes to replicas)
  * generation-stamped (model hot-loading swaps whole generations)

Host-side numpy implementation: this tier backs the >HBM tail of the model;
the HBM-resident head is the row-sharded table (repro.sparse.sharded) — see
DESIGN.md §2 for how the two compose on a pod.
"""
from __future__ import annotations

import os
import tempfile
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.sparse.hashing import signature_np


@dataclass
class CubeMetrics:
    lookups: int = 0
    mem_block_hits: int = 0
    disk_block_hits: int = 0
    failovers: int = 0
    simulated_latency_s: float = 0.0


class _Block:
    """One value block: contiguous (n, dim) array, in RAM or memmapped."""

    def __init__(self, values: np.ndarray, on_disk: bool, tmpdir: str, bid: str):
        self.on_disk = on_disk
        if on_disk:
            path = os.path.join(tmpdir, f"block_{bid}.npy")
            mm = np.lib.format.open_memmap(path, mode="w+",
                                           dtype=values.dtype, shape=values.shape)
            mm[:] = values
            mm.flush()
            self.values = mm
        else:
            self.values = values


class CubeServer:
    def __init__(self, server_id: int, tmpdir: str):
        self.server_id = server_id
        self.tmpdir = tmpdir
        self.keys: dict[int, tuple[int, int]] = {}     # sig -> (block, offset)
        self.blocks: list[_Block] = []
        self.alive = True

    def add_block(self, sigs: np.ndarray, values: np.ndarray, on_disk: bool):
        bid = len(self.blocks)
        # filename carries the server id — servers share a tmpdir
        self.blocks.append(_Block(values, on_disk, self.tmpdir,
                                  f"s{self.server_id}_{bid}"))
        for off, s in enumerate(sigs):
            self.keys[int(s)] = (bid, off)

    def get(self, sig: int) -> Optional[tuple[np.ndarray, bool]]:
        loc = self.keys.get(int(sig))
        if loc is None:
            return None
        blk = self.blocks[loc[0]]
        return np.asarray(blk.values[loc[1]]), blk.on_disk


class ParameterCube:
    """Build from feature-group embedding tables; serve batched lookups."""

    def __init__(self, n_servers: int = 4, replication: int = 2,
                 block_rows: int = 65536, mem_block_fraction: float = 0.5,
                 mem_latency_s: float = 2e-6, disk_latency_s: float = 50e-6,
                 net_latency_s: float = 300e-6, generation: int = 0,
                 tmpdir: Optional[str] = None):
        assert replication <= n_servers
        self.n_servers = n_servers
        self.replication = replication
        self.block_rows = block_rows
        self.mem_block_fraction = mem_block_fraction
        self.lat = {"mem": mem_latency_s, "disk": disk_latency_s,
                    "net": net_latency_s}
        self.generation = generation
        self.tmpdir = tmpdir or tempfile.mkdtemp(prefix="cube_")
        self.servers = [CubeServer(i, self.tmpdir) for i in range(n_servers)]
        self.metrics = CubeMetrics()

    # ------------------------------------------------------------- build
    def load_table(self, group: int, table: np.ndarray,
                   raw_ids: Optional[np.ndarray] = None):
        """Ingest rows of one feature group. Values are the rows; keys are
        signature(group, row_id)."""
        ids = raw_ids if raw_ids is not None else np.arange(table.shape[0])
        sigs = signature_np(group, ids)
        order = np.argsort(sigs % np.uint64(self.n_servers), kind="stable")
        sigs, rows = sigs[order], table[order]
        shard = (sigs % np.uint64(self.n_servers)).astype(np.int64)
        for sid in range(self.n_servers):
            sel = shard == sid
            s_sigs, s_rows = sigs[sel], rows[sel]
            for start in range(0, len(s_sigs), self.block_rows):
                blk_s = s_sigs[start:start + self.block_rows]
                blk_v = s_rows[start:start + self.block_rows]
                n_blocks = max(1, len(s_sigs) // self.block_rows)
                on_disk = (start // self.block_rows) >= max(
                    1, int(n_blocks * self.mem_block_fraction))
                for r in range(self.replication):
                    self.servers[(sid + r) % self.n_servers].add_block(
                        blk_s, blk_v, on_disk)

    # ------------------------------------------------------------ lookup
    def lookup(self, group: int, raw_ids: np.ndarray) -> np.ndarray:
        sigs = signature_np(group, np.asarray(raw_ids))
        out = []
        t = 0.0
        for s in np.atleast_1d(sigs):
            primary = int(s % np.uint64(self.n_servers))
            row = None
            for r in range(self.replication):
                srv = self.servers[(primary + r) % self.n_servers]
                if not srv.alive:
                    if r == 0:
                        self.metrics.failovers += 1
                    continue
                got = srv.get(int(s))
                if got is not None:
                    row, on_disk = got
                    t += self.lat["net"] / 64 + (
                        self.lat["disk"] if on_disk else self.lat["mem"])
                    if on_disk:
                        self.metrics.disk_block_hits += 1
                    else:
                        self.metrics.mem_block_hits += 1
                    break
            if row is None:
                raise KeyError(f"signature {s} unavailable (group {group})")
            out.append(row)
        self.metrics.lookups += len(out)
        self.metrics.simulated_latency_s += t
        return np.stack(out)

    # ----------------------------------------------------- fault injection
    def kill_server(self, sid: int):
        self.servers[sid].alive = False

    def revive_server(self, sid: int):
        self.servers[sid].alive = True
