"""Offline auto-tuning for quasi-optimal resource allocation (paper §6.1).

Pipeline (Eq. 1):
  1. LOGS    — sweep random knob vectors through the service simulator,
               recording per-stage latency F^L_j and resource F^R_j targets.
  2. MODELS  — fit the RidgeEnsemble predictors (noisy, biased, and
               non-differentiable in the useful sense — hence CMA-ES).
  3. SEARCH  — CMA-ES-with-constraints minimizes Σ_j F^R_j subject to
               F^L_j(θ) ≤ F^L_j(θ̄) for every stage j (N constraints).
  4. VALIDATE— the paper re-runs constraint-satisfied minima from the CMA-ES
               SOLUTION PATH on 5% of live traffic; we re-run them in the
               full simulator and pick the true winner.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.irm.cmaes import cmaes_minimize
from repro.core.irm.models import RidgeEnsemble
from repro.core.service_model import (Knobs, ServiceSpec, derive_instances,
                                      run_service)

STAGE_KEYS = ("user_proc", "item_extract", "item_proc", "cube_access", "dnn")


def _stage_latency(report, key: str) -> float:
    """Mean busy time per event for stages matching key (dnn_* aggregated)."""
    tot_busy = tot_ev = 0.0
    for name, st in report.stage_stats.items():
        if name.startswith(key):
            tot_busy += st.busy_s
            tot_ev += st.events
    return tot_busy / max(1.0, tot_ev)


def logs_from_history(history_dir: str):
    """Load (X, lat, res) training logs from a ``StatsRecorder`` history
    directory (the durable artifact a live service records — DESIGN.md
    §10.4). Returns None when the directory holds no IRM samples, so
    callers can fall back to a fresh sweep."""
    from repro.obs.recorder import read_history
    X, lat, res = [], [], []
    for s in read_history(history_dir):
        irm = (s.get("extra") or {}).get("irm")
        if not irm:
            continue
        X.append(np.asarray(irm["knobs"], float))
        lat.append(np.asarray(irm["stage_latency_s"], float))
        res.append(float(irm["instances"]))
    if not X:
        return None
    return np.stack(X), np.stack(lat), np.array(res)


def collect_logs(spec: ServiceSpec, n_samples: int = 60, n_events: int = 1200,
                 rate_qps: float = 1200.0, seed: int = 0,
                 history_dir: str | None = None):
    """Historical logs: (knob vector → per-stage latencies, instances).

    With ``history_dir`` set, previously recorded history is REUSED when
    present (the paper's IRM searches over logs the serving fleet already
    produced, not fresh sweeps); otherwise the sweep runs and every sample
    is recorded there through a ``StatsRecorder`` — so the next tuning run,
    and any other consumer, reads the same durable artifact."""
    if history_dir is not None:
        loaded = logs_from_history(history_dir)
        if loaded is not None:
            return loaded
    recorder = None
    if history_dir is not None:
        from repro.obs.metrics import MetricsRegistry
        from repro.obs.recorder import StatsRecorder
        recorder = StatsRecorder(history_dir, MetricsRegistry(),
                                 window_samples=max(1, n_samples))
    rng = np.random.default_rng(seed)
    X, lat, res = [], [], []
    bounds = [(lo, hi) for _, lo, hi in Knobs.BOUNDS]
    for i in range(n_samples):
        x = np.array([rng.uniform(lo, hi) for lo, hi in bounds])
        k = Knobs.from_vector(x)
        rep, rt, inst = run_service(spec, k, n_events=n_events,
                                    rate_qps=rate_qps, seed=seed + i)
        stage_lat = [_stage_latency(rep, s) for s in STAGE_KEYS]
        X.append(k.to_vector())
        lat.append(stage_lat)
        res.append(float(inst))
        if recorder is not None:
            recorder.sample(extra={"irm": {
                "knobs": [float(v) for v in k.to_vector()],
                "stage_latency_s": [float(v) for v in stage_lat],
                "instances": float(inst),
                "avg_latency_s": float(rep.avg_latency),
                "p99_latency_s": float(rep.latency_percentile(0.99)),
                "seed": seed + i,
            }})
    if recorder is not None:
        recorder.roll()
    return np.stack(X), np.stack(lat), np.array(res)


@dataclass
class TuneResult:
    knobs_before: Knobs
    knobs_after: Knobs
    instances_before: int
    instances_after: int
    latency_before_ms: float
    latency_after_ms: float
    candidates_tried: int = 0

    @property
    def instance_gain(self) -> float:
        return 1.0 - self.instances_after / max(1, self.instances_before)


def autotune(spec: ServiceSpec, n_log_samples: int = 60,
             n_events: int = 1200, rate_qps: float = 1200.0,
             budget: int = 1500, seed: int = 0,
             latency_slack: float = 1.02,
             history_dir: str | None = None) -> TuneResult:
    default = Knobs()
    X, lat, res = collect_logs(spec, n_log_samples, n_events, rate_qps, seed,
                               history_dir=history_dir)

    f_r = RidgeEnsemble(seed=seed).fit(X, res)
    f_l = [RidgeEnsemble(seed=seed + 1 + j).fit(X, lat[:, j])
           for j in range(len(STAGE_KEYS))]

    # baseline (default knobs) — both predicted and simulated
    rep0, rt0, inst0 = run_service(spec, default, n_events=n_events * 2,
                                   rate_qps=rate_qps, seed=seed + 777)
    lat0 = np.array([_stage_latency(rep0, s) for s in STAGE_KEYS])

    def objective(x):
        return float(f_r(x))

    def constraints(x):
        # F^L_j(θ) ≤ F^L_j(default)·slack  ∀j   (Eq. 1's N constraints)
        return np.array([f(x) - latency_slack * l0
                         for f, l0 in zip(f_l, lat0)])

    bounds = [(lo, hi) for _, lo, hi in Knobs.BOUNDS]
    result = cmaes_minimize(objective, default.to_vector(), 0.3, bounds,
                            constraints=constraints, budget=budget, seed=seed)

    # paper step: validate constraint-satisfied path minima on real traffic
    candidates = result.best_feasible_candidates(k=6) or []
    best_k, best_inst, best_lat = default, inst0, rep0.avg_latency
    tried = 0
    for cand in candidates:
        k = Knobs.from_vector(cand.x)
        rep, rt, inst = run_service(spec, k, n_events=n_events * 2,
                                    rate_qps=rate_qps, seed=seed + 777)
        tried += 1
        if (inst < best_inst
                and rep.avg_latency <= rep0.avg_latency * latency_slack):
            best_k, best_inst, best_lat = k, inst, rep.avg_latency
    return TuneResult(default, best_k, inst0, best_inst,
                      rep0.avg_latency * 1e3, best_lat * 1e3, tried)
