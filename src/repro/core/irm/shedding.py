"""Online load shedding via a pruning DNN (paper §6.2, Fig. 6, Table 7).

Funnel context: recall hands ~10³ candidates per request to the expensive
re-rank stage; only ~a dozen are shown. When traffic exceeds capacity, prune
low-quality candidates per-request, bounded by a recommendation-effectiveness
constraint |L* − L̂| ≤ ε (Eq. 2).

  * Features (Table 7): quota (available resource), previous cutoff ratio,
    queue id, and the recall-score statistics (avg/var/max/min).
  * The pruning DNN is an ultra-lightweight MLP (decides in ~μs) trained to
    imitate the ORACLE cutoff: the largest prune such that the expected
    recall@K loss ≤ ε, shrunk further as quota tightens.
  * Candidates are sorted by recall score; everything behind the cutoff is
    dropped before re-rank.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import mlp_tower_apply, mlp_tower_init
from repro.obs.trace import annotate

FEATURES = ("quota", "cutoff_ratio_prev", "qid",
            "escore_avg", "escore_variance", "escore_max", "escore_min")


def features_from(scores: np.ndarray, quota: float, prev_cutoff: float,
                  qid: int) -> np.ndarray:
    return np.array([quota, prev_cutoff, float(qid % 16) / 16.0,
                     float(scores.mean()), float(scores.var()),
                     float(scores.max()), float(scores.min())], np.float32)


def oracle_cutoff(scores: np.ndarray, quota: float, eps: float,
                  k: int = 12) -> float:
    """Max prune ratio with bounded effectiveness loss: keep every candidate
    that could plausibly reach the final top-k (score within the ε-quantile
    band of the k-th best), then shed further only as quota forces it."""
    s = np.sort(scores)[::-1]
    n = len(s)
    kth = s[min(k, n) - 1]
    # ε-band: items scoring within eps-quantile of the k-th score may reorder
    # under the re-rank model; they must survive
    thresh = kth - eps * (s[0] - s[-1] + 1e-9)
    must_keep = int(np.sum(s >= thresh))
    quota_keep = int(np.ceil(n * min(1.0, max(quota, 0.02))))
    keep = max(k, min(n, max(must_keep, quota_keep) if quota >= 1.0
                      else max(k, min(must_keep, quota_keep))))
    keep = max(keep, k)
    return 1.0 - keep / n


class PruningDNN:
    """7 → 32 → 16 → 1 sigmoid MLP: predicts the cutoff ratio."""

    def __init__(self, seed: int = 0):
        self.params = mlp_tower_init(jax.random.PRNGKey(seed), len(FEATURES),
                                     (32, 16, 1), jnp.float32)
        self.x_mean = np.zeros(len(FEATURES), np.float32)
        self.x_std = np.ones(len(FEATURES), np.float32)

        def fwd(params, x):
            return jax.nn.sigmoid(
                mlp_tower_apply(params, x, act="silu")[..., 0])

        self._fwd = jax.jit(fwd)

        def loss(params, x, y):
            return jnp.mean((fwd(params, x) - y) ** 2)

        self._grad = jax.jit(jax.value_and_grad(loss))

    def __call__(self, feats: np.ndarray) -> np.ndarray:
        x = (np.atleast_2d(feats) - self.x_mean) / self.x_std
        return np.asarray(self._fwd(self.params, jnp.asarray(x)))

    def fit(self, X: np.ndarray, y: np.ndarray, steps: int = 2000,
            lr: float = 3e-3, seed: int = 0) -> float:
        rng = np.random.default_rng(seed)
        # feature standardization (quota ~O(1) but variance features are not)
        self.x_mean = X.mean(0)
        self.x_std = X.std(0) + 1e-6
        Xn = (X - self.x_mean) / self.x_std
        Xj, yj = jnp.asarray(Xn), jnp.asarray(y)
        m = jax.tree.map(jnp.zeros_like, self.params)
        v = jax.tree.map(jnp.zeros_like, self.params)
        for step in range(steps):
            idx = jnp.asarray(rng.integers(0, Xj.shape[0], 256))
            l, g = self._grad(self.params, Xj[idx], yj[idx])
            m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
            v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
            self.params = jax.tree.map(
                lambda p, mm, vv: p - lr * mm / (jnp.sqrt(vv) + 1e-8),
                self.params, m, v)
        final, _ = self._grad(self.params, Xj, yj)
        return float(final)


def train_pruning_dnn(n_samples: int = 4000, eps: float = 0.05,
                      seed: int = 0, steps: int = 2000
                      ) -> tuple[PruningDNN, float]:
    """Generate oracle-labelled synthetic funnel traffic and fit the DNN."""
    rng = np.random.default_rng(seed)
    X, y = [], []
    prev = 0.0
    for i in range(n_samples):
        # candidate-count and score distributions matched to the serving
        # traffic (lognormal funnel sizes; mixed score shapes)
        n = int(np.clip(rng.lognormal(np.log(120), 1.0), 8, 2000))
        mode = rng.choice(4)
        if mode == 0:
            scores = rng.beta(2, 5, n)
        elif mode == 1:
            scores = rng.beta(5, 2, n)
        elif mode == 2:
            scores = rng.random(n)
        else:
            scores = rng.normal(0.5, 0.15, n).clip(0, 1)
        quota = float(rng.uniform(0.02, 1.2))
        cut = oracle_cutoff(scores, quota, eps)
        X.append(features_from(scores, quota, prev, i))
        y.append(cut)
        prev = cut
    dnn = PruningDNN(seed)
    mse = dnn.fit(np.stack(X), np.array(y, np.float32), steps=steps)
    return dnn, mse


@dataclass
class ShedderState:
    prev_cutoff: float = 0.0
    shed_events: int = 0
    kept_events: int = 0
    dropped_requests: int = 0     # whole requests shed at a full channel
    overflow_pruned: int = 0      # requests hard-pruned at a full channel


class QuotaController:
    """Live quota from intermediate system feedback (paper §6.2: the policy
    is "fine-tuned over intermediate system feedback").

    Maps the downstream stage's queue depth and server utilization
    (``ExecContext.queue_depth`` / ``ExecContext.utilization``, i.e.
    StageStats) to the 'available resource' feature of Table 7, smoothed
    with an EWMA so a single burst doesn't whipsaw the cutoff. Quota 1.0 ≈
    free capacity; → 0.02 as the downstream saturates."""

    def __init__(self, downstream: str = "rerank",
                 depth_capacity: float = 64.0, alpha: float = 0.35,
                 expiry_weight: float = 8.0,
                 warmup_fn: Optional[Callable[[], bool]] = None,
                 warmup_quota: float = 0.25):
        self.downstream = downstream
        self.depth_capacity = depth_capacity
        self.alpha = alpha
        # deadline-expiry shedding signal (DESIGN.md §8.4): requests dying
        # of old age downstream are the most direct overload evidence
        # there is — weight each fresh expiration this many queue-depth
        # units when folding it into the quota
        self.expiry_weight = expiry_weight
        # recovery warm-up clamp (DESIGN.md §9): while ``warmup_fn()`` is
        # truthy (the substrate is replaying its delta log), admitted
        # quota is capped at ``warmup_quota`` regardless of how idle the
        # downstream looks — a just-restarted node serving from a cold
        # cache must not take full load before replay catches up. The
        # EWMA keeps integrating the real signal underneath, so the clamp
        # lifting is a step back to the true quota, not a cold restart of
        # the controller.
        self.warmup_fn = warmup_fn
        self.warmup_quota = warmup_quota
        self._q = 1.0
        self._last_expired = 0

    def observe(self, ctx) -> float:
        depth = (ctx.queue_depth(self.downstream)
                 if hasattr(ctx, "queue_depth") else 0)
        raw = self.depth_capacity / (depth + self.depth_capacity)
        if hasattr(ctx, "utilization"):
            util = ctx.utilization(self.downstream)
            if util > 1.0:      # demand exceeds service capacity: clamp hard
                raw = min(raw, 1.0 / (util * util))
        if hasattr(ctx, "total_expired"):
            exp = ctx.total_expired()
            d_exp = exp - self._last_expired
            self._last_expired = exp
            if d_exp > 0:       # requests are expiring NOW: cut quota like
                # an equivalent queue-depth surge would
                raw = min(raw, self.depth_capacity
                          / (self.depth_capacity + self.expiry_weight * d_exp))
        self._q += self.alpha * (raw - self._q)
        q = float(np.clip(self._q, 0.02, 1.2))
        if self.warmup_fn is not None and self.warmup_fn():
            q = min(q, self.warmup_quota)
        return q

    @property
    def value(self) -> float:
        return float(np.clip(self._q, 0.02, 1.2))


class OnlineShedder:
    """SEDP-stage wrapper: reads system feedback → quota, prunes candidate
    lists in event payloads (payload["candidates"] = list of (item, score)).

    Two hooks into the serving loop:
      * ``op`` — the in-pipeline stage (quota-aware per-request pruning);
      * ``on_overflow`` — the bounded-channel overflow policy (SimExecutor):
        a full downstream queue offers the event here, which hard-prunes it
        to ``min_keep`` or, when nothing is left to prune, sheds the whole
        request (returns None).
    """

    def __init__(self, dnn: PruningDNN, capacity_qps_proxy: float = 100.0,
                 min_keep: int = 12, downstream: str = "rerank",
                 controller: Optional[QuotaController] = None):
        self.dnn = dnn
        self.capacity = capacity_qps_proxy
        self.min_keep = min_keep
        self.downstream = downstream
        self.controller = controller
        self.state = ShedderState()

    def quota(self, queue_depth: int) -> float:
        return float(np.clip(self.capacity / (queue_depth + self.capacity), 0.02, 1.2))

    def current_quota(self, ctx) -> float:
        if self.controller is not None:
            return self.controller.observe(ctx)
        depth = (ctx.queue_depth(self.downstream)
                 if hasattr(ctx, "queue_depth") else 0)
        return self.quota(depth)

    def on_overflow(self, stage: str, ev, ctx):
        """Bounded-channel overflow hook. Prune hard; drop when already
        minimal. Returning None sheds the request at the channel.

        Accounting: candidates the shed stage already tallied (meta marker)
        MOVE from kept to shed here — counting them afresh would make
        shed+kept exceed the candidates that ever existed."""
        cands = (ev.payload.get("candidates")
                 if hasattr(ev.payload, "get") else None)
        counted = bool(ev.meta.get("shed_accounted")) if cands else False
        if cands and len(cands) > self.min_keep:
            scores = np.array([c[1] for c in cands], np.float32)
            order = np.argsort(-scores)[:self.min_keep]
            kept = [cands[i] for i in order]
            n_shed = len(cands) - len(kept)
            self.state.shed_events += n_shed
            if counted:
                self.state.kept_events -= n_shed
            else:
                self.state.kept_events += len(kept)
                ev.meta["shed_accounted"] = True
            self.state.overflow_pruned += 1
            ev.payload["candidates"] = kept
            ev.meta["overflow_pruned"] = True
            return ev
        if counted and cands:            # whole request (and its candidates)
            self.state.shed_events += len(cands)   # sheds at the channel
            self.state.kept_events -= len(cands)
        self.state.dropped_requests += 1
        return None

    def op(self, batch, ctx):
        q = self.current_quota(ctx)
        for ev in batch:
            cands = ev.payload.get("candidates", [])
            if not cands:
                continue
            scores = np.array([c[1] for c in cands], np.float32)
            feats = features_from(scores, q, self.state.prev_cutoff,
                                  ev.req_id)
            cut = float(self.dnn(feats[None])[0])
            keep = max(self.min_keep, int(len(cands) * (1.0 - cut)))
            order = np.argsort(-scores)
            kept = [cands[i] for i in order[:keep]]
            self.state.shed_events += len(cands) - len(kept)
            self.state.kept_events += len(kept)
            self.state.prev_cutoff = cut
            ev.payload["candidates"] = kept
            ev.meta["cutoff_ratio"] = cut
            ev.meta["shed_accounted"] = True
            annotate(ev, cutoff_ratio=round(cut, 4),
                     shed=len(cands) - len(kept), kept=len(kept))
        return batch
