"""Online load shedding via a pruning DNN (paper §6.2, Fig. 6, Table 7).

Funnel context: recall hands ~10³ candidates per request to the expensive
re-rank stage; only ~a dozen are shown. When traffic exceeds capacity, prune
low-quality candidates per-request, bounded by a recommendation-effectiveness
constraint |L* − L̂| ≤ ε (Eq. 2).

  * Features (Table 7): quota (available resource), previous cutoff ratio,
    queue id, and the recall-score statistics (avg/var/max/min).
  * The pruning DNN is an ultra-lightweight MLP (decides in ~μs) trained to
    imitate the ORACLE cutoff: the largest prune such that the expected
    recall@K loss ≤ ε, shrunk further as quota tightens.
  * Candidates are sorted by recall score; everything behind the cutoff is
    dropped before re-rank.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import mlp_tower_apply, mlp_tower_init

FEATURES = ("quota", "cutoff_ratio_prev", "qid",
            "escore_avg", "escore_variance", "escore_max", "escore_min")


def features_from(scores: np.ndarray, quota: float, prev_cutoff: float,
                  qid: int) -> np.ndarray:
    return np.array([quota, prev_cutoff, float(qid % 16) / 16.0,
                     float(scores.mean()), float(scores.var()),
                     float(scores.max()), float(scores.min())], np.float32)


def oracle_cutoff(scores: np.ndarray, quota: float, eps: float,
                  k: int = 12) -> float:
    """Max prune ratio with bounded effectiveness loss: keep every candidate
    that could plausibly reach the final top-k (score within the ε-quantile
    band of the k-th best), then shed further only as quota forces it."""
    s = np.sort(scores)[::-1]
    n = len(s)
    kth = s[min(k, n) - 1]
    # ε-band: items scoring within eps-quantile of the k-th score may reorder
    # under the re-rank model; they must survive
    thresh = kth - eps * (s[0] - s[-1] + 1e-9)
    must_keep = int(np.sum(s >= thresh))
    quota_keep = int(np.ceil(n * min(1.0, max(quota, 0.02))))
    keep = max(k, min(n, max(must_keep, quota_keep) if quota >= 1.0
                      else max(k, min(must_keep, quota_keep))))
    keep = max(keep, k)
    return 1.0 - keep / n


class PruningDNN:
    """7 → 32 → 16 → 1 sigmoid MLP: predicts the cutoff ratio."""

    def __init__(self, seed: int = 0):
        self.params = mlp_tower_init(jax.random.PRNGKey(seed), len(FEATURES),
                                     (32, 16, 1), jnp.float32)
        self.x_mean = np.zeros(len(FEATURES), np.float32)
        self.x_std = np.ones(len(FEATURES), np.float32)

        def fwd(params, x):
            return jax.nn.sigmoid(
                mlp_tower_apply(params, x, act="silu")[..., 0])

        self._fwd = jax.jit(fwd)

        def loss(params, x, y):
            return jnp.mean((fwd(params, x) - y) ** 2)

        self._grad = jax.jit(jax.value_and_grad(loss))

    def __call__(self, feats: np.ndarray) -> np.ndarray:
        x = (np.atleast_2d(feats) - self.x_mean) / self.x_std
        return np.asarray(self._fwd(self.params, jnp.asarray(x)))

    def fit(self, X: np.ndarray, y: np.ndarray, steps: int = 2000,
            lr: float = 3e-3, seed: int = 0) -> float:
        rng = np.random.default_rng(seed)
        # feature standardization (quota ~O(1) but variance features are not)
        self.x_mean = X.mean(0)
        self.x_std = X.std(0) + 1e-6
        Xn = (X - self.x_mean) / self.x_std
        Xj, yj = jnp.asarray(Xn), jnp.asarray(y)
        m = jax.tree.map(jnp.zeros_like, self.params)
        v = jax.tree.map(jnp.zeros_like, self.params)
        for step in range(steps):
            idx = jnp.asarray(rng.integers(0, Xj.shape[0], 256))
            l, g = self._grad(self.params, Xj[idx], yj[idx])
            m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
            v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
            self.params = jax.tree.map(
                lambda p, mm, vv: p - lr * mm / (jnp.sqrt(vv) + 1e-8),
                self.params, m, v)
        final, _ = self._grad(self.params, Xj, yj)
        return float(final)


def train_pruning_dnn(n_samples: int = 4000, eps: float = 0.05,
                      seed: int = 0) -> tuple[PruningDNN, float]:
    """Generate oracle-labelled synthetic funnel traffic and fit the DNN."""
    rng = np.random.default_rng(seed)
    X, y = [], []
    prev = 0.0
    for i in range(n_samples):
        # candidate-count and score distributions matched to the serving
        # traffic (lognormal funnel sizes; mixed score shapes)
        n = int(np.clip(rng.lognormal(np.log(120), 1.0), 8, 2000))
        mode = rng.choice(4)
        if mode == 0:
            scores = rng.beta(2, 5, n)
        elif mode == 1:
            scores = rng.beta(5, 2, n)
        elif mode == 2:
            scores = rng.random(n)
        else:
            scores = rng.normal(0.5, 0.15, n).clip(0, 1)
        quota = float(rng.uniform(0.02, 1.2))
        cut = oracle_cutoff(scores, quota, eps)
        X.append(features_from(scores, quota, prev, i))
        y.append(cut)
        prev = cut
    dnn = PruningDNN(seed)
    mse = dnn.fit(np.stack(X), np.array(y, np.float32))
    return dnn, mse


@dataclass
class ShedderState:
    prev_cutoff: float = 0.0
    shed_events: int = 0
    kept_events: int = 0


class OnlineShedder:
    """SEDP-stage wrapper: reads queue depth → quota, prunes candidate lists
    in event payloads (payload["candidates"] = list of (item, score))."""

    def __init__(self, dnn: PruningDNN, capacity_qps_proxy: float = 100.0,
                 min_keep: int = 12, downstream: str = "rerank"):
        self.dnn = dnn
        self.capacity = capacity_qps_proxy
        self.min_keep = min_keep
        self.downstream = downstream
        self.state = ShedderState()

    def quota(self, queue_depth: int) -> float:
        return float(np.clip(self.capacity / (queue_depth + self.capacity), 0.02, 1.2))

    def op(self, batch, ctx):
        depth = (ctx.queue_depth(self.downstream)
                 if hasattr(ctx, "queue_depth") else 0)
        q = self.quota(depth)
        for ev in batch:
            cands = ev.payload.get("candidates", [])
            if not cands:
                continue
            scores = np.array([c[1] for c in cands], np.float32)
            feats = features_from(scores, q, self.state.prev_cutoff,
                                  ev.req_id)
            cut = float(self.dnn(feats[None])[0])
            keep = max(self.min_keep, int(len(cands) * (1.0 - cut)))
            order = np.argsort(-scores)
            kept = [cands[i] for i in order[:keep]]
            self.state.shed_events += len(cands) - len(kept)
            self.state.kept_events += len(kept)
            self.state.prev_cutoff = cut
            ev.payload["candidates"] = kept
            ev.meta["cutoff_ratio"] = cut
        return batch
