"""CMA-ES with constraints (paper §6.1; cites Arnold & Hansen 2012).

Two pure-numpy optimizers:
  * ``cmaes_minimize``      — (μ/μw, λ)-CMA-ES (Hansen's standard strategy)
    with box bounds + black-box inequality constraints handled by adaptive
    penalty; restores the full SOLUTION PATH so the caller can re-validate
    constraint-satisfied minima on live traffic (paper: 5% of requests).
  * ``one_plus_one_cmaes``  — the (1+1)-CMA-ES with active constraint
    covariance downdates of Arnold & Hansen [GECCO'12], the exact variant
    the paper cites; used for the low-dimensional stage-level searches.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np


@dataclass
class PathPoint:
    x: np.ndarray
    f: float
    feasible: bool
    violation: float


@dataclass
class Result:
    x: np.ndarray
    f: float
    feasible: bool
    path: list[PathPoint] = field(default_factory=list)
    evaluations: int = 0

    def best_feasible_candidates(self, k: int = 5) -> list[PathPoint]:
        feas = [p for p in self.path if p.feasible]
        return sorted(feas, key=lambda p: p.f)[:k]


def _clip(x, lo, hi):
    return np.minimum(np.maximum(x, lo), hi)


def cmaes_minimize(f: Callable[[np.ndarray], float],
                   x0: np.ndarray, sigma0: float,
                   bounds: Sequence[tuple[float, float]],
                   constraints: Optional[Callable[[np.ndarray], np.ndarray]] = None,
                   budget: int = 2000, seed: int = 0,
                   penalty0: float = 10.0) -> Result:
    """constraints(x) → vector g(x); feasible iff all g ≤ 0."""
    rng = np.random.default_rng(seed)
    n = len(x0)
    lo = np.array([b[0] for b in bounds], float)
    hi = np.array([b[1] for b in bounds], float)
    span = hi - lo
    # normalized coordinates
    m = (np.asarray(x0, float) - lo) / span
    sigma = sigma0
    lam = 4 + int(3 * np.log(n))
    mu = lam // 2
    w = np.log(mu + 0.5) - np.log(np.arange(1, mu + 1))
    w /= w.sum()
    mu_eff = 1.0 / np.sum(w ** 2)
    cc = (4 + mu_eff / n) / (n + 4 + 2 * mu_eff / n)
    cs = (mu_eff + 2) / (n + mu_eff + 5)
    c1 = 2 / ((n + 1.3) ** 2 + mu_eff)
    cmu = min(1 - c1, 2 * (mu_eff - 2 + 1 / mu_eff) / ((n + 2) ** 2 + mu_eff))
    damps = 1 + 2 * max(0, np.sqrt((mu_eff - 1) / (n + 1)) - 1) + cs
    chi_n = np.sqrt(n) * (1 - 1 / (4 * n) + 1 / (21 * n * n))

    pc = np.zeros(n)
    ps = np.zeros(n)
    C = np.eye(n)
    path: list[PathPoint] = []
    evals = 0
    penalty = penalty0
    best = Result(x=np.asarray(x0, float), f=np.inf, feasible=False, path=path)

    def eval_x(z_norm):
        nonlocal evals
        x = lo + _clip(z_norm, 0, 1) * span
        fx = float(f(x))
        g = np.asarray(constraints(x), float) if constraints else np.zeros(1)
        viol = float(np.maximum(g, 0).sum())
        feas = viol <= 1e-12
        evals += 1
        path.append(PathPoint(x.copy(), fx, feas, viol))
        return x, fx, viol, feas

    while evals < budget:
        try:
            A = np.linalg.cholesky(C + 1e-12 * np.eye(n))
        except np.linalg.LinAlgError:
            C = np.eye(n)
            A = np.eye(n)
        zs = rng.standard_normal((lam, n))
        ys = zs @ A.T
        xs_norm = m + sigma * ys
        scored = []
        for z_norm, y in zip(xs_norm, ys):
            x, fx, viol, feas = eval_x(z_norm)
            pen_f = fx + penalty * viol
            scored.append((pen_f, fx, viol, feas, y, x))
            if feas and fx < best.f:
                best.x, best.f, best.feasible = x.copy(), fx, True
            elif not best.feasible and not feas and fx + penalty * viol < best.f:
                best.x, best.f = x.copy(), fx + penalty * viol
        scored.sort(key=lambda s: s[0])
        sel = scored[:mu]
        y_w = np.sum([wi * s[4] for wi, s in zip(w, sel)], axis=0)
        m = _clip(m + sigma * y_w, 0, 1)
        # step-size + covariance adaptation
        A_inv = np.linalg.inv(A + 1e-12 * np.eye(n))
        ps = (1 - cs) * ps + np.sqrt(cs * (2 - cs) * mu_eff) * (A_inv @ y_w)
        sigma *= np.exp((cs / damps) * (np.linalg.norm(ps) / chi_n - 1))
        sigma = float(np.clip(sigma, 1e-8, 0.5))
        hs = np.linalg.norm(ps) / np.sqrt(
            1 - (1 - cs) ** (2 * evals / lam)) < (1.4 + 2 / (n + 1)) * chi_n
        pc = (1 - cc) * pc + hs * np.sqrt(cc * (2 - cc) * mu_eff) * y_w
        rank_mu = sum(wi * np.outer(s[4], s[4]) for wi, s in zip(w, sel))
        C = (1 - c1 - cmu) * C + c1 * np.outer(pc, pc) + cmu * rank_mu
        # adapt penalty: raise while infeasible solutions dominate
        frac_infeas = np.mean([0.0 if s[3] else 1.0 for s in scored])
        penalty *= 1.5 if frac_infeas > 0.6 else (0.9 if frac_infeas < 0.2 else 1.0)
        penalty = float(np.clip(penalty, 1e-3, 1e9))

    best.evaluations = evals
    return best


def one_plus_one_cmaes(f, x0, sigma0, bounds,
                       constraints=None, budget: int = 1000, seed: int = 0,
                       d: float = None, c_cov_plus: float = None,
                       c_constraint: float = 0.1, beta: float = 0.1) -> Result:
    """(1+1)-CMA-ES with active constraint handling [Arnold & Hansen 2012]:
    maintains Cholesky factor A; infeasible offspring update per-constraint
    exponentially-fading direction vectors v_j and DOWNDATE A along them."""
    rng = np.random.default_rng(seed)
    n = len(x0)
    lo = np.array([b[0] for b in bounds], float)
    hi = np.array([b[1] for b in bounds], float)
    span = hi - lo
    d = d or (1 + n / 2)
    c_cov_plus = c_cov_plus or (2 / (n * n + 6))
    p_target = 2 / 11
    x = (np.asarray(x0, float) - lo) / span
    sigma = sigma0
    A = np.eye(n)
    v: dict[int, np.ndarray] = {}
    p_succ = p_target
    path: list[PathPoint] = []
    evals = 0

    def full_eval(xn):
        nonlocal evals
        xx = lo + _clip(xn, 0, 1) * span
        g = np.asarray(constraints(xx), float) if constraints else np.zeros(1)
        feas = bool(np.all(g <= 0))
        fx = float(f(xx)) if feas else np.inf
        evals += 1
        path.append(PathPoint(xx.copy(), fx, feas, float(np.maximum(g, 0).sum())))
        return xx, fx, g, feas

    _, f_par, _, feas_par = full_eval(x)
    best = Result(x=lo + x * span, f=f_par if feas_par else np.inf,
                  feasible=feas_par, path=path)

    while evals < budget:
        z = rng.standard_normal(n)
        y = A @ z
        x_off = x + sigma * y
        xx, f_off, g, feas = full_eval(x_off)
        if not feas:
            # constraint-direction downdates (Arnold-Hansen eq. 5-7)
            for j in np.nonzero(g > 0)[0]:
                vj = v.get(j, np.zeros(n))
                vj = (1 - c_constraint) * vj + c_constraint * (A @ z)
                v[j] = vj
                wj = np.linalg.solve(A, vj)
                denom = np.dot(wj, wj)
                if denom > 1e-30:
                    A = A - (beta / len(v)) * np.outer(vj, wj) / denom
            sigma *= np.exp(-1.0 / d * p_succ / (1 - p_target))
            sigma = float(np.clip(sigma, 1e-9, 0.5))
            continue
        success = f_off <= f_par
        p_succ = (1 - 0.2) * p_succ + 0.2 * (1.0 if success else 0.0)
        sigma *= np.exp((1.0 / d) * (p_succ - p_target) / (1 - p_target))
        sigma = float(np.clip(sigma, 1e-9, 0.5))
        if success:
            x, f_par = x_off, f_off
            # rank-one update of A toward successful step
            a = np.sqrt(1 - c_cov_plus)
            norm2 = np.dot(z, z)
            if norm2 > 1e-30:
                b = a / norm2 * (np.sqrt(1 + c_cov_plus / (1 - c_cov_plus) * norm2) - 1)
                A = a * A + b * np.outer(A @ z, z)
            if f_off < best.f:
                best.x, best.f, best.feasible = lo + _clip(x, 0, 1) * span, f_off, True
    best.evaluations = evals
    return best
