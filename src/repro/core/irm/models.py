"""F^R / F^L — learned resource & latency predictors (paper §6.1).

"Ensembles of practical regression models, not naturally differentiable over
the parameter spaces, noisy and probably biased" — we use bagged ridge
regression over quadratic features (pure numpy): non-differentiable w.r.t.
the *system* parameters in any useful sense (hence CMA-ES), cheap to fit
from logs, and an ensemble whose spread models the noise the paper warns
about.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def quad_features(X: np.ndarray) -> np.ndarray:
    """[1, x, x², upper-triangle cross terms]"""
    n, d = X.shape
    cols = [np.ones((n, 1)), X, X ** 2]
    for i in range(d):
        for j in range(i + 1, d):
            cols.append((X[:, i] * X[:, j])[:, None])
    return np.concatenate(cols, axis=1)


@dataclass
class RidgeEnsemble:
    n_members: int = 8
    l2: float = 1e-3
    seed: int = 0

    def fit(self, X: np.ndarray, y: np.ndarray):
        rng = np.random.default_rng(self.seed)
        self.x_mean = X.mean(0)
        self.x_std = X.std(0) + 1e-9
        Phi = quad_features((X - self.x_mean) / self.x_std)
        self.coefs = []
        n = len(y)
        for _ in range(self.n_members):
            idx = rng.integers(0, n, n)                  # bootstrap bag
            P, t = Phi[idx], y[idx]
            A = P.T @ P + self.l2 * np.eye(P.shape[1])
            self.coefs.append(np.linalg.solve(A, P.T @ t))
        return self

    def predict(self, X: np.ndarray, with_std: bool = False):
        Phi = quad_features((np.atleast_2d(X) - self.x_mean) / self.x_std)
        preds = np.stack([Phi @ c for c in self.coefs])
        mean = preds.mean(0)
        if with_std:
            return mean, preds.std(0)
        return mean

    def __call__(self, x: np.ndarray) -> float:
        return float(self.predict(np.atleast_2d(x))[0])
