"""Cube cache (paper §5.2): two-tier local LFU over cube key-values.

  * memory tier  — hottest ~0.1% of keys, avoids even disk I/O
  * disk tier    — hottest ~1%, hides remote-cube network I/O
  * LFU replacement (paper's choice — access counts, not recency, match the
    heavy-tailed, slowly-drifting feature popularity of Fig. 5a)

The paper reports ~84% hit ratio, avoiding up to 90% of remote accesses →
~10% average latency reduction. benchmarks/fig8 reproduces this on Zipf
traffic.

Cache coherence with the streaming-update subsystem (DESIGN.md §6): every
entry is stamped with the cache ``version`` current at insert. A delta that
touches a set of signatures calls ``invalidate_keys`` (targeted — exactly
the touched keys drop, LFU statistics persist); a whole-generation hot swap
calls ``bump_generation`` (lazy — the floor rises and stale entries fall
out on their next probe, no O(capacity) sweep).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Optional


@dataclass
class TierStats:
    hits: int = 0
    misses: int = 0

    @property
    def hit_ratio(self):
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class _LFU:
    """O(log n) LFU via lazy heap; counts persist across evictions (paper
    replaces *entries*, not statistics)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.data: dict[Any, Any] = {}
        self.counts: dict[Any, int] = {}
        self._heap: list = []
        self._tick = itertools.count()

    def get(self, key):
        self.counts[key] = self.counts.get(key, 0) + 1
        if key in self.data:
            heapq.heappush(self._heap, (self.counts[key], next(self._tick), key))
            return self.data[key]
        return None

    def put(self, key, value):
        if self.capacity <= 0:
            return None
        evicted = None
        if key not in self.data and len(self.data) >= self.capacity:
            while self._heap:
                cnt, _, k = heapq.heappop(self._heap)
                if k in self.data and cnt >= self.counts.get(k, 0):
                    evicted = (k, self.data.pop(k))
                    break
            if evicted is None and self.data:
                k = min(self.data, key=lambda k: self.counts.get(k, 0))
                evicted = (k, self.data.pop(k))
        self.data[key] = value
        self.counts[key] = self.counts.get(key, 0) + 1
        heapq.heappush(self._heap, (self.counts[key], next(self._tick), key))
        return evicted


class TwoTierLFUCache:
    """get() probes memory → disk (promoting on disk hit); put() inserts to
    memory, demoting memory evictions to the disk tier. Values are stored
    internally as ``(version, value)`` — the version stamp is how a model
    generation swap invalidates the whole cache lazily while a delta batch
    invalidates exactly the keys it touched."""

    def __init__(self, mem_capacity: int, disk_capacity: int,
                 mem_latency_s: float = 1e-6, disk_latency_s: float = 40e-6):
        self.mem = _LFU(mem_capacity)
        self.disk = _LFU(disk_capacity)
        self.stats = {"mem": TierStats(), "disk": TierStats()}
        self.lat = {"mem": mem_latency_s, "disk": disk_latency_s}
        self.simulated_latency_s = 0.0
        self.version = 0           # stamp applied to inserts
        self._min_valid = 0        # entries stamped below this are stale
        self.invalidations = 0     # entries dropped by coherence events
        # bumped by every coherence event: the disk→mem promote checks it
        # so a hit that RACED an invalidation is not re-inserted (the
        # transient read is fine — equivalent to reading just before the
        # delta — but a resurrected entry would serve stale rows forever)
        self._inval_epoch = 0

    # ------------------------------------------------------- invalidation
    def invalidate_keys(self, keys) -> int:
        """Targeted coherence: drop exactly these keys from both tiers (a
        delta just rewrote their cube rows). LFU counts persist — the key's
        popularity did not change, only its value did. Returns drops."""
        self._inval_epoch += 1
        n = 0
        for key in keys:
            if self.mem.data.pop(key, None) is not None:
                n += 1
            if self.disk.data.pop(key, None) is not None:
                n += 1
        self.invalidations += n
        return n

    def bump_generation(self):
        """Whole-generation coherence (hot swap): raise the validity floor;
        every pre-bump entry becomes a miss on its next probe and is dropped
        then — O(1) now, no sweep over capacity."""
        self._inval_epoch += 1
        self.version += 1
        self._min_valid = self.version

    def _fresh(self, tier: _LFU, key, entry) -> bool:
        if entry[0] >= self._min_valid:
            return True
        tier.data.pop(key, None)          # lazily drop the stale entry
        self.invalidations += 1
        return False

    # ------------------------------------------------------------- access
    def get(self, key) -> Optional[Any]:
        v = self.mem.get(key)
        if v is not None and self._fresh(self.mem, key, v):
            self.stats["mem"].hits += 1
            self.simulated_latency_s += self.lat["mem"]
            return v[1]
        self.stats["mem"].misses += 1
        epoch = self._inval_epoch
        v = self.disk.get(key)
        if v is not None and self._fresh(self.disk, key, v):
            self.stats["disk"].hits += 1
            self.simulated_latency_s += self.lat["disk"]
            if self._inval_epoch == epoch:      # no invalidation raced us
                dem = self.mem.put(key, v)      # promote (stamp rides along)
                if dem is not None:
                    self.disk.put(*dem)
            return v[1]
        self.stats["disk"].misses += 1
        return None

    def put(self, key, value):
        dem = self.mem.put(key, (self.version, value))
        if dem is not None:
            self.disk.put(*dem)

    # ------------------------------------------------------------ batched
    def get_many(self, keys) -> list:
        """Multi-get for one batch: single pass with locally-bound tier
        methods, stats/latency folded in once at the end. Probe order per key
        is IDENTICAL to sequential get() calls — in particular a duplicate
        of a disk-resident key hits the memory tier after the first
        occurrence promotes it, not the disk tier twice. Returns a list
        aligned with ``keys`` (None per miss)."""
        mem_get, disk_get = self.mem.get, self.disk.get
        mem_put, disk_put = self.mem.put, self.disk.put
        fresh = self._fresh
        out = []
        mem_hits = mem_misses = disk_hits = disk_misses = 0
        lat = 0.0
        for key in keys:
            v = mem_get(key)
            if v is not None and fresh(self.mem, key, v):
                mem_hits += 1
                lat += self.lat["mem"]
                out.append(v[1])
                continue
            mem_misses += 1
            epoch = self._inval_epoch
            v = disk_get(key)
            if v is not None and fresh(self.disk, key, v):
                disk_hits += 1
                lat += self.lat["disk"]
                if self._inval_epoch == epoch:      # no raced invalidation
                    dem = mem_put(key, v)           # promote
                    if dem is not None:
                        disk_put(*dem)
                out.append(v[1])
            else:
                disk_misses += 1
                out.append(None)
        self.stats["mem"].hits += mem_hits
        self.stats["mem"].misses += mem_misses
        self.stats["disk"].hits += disk_hits
        self.stats["disk"].misses += disk_misses
        self.simulated_latency_s += lat
        return out

    def put_many(self, keys, values):
        """Vectorized multi-put: memory-tier inserts with demotions flushed
        to the disk tier, one pass for the whole batch."""
        mem_put, disk_put = self.mem.put, self.disk.put
        ver = self.version
        for key, value in zip(keys, values):
            dem = mem_put(key, (ver, value))
            if dem is not None:
                disk_put(*dem)

    @property
    def overall_hit_ratio(self) -> float:
        m, d = self.stats["mem"], self.stats["disk"]
        total = m.hits + m.misses
        return (m.hits + d.hits) / total if total else 0.0


def capacity_from_ratio(vocab: int, cache_ratio_pct: float,
                        mem_share: float = 0.1) -> tuple[int, int]:
    """Paper defaults: disk tier = cache_ratio (~1%) of keys, memory tier =
    top tenth of that (~0.1%). Both are offline-tunable (Table 6)."""
    disk = max(1, int(vocab * cache_ratio_pct / 100.0))
    mem = max(1, int(disk * mem_share))
    return mem, disk
