"""Versioned shard topology + deterministic signature→shard routing
(DESIGN.md §11.1).

The mesh partitions the parameter cube across N shards served by H
simulated hosts. Routing must be (a) deterministic — every replica,
every drill re-run, and the single-host oracle agree on which shard owns
a signature; (b) stable under topology REPUBLISH — bumping the topology
version (failover reorder, host add) must not move keys; and (c) minimal
under RESHARD — growing n_shards moves only the keys the new shard wins.
Rendezvous (highest-random-weight) hashing gives all three: each shard
scores ``mix64(sig ^ salt_shard)`` and the max score owns the key, so
removing/adding one shard only touches that shard's keys.

Topology changes follow the cube's snapshot-swap discipline: a
:class:`ShardTopology` is immutable; the :class:`ShardRouter` publishes a
whole new versioned object with ONE atomic reference swap (readers that
captured the old object keep routing against exactly it — no reader ever
sees shard assignments from one version with host preferences from
another).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Sequence

import numpy as np

__all__ = ["ShardTopology", "ShardRouter", "make_topology", "mix64"]

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)


def mix64(x) -> np.ndarray:
    """splitmix64 finalizer, vectorized over uint64 arrays — the routing
    hash. Bijective, so distinct signatures never collide into identical
    score vectors."""
    x = np.atleast_1d(np.asarray(x, np.uint64)).copy()
    with np.errstate(over="ignore"):
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
    return x


@dataclass(frozen=True)
class ShardTopology:
    """One immutable, versioned view of the mesh layout.

    ``assignments[s]`` lists host INDEXES (into ``hosts``) that hold a
    copy of shard ``s``, in routing-preference order — element 0 is the
    primary, the rest are failover targets. A failover is a republished
    topology with the dead host rotated to the back of every assignment;
    ``shard_of`` does not read ``assignments``, so the key→shard mapping
    is untouched by failover republishes."""
    version: int
    n_shards: int
    hosts: tuple              # host ids, e.g. ("host0", "host1", ...)
    assignments: tuple        # per shard: tuple of host indexes, pref order
    seed: int = 0

    def _salts(self) -> np.ndarray:
        with np.errstate(over="ignore"):
            base = np.uint64(self.seed) * _GOLDEN
            return mix64(np.arange(1, self.n_shards + 1, dtype=np.uint64)
                         + base)

    def shard_of(self, sigs) -> np.ndarray:
        """Vectorized rendezvous routing: (B,) uint64 signatures →
        (B,) int32 shard ids. Depends only on (n_shards, seed) — never on
        version or host assignments."""
        sigs = np.atleast_1d(np.asarray(sigs, np.uint64))
        scores = mix64((sigs[None, :] ^ self._salts()[:, None]).ravel())
        scores = scores.reshape(self.n_shards, sigs.size)
        return np.argmax(scores, axis=0).astype(np.int32)

    def hosts_for(self, shard: int) -> tuple:
        """Host ids holding ``shard``, preference order."""
        return tuple(self.hosts[i] for i in self.assignments[shard])

    # ------------------------------------------------------- derivations
    def with_version(self, version: int) -> "ShardTopology":
        return ShardTopology(version, self.n_shards, self.hosts,
                             self.assignments, self.seed)

    def with_host_down(self, host_id: str) -> "ShardTopology":
        """Failover derivation: the dead host drops to the BACK of every
        assignment (still listed — it may revive), version bumps. The
        signature→shard mapping is untouched."""
        hi = self.hosts.index(host_id)
        assignments = tuple(
            tuple([i for i in a if i != hi] + [i for i in a if i == hi])
            for a in self.assignments)
        return ShardTopology(self.version + 1, self.n_shards, self.hosts,
                             assignments, self.seed)

    def with_shards(self, n_shards: int) -> "ShardTopology":
        """Reshard derivation: same hosts/seed, new shard count (the
        rendezvous property bounds key movement to the new shard's wins)."""
        return make_topology(n_shards, self.hosts,
                             replication=max(len(a)
                                             for a in self.assignments),
                             version=self.version + 1, seed=self.seed)


def make_topology(n_shards: int, hosts: Sequence[str], replication: int = 2,
                  version: int = 1, seed: int = 0) -> ShardTopology:
    """Standard layout: shard ``s`` lives on hosts ``(s+r) % H`` for
    ``r < replication`` — the same rotation the cube uses for its
    in-process server replicas, one level up."""
    hosts = tuple(hosts)
    replication = min(replication, len(hosts))
    assignments = tuple(
        tuple((s + r) % len(hosts) for r in range(replication))
        for s in range(n_shards))
    return ShardTopology(version, n_shards, hosts, assignments, seed)


class ShardRouter:
    """Atomic topology publication + batch splitting.

    ``publish`` swaps the whole versioned topology object (monotonic
    versions enforced — a stale republish must never roll the mesh back);
    ``split`` routes one signature batch against ONE topology capture."""

    def __init__(self, topology: ShardTopology):
        self._topology = topology
        self._lock = threading.Lock()
        self.publishes = 0

    @property
    def topology(self) -> ShardTopology:
        return self._topology

    def publish(self, topology: ShardTopology) -> ShardTopology:
        with self._lock:
            if topology.version <= self._topology.version:
                raise ValueError(
                    f"topology version must advance: "
                    f"{topology.version} <= {self._topology.version}")
            self._topology = topology
            self.publishes += 1
        return topology

    def split(self, sigs) -> list:
        """Route a signature batch: returns ``[(shard, idx)]`` where
        ``idx`` indexes the input positions owned by ``shard`` (ascending
        shard order; empty shards omitted). One topology capture covers
        the whole batch."""
        topo = self._topology
        sigs = np.atleast_1d(np.asarray(sigs, np.uint64))
        if sigs.size == 0:
            return []
        shard = topo.shard_of(sigs)
        order = np.argsort(shard, kind="stable")
        sorted_shard = shard[order]
        bounds = np.searchsorted(sorted_shard,
                                 np.arange(topo.n_shards + 1))
        out = []
        for s in range(topo.n_shards):
            lo, hi = bounds[s], bounds[s + 1]
            if lo != hi:
                out.append((s, order[lo:hi]))
        return out
