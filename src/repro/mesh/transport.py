"""ShardClient / ShardHost transport boundary (DESIGN.md §11.2).

A :class:`ShardHost` is the access path to the shard cubes a simulated
host serves: an in-process thread pool standing in for the remote RPC
endpoint, with an injectable fault surface (``alive``,
``extra_latency_s``) that the host-level fault injector
(:class:`repro.faults.plan.HostFaultInjector`) drives mid-drill. Work
submitted to a dead host raises :class:`HostDown` — the transport-level
failure the client turns into failover + a host-level breaker trip.

The :class:`ShardClient` owns per-call routing policy:

  * host choice follows the topology's preference order, filtered by the
    ``(host, shard)``-keyed breaker registry (an OPEN breaker skips the
    host for free; a dead host costs ONE failed probe fleet-wide —
    ``record_host_failure`` trips every breaker of the host at once);
  * **hedged requests**: if the first host has not answered within
    ``hedge_after_s``, the same work is launched on the next preference
    host; the first response wins and the LOSER IS CANCELLED (its cancel
    event is set; a host checks it before touching the shard);
  * scatter: per-shard sub-batches of one lookup run concurrently on the
    client's pool, and every call records a fan-out entry (shard, host,
    key count, wall t0/t1, hedged) that the fetch stage turns into
    ``shard_fetch`` child spans.

Wall-clock latency injection (``time.sleep``) is opt-in per host
(``wall_latency=True``) — async/thread drills want real stalls, the
SimExecutor bench models the same latency on the virtual clock via its
service-time model instead.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Callable, Optional

__all__ = ["HostDown", "MeshUnavailable", "RequestCancelled", "ShardHost",
           "ShardClient"]


class HostDown(RuntimeError):
    """The submitted-to host is dead (transport-level failure)."""


class MeshUnavailable(RuntimeError):
    """No host holding the shard could serve the call."""


class RequestCancelled(Exception):
    """A hedged call lost the race and was cancelled before executing."""


class ShardHost:
    """One simulated host: a bounded worker pool + fault surface."""

    def __init__(self, host_id: str, n_workers: int = 2,
                 wall_latency: bool = False):
        self.host_id = host_id
        self.alive = True
        self.extra_latency_s = 0.0      # per-RPC latency injection
        self.wall_latency = wall_latency
        self.served = 0
        self.rejected = 0
        self.cancelled = 0
        self._pool = ThreadPoolExecutor(
            max_workers=n_workers, thread_name_prefix=f"mesh-{host_id}")

    def submit(self, fn: Callable, *args,
               cancel: Optional[threading.Event] = None):
        """Run ``fn(*args)`` on this host's pool. Checks the fault surface
        AT EXECUTION TIME (a kill landing while the call is queued still
        rejects it) and honours ``cancel`` both before and after any
        injected latency — a cancelled hedge loser never touches the
        shard."""
        def run():
            if cancel is not None and cancel.is_set():
                self.cancelled += 1
                raise RequestCancelled(self.host_id)
            if not self.alive:
                self.rejected += 1
                raise HostDown(self.host_id)
            if self.extra_latency_s > 0.0 and self.wall_latency:
                time.sleep(self.extra_latency_s)
            if cancel is not None and cancel.is_set():
                self.cancelled += 1
                raise RequestCancelled(self.host_id)
            if not self.alive:
                self.rejected += 1
                raise HostDown(self.host_id)
            out = fn(*args)
            self.served += 1
            return out
        return self._pool.submit(run)

    def shutdown(self):
        self._pool.shutdown(wait=False)


class ShardClient:
    """Routing + hedging + failover policy over a host fleet."""

    def __init__(self, hosts: dict, router, health=None,
                 hedge_after_s: Optional[float] = None,
                 scatter_workers: int = 8, clock=None):
        self.hosts = hosts              # host_id → ShardHost
        self.router = router
        self.health = health            # (host, shard)-keyed HealthRegistry
        self.hedge_after_s = hedge_after_s
        self.clock = clock or time.monotonic
        self._pool = ThreadPoolExecutor(max_workers=scatter_workers,
                                        thread_name_prefix="mesh-scatter")
        self._lock = threading.Lock()
        self.stats = {"calls": 0, "hedges": 0, "hedge_wins": 0,
                      "failovers": 0, "cancelled": 0, "host_failures": 0}

    # ------------------------------------------------------------ breakers
    def _allow(self, host_id: str, shard: int) -> bool:
        if self.health is None:
            return True
        try:
            breaker = self.health[(host_id, shard)]
        except KeyError:
            return True
        return breaker.allow_request(self.health.clock())

    def _record(self, host_id: str, shard: int, ok: bool):
        if self.health is None:
            return
        now = self.health.clock()
        if ok:
            try:
                self.health[(host_id, shard)].record_success(now)
            except KeyError:
                pass
        else:
            # a dead HOST is one strike fleet-wide: every (host, *)
            # breaker trips at once instead of paying one failed probe
            # per shard the host serves
            with self._lock:
                self.stats["host_failures"] += 1
            if hasattr(self.health, "record_host_failure"):
                self.health.record_host_failure(host_id, now)
            else:
                try:
                    self.health[(host_id, shard)].record_failure(now)
                except KeyError:
                    pass

    # ---------------------------------------------------------------- call
    def call(self, shard: int, fn: Callable):
        """Execute ``fn()`` on a host holding ``shard``. Returns
        ``(result, meta)`` with ``meta = {host, hedged, attempts}``.
        Raises :class:`MeshUnavailable` when every candidate fails."""
        topo = self.router.topology
        order = list(topo.hosts_for(shard))
        cands = [h for h in order if self._allow(h, shard)]
        if not cands:
            cands = order           # all breakers open: last-resort probes
        with self._lock:
            self.stats["calls"] += 1
        inflight: list = []         # (future, host_id, cancel, is_hedge)
        seq = 0
        errors: list = []

        def launch(host_id, is_hedge=False):
            nonlocal seq
            cancel = threading.Event()
            fut = self.hosts[host_id].submit(fn, cancel=cancel)
            inflight.append((fut, host_id, cancel, is_hedge))
            seq += 1

        launch(cands[0])
        next_cand = 1
        while True:
            hedge = (self.hedge_after_s
                     if (self.hedge_after_s is not None
                         and next_cand < len(cands) and len(inflight) == 1)
                     else None)
            done, _ = wait([f for f, *_ in inflight], timeout=hedge,
                           return_when=FIRST_COMPLETED)
            if not done:            # hedge window expired: race a second host
                with self._lock:
                    self.stats["hedges"] += 1
                launch(cands[next_cand], is_hedge=True)
                next_cand += 1
                continue
            for entry in list(inflight):
                fut, host_id, cancel, is_hedge = entry
                if not fut.done():
                    continue
                inflight.remove(entry)
                try:
                    out = fut.result()
                except RequestCancelled:
                    continue
                except HostDown:
                    self._record(host_id, shard, ok=False)
                    errors.append(host_id)
                    continue
                self._record(host_id, shard, ok=True)
                for _f2, _h2, c2, _s2 in inflight:
                    c2.set()        # first response wins: cancel the rest
                    with self._lock:
                        self.stats["cancelled"] += 1
                if is_hedge:
                    with self._lock:
                        self.stats["hedge_wins"] += 1
                return out, {"host": host_id, "hedged": is_hedge,
                             "attempts": seq}
            if not inflight:
                if next_cand < len(cands):
                    with self._lock:
                        self.stats["failovers"] += 1
                    launch(cands[next_cand])
                    next_cand += 1
                else:
                    raise MeshUnavailable(
                        f"shard {shard}: no live host among {order} "
                        f"(failed: {errors})")

    # ------------------------------------------------------------- scatter
    def scatter(self, calls: list) -> list:
        """Run ``[(shard, fn)]`` concurrently; returns
        ``[(shard, result_or_None, meta)]`` in input order. A shard whose
        every host is down yields ``result=None`` with
        ``meta["failed"]=True`` — the mesh lookup degrades that sub-batch
        to the default tier instead of failing the whole gather."""
        def one(shard, fn):
            t0 = self.clock()
            try:
                out, meta = self.call(shard, fn)
            except MeshUnavailable:
                out, meta = None, {"host": None, "hedged": False,
                                   "failed": True}
            meta.setdefault("failed", False)
            meta["t0"], meta["t1"] = t0, self.clock()
            return shard, out, meta
        if len(calls) == 1:
            return [one(*calls[0])]
        futs = [self._pool.submit(one, s, fn) for s, fn in calls]
        return [f.result() for f in futs]

    def shutdown(self):
        self._pool.shutdown(wait=False)
        for h in self.hosts.values():
            h.shutdown()
