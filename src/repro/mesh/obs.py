"""Mesh-level metrics: per-shard / per-host / per-replica labeled
families on the existing :class:`~repro.obs.metrics.MetricsRegistry`
collector contract (``fn() -> {label_tuple: value}``)."""
from __future__ import annotations

__all__ = ["register_mesh_collectors"]


def register_mesh_collectors(registry, mesh=None, fleet=None):
    """Register mesh/fleet gauge families. Safe to call with either side
    absent. Families:

      * ``jizhi_mesh_shard_calls`` / ``_rows`` / ``_degraded_rows``
        labeled ``{shard=<s>}`` — data-plane traffic per shard;
      * ``jizhi_mesh_host_alive`` / ``_served`` labeled ``{host=<id>}``;
      * ``jizhi_mesh_client_<stat>`` (hedges, hedge_wins, failovers, …);
      * ``jizhi_mesh_topology_version``;
      * ``jizhi_fleet_replica_routed`` / ``_alive`` labeled
        ``{replica=<name>}``.
    """
    if mesh is not None:
        def shard_family(field):
            def collect():
                return {(("shard", str(s)),): float(st[field])
                        for s, st in enumerate(mesh.shard_stats)}
            return collect
        for fld in ("calls", "rows", "degraded_rows"):
            registry.collector(f"mesh_shard_{fld}", shard_family(fld))
        registry.collector(
            "mesh_host_alive",
            lambda: {(("host", hid),): float(h.alive)
                     for hid, h in mesh.hosts.items()})
        registry.collector(
            "mesh_host_served",
            lambda: {(("host", hid),): float(h.served)
                     for hid, h in mesh.hosts.items()})
        registry.collector(
            "mesh_topology_version",
            lambda: {(): float(mesh.router.topology.version)})
        registry.collector(
            "mesh_version",
            lambda: {(): float(mesh.version)})

        def client_stats():
            return {(("stat", k),): float(v)
                    for k, v in mesh.client.stats.items()}
        registry.collector("mesh_client", client_stats)
    if fleet is not None:
        registry.collector(
            "fleet_replica_routed",
            lambda: {(("replica", r.name),): float(r.routed)
                     for r in fleet.replicas})
        registry.collector(
            "fleet_replica_alive",
            lambda: {(("replica", r.name),): float(r.alive)
                     for r in fleet.replicas})
    return registry
