"""MeshCube: the parameter cube partitioned across simulated hosts
(DESIGN.md §11.3).

One :class:`MeshCube` owns N :class:`~repro.core.cube.ParameterCube`
shards, a :class:`~repro.mesh.topology.ShardRouter`, and a
:class:`~repro.mesh.transport.ShardClient` over H :class:`ShardHost`
endpoints. It duck-types the exact cube surface `CubeFetchStage` and
`UpdateManager` consume — ``pin()`` / ``lookup`` / ``lookup_ex`` /
``contains`` / ``version`` / ``row_shape`` / ``apply_batch`` /
``load_table`` / ``overlay_blocks`` / ``compact`` — so the whole serving
and update plane runs against a mesh unchanged.

**Cross-shard pin semantics.** The single-host cube's batch-atomicity
(§6.6) comes from swapping ONE snapshot tuple. The mesh extends that
with a refcounted :class:`_MeshRecord`: at every mesh publish the writer
captures a pin of EVERY shard (each shard's own `pin()` discipline) and
swaps the record atomically. A reader pins the record, not the shards —
so one mesh pin yields a frozen cross-shard frontier: every shard read
resolves at exactly the shard version captured by one publish. A delta
batch is applied to all owning shards FIRST, and only then does the
topology-visible mesh version bump — no reader can observe group g's
rows on shard A new and group h's rows on shard B old from the same
batch. Retired records release their shard pins when the last reader
drains, letting each shard's compactor reclaim as usual.

**Data vs control plane.** Row reads (`lookup`/`lookup_ex`) cross the
ShardClient transport boundary — they pay host faults, hedging, and
failover. Membership probes (`contains`) resolve against the shard
primary indexes directly: per the paper the key index is all-in-memory
and replicated to routers, so membership is a local metadata check (and
a dead host must degrade DATA reads to `TIER_DEFAULT`, never flip
membership to "absent", which would turn outage zeros into authoritative
tombstones).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import numpy as np

from repro.core.cube import (TIER_DEFAULT, ParameterCube, PinnedVersion)
from repro.sparse.hashing import signature_np

from .topology import ShardRouter, ShardTopology, make_topology
from .transport import ShardClient, ShardHost

__all__ = ["MeshCube", "_MeshRecord"]


class _MeshRecord:
    """One published cross-shard frontier: the mesh version plus a live
    pin on every shard at the versions captured together. Indexable at
    ``[0]`` (the version) so `UpdateManager.pinned_capture`'s
    ``PinnedVersion(snap)`` / ``snap[0]`` contract holds verbatim."""

    __slots__ = ("version", "shard_pins", "shard_versions", "_stack",
                 "refs", "closed")

    def __init__(self, version: int, shard_pins: list,
                 stack: contextlib.ExitStack):
        self.version = version
        self.shard_pins = shard_pins          # per-shard PinnedVersion
        self.shard_versions = tuple(p.version for p in shard_pins)
        self._stack = stack
        self.refs = 0
        self.closed = False

    def __getitem__(self, i: int) -> int:
        if i == 0:
            return self.version
        raise IndexError(i)

    def close(self):
        if not self.closed:
            self.closed = True
            self._stack.close()               # releases every shard pin


class MeshCube:
    """Sharded, host-distributed parameter cube behind the cube API."""

    is_mesh = True

    def __init__(self, n_shards: int = 4, n_hosts: int = 4,
                 replication: int = 2, seed: int = 0,
                 hedge_after_s: Optional[float] = None,
                 wall_latency: bool = False, host_workers: int = 2,
                 n_servers: int = 2, cube_replication: int = 2,
                 block_rows: int = 65536, **cube_kwargs):
        self.n_shards = n_shards
        self.shards = [ParameterCube(n_servers=n_servers,
                                     replication=cube_replication,
                                     block_rows=block_rows, **cube_kwargs)
                       for _ in range(n_shards)]
        host_ids = tuple(f"host{h}" for h in range(n_hosts))
        self.hosts = {hid: ShardHost(hid, n_workers=host_workers,
                                     wall_latency=wall_latency)
                      for hid in host_ids}
        self.host_list = [self.hosts[hid] for hid in host_ids]
        self.router = ShardRouter(make_topology(
            n_shards, host_ids, replication=replication, seed=seed))
        self.health = None
        self.client = ShardClient(self.hosts, self.router, health=None,
                                  hedge_after_s=hedge_after_s)
        self._shapes: dict[int, tuple] = {}
        self._w_lock = threading.RLock()      # serializes mesh mutations
        self._pin_lock = threading.Lock()
        self._records: dict[int, _MeshRecord] = {}
        self._record = self._capture(0)
        self._records[0] = self._record
        self.publishes = 0
        # per-shard data-plane counters (metrics collectors read these)
        self.shard_stats = [{"calls": 0, "rows": 0, "degraded_rows": 0}
                            for _ in range(n_shards)]
        self._fanout = threading.local()

    # ----------------------------------------------------------- publish
    def _capture(self, version: int) -> _MeshRecord:
        stack = contextlib.ExitStack()
        pins = [stack.enter_context(s.pin()) for s in self.shards]
        return _MeshRecord(version, pins, stack)

    def _republish(self) -> int:
        """Swap in a fresh cross-shard frontier. Called after every mesh
        mutation, with all shard-local publishes already complete — the
        §6.6 extension: the delta is on every owning shard before the
        topology-visible version bumps."""
        with self._w_lock:
            new = self._capture(self._record.version + 1)
            with self._pin_lock:
                old = self._record
                self._record = new
                self._records[new.version] = new
                if old.refs <= 0:
                    self._records.pop(old.version, None)
                    old.close()
            self.publishes += 1
            return new.version

    # --------------------------------------------------------------- pin
    @property
    def version(self) -> int:
        return self._record.version

    def _pin_current(self):
        with self._pin_lock:
            rec = self._record
            rec.refs += 1
        return rec

    def _pin_release(self, ver: int):
        with self._pin_lock:
            rec = self._records.get(ver)
            if rec is None:
                return
            rec.refs -= 1
            if rec.refs <= 0 and rec is not self._record:
                self._records.pop(ver, None)
                rec.close()

    @contextlib.contextmanager
    def pin(self):
        """Pin the published cross-shard frontier: every shard lookup made
        with the handle resolves at the shard versions captured by ONE
        mesh publish, while deltas/failovers land concurrently."""
        rec = self._pin_current()
        try:
            yield PinnedVersion(rec)
        finally:
            self._pin_release(rec.version)

    @staticmethod
    def _rec_of(version) -> Optional[_MeshRecord]:
        return version.snap if version is not None else None

    # ------------------------------------------------------------- reads
    def row_shape(self, group: int) -> Optional[tuple]:
        return self._shapes.get(group)

    def _take_fanout_sink(self) -> list:
        sink = getattr(self._fanout, "records", None)
        if sink is None:
            sink = self._fanout.records = []
        return sink

    def take_fanout(self) -> list:
        """Drain this thread's per-shard fan-out records (appended by the
        last `lookup_ex` on this thread) — the fetch stage turns them into
        ``shard_fetch`` child spans."""
        sink = self._take_fanout_sink()
        out, sink[:] = list(sink), []
        return out

    def lookup_ex(self, group: int, raw_ids,
                  version: Optional[PinnedVersion] = None):
        """Scatter/gather degradation-aware read. Sub-batches fan out to
        the owning shards' hosts concurrently; each travels with that
        shard's pin from the mesh record, so the merged batch is one
        consistent cross-shard frontier. A shard with no live host
        degrades to zeros + ``TIER_DEFAULT`` (the §8 ladder), never an
        error."""
        raw = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
        rec = self._rec_of(version)
        self_pinned = rec is None
        if self_pinned:
            rec = self._pin_current()
        try:
            dim, dtype = self._shapes.get(group, (0, np.float32))
            if raw.size == 0:
                return (np.empty((0, dim), dtype), np.empty(0, np.int8))
            sigs = signature_np(group, raw)
            parts = self.router.split(sigs)
            calls = []
            for s, idx in parts:
                shard, pin = self.shards[s], rec.shard_pins[s]
                calls.append((s, (lambda sh=shard, ids=raw[idx], pv=pin:
                                  sh.lookup_ex(group, ids, version=pv))))
            results = self.client.scatter(calls)
            rows = np.zeros((raw.size, dim), dtype)
            tiers = np.full(raw.size, TIER_DEFAULT, np.int8)
            sink = self._take_fanout_sink()
            for (s, idx), (_s, out, meta) in zip(parts, results):
                st = self.shard_stats[s]
                st["calls"] += 1
                st["rows"] += int(idx.size)
                if out is None:          # every host down: stay degraded
                    st["degraded_rows"] += int(idx.size)
                else:
                    r, t = out
                    rows[idx] = r
                    tiers[idx] = t
                sink.append({"shard": s, "host": meta.get("host"),
                             "n_keys": int(idx.size),
                             "hedged": bool(meta.get("hedged")),
                             "failed": bool(meta.get("failed")),
                             "t0": meta["t0"], "t1": meta["t1"]})
            return rows, tiers
        finally:
            if self_pinned:
                self._pin_release(rec.version)

    def lookup(self, group: int, raw_ids,
               version: Optional[PinnedVersion] = None) -> np.ndarray:
        rows, _ = self.lookup_ex(group, raw_ids, version=version)
        return rows

    def contains(self, group: int, raw_ids,
                 version: Optional[PinnedVersion] = None) -> np.ndarray:
        """Local metadata probe against each owning shard's primary index
        at the pinned frontier (see module docstring for why this does
        not cross the transport)."""
        raw = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
        rec = self._rec_of(version)
        self_pinned = rec is None
        if self_pinned:
            rec = self._pin_current()
        try:
            out = np.zeros(raw.size, bool)
            if raw.size == 0:
                return out
            for s, idx in self.router.split(signature_np(group, raw)):
                out[idx] = self.shards[s].contains(
                    group, raw[idx], version=rec.shard_pins[s])
            return out
        finally:
            if self_pinned:
                self._pin_release(rec.version)

    # ------------------------------------------------------------ writes
    def load_table(self, group: int, table: np.ndarray,
                   raw_ids: Optional[np.ndarray] = None) -> int:
        table = np.asarray(table)
        ids = np.asarray(raw_ids) if raw_ids is not None \
            else np.arange(table.shape[0])
        ids = np.atleast_1d(ids).reshape(-1)
        with self._w_lock:
            self._shapes[group] = (table.shape[1], table.dtype)
            for s, idx in self.router.split(signature_np(group, ids)):
                self.shards[s].load_table(group, table[idx],
                                          raw_ids=ids[idx])
            return self._republish()

    def apply_batch(self, parts) -> int:
        """Split one delta batch per owning shard, apply every shard-local
        batch (each its own §6.6 atomic shard publish), THEN bump the
        mesh version with one record swap — readers pinning the old
        record keep the whole old frontier; readers pinning the new one
        see the whole batch on every shard."""
        parts = list(parts)
        with self._w_lock:
            shapes = dict(self._shapes)
            norm = []
            for group, raw_ids, rows, delete_ids in parts:
                ids = vals = dels = None
                if raw_ids is not None and np.asarray(raw_ids).size:
                    ids = np.atleast_1d(np.asarray(raw_ids)).reshape(-1)
                    vals = np.asarray(rows)
                    if vals.ndim != 2 or vals.shape[0] != ids.size:
                        raise ValueError(
                            f"rows shape {vals.shape} does not match "
                            f"{ids.size} upsert ids")
                    dim, dtype = shapes.get(group,
                                            (vals.shape[1], vals.dtype))
                    if vals.shape[1] != dim:
                        raise ValueError(
                            f"group {group} rows are dim {dim}, delta has "
                            f"{vals.shape[1]}")
                    shapes[group] = (dim, dtype)
                if delete_ids is not None and np.asarray(delete_ids).size:
                    dels = np.atleast_1d(np.asarray(delete_ids)).reshape(-1)
                norm.append((group, ids, vals, dels))
            shard_parts: dict[int, list] = {}
            for group, ids, vals, dels in norm:
                per_shard: dict[int, list] = {}
                if ids is not None:
                    for s, idx in self.router.split(
                            signature_np(group, ids)):
                        per_shard.setdefault(s, [None, None])[0] = \
                            (ids[idx], vals[idx])
                if dels is not None:
                    for s, idx in self.router.split(
                            signature_np(group, dels)):
                        per_shard.setdefault(s, [None, None])[1] = dels[idx]
                for s, (up, dl) in per_shard.items():
                    u_ids, u_rows = up if up is not None else (None, None)
                    shard_parts.setdefault(s, []).append(
                        (group, u_ids, u_rows, dl))
            for s, sp in sorted(shard_parts.items()):
                self.shards[s].apply_batch(sp)
            self._shapes = shapes
            return self._republish()

    def apply_delta(self, group: int, raw_ids=None, rows=None,
                    delete_ids=None) -> int:
        return self.apply_batch([(group, raw_ids, rows, delete_ids)])

    # ------------------------------------------------------- maintenance
    @property
    def overlay_blocks(self) -> int:
        return sum(s.overlay_blocks for s in self.shards)

    def compact(self, max_rows_per_pass: Optional[int] = None) -> int:
        with self._w_lock:
            total = sum(s.compact(max_rows_per_pass=max_rows_per_pass)
                        for s in self.shards)
            self._republish()
            return total

    def reclaim(self):
        for s in self.shards:
            with s._p_lock:
                s.reclaim()

    # ------------------------------------------------------ fleet control
    def attach_health(self, registry):
        """Attach a ``(host, shard)``-keyed HealthRegistry the transport
        consults before probing a host (one dead host = one strike
        fleet-wide via ``record_host_failure``)."""
        self.health = registry
        self.client.health = registry
        return registry

    def kill_host(self, host_id: str):
        self.hosts[host_id].alive = False

    def revive_host(self, host_id: str):
        self.hosts[host_id].alive = True

    def fail_over(self, host_id: str) -> ShardTopology:
        """Control-plane failover: republish the topology with the dead
        host demoted to the back of every preference list. The
        signature→shard mapping is untouched — no keys move, no reader
        re-pins."""
        return self.router.publish(
            self.router.topology.with_host_down(host_id))

    def shutdown(self):
        self.client.shutdown()
