"""Replica fleet + least-loaded balancer (DESIGN.md §11.4).

Data-parallel scenario replicas — M copies of the same stage chain in
ONE executor plan — sit behind a :class:`FleetBalancer`. The balancer's
``pick`` policy is (1) liveness: a killed replica receives ZERO new
arrivals (its already-queued events still drain through its stages);
(2) health: an open breaker for the replica (``(replica, "entry")``-keyed
:class:`~repro.faults.health.HealthRegistry`) skips it like a dead one;
(3) load: among the live candidates, route to the replica with the
shallowest entry queue (`ExecContext.queue_depth` — the same per-replica
`StageStats` signal the quota controller reads). Ties break
round-robin so equal-load replicas share traffic instead of pile-on.

Wire it into a plan with
:func:`repro.core.multitenant.make_balance_op(balancer.pick)` on a
dispatch stage whose successors are the replica entry stages.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional

__all__ = ["Replica", "FleetBalancer"]


@dataclass
class Replica:
    """One scenario-service replica: its entry stage in the shared plan
    plus balancer-visible state."""
    name: str
    entry: str                    # entry stage name in the executor plan
    alive: bool = True
    routed: int = 0               # arrivals the balancer sent here


class FleetBalancer:
    """Least-loaded, health-aware replica choice."""

    def __init__(self, replicas: list, health=None, clock=None):
        self.replicas = list(replicas)
        self.by_name = {r.name: r for r in self.replicas}
        self.health = health      # optional (replica, "entry")-keyed registry
        self.clock = clock
        self._lock = threading.Lock()
        self._rr = 0              # tie-break cursor
        self.unroutable = 0

    # ------------------------------------------------------------ control
    def kill(self, name: str):
        self.by_name[name].alive = False

    def revive(self, name: str):
        self.by_name[name].alive = True

    def _allowed(self, replica: Replica) -> bool:
        if not replica.alive:
            return False
        if self.health is None:
            return True
        try:
            breaker = self.health[(replica.name, "entry")]
        except KeyError:
            return True
        now = self.health.clock() if self.clock is None else self.clock()
        return breaker.allow_request(now)

    # --------------------------------------------------------------- pick
    def pick(self, ev, ctx) -> Optional[str]:
        """Balance-op policy: entry stage of the chosen replica, or None
        when no replica is routable."""
        with self._lock:
            live = [r for r in self.replicas if self._allowed(r)]
            if not live:
                self.unroutable += 1
                return None
            depth = {r.name: ctx.queue_depth(r.entry) for r in live}
            best = min(depth[r.name] for r in live)
            cands = [r for r in live if depth[r.name] == best]
            choice = cands[self._rr % len(cands)]
            self._rr += 1
            choice.routed += 1
            return choice.entry

    # ------------------------------------------------------------- stats
    def snapshot(self) -> dict:
        return {r.name: {"alive": r.alive, "routed": r.routed}
                for r in self.replicas}
