"""Multi-host serving mesh (DESIGN.md §11): sharded cube tier behind a
versioned rendezvous router, an in-process ShardHost/ShardClient
transport with hedging + breaker-aware failover, and a replicated
scenario fleet behind a least-loaded balancer."""
from .fleet import FleetBalancer, Replica
from .obs import register_mesh_collectors
from .sharded import MeshCube
from .topology import ShardRouter, ShardTopology, make_topology, mix64
from .transport import (HostDown, MeshUnavailable, RequestCancelled,
                        ShardClient, ShardHost)

__all__ = [
    "MeshCube", "ShardTopology", "ShardRouter", "make_topology", "mix64",
    "ShardHost", "ShardClient", "HostDown", "MeshUnavailable",
    "RequestCancelled", "FleetBalancer", "Replica",
    "register_mesh_collectors",
]
