"""Optimizers, self-contained (no optax): AdamW, Adafactor (factored second
moment — the only Adam-family choice whose state fits 671B on a 4 TB pod),
and row-wise Adagrad for embedding tables (recsys production standard:
one accumulator scalar per row, not per element).

A combined optimizer routes params by path: table leaves (2-D, huge vocab
rows) → rowwise adagrad; everything else → adamw/adafactor.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    inner: Any


# ------------------------------------------------------------------ AdamW

def adamw(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, wd=0.01):
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), {"m": z, "v": jax.tree.map(jnp.copy, z)})

    def update(grads, state, params):
        t = state.step + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state.inner["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state.inner["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m, v):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return (p.astype(jnp.float32) - lr * (step + wd * p.astype(jnp.float32))
                    ).astype(p.dtype)
        new_params = jax.tree.map(upd, params, m, v)
        return new_params, OptState(t, {"m": m, "v": v})

    return init, update


# --------------------------------------------------------------- Adafactor

def adafactor(lr=1e-2, eps=1e-30, clip=1.0, decay=0.8):
    """Shazeer & Stern [arXiv:1804.04235], factored second moment."""
    def factored(p):
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params):
        def st(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return OptState(jnp.zeros((), jnp.int32), jax.tree.map(st, params,
                        is_leaf=lambda x: isinstance(x, jax.Array)))

    # fp32 temporaries for a fused expert stack (e.g. (58,256,7168,f)) would
    # be several × param size — chunk huge leaves' updates over the leading
    # (layer) dim with lax.map so peak temp shrinks by that factor. The RMS
    # update clip then applies per leading slice (documented deviation;
    # identical in expectation, negligible in effect).
    CHUNK_ELEMS = 1 << 27

    def update(grads, state, params):
        t = state.step + 1
        beta = 1.0 - (t.astype(jnp.float32) + 1.0) ** (-decay)

        def upd_one(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if factored(p):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] / jnp.maximum(
                    vr.mean(-1, keepdims=True), eps)[..., None]) * vc[..., None, :]
                u = g * jax.lax.rsqrt(denom + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

        def upd(p, g, s):
            if (factored(p) and p.ndim >= 3 and p.size > CHUNK_ELEMS
                    and p.shape[0] > 1):
                return jax.lax.map(lambda xs: upd_one(*xs), (p, g, s))
            return upd_one(p, g, s)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state.inner)
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_inner = tdef.unflatten([o[1] for o in out])
        return new_params, OptState(t, new_inner)

    return init, update


# -------------------------------------------------------- row-wise Adagrad

def rowwise_adagrad(lr=0.05, eps=1e-8):
    """One fp32 accumulator per embedding ROW (FBGEMM-style)."""
    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(lambda p: jnp.zeros(p.shape[:1], jnp.float32),
                                     params))

    def update(grads, state, params):
        def upd(p, g, a):
            g = g.astype(jnp.float32)
            a_new = a + jnp.mean(jnp.square(g), axis=-1)
            step = g * (lr * jax.lax.rsqrt(a_new + eps))[:, None]
            return (p.astype(jnp.float32) - step).astype(p.dtype), a_new
        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_a = tdef.flatten_up_to(state.inner)
        out = [upd(p, g, a) for p, g, a in zip(flat_p, flat_g, flat_a)]
        return (tdef.unflatten([o[0] for o in out]),
                OptState(state.step + 1, tdef.unflatten([o[1] for o in out])))

    return init, update


# --------------------------------------------------------------- combined

def is_table_path(path) -> bool:
    return any(getattr(k, "key", None) == "tables" for k in path)


def combined(dense_opt, table_opt):
    """Route 'tables' subtrees to table_opt, the rest to dense_opt."""
    d_init, d_update = dense_opt
    t_init, t_update = table_opt

    def split(params):
        tables = {}
        dense = {}
        for k, v in params.items():
            (tables if k == "tables" else dense)[k] = v
        return dense, tables

    def init(params):
        dense, tables = split(params)
        return OptState(jnp.zeros((), jnp.int32),
                        {"dense": d_init(dense), "tables": t_init(tables)})

    def update(grads, state, params):
        dense, tables = split(params)
        gd, gt = split(grads)
        nd, sd = d_update(gd, state.inner["dense"], dense)
        nt, st = t_update(gt, state.inner["tables"], tables)
        new = dict(nd)
        new.update(nt)
        return new, OptState(state.step + 1, {"dense": sd, "tables": st})

    return init, update


def for_family(family: str, size_hint: int = 0):
    """Production defaults: adafactor for big LMs, adamw for small/gnn,
    rowwise-adagrad tables + adamw dense for recsys."""
    if family == "recsys":
        return combined(adamw(lr=1e-3), rowwise_adagrad())
    if family == "lm" and size_hint > 1_000_000_000:
        return adafactor()
    return adamw()
