"""Sharding-aware, fault-tolerant checkpointing.

  * save: each leaf written as an .npy shard set with a JSON manifest
    (tree structure, dtypes, sharding specs, step, config hash, checksum);
    atomic via write-to-temp + rename; DONE marker gates readers (the
    hot-load monitor and restore both key on it).
  * async save: snapshot to host (device_get) then write on a thread —
    training continues (the standard large-run pattern).
  * restore-with-resharding: leaves are loaded full and device_put with the
    TARGET mesh's shardings — an elastic restart onto a different mesh
    (e.g. 256 → 128 survivors after failures) is just restore(new_mesh).
  * emergency save on SIGTERM (preemption notice).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import signal
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def tree_paths(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, _leaf in flat:
        out.append("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path))
    return out


def save(path: str, tree: Any, step: int = 0, meta: Optional[dict] = None,
         mark_done: bool = True) -> dict:
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    names = tree_paths(tree)
    manifest = {"step": step, "meta": meta or {}, "leaves": [],
                "treedef": str(treedef)}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append({
            "name": name, "file": fn, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if mark_done:
        open(os.path.join(tmp, "DONE"), "w").close()
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return manifest


def restore(path: str, like: Any, shardings: Any = None,
            verify: bool = True) -> tuple[Any, int]:
    """like: pytree prototype (for structure). shardings: optional matching
    tree of NamedSharding for reshard-on-restore."""
    if not os.path.exists(os.path.join(path, "DONE")):
        raise FileNotFoundError(f"checkpoint {path} incomplete (no DONE)")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(f"leaf count mismatch: {len(leaves)} vs "
                         f"{len(manifest['leaves'])}")
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    out = []
    for rec, proto, shd in zip(manifest["leaves"], leaves, shard_leaves):
        arr = np.load(os.path.join(path, rec["file"]))
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
            if crc != rec["crc32"]:
                raise IOError(f"checksum mismatch in {rec['name']}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), manifest["step"]


class AsyncCheckpointer:
    """Snapshot-then-write-on-thread; at most one in flight (back-pressure)."""

    def __init__(self, base_dir: str, keep: int = 3):
        self.base_dir = base_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(base_dir, exist_ok=True)
        self.saved_steps: list[int] = []

    def save(self, tree: Any, step: int, meta: Optional[dict] = None,
             block: bool = False):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def write():
            p = os.path.join(self.base_dir, f"gen_{step}")
            save(p, host_tree, step, meta)
            self.saved_steps.append(step)
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        gens = sorted(d for d in os.listdir(self.base_dir)
                      if d.startswith("gen_"))
        for d in gens[: max(0, len(gens) - self.keep)]:
            shutil.rmtree(os.path.join(self.base_dir, d), ignore_errors=True)

    def latest(self) -> Optional[str]:
        gens = [d for d in os.listdir(self.base_dir) if d.startswith("gen_")
                and os.path.exists(os.path.join(self.base_dir, d, "DONE"))]
        if not gens:
            return None
        return os.path.join(self.base_dir,
                            max(gens, key=lambda d: int(d.split("_")[1])))

    def install_sigterm_hook(self, get_state, get_step):
        """Preemption: best-effort synchronous save on SIGTERM."""
        def handler(signum, frame):
            try:
                save(os.path.join(self.base_dir, f"gen_{get_step()}_emergency"),
                     get_state(), get_step(), {"emergency": True})
            finally:
                signal.default_int_handler(signum, frame)
        signal.signal(signal.SIGTERM, handler)
