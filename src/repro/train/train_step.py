"""Generic train-step builders: value_and_grad + (optional) microbatched
gradient accumulation (lax.scan) + optimizer update.

Gradients accumulate in param dtype — for deepseek-v3 that is bf16 by memory
necessity (fp32 accumulation of 671B grads is 2.7 TB; documented trade-off in
DESIGN.md; Adafactor's update clipping absorbs the extra noise).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def build_train_step(loss_fn: Callable, opt, *, n_micro: int = 1,
                     split_batch: Callable = None, grad_shardings=None):
    """loss_fn(params, batch) → scalar. split_batch(batch, n_micro) → pytree
    whose leaves have a leading n_micro dim (default: reshape dim 0).
    grad_shardings: optional NamedSharding tree — constrains the grad
    accumulator (ZeRO-2: grads reduce-scatter into shards, optimizer runs
    sharded, updated params all-gather once per step)."""
    opt_init, opt_update = opt

    if split_batch is None:
        def split_batch(batch, n):
            return jax.tree.map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)

    def constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, grad_shardings)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = constrain(grads)
        else:
            mb = split_batch(batch, n_micro)

            def micro(acc, b):
                l, g = jax.value_and_grad(loss_fn)(params, b)
                return constrain(jax.tree.map(jnp.add, acc, g)), l

            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, p.dtype), params))
            grads, losses = jax.lax.scan(micro, zeros, mb)
            grads = jax.tree.map(lambda g: (g / n_micro).astype(g.dtype), grads)
            loss = losses.mean()
        new_params, new_opt = opt_update(grads, opt_state, params)
        return new_params, new_opt, loss.astype(jnp.float32)

    return train_step, opt_init
