"""Elastic scaling + failure handling policy for 1000+-node fleets.

What actually happens on real pods: a chip/host dies → the job restarts on
the surviving topology. The framework's job is to make that restart CHEAP
and AUTOMATIC:

  1. health: heartbeat registry; missing heartbeats mark hosts dead.
  2. re-mesh: pick the largest supported mesh ≤ survivors (pods × 16 × 16,
     then halving data); recompute per-device batch so the GLOBAL batch and
     therefore the training trajectory is preserved (grad-accum absorbs the
     difference).
  3. restore: sharding-aware checkpoint restore onto the new mesh
     (repro.train.checkpoint.restore with the new shardings) — no format
     migration, leaves reshard on device_put.
  4. stragglers: the data pipeline hands out redundant shard leases;
     SEDP stages apply batch timeouts so one slow worker can't stall a
     batch (the paper's long-tail mitigation, applied to training I/O).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    alive: bool = True


class HealthRegistry:
    def __init__(self, n_hosts: int, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        now = time.monotonic()
        self.hosts = {i: HostState(i, now) for i in range(n_hosts)}

    def heartbeat(self, host_id: int, now: Optional[float] = None):
        self.hosts[host_id].last_heartbeat = now or time.monotonic()
        self.hosts[host_id].alive = True

    def sweep(self, now: Optional[float] = None) -> list[int]:
        now = now or time.monotonic()
        dead = []
        for h in self.hosts.values():
            if h.alive and now - h.last_heartbeat > self.timeout_s:
                h.alive = False
                dead.append(h.host_id)
        return dead

    @property
    def n_alive(self) -> int:
        return sum(h.alive for h in self.hosts.values())


@dataclass(frozen=True)
class MeshPlan:
    shape: tuple
    axes: tuple
    n_micro: int
    per_shard_batch: int


def plan_mesh(n_devices: int, global_batch: int,
              per_shard_seqs: int = 1, model_axis: int = 16) -> MeshPlan:
    """Largest supported mesh ≤ n_devices keeping the model axis intact
    (TP size is a model property; only data parallelism is elastic)."""
    if n_devices < model_axis:
        raise ValueError(f"need ≥{model_axis} devices for the model axis")
    data = n_devices // model_axis
    # data axis: largest power of two ≤ available (keeps batch divisible)
    d = 1
    while d * 2 <= data:
        d *= 2
    pods = 1
    if d > 16:                       # factor into (pod, 16)
        pods, d = d // 16, 16
    ds = pods * d
    n_micro = max(1, global_batch // (per_shard_seqs * ds))
    while global_batch % n_micro or (global_batch // n_micro) % ds:
        n_micro -= 1
    shape = (pods, d, model_axis) if pods > 1 else (d, model_axis)
    axes = ("pod", "data", "model") if pods > 1 else ("data", "model")
    return MeshPlan(shape, axes, max(1, n_micro), global_batch // ds)


@dataclass
class ShardLease:
    """Straggler-tolerant input sharding: every data shard is leased to a
    primary AND a backup reader; first completion wins (backup task
    pattern à la MapReduce)."""
    shard_id: int
    primary: int
    backup: int
    completed_by: Optional[int] = None


def lease_shards(n_shards: int, workers: list[int]) -> list[ShardLease]:
    n = len(workers)
    return [ShardLease(s, workers[s % n], workers[(s + n // 2) % n])
            for s in range(n_shards)]
