"""History recorder — the IRM's "historical logs" artifact (DESIGN.md
§10.4, ROADMAP item 4 data plane).

``StatsRecorder`` samples a ``MetricsRegistry`` (plus arbitrary caller
extras — knob vectors, per-stage latencies) on an interval into an
append-only windowed timeseries log:

    <dir>/win_<n>/samples.jsonl     one JSON object per sample
    <dir>/win_<n>/CHECKSUMS         sha256 of samples.jsonl
    <dir>/win_<n>/DONE              empty marker, written LAST

The publish discipline mirrors the delta log: a window is visible to
readers only once DONE exists, and DONE is written after the data +
checksum — a reader polling mid-write (or after a crash) sees either the
whole window or nothing. ``read_history`` verifies checksums and skips
torn windows, so ``irm/offline.py`` consumes only intact history.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import time
from typing import Callable, Optional

from repro.obs.metrics import MetricsRegistry

_SAMPLES = "samples.jsonl"
_CHECKSUMS = "CHECKSUMS"
_DONE = "DONE"


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _publish_window(dirpath: str, samples: list[dict]) -> None:
    os.makedirs(dirpath, exist_ok=True)
    done = os.path.join(dirpath, _DONE)
    if os.path.exists(done):          # unpublish before rewrite
        os.remove(done)
    spath = os.path.join(dirpath, _SAMPLES)
    with open(spath, "w") as f:
        for s in samples:
            f.write(json.dumps(s, sort_keys=True, default=str) + "\n")
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(dirpath, _CHECKSUMS), "w") as f:
        f.write(f"{_sha256_file(spath)}  {_SAMPLES}\n")
        f.flush()
        os.fsync(f.fileno())
    with open(done, "w"):             # the atomic publish bit, LAST
        pass


class StatsRecorder:
    """Samples ``registry.snapshot()`` every ``interval_s`` into windows of
    ``window_samples`` samples each. Run it as a daemon thread
    (``start``/``stop``) or drive it manually (``sample``/``roll``) — the
    benches and IRM log collection use manual mode for determinism."""

    def __init__(self, out_dir: str, registry: MetricsRegistry,
                 interval_s: float = 1.0, window_samples: int = 60,
                 extra_fn: Optional[Callable[[], dict]] = None,
                 clock: Callable[[], float] = time.time):
        self.out_dir = out_dir
        self.registry = registry
        self.interval_s = interval_s
        self.window_samples = window_samples
        self.extra_fn = extra_fn
        self.clock = clock
        os.makedirs(out_dir, exist_ok=True)
        self._buf: list[dict] = []
        self._win = self._next_window_index()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.samples_taken = 0
        self.windows_published = 0

    def _next_window_index(self) -> int:
        mx = -1
        for name in os.listdir(self.out_dir):
            if name.startswith("win_"):
                try:
                    mx = max(mx, int(name.split("_", 1)[1]))
                except ValueError:
                    continue
        return mx + 1

    # ----------------------------------------------------------- manual

    def sample(self, extra: Optional[dict] = None) -> dict:
        """Take one sample now. ``extra`` fields (e.g. the IRM's knob
        vector + measured objective) are merged at top level under
        ``extra`` so registry keys can never collide with them."""
        rec = {"t": self.clock(), "metrics": self.registry.snapshot()}
        if self.extra_fn is not None:
            try:
                rec.setdefault("extra", {}).update(self.extra_fn() or {})
            except Exception:  # noqa: BLE001 — telemetry must not wedge
                pass
        if extra:
            rec.setdefault("extra", {}).update(extra)
        with self._lock:
            self._buf.append(rec)
            self.samples_taken += 1
            if len(self._buf) >= self.window_samples:
                self._roll_locked()
        return rec

    def roll(self) -> None:
        """Publish the current partial window (if any)."""
        with self._lock:
            self._roll_locked()

    def _roll_locked(self) -> None:
        if not self._buf:
            return
        _publish_window(os.path.join(self.out_dir, f"win_{self._win}"),
                        self._buf)
        self._buf = []
        self._win += 1
        self.windows_published += 1

    # ----------------------------------------------------------- thread

    def start(self) -> "StatsRecorder":
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.wait(self.interval_s):
                self.sample()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="stats-recorder")
        self._thread.start()
        return self

    def stop(self, flush: bool = True) -> None:
        self._stop.set()
        th, self._thread = self._thread, None
        if th is not None:
            th.join(timeout=2.0)
        if flush:
            self.roll()


def read_history(out_dir: str, verify: bool = True) -> list[dict]:
    """All samples from published (DONE-marked) windows, in window order.
    Torn or checksum-mismatched windows are skipped, not raised — history
    reads must survive a recorder crash mid-window."""
    if not os.path.isdir(out_dir):
        return []
    wins = []
    for name in os.listdir(out_dir):
        if name.startswith("win_"):
            try:
                wins.append((int(name.split("_", 1)[1]), name))
            except ValueError:
                continue
    samples: list[dict] = []
    for _, name in sorted(wins):
        full = os.path.join(out_dir, name)
        spath = os.path.join(full, _SAMPLES)
        if not os.path.exists(os.path.join(full, _DONE)):
            continue
        if not os.path.exists(spath):
            continue
        if verify:
            cpath = os.path.join(full, _CHECKSUMS)
            try:
                with open(cpath) as f:
                    want = f.read().split()[0]
                if _sha256_file(spath) != want:
                    continue
            except (OSError, IndexError):
                continue
        with open(spath) as f:
            for line in f:
                line = line.strip()
                if line:
                    samples.append(json.loads(line))
    return samples
