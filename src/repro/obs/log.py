"""Structured logging helper — one emit path for all watcher/monitor
components (DESIGN.md §10.5).

``log_event(logger, "delta_checksum_mismatch", version=12, path=...)``
renders a grep-friendly ``key=value`` message AND attaches the full record
as ``record.structured`` so a handler (or test) can consume the fields
without re-parsing the text. Correlation ids are ordinary fields:
``version`` (update plane), ``trace_id`` (request plane), ``watcher``
(component instance).
"""
from __future__ import annotations

import logging
from typing import Optional


def _fmt_value(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    s = str(v)
    if " " in s or "=" in s:
        return repr(s)
    return s


def log_event(logger: logging.Logger, event: str,
              level: int = logging.INFO,
              exc_info: bool = False, **fields) -> dict:
    """Emit one structured record. Returns the field dict (handy for
    tests). ``None``-valued fields are dropped so call sites can pass
    optional correlation ids unconditionally."""
    record = {"event": event}
    record.update((k, v) for k, v in fields.items() if v is not None)
    msg = " ".join([event] + [f"{k}={_fmt_value(v)}"
                              for k, v in record.items() if k != "event"])
    logger.log(level, "%s", msg, exc_info=exc_info,
               extra={"structured": record})
    return record


class CapturingHandler(logging.Handler):
    """Test helper: collects the ``structured`` dicts of records passing
    through a logger, so assertions read fields instead of regexing text."""

    def __init__(self, level: int = logging.DEBUG):
        super().__init__(level)
        self.records: list[dict] = []
        self.messages: list[str] = []

    def emit(self, record: logging.LogRecord) -> None:
        structured = getattr(record, "structured", None)
        if structured is not None:
            self.records.append(dict(structured))
            self.messages.append(record.getMessage())

    def events(self, name: Optional[str] = None) -> list[dict]:
        if name is None:
            return list(self.records)
        return [r for r in self.records if r.get("event") == name]
