"""Metrics registry — process-wide Counter/Gauge/Histogram under one
namespace (DESIGN.md §10.2).

The histogram is log-bucketed: observations land in geometric buckets
(factor 2**0.25 ≈ 19% width) so p50/p95/p99 come from cumulative bucket
counts without retaining raw samples. That replaces ``RunReport.latencies``'
unbounded list as the default accounting path on the serving loop; exact
mode stays available for tests/benches that need sample-level numbers.

Gauges are callback-based: ``registry.gauge(name, fn)`` registers a thunk
sampled at export time, so existing telemetry structs (``StageStats``,
``CubeMetrics``, breaker states, ...) plug in without copying state.
Multi-series collectors (``registry.collector``) emit whole labeled
families the same way.

Export formats: Prometheus text exposition (``to_prometheus``) and a flat
JSON snapshot (``snapshot``) — both read the same live objects.
"""
from __future__ import annotations

import json
import math
import threading
from bisect import bisect_right
from typing import Callable, Optional

NAMESPACE = "jizhi"

# geometric bucket ladder: 1µs .. ~4200s in 19%-wide steps. One shared
# ladder for every histogram keeps snapshots mergeable and the exposition
# page compact.
_BUCKET_FACTOR = 2.0 ** 0.25
_BUCKET_LO = 1e-6
_N_BUCKETS = 128
BUCKET_BOUNDS = tuple(_BUCKET_LO * _BUCKET_FACTOR ** i
                      for i in range(_N_BUCKETS))


def _sanitize(name: str) -> str:
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


class Counter:
    """Monotonic counter. ``inc`` is lock-protected — workers on the async
    executor bump counters concurrently."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def sample(self):
        return self.value


class Gauge:
    """Point-in-time value. Either set directly (``set``) or backed by a
    callback sampled at export time (``fn``)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.help = help
        self.fn = fn
        self._value = 0.0

    def set(self, value: float) -> None:
        self._value = float(value)

    def sample(self) -> float:
        if self.fn is not None:
            try:
                return float(self.fn())
            except Exception:  # noqa: BLE001 — a dead callback must not
                # poison the whole exposition page
                return float("nan")
        return self._value


class Histogram:
    """Log-bucketed histogram: O(1) memory per series, percentile via
    cumulative counts (upper bucket bound = conservative estimate, error
    bounded by the 19% bucket width)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._counts = [0] * (_N_BUCKETS + 1)   # +1 overflow bucket
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, value: float) -> None:
        idx = bisect_right(BUCKET_BOUNDS, value)
        with self._lock:
            self._counts[idx] += 1
            self._count += 1
            self._sum += value
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value

    def observe_many(self, values) -> None:
        for v in values:
            self.observe(v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def percentile(self, q: float) -> float:
        """Ceil-based nearest-rank over cumulative bucket counts; returns
        the upper bound of the bucket holding that rank (clamped to the
        observed max so a single-sample histogram reports the sample)."""
        with self._lock:
            n = self._count
            if n == 0:
                return 0.0
            rank = max(1, math.ceil(q * n))
            acc = 0
            for i, c in enumerate(self._counts):
                acc += c
                if acc >= rank:
                    if i >= _N_BUCKETS:
                        return self._max
                    hi = BUCKET_BOUNDS[i]
                    return min(hi, self._max) if self._max > -math.inf else hi
            return self._max

    def sample(self) -> dict:
        with self._lock:
            if self._count == 0:
                return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                        "p50": 0.0, "p95": 0.0, "p99": 0.0}
            return self._sample_locked()

    def _sample_locked(self) -> dict:
        # caller holds the lock; percentile() re-acquires, so inline it
        out = {"count": self._count, "sum": self._sum,
               "min": self._min, "max": self._max}
        for key, q in (("p50", 0.50), ("p95", 0.95), ("p99", 0.99)):
            rank = max(1, math.ceil(q * self._count))
            acc = 0
            val = self._max
            for i, c in enumerate(self._counts):
                acc += c
                if acc >= rank:
                    val = (self._max if i >= _N_BUCKETS
                           else min(BUCKET_BOUNDS[i], self._max))
                    break
            out[key] = val
        return out

    def bucket_counts(self):
        with self._lock:
            return list(self._counts)


class MetricsRegistry:
    """Get-or-create registry for all series in the process. Thread-safe.

    ``collector(name, fn)`` registers a callback returning a whole labeled
    family at once: ``{(("stage","rerank"),): value, ...}`` — a dict mapping
    label tuples (sorted (key, value) pairs) to numbers. Used for per-stage
    / per-server series whose population is only known at sample time.
    """

    def __init__(self, namespace: str = NAMESPACE):
        self.namespace = namespace
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}
        self._collectors: dict[str, Callable[[], dict]] = {}

    # ---------------------------------------------------- get-or-create

    def _get(self, cls, name: str, help: str, **kw):
        name = _sanitize(name)
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, wanted {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "",
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get(Gauge, name, help)
        if fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def collector(self, name: str, fn: Callable[[], dict],
                  help: str = "") -> None:
        """fn() -> {label_tuple: value}; label_tuple is a tuple of
        (key, value) string pairs."""
        with self._lock:
            self._collectors[_sanitize(name)] = fn

    def unregister(self, name: str) -> None:
        name = _sanitize(name)
        with self._lock:
            self._metrics.pop(name, None)
            self._collectors.pop(name, None)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()
            self._collectors.clear()

    # ----------------------------------------------------------- export

    def _items(self):
        with self._lock:
            metrics = sorted(self._metrics.items())
            collectors = sorted(self._collectors.items())
        return metrics, collectors

    def snapshot(self) -> dict:
        """Flat JSON-serializable snapshot: ``{full_name: value}`` for
        scalars, ``{full_name: {count,sum,min,max,p50,p95,p99}}`` for
        histograms, labeled series as ``name{k=v,...}`` keys."""
        out: dict[str, object] = {}
        metrics, collectors = self._items()
        for name, m in metrics:
            out[f"{self.namespace}_{name}"] = m.sample()
        for name, fn in collectors:
            try:
                series = fn() or {}
            except Exception:  # noqa: BLE001
                continue
            for labels, value in sorted(series.items()):
                lbl = ",".join(f"{k}={v}" for k, v in labels)
                out[f"{self.namespace}_{name}{{{lbl}}}"] = value
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format, version 0.0.4."""
        lines: list[str] = []
        metrics, collectors = self._items()
        for name, m in metrics:
            full = f"{self.namespace}_{name}"
            if m.help:
                lines.append(f"# HELP {full} {m.help}")
            if isinstance(m, Histogram):
                lines.append(f"# TYPE {full} histogram")
                acc = 0
                counts = m.bucket_counts()
                for i, c in enumerate(counts[:-1]):
                    if c == 0:
                        continue
                    acc += c
                    lines.append(f'{full}_bucket{{le="{BUCKET_BOUNDS[i]:.6g}"'
                                 f'}} {acc}')
                acc += counts[-1]
                lines.append(f'{full}_bucket{{le="+Inf"}} {acc}')
                lines.append(f"{full}_sum {m.sum:.9g}")
                lines.append(f"{full}_count {m.count}")
            else:
                lines.append(f"# TYPE {full} {m.kind}")
                v = m.sample()
                lines.append(f"{full} {v:.9g}")
        for name, fn in collectors:
            full = f"{self.namespace}_{name}"
            try:
                series = fn() or {}
            except Exception:  # noqa: BLE001
                continue
            lines.append(f"# TYPE {full} gauge")
            for labels, value in sorted(series.items()):
                lbl = ",".join(f'{k}="{v}"' for k, v in labels)
                lines.append(f"{full}{{{lbl}}} {float(value):.9g}")
        return "\n".join(lines) + "\n"

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), indent=1, sort_keys=True,
                          default=str)


# The process-wide default registry. Components register here unless handed
# an explicit registry (tests construct private ones).
DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return DEFAULT
