"""Unified telemetry plane (DESIGN.md §10).

Three legs:
  * ``obs.trace``   — per-request span trees through both executors,
                      tail-sampled ``TraceBuffer``, Chrome/Perfetto export,
                      critical-path analysis.
  * ``obs.metrics`` — process-wide Counter/Gauge/Histogram registry with
                      Prometheus + JSON export; ``obs.bridge`` plugs the
                      existing telemetry structs in callback-style.
  * ``obs.recorder``— windowed, DONE-marker-published history log the IRM's
                      offline auto-search reads (ROADMAP item 4).
``obs.log`` is the one structured-logging helper every watcher/monitor
emits through.
"""
from repro.obs import bridge  # noqa: F401
from repro.obs.log import CapturingHandler, log_event  # noqa: F401
from repro.obs.metrics import (DEFAULT, BUCKET_BOUNDS, Counter,  # noqa: F401
                               Gauge, Histogram, MetricsRegistry,
                               get_registry)
from repro.obs.trace import (TraceBuffer, Tracer, add_child_spans,  # noqa: F401
                             annotate, critical_path, shard_fanout_spans,
                             shard_profile, span_topology, stage_path)

__all__ = [
    "DEFAULT", "BUCKET_BOUNDS", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "get_registry", "Tracer", "TraceBuffer", "annotate",
    "add_child_spans", "shard_fanout_spans", "shard_profile",
    "critical_path", "span_topology", "stage_path", "log_event",
    "CapturingHandler", "bridge", "StatsRecorder", "read_history",
]


def __getattr__(name):
    # recorder imports stay lazy: obs.log is imported by serve/hotload,
    # and an eager recorder import here would close an import cycle the
    # moment a watcher pulls in obs
    if name in ("StatsRecorder", "read_history"):
        from repro.obs import recorder
        return getattr(recorder, name)
    raise AttributeError(name)
