"""Per-request tracing for the SEDP loop (DESIGN.md §10.1).

A ``Tracer`` threads a trace id + span list through ``Event.meta`` on both
executors. Every stage visit records three spans — ``queue`` (channel
enqueue → dequeue, including backpressure stall on the async executor),
``assemble`` (dequeue → micro-batch dispatch), ``exec`` (op start → op
end) — so the span topology is identical Sim-vs-Async even though the
durations come from different clocks (virtual vs wall).

Stages annotate the OPEN span via ``annotate(ev, cache_hit=True, ...)``;
the call is a no-op (one dict lookup) on untraced events, which is what
keeps the telemetry-OFF path free.

``TraceBuffer`` bounds memory with tail-based sampling: errors, deadline
expiries, shed-dropped and degraded(>0) traces are ALWAYS kept (up to a
cap), plus a top-K latency heap and a recent ring for baseline context.
Export is Chrome trace-event JSON (load in Perfetto / chrome://tracing);
``from_chrome`` round-trips it and ``critical_path`` attributes a
request's latency to stages/queues from the exported form alone.
"""
from __future__ import annotations

import heapq
import itertools
import json
import threading
from collections import deque
from typing import Optional


def annotate(ev, **attrs) -> None:
    """Merge attributes into the event's currently-open span. No-op when
    the event is untraced (the hot-path cost when telemetry is off)."""
    spans = ev.meta.get("spans")
    if spans:
        spans[-1]["attrs"].update(attrs)


def add_child_spans(ev, child_spans) -> None:
    """Attach pre-built child spans (e.g. the mesh's per-shard
    ``shard_fetch`` sub-batches) to a traced event's current stage visit.

    Children are inserted BEFORE the currently-open ``exec`` span rather
    than appended: ``Tracer.exec_end`` closes ``spans[-1]`` only if it is
    the exec span, and ``annotate`` targets ``spans[-1]`` — appending
    would orphan the stage's own span. No-op on untraced events."""
    spans = ev.meta.get("spans")
    if not spans or not child_spans:
        return
    if spans[-1]["kind"] == "exec":
        spans[-1:-1] = child_spans
    else:
        spans.extend(child_spans)


def shard_fanout_spans(fanout: list) -> list:
    """Build the ``shard_fanout`` span family from a MeshCube fan-out
    record list (``take_fanout()``): one ``cube:shard_fanout`` parent
    covering the scatter/gather envelope plus one ``shard_<s>:shard_fetch``
    child per sub-batch. The spans travel through Chrome export like any
    other (kind rides in the ``stage:kind`` name), so ``critical_path`` /
    ``shard_profile`` attribute tail latency to the slowest shard from an
    exported trace alone."""
    if not fanout:
        return []
    t0 = min(f["t0"] for f in fanout)
    t1 = max(f["t1"] for f in fanout)
    spans = [{"stage": "cube", "kind": "shard_fanout", "t0": t0, "t1": t1,
              "attrs": {"n_shards": len(fanout)}}]
    for f in fanout:
        spans.append({"stage": f"shard_{f['shard']}", "kind": "shard_fetch",
                      "t0": f["t0"], "t1": f["t1"],
                      "attrs": {"shard": f["shard"], "host": f["host"],
                                "n_keys": f["n_keys"],
                                "hedged": f["hedged"],
                                "failed": f["failed"]}})
    return spans


def _status_of(ev) -> str:
    if ev.meta.get("error"):
        return "error"
    if ev.meta.get("timed_out"):
        return "expired"
    return "ok"


class Tracer:
    """Executor-side hook set. All methods tolerate untraced events (an
    executor may run a mix when fanout clones predate the tracer)."""

    def __init__(self, buffer: Optional["TraceBuffer"] = None):
        self.buffer = buffer if buffer is not None else TraceBuffer()
        self._ids = itertools.count(1)

    # ------------------------------------------------------------ hooks

    def begin(self, ev, t: float) -> None:
        if "trace_id" not in ev.meta:
            ev.meta["trace_id"] = next(self._ids)
            ev.meta["spans"] = []

    def adopt(self, parent_ev, clone_ev) -> None:
        """Fanout clones share the parent's trace id and inherit a copy of
        the span history up to the fork (the closed prefix is shared
        structurally; each branch appends to its own list)."""
        spans = parent_ev.meta.get("spans")
        if spans is None:
            return
        clone_ev.meta["trace_id"] = parent_ev.meta["trace_id"]
        clone_ev.meta["spans"] = list(spans)

    def enqueued(self, ev, stage: str, t: float) -> None:
        spans = ev.meta.get("spans")
        if spans is not None:
            spans.append({"stage": stage, "kind": "queue",
                          "t0": t, "t1": t, "attrs": {}})

    def dequeued(self, ev, stage: str, t: float) -> None:
        spans = ev.meta.get("spans")
        if spans is not None:
            if spans and spans[-1]["kind"] == "queue":
                spans[-1]["t1"] = t
            spans.append({"stage": stage, "kind": "assemble",
                          "t0": t, "t1": t, "attrs": {}})

    def exec_begin(self, batch, stage: str, t: float) -> None:
        for ev in batch:
            spans = ev.meta.get("spans")
            if spans is not None:
                if spans and spans[-1]["kind"] == "assemble":
                    spans[-1]["t1"] = t
                spans.append({"stage": stage, "kind": "exec",
                              "t0": t, "t1": t,
                              "attrs": {"batch": len(batch)}})

    def exec_end(self, batch, stage: str, t: float, **attrs) -> None:
        for ev in batch:
            spans = ev.meta.get("spans")
            if spans is not None and spans and spans[-1]["kind"] == "exec":
                spans[-1]["t1"] = t
                if attrs:
                    spans[-1]["attrs"].update(attrs)

    def expired(self, ev, stage: str, t: float) -> None:
        """Deadline gate fired at dispatch: close whatever span is open
        and mark the expiry decision on it."""
        spans = ev.meta.get("spans")
        if spans is not None and spans:
            spans[-1]["t1"] = t
            spans[-1]["attrs"]["expired"] = True

    def dropped(self, ev, stage: str, t: float) -> None:
        """Overflow-policy drop at a bounded channel: the request sheds
        before its queue span ever opened."""
        spans = ev.meta.get("spans")
        if spans is not None:
            spans.append({"stage": stage, "kind": "queue", "t0": t, "t1": t,
                          "attrs": {"dropped": True}})
        self.finish(ev, t, status="dropped")

    def finish(self, ev, t: float, status: Optional[str] = None) -> None:
        spans = ev.meta.get("spans")
        if spans is None:
            return
        payload = ev.payload
        tier = (payload.get("degraded_tier", 0)
                if hasattr(payload, "get") else 0) or 0
        rec = {
            "trace_id": ev.meta["trace_id"],
            "req_id": ev.req_id,
            "born_at": ev.born_at,
            "done_at": t,
            "latency_s": max(0.0, t - ev.born_at),
            "status": status or _status_of(ev),
            "degraded_tier": int(tier),
            "spans": spans,
        }
        if ev.meta.get("error"):
            rec["error"] = ev.meta["error"]
        self.buffer.add(rec)


class TraceBuffer:
    """Bounded trace store with tail-based sampling.

    Three compartments: ``flagged`` (errors / expired / dropped /
    degraded>0 — the traces an operator actually pages through),
    ``top`` (K slowest OK traces), ``recent`` (ring of the latest OK
    traces for baseline comparison). Each is individually bounded, so
    total memory is O(max_flagged + max_top + max_recent)."""

    def __init__(self, max_flagged: int = 512, max_top: int = 64,
                 max_recent: int = 256):
        self.max_top = max_top
        self._flagged: deque = deque(maxlen=max_flagged)
        self._top: list = []                       # min-heap (latency, seq, rec)
        self._recent: deque = deque(maxlen=max_recent)
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self.added = 0          # every record offered
        self.flagged_total = 0  # records that hit the always-keep rules

    def add(self, rec: dict) -> None:
        with self._lock:
            self.added += 1
            if rec["status"] != "ok" or rec["degraded_tier"] > 0:
                self.flagged_total += 1
                self._flagged.append(rec)
                return
            self._recent.append(rec)
            item = (rec["latency_s"], next(self._seq), rec)
            if len(self._top) < self.max_top:
                heapq.heappush(self._top, item)
            elif item[0] > self._top[0][0]:
                heapq.heapreplace(self._top, item)

    def traces(self) -> list[dict]:
        """All retained traces, deduped (a top-K trace may also sit in the
        recent ring), ordered by completion time."""
        with self._lock:
            seen: set[int] = set()
            out: list[dict] = []
            for rec in itertools.chain(self._flagged,
                                       (r for _, _, r in self._top),
                                       self._recent):
                if id(rec) not in seen:
                    seen.add(id(rec))
                    out.append(rec)
        out.sort(key=lambda r: (r["done_at"], r["trace_id"]))
        return out

    def find(self, **conds) -> list[dict]:
        """Filter retained traces by top-level record fields
        (``find(status="expired")``, ``find(trace_id=7)``)."""
        return [r for r in self.traces()
                if all(r.get(k) == v for k, v in conds.items())]

    def clear(self) -> None:
        with self._lock:
            self._flagged.clear()
            self._top = []
            self._recent.clear()

    # ----------------------------------------------------------- export

    def export_chrome(self, path: Optional[str] = None) -> dict:
        """Chrome trace-event JSON: one ``X`` (complete) event per span plus
        a per-request summary event carrying status/degraded_tier — enough
        to reconstruct each trace with ``from_chrome``."""
        events = []
        for rec in self.traces():
            tid = rec["trace_id"]
            events.append({
                "name": "request", "cat": "request", "ph": "X",
                "ts": rec["born_at"] * 1e6,
                "dur": max(0.0, rec["done_at"] - rec["born_at"]) * 1e6,
                "pid": 1, "tid": tid,
                "args": {"status": rec["status"],
                         "degraded_tier": rec["degraded_tier"],
                         "req_id": rec["req_id"]},
            })
            for sp in rec["spans"]:
                events.append({
                    "name": f'{sp["stage"]}:{sp["kind"]}',
                    "cat": sp["kind"], "ph": "X",
                    "ts": sp["t0"] * 1e6,
                    "dur": max(0.0, sp["t1"] - sp["t0"]) * 1e6,
                    "pid": 1, "tid": tid,
                    "args": dict(sp["attrs"]),
                })
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc

    @staticmethod
    def from_chrome(doc) -> list[dict]:
        """Rebuild trace records from an exported Chrome trace document
        (dict, JSON string, or path). The analyzer functions below accept
        these reconstructed records — the acceptance drill reads the
        request path back from the export alone."""
        if isinstance(doc, str):
            try:
                doc = json.loads(doc)
            except ValueError:
                with open(doc) as f:
                    doc = json.load(f)
        by_tid: dict[int, dict] = {}
        for e in doc.get("traceEvents", []):
            tid = e["tid"]
            rec = by_tid.setdefault(tid, {"trace_id": tid, "spans": []})
            t0 = e["ts"] / 1e6
            t1 = t0 + e.get("dur", 0.0) / 1e6
            if e["name"] == "request":
                rec.update(born_at=t0, done_at=t1,
                           latency_s=max(0.0, t1 - t0),
                           status=e["args"].get("status", "ok"),
                           degraded_tier=e["args"].get("degraded_tier", 0),
                           req_id=e["args"].get("req_id"))
            else:
                stage, _, kind = e["name"].rpartition(":")
                rec["spans"].append({"stage": stage, "kind": kind,
                                     "t0": t0, "t1": t1,
                                     "attrs": dict(e.get("args", {}))})
        for rec in by_tid.values():
            rec["spans"].sort(key=lambda s: (s["t0"], s["t1"]))
            rec.setdefault("status", "ok")
            rec.setdefault("degraded_tier", 0)
        return sorted(by_tid.values(), key=lambda r: r["trace_id"])


# ------------------------------------------------------------- analysis

def span_topology(rec: dict) -> list[tuple[str, str]]:
    """(stage, kind) sequence — the structural shape of a trace, invariant
    across executors for the same routing decisions."""
    return [(sp["stage"], sp["kind"]) for sp in rec["spans"]]


def stage_path(rec: dict) -> list[str]:
    """The stages a request actually visited, in visit order (one entry
    per stage visit, from the queue spans — present even for visits that
    expired before executing)."""
    return [sp["stage"] for sp in rec["spans"] if sp["kind"] == "queue"]


def critical_path(rec: dict) -> dict:
    """Attribute a request's end-to-end latency to (stage, kind) segments.

    Returns ``{"total_s", "segments": [{stage, kind, dur_s, frac}...],
    "unattributed_s"}`` with segments sorted by descending duration —
    "where did my p99 go" from one trace."""
    total = rec.get("latency_s")
    if total is None:
        total = max(0.0, rec.get("done_at", 0.0) - rec.get("born_at", 0.0))
    agg: dict[tuple[str, str], float] = {}
    covered = 0.0
    for sp in rec["spans"]:
        dur = max(0.0, sp["t1"] - sp["t0"])
        agg[(sp["stage"], sp["kind"])] = agg.get(
            (sp["stage"], sp["kind"]), 0.0) + dur
        covered += dur
    segments = [{"stage": s, "kind": k, "dur_s": d,
                 "frac": d / total if total > 0 else 0.0}
                for (s, k), d in agg.items()]
    segments.sort(key=lambda seg: -seg["dur_s"])
    return {"total_s": total, "segments": segments,
            "unattributed_s": max(0.0, total - covered)}


def shard_profile(rec: dict) -> dict:
    """Per-shard time of one trace from its ``shard_fetch`` child spans:
    ``{shard_id: {"dur_s", "n_fetches", "hosts", "hedged"}}``. The hot
    shard — the fan-out straggler the request's tail hides behind — is
    ``max(profile, key=lambda s: profile[s]["dur_s"])``. Works on live
    records and on ``from_chrome`` reconstructions alike (shard ids
    recover from the span attrs / stage name)."""
    out: dict[int, dict] = {}
    for sp in rec["spans"]:
        if sp["kind"] != "shard_fetch":
            continue
        attrs = sp.get("attrs", {})
        sid = attrs.get("shard")
        if sid is None:
            try:
                sid = int(sp["stage"].rpartition("_")[2])
            except ValueError:
                continue
        sid = int(sid)
        ent = out.setdefault(sid, {"dur_s": 0.0, "n_fetches": 0,
                                   "hosts": set(), "hedged": 0})
        ent["dur_s"] += max(0.0, sp["t1"] - sp["t0"])
        ent["n_fetches"] += 1
        if attrs.get("host") is not None:
            ent["hosts"].add(attrs["host"])
        if attrs.get("hedged"):
            ent["hedged"] += 1
    return out
