"""Bridges: register the existing telemetry structs with a
``MetricsRegistry`` under the ``jizhi_`` namespace (DESIGN.md §10.3).

Every bridge is callback-based — registration stores a thunk over the
live object, sampled only at export time, so attaching observability to
a component costs nothing on its hot path. Each ``register_*`` takes an
optional registry (defaults to the process-wide one) and an optional
``prefix`` so multiple instances (two cubes, per-scenario executors)
coexist without colliding.
"""
from __future__ import annotations

from dataclasses import fields, is_dataclass
from typing import Optional

from repro.obs.metrics import DEFAULT, MetricsRegistry


def _reg(registry: Optional[MetricsRegistry]) -> MetricsRegistry:
    return registry if registry is not None else DEFAULT


def _dataclass_series(obj, label: tuple) -> dict:
    out = {}
    for f in fields(obj):
        v = getattr(obj, f.name)
        if isinstance(v, (int, float)):
            out[label + (("field", f.name),)] = v
    return out


def register_executor(executor, name: str = "exec",
                      registry: Optional[MetricsRegistry] = None) -> None:
    """Per-stage ``StageStats`` as one labeled family
    (``jizhi_stage_stats{exec=...,stage=...,field=...}``) plus live queue
    depths."""
    r = _reg(registry)

    def stage_series():
        out = {}
        for stage, st in list(executor.stats.items()):
            out.update(_dataclass_series(
                st, (("exec", name), ("stage", stage))))
        return out

    def depth_series():
        out = {}
        for stage in executor.plan.stages:
            try:
                out[(("exec", name), ("stage", stage))] = \
                    executor._depth(stage)
            except Exception:  # noqa: BLE001 — depth on a torn-down
                # executor must not poison the page
                pass
        return out

    r.collector(f"stage_stats_{name}", stage_series,
                help="per-stage SEDP executor counters")
    r.collector(f"queue_depth_{name}", depth_series,
                help="live channel depth per stage")


def register_cube(cube, name: str = "cube",
                  registry: Optional[MetricsRegistry] = None) -> None:
    r = _reg(registry)
    r.gauge(f"{name}_version", "published cube version",
            fn=lambda: cube.version)
    r.collector(
        f"{name}_metrics",
        lambda: _dataclass_series(cube.metrics, (("cube", name),)),
        help="ParameterCube counters (lookups, failovers, compaction)")


def register_health(health, name: str = "cube",
                    registry: Optional[MetricsRegistry] = None) -> None:
    """Per-server breaker state (0=closed, 1=half_open, 2=open) and its
    open/close/skip counters."""
    r = _reg(registry)
    code = {"closed": 0, "half_open": 1, "open": 2}

    def series():
        out = {}
        for sid, h in enumerate(health.servers):
            base = (("cube", name), ("server", str(sid)))
            out[base + (("field", "state"),)] = code.get(h.state, -1)
            out[base + (("field", "opens"),)] = h.opens
            out[base + (("field", "closes"),)] = h.closes
            out[base + (("field", "skipped"),)] = h.skipped
        return out

    r.collector(f"{name}_breaker", series,
                help="per-server circuit breaker state + transitions")


def register_update_manager(mgr, name: str = "update",
                            registry: Optional[MetricsRegistry] = None) -> None:
    r = _reg(registry)
    r.collector(
        f"{name}_stats",
        lambda: _dataclass_series(mgr.stats, (("mgr", name),)),
        help="UpdateManager counters incl. apply/compaction timings")
    r.gauge(f"{name}_last_version", "last delta version applied",
            fn=lambda: mgr.stats.last_version)


def register_quota(quota, name: str = "shed",
                   registry: Optional[MetricsRegistry] = None) -> None:
    r = _reg(registry)
    r.gauge(f"{name}_quota", "live admission quota (1.0 = free capacity)",
            fn=lambda: quota.value)


def register_traced_jit(tj, name: str,
                        registry: Optional[MetricsRegistry] = None) -> None:
    r = _reg(registry)
    r.gauge(f"jit_traces_{name}", "jit cache size (recompilation count)",
            fn=lambda: tj.n_traces)


def register_snapshotter(snap, name: str = "snapshot",
                         registry: Optional[MetricsRegistry] = None) -> None:
    r = _reg(registry)
    r.gauge(f"{name}_last_version", "last durable snapshot version",
            fn=lambda: snap.last_snapshot_version)
    r.gauge(f"{name}_last_duration_s", "duration of the last snapshot",
            fn=lambda: getattr(snap, "last_snapshot_s", 0.0))


def register_delta_watcher(dw, name: str = "delta",
                           registry: Optional[MetricsRegistry] = None) -> None:
    r = _reg(registry)
    r.gauge(f"{name}_applied_version", "delta-log apply cursor",
            fn=lambda: dw.applied_version)


def register_substrate(sub, name: str = "substrate",
                       registry: Optional[MetricsRegistry] = None) -> None:
    """One call registers a ServingSubstrate's cube, health (if attached),
    update manager and replay timing."""
    r = _reg(registry)
    register_cube(sub.cube, name=f"{name}_cube", registry=r)
    if getattr(sub.cube, "health", None) is not None:
        register_health(sub.cube.health, name=f"{name}_cube", registry=r)
    if getattr(sub, "updates", None) is not None:
        register_update_manager(sub.updates, name=f"{name}_update",
                                registry=r)
    r.gauge(f"{name}_last_replay_s", "duration of the last delta-log replay",
            fn=lambda: getattr(sub, "last_replay_s", 0.0))


def register_runtime(rt, name: str,
                     registry: Optional[MetricsRegistry] = None) -> None:
    """A ScenarioRuntime's jit trace counters."""
    r = _reg(registry)
    for attr in ("serve", "rerank", "retrieve"):
        tj = getattr(rt, attr, None)
        if tj is not None and hasattr(tj, "n_traces"):
            register_traced_jit(tj, f"{name}_{attr}", registry=r)


def register_service(svc, name: str = "svc",
                     registry: Optional[MetricsRegistry] = None) -> None:
    """Convenience: wire a whole InferenceService/MultiScenarioService —
    substrate, runtimes, shedder quota — in one call."""
    r = _reg(registry)
    sub = getattr(svc, "substrate", None)
    if sub is not None:
        register_substrate(sub, name=name, registry=r)
    runtimes = getattr(svc, "runtimes", None) or {}
    for sc_name, rt in (runtimes.items()
                        if hasattr(runtimes, "items") else []):
        register_runtime(rt, f"{name}_{sc_name}", registry=r)
    rt = getattr(svc, "runtime", None)
    if rt is not None:
        register_runtime(rt, name, registry=r)
    shedder = getattr(svc, "shedder", None)
    if shedder is not None and getattr(shedder, "controller", None) is not None:
        register_quota(shedder.controller, name=name, registry=r)
