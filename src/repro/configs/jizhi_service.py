"""The paper's own service configs (Table 1 + §8 setup) as framework configs:
model size, feature-group count, and traffic per production service, mapped
onto the simulator's ServiceSpec and the servable ranking models.

The dense DNN of each service is a DIN-family ranker; the sparse part
(Table 1: 210-500 GB) lives in the parameter cube / sharded tables.

This module is also the SCENARIO REGISTRY of the serving surface
(DESIGN.md §7): each entry below is a declarative ScenarioSpec that
``MultiScenarioService`` compiles into a pipeline on the shared substrate
— the repro's analogue of the paper's twenty-plus production services
behind one SEDP abstraction. Adding a scenario is one ``register_scenario``
call, not a fork of core/service.py.
"""
from repro.core.service_model import SERVICES, ServiceSpec  # noqa: F401
from repro.serve.scenario import ScenarioSpec, register_scenario

# ------------------------------------------------------ scenario registry
# Priority 0 = the primary objective (never shed by the quota-aware
# fanout); priority 1 scenarios ride out overload spikes (§8.6: CTR keeps
# serving while FR/CMT shed first).
DIN_RERANK = register_scenario(ScenarioSpec(
    name="din-rerank", arch_id="din", pipeline="rerank", priority=0,
    batch_size=16))
DIEN_RERANK = register_scenario(ScenarioSpec(
    name="dien-rerank", arch_id="dien", pipeline="rerank", priority=1,
    batch_size=16))
MIND_RETRIEVAL = register_scenario(ScenarioSpec(
    name="mind-retrieval", arch_id="mind", pipeline="retrieval",
    # retrieval responses are top-k lists, not (user, item) scores — the
    # pointwise query cache does not apply
    query_cache=False, priority=1, batch_size=8))
TOWERS_RETRIEVAL = register_scenario(ScenarioSpec(
    name="towers-retrieval", arch_id="two-tower-retrieval",
    pipeline="retrieval", query_cache=False, priority=1, batch_size=8))

#: The default multi-scenario serving surface (MultiScenarioService()).
DEFAULT_SCENARIOS = ("din-rerank", "dien-rerank", "mind-retrieval")

# Table 1 statistics (the paper's deployed services)
TABLE_1 = {
    "A": {"model_size_gb": 430, "feature_groups": 379, "traffic_per_s": 4.58e8},
    "B": {"model_size_gb": 500, "feature_groups": 430, "traffic_per_s": 4.21e8},
    "C": {"model_size_gb": 285, "feature_groups": 270, "traffic_per_s": 3.67e7},
    "D": {"model_size_gb": 210, "feature_groups": 106, "traffic_per_s": 7.15e7},
    # Service E (§8.6): three models, 1743 GB total, 968 feature groups
    "E": {"model_size_gb": 1743, "feature_groups": 968, "traffic_per_s": 9.19e7,
          "tenants": ("ctr", "fr", "cmt"), "shared_feature_groups": 0.8},
}

# Paper Table 2 reference values for the reproduction check
TABLE_2 = {
    "A": {"legacy": (30, 1.53e6, 11450), "jizhi": (23, 4.42e6, 3970)},
    "B": {"legacy": (29, 1.63e6, 12750), "jizhi": (24, 4.36e6, 4773)},
    "C": {"legacy": (41, 2.80e6, 2067), "jizhi": (40, 5.21e6, 1110)},
    "D": {"legacy": (22, 3.53e6, 4280), "jizhi": (18, 8.24e6, 1833)},
}


def production_scale_note() -> str:
    return ("Simulated services preserve Table 1's RATIOS (feature groups, "
            "traffic spread, model-size ordering); absolute traffic is "
            "scaled by INSTANCE_SCALE (service_model.py) so a CPU sim of "
            "10^3-10^4 requests maps onto the paper's 10^7-10^8/s fleet.")
