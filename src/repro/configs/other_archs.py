"""SchNet (GNN) and the four recsys architectures, exact assigned configs.

RecSys feature-field vocabularies: the assignment fixes embed_dim / mlp /
seq_len; table row counts follow the paper's regime (10^6–10^9 rows; JiZHI's
production models are 210–500 GB of sparse parameters, Table 1). We size
fields to land the flagship (two-tower) at ~0.5 TB fp32 — web-scale, and
shardable over 256 chips (2 GB/device) — with smaller tables for the
18/64-dim rankers, mirroring Table 1's service spread. All vocabs are
multiples of 512 so rows shard evenly over the ``model`` axis of both meshes.
"""
from dataclasses import replace

from repro.configs.base import FeatureField, GNNConfig, RecsysConfig

# [arXiv:1706.08566] SchNet: 3 interactions, 64 hidden, 300 RBF, 10Å cutoff.
SCHNET = GNNConfig(name="schnet", n_interactions=3, d_hidden=64,
                   n_rbf=300, cutoff=10.0, n_atom_types=100)

_M = 1024 * 1024

# [RecSys'19 (YouTube)] two-tower retrieval: dim 256, towers 1024-512-256, dot.
TWO_TOWER = RecsysConfig(
    name="two-tower-retrieval", model="two_tower", embed_dim=256,
    user_fields=(
        FeatureField("user_id", 256 * _M),
        FeatureField("user_hist", 64 * _M, bag=50, combiner="mean"),
        FeatureField("user_geo", 1 * _M),
        FeatureField("user_ctx", 16 * _M, bag=8),
    ),
    item_fields=(
        FeatureField("item_id", 128 * _M),
        FeatureField("item_cat", 1 * _M, bag=4),
        FeatureField("item_author", 32 * _M),
    ),
    tower_mlp=(1024, 512, 256),
)

# [arXiv:1904.08030] MIND: dim 64, 4 interests, 3 capsule routing iters.
MIND = RecsysConfig(
    name="mind", model="mind", embed_dim=64,
    user_fields=(FeatureField("user_id", 64 * _M),),
    item_fields=(FeatureField("item_id", 64 * _M),
                 FeatureField("item_cat", 1 * _M)),
    n_interests=4, capsule_iters=3, seq_len=50,
    mlp=(256, 64),
)

# [arXiv:1706.06978] DIN: dim 18, seq 100, attn MLP 80-40, MLP 200-80.
DIN = RecsysConfig(
    name="din", model="din", embed_dim=18,
    user_fields=(FeatureField("user_id", 64 * _M),
                 FeatureField("user_profile", 1 * _M, bag=4)),
    item_fields=(FeatureField("item_id", 64 * _M),
                 FeatureField("item_cat", 1 * _M)),
    seq_len=100, attn_mlp=(80, 40), mlp=(200, 80),
)

# [arXiv:1809.03672] DIEN: dim 18, seq 100, GRU 108, AUGRU, MLP 200-80.
DIEN = RecsysConfig(
    name="dien", model="dien", embed_dim=18,
    user_fields=(FeatureField("user_id", 64 * _M),
                 FeatureField("user_profile", 1 * _M, bag=4)),
    item_fields=(FeatureField("item_id", 64 * _M),
                 FeatureField("item_cat", 1 * _M)),
    seq_len=100, gru_dim=108, mlp=(200, 80),
)


def reduced_gnn(cfg: GNNConfig) -> GNNConfig:
    return replace(cfg, n_interactions=2, d_hidden=16, n_rbf=20)


def reduced_recsys(cfg: RecsysConfig) -> RecsysConfig:
    uf = tuple(replace(f, vocab=1024) for f in cfg.user_fields)
    itf = tuple(replace(f, vocab=1024) for f in cfg.item_fields)
    small = {"tower_mlp": tuple(min(w, 32) for w in cfg.tower_mlp),
             "mlp": tuple(min(w, 32) for w in cfg.mlp),
             "attn_mlp": tuple(min(w, 16) for w in cfg.attn_mlp)}
    return replace(cfg, user_fields=uf, item_fields=itf,
                   embed_dim=min(cfg.embed_dim, 16),
                   seq_len=min(cfg.seq_len, 12) if cfg.seq_len else 0,
                   gru_dim=min(cfg.gru_dim, 16) if cfg.gru_dim else 0, **small)
