"""Config dataclasses for every supported architecture family + shape specs."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Sequence

# --------------------------------------------------------------------------
# LM family
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: Optional[int] = None
    d_nope: int = 128
    d_rope: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 1
    n_dense_layers: int = 1          # leading dense-FFN layers (DeepSeek style)
    dense_d_ff: Optional[int] = None  # d_ff of those leading dense layers
    capacity_factor: float = 1.25
    router_scale: Optional[float] = None


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu
    glu: bool = True                 # SwiGLU-style gated FFN
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    mtp: bool = False                # DeepSeek-V3 multi-token prediction head
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    param_dtype: str = "float32"     # big configs override to bfloat16
    attn_chunk: int = 1024           # KV-chunk for memory-efficient attention
    attn_shard: str = "kv"           # which head dim to TP-shard: kv | group | none
    remat: bool = True
    shard_carry: bool = False        # shard residual stream over `model`
                                     # (Megatron-SP-style activation sharding)
    fsdp_params: bool = False        # ZeRO-3: shard non-expert params over
                                     # `data` too (re-gathered per layer)
    family: str = "lm"

    @property
    def n_group(self) -> int:
        return self.n_heads // self.n_kv

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.mla is None:
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv * self.d_head \
                + self.n_heads * self.d_head * d
        else:
            m = self.mla
            dq = m.d_nope + m.d_rope
            if m.q_lora:
                q = d * m.q_lora + m.q_lora * self.n_heads * dq
            else:
                q = d * self.n_heads * dq
            attn = q + d * (m.kv_lora + m.d_rope) \
                + m.kv_lora * self.n_heads * (m.d_nope + m.v_dim) \
                + self.n_heads * m.v_dim * d
        def ffn(dff): return d * dff * (3 if self.glu else 2)
        if self.moe is None:
            blocks = L * (attn + ffn(self.d_ff))
        else:
            mo = self.moe
            n_moe = L - mo.n_dense_layers
            dense = mo.n_dense_layers * ffn(mo.dense_d_ff or self.d_ff)
            routed = n_moe * (mo.n_routed * ffn(mo.d_ff_expert)
                              + mo.n_shared * ffn(mo.d_ff_expert)
                              + d * mo.n_routed)
            blocks = L * attn + dense + routed
        if self.mtp:
            blocks += attn + ffn(self.moe.d_ff_expert * (self.moe.n_routed + self.moe.n_shared)
                                 if self.moe else self.d_ff) * 0  # MTP block ≈ one layer, counted coarsely below
            blocks += 2 * d * d  # mtp projection
        return emb + blocks

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        mo = self.moe
        full = self.param_count()
        def ffn(dff): return d * dff * (3 if self.glu else 2)
        n_moe = L - mo.n_dense_layers
        inactive = n_moe * (mo.n_routed - mo.top_k) * ffn(mo.d_ff_expert)
        return full - inactive


# --------------------------------------------------------------------------
# GNN family
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_atom_types: int = 100          # embedding vocab for molecular graphs
    readout: str = "sum"
    param_dtype: str = "float32"
    family: str = "gnn"


# --------------------------------------------------------------------------
# RecSys family
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class FeatureField:
    name: str
    vocab: int                       # hashed bucket count
    bag: int = 1                     # multi-hot width (1 = one-hot)
    combiner: str = "sum"


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    model: str                       # two_tower | mind | din | dien
    embed_dim: int
    user_fields: tuple[FeatureField, ...] = ()
    item_fields: tuple[FeatureField, ...] = ()
    tower_mlp: tuple[int, ...] = ()          # two-tower
    n_interests: int = 0                      # mind
    capsule_iters: int = 0                    # mind
    seq_len: int = 0                          # din/dien/mind history length
    attn_mlp: tuple[int, ...] = ()            # din
    gru_dim: int = 0                          # dien
    mlp: tuple[int, ...] = ()                 # final MLP
    param_dtype: str = "float32"
    family: str = "recsys"

    def table_specs(self):
        from repro.sparse.embedding import TableSpec
        return [TableSpec(f.name, f.vocab, self.embed_dim, f.combiner)
                for f in self.user_fields + self.item_fields]

    def param_count(self) -> int:
        n = sum(f.vocab * self.embed_dim for f in self.user_fields + self.item_fields)
        return n  # MLP params are negligible vs tables; counted exactly in models


# --------------------------------------------------------------------------
# Shapes
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode | graph_full | graph_mini | graph_batched
                     # | rec_train | rec_serve | rec_retrieval
    dims: dict = field(default_factory=dict)


LM_SHAPES = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeSpec("long_500k", "decode_long", {"seq_len": 524288, "global_batch": 1}),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "graph_full",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
    ShapeSpec("minibatch_lg", "graph_mini",
              {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
               "fanout": (15, 10), "d_feat": 602}),
    ShapeSpec("ogb_products", "graph_full",
              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100}),
    ShapeSpec("molecule", "graph_batched",
              {"n_nodes": 30, "n_edges": 64, "batch": 128}),
)

REC_SHAPES = (
    ShapeSpec("train_batch", "rec_train", {"batch": 65536}),
    ShapeSpec("serve_p99", "rec_serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "rec_serve", {"batch": 262144}),
    ShapeSpec("retrieval_cand", "rec_retrieval", {"batch": 1, "n_candidates": 1000000}),
)
