"""The five assigned LM-family architectures (exact public configs)."""
from repro.configs.base import LMConfig, MLAConfig, MoEConfig

# [hf:Qwen/Qwen3-8B] 36L d4096 32H (GQA kv=8) ff12288 v151936, qk_norm, RoPE
QWEN3_8B = LMConfig(
    name="qwen3-8b", n_layers=36, d_model=4096, n_heads=32, n_kv=8, d_head=128,
    d_ff=12288, vocab=151936, qk_norm=True, rope_theta=1e6,
    norm="rmsnorm", act="silu", glu=True,
    param_dtype="bfloat16", attn_shard="kv")

# [hf:HuggingFaceTB/SmolLM-135M] 30L d576 9H (GQA kv=3) ff1536 v49152, llama-arch
SMOLLM_135M = LMConfig(
    name="smollm-135m", n_layers=30, d_model=576, n_heads=9, n_kv=3, d_head=64,
    d_ff=1536, vocab=49152, rope_theta=1e4, tie_embeddings=True,
    norm="rmsnorm", act="silu", glu=True,
    param_dtype="float32", attn_shard="none")

# [arXiv:2402.19173] 32L d4608 36H (GQA kv=4) ff18432 v49152; LayerNorm+GELU MLP
STARCODER2_7B = LMConfig(
    name="starcoder2-7b", n_layers=32, d_model=4608, n_heads=36, n_kv=4, d_head=128,
    d_ff=18432, vocab=49152, rope_theta=1e5,
    norm="layernorm", act="gelu", glu=False,
    param_dtype="bfloat16", attn_shard="group")

# [arXiv:2405.04434 / hf:deepseek-ai/DeepSeek-V2-Lite] 27L d2048 16H MLA
# kv_lora=512 d_rope=64; 1 leading dense layer (ff 10944); 26 MoE layers:
# 2 shared + 64 routed top-6, expert ff 1408.
DEEPSEEK_V2_LITE = LMConfig(
    name="deepseek-v2-lite-16b", n_layers=27, d_model=2048, n_heads=16, n_kv=16,
    d_head=128, d_ff=10944, vocab=102400, rope_theta=1e4,
    mla=MLAConfig(kv_lora=512, q_lora=None, d_nope=128, d_rope=64, v_dim=128),
    moe=MoEConfig(n_routed=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  n_dense_layers=1, dense_d_ff=10944, capacity_factor=1.25),
    norm="rmsnorm", act="silu", glu=True,
    param_dtype="bfloat16", attn_shard="kv", attn_chunk=512)

# [arXiv:2412.19437] 61L d7168 128H MLA (kv_lora 512, q_lora 1536, rope 64);
# 3 leading dense layers (ff 18432); 58 MoE layers: 1 shared + 256 routed
# top-8, expert ff 2048; MTP depth 1.
DEEPSEEK_V3 = LMConfig(
    name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128, n_kv=128,
    d_head=128, d_ff=18432, vocab=129280, rope_theta=1e4,
    mla=MLAConfig(kv_lora=512, q_lora=1536, d_nope=128, d_rope=64, v_dim=128),
    moe=MoEConfig(n_routed=256, top_k=8, d_ff_expert=2048, n_shared=1,
                  n_dense_layers=3, dense_d_ff=18432, capacity_factor=1.25),
    mtp=True,
    norm="rmsnorm", act="silu", glu=True,
    param_dtype="bfloat16", attn_shard="kv", shard_carry=True,
    fsdp_params=True, attn_chunk=512)


def reduced_lm(cfg: LMConfig) -> LMConfig:
    """Same family, smoke-test scale: few layers, narrow, tiny vocab."""
    from dataclasses import replace
    moe = cfg.moe
    if moe is not None:
        # capacity 4.0 → no token drops: smoke tests assert exact
        # decode≡prefill equivalence, which capacity drops would break
        moe = replace(moe, n_routed=8, top_k=2, d_ff_expert=64,
                      n_dense_layers=min(1, moe.n_dense_layers),
                      dense_d_ff=128, capacity_factor=4.0)
    mla = cfg.mla
    if mla is not None:
        from dataclasses import replace as rep
        mla = rep(mla, kv_lora=32, q_lora=(24 if mla.q_lora else None),
                  d_nope=16, d_rope=8, v_dim=16)
    n_kv = min(cfg.n_kv, 2) if cfg.mla is None else 4
    n_heads = (4 if cfg.mla else (n_kv * min(cfg.n_group, 2)))
    return replace(
        cfg, n_layers=3 if moe is None else 4, d_model=64,
        n_heads=n_heads, n_kv=(n_heads if cfg.mla else n_kv), d_head=16,
        d_ff=128, vocab=512, mla=mla, moe=moe,
        param_dtype="float32", attn_chunk=32, remat=False)
