"""Architecture registry: --arch <id> → (config, shapes, reduced config)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from repro.configs import lm_archs, other_archs
from repro.configs.base import (GNN_SHAPES, LM_SHAPES, REC_SHAPES, ShapeSpec)


@dataclass(frozen=True)
class ArchDef:
    arch_id: str
    family: str                 # lm | gnn | recsys
    config: Any
    shapes: tuple[ShapeSpec, ...]
    reduced: Callable[[Any], Any]


ARCHS: dict[str, ArchDef] = {}


def _reg(arch_id, family, config, shapes, reduced):
    ARCHS[arch_id] = ArchDef(arch_id, family, config, shapes, reduced)


_reg("qwen3-8b", "lm", lm_archs.QWEN3_8B, LM_SHAPES, lm_archs.reduced_lm)
_reg("smollm-135m", "lm", lm_archs.SMOLLM_135M, LM_SHAPES, lm_archs.reduced_lm)
_reg("starcoder2-7b", "lm", lm_archs.STARCODER2_7B, LM_SHAPES, lm_archs.reduced_lm)
_reg("deepseek-v2-lite-16b", "lm", lm_archs.DEEPSEEK_V2_LITE, LM_SHAPES,
     lm_archs.reduced_lm)
_reg("deepseek-v3-671b", "lm", lm_archs.DEEPSEEK_V3, LM_SHAPES, lm_archs.reduced_lm)
_reg("schnet", "gnn", other_archs.SCHNET, GNN_SHAPES, other_archs.reduced_gnn)
_reg("two-tower-retrieval", "recsys", other_archs.TWO_TOWER, REC_SHAPES,
     other_archs.reduced_recsys)
_reg("mind", "recsys", other_archs.MIND, REC_SHAPES, other_archs.reduced_recsys)
_reg("din", "recsys", other_archs.DIN, REC_SHAPES, other_archs.reduced_recsys)
_reg("dien", "recsys", other_archs.DIEN, REC_SHAPES, other_archs.reduced_recsys)


def get(arch_id: str) -> ArchDef:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def get_shape(arch: ArchDef, shape_name: str) -> ShapeSpec:
    for s in arch.shapes:
        if s.name == shape_name:
            return s
    raise KeyError(f"{arch.arch_id} has no shape {shape_name!r}; "
                   f"known: {[s.name for s in arch.shapes]}")


def all_cells():
    """All 40 (arch, shape) baseline cells."""
    return [(a, s) for a in ARCHS.values() for s in a.shapes]
