"""Executor equivalence (ISSUE 2 satellite): the same SEDP + seeded traffic
must produce the same per-event RESULTS under SimExecutor, AsyncExecutor and
LegacyExecutor. Latencies/throughput differ by design (that's what the
executors model); payloads must not — the pipeline's function is executor-
independent."""
import numpy as np

from repro.core.executors import AsyncExecutor, LegacyExecutor, SimExecutor
from repro.core.sedp import SEDP, Event


def _build():
    """A funnel of pure per-event transforms. Ops are batch-size-invariant
    and order-invariant (each event's output depends only on its own
    payload), so any batching/interleaving discipline must agree."""
    g = SEDP()

    def op_feat(batch, ctx):
        for ev in batch:
            x = ev.payload["x"]
            ev.payload["feat"] = (x * 2654435761) % 1013
        return batch

    def op_score(batch, ctx):
        for ev in batch:
            rng = np.random.default_rng(ev.payload["feat"])
            ev.payload["scores"] = [round(float(s), 9)
                                    for s in rng.random(4)]
        return batch

    def op_top(batch, ctx):
        for ev in batch:
            ev.payload["best"] = max(ev.payload["scores"])
            ev.payload["trace"] = ev.payload.get("trace", 0) + 1
        return batch

    g.add_stage("feat", op_feat, batch_size=4, parallelism=2,
                sim_per_item_s=1e-4)
    g.add_stage("score", op_score, batch_size=8, parallelism=2,
                sim_per_item_s=2e-4, max_wait_s=1e-3)
    g.add_stage("top", op_top, batch_size=2, parallelism=1,
                sim_per_item_s=5e-5)
    g.chain("feat", "score", "top")
    return g


def _payloads(n, seed):
    rng = np.random.default_rng(seed)
    # unique ids: results are keyed by x, so collisions would false-positive
    # the duplication check
    return [{"x": int(v)} for v in rng.permutation(10_000)[:n]]


def _result_map(report):
    out = {}
    for ev in report.results:
        key = ev.payload["x"]
        assert key not in out, "event duplicated"
        out[key] = {k: ev.payload[k] for k in ("feat", "scores", "best",
                                               "trace")}
    return out


def test_sim_async_legacy_same_results():
    n, seed = 60, 3
    base = _payloads(n, seed)

    sim = SimExecutor(_build().compile()).run(
        [(i * 1e-3, Event(payload=dict(p))) for i, p in enumerate(base)])
    asy = AsyncExecutor(_build().compile()).run(
        [Event(payload=dict(p)) for p in base])
    leg = LegacyExecutor(_build().compile(), batch_size=8).run(
        [(i * 1e-3, Event(payload=dict(p))) for i, p in enumerate(base)])

    assert len(sim.results) == len(asy.results) == len(leg.results) == n
    m_sim, m_asy, m_leg = map(_result_map, (sim, asy, leg))
    assert m_sim == m_asy == m_leg
    # every event traversed every stage exactly once
    assert all(v["trace"] == 1 for v in m_sim.values())


def test_sim_deterministic_across_repeats_with_microbatching():
    """Micro-batch windows + bounded queues must not break determinism:
    two identical runs produce identical latencies AND payloads."""
    n, seed = 80, 11
    base = _payloads(n, seed)

    def run_once():
        return SimExecutor(_build().compile()).run(
            [(i * 5e-4, Event(payload=dict(p))) for i, p in enumerate(base)])

    r1, r2 = run_once(), run_once()
    assert r1.latencies == r2.latencies
    assert _result_map(r1) == _result_map(r2)
    # the micro-batch window actually engaged on the score stage
    assert r1.stage_stats["score"].batches > 0


def test_async_sim_agree_under_route_steering():
    """Routing shortcuts (cache-hit style) must steer identically in both
    event-driven executors (Legacy by design ignores shortcuts)."""
    def build():
        g = SEDP()

        def router(batch, ctx):
            for ev in batch:
                ev.payload["routed"] = ev.payload["x"] % 2 == 0
                if ev.payload["routed"]:
                    ev.route = "sink"
            return batch

        def work(batch, ctx):
            for ev in batch:
                ev.payload["worked"] = True
            return batch

        g.add_stage("router", router, batch_size=4, sim_per_item_s=1e-4)
        g.add_stage("work", work, batch_size=4, sim_per_item_s=1e-3)
        g.add_stage("sink", lambda b, c: b, batch_size=4)
        g.add_edge("router", "work")
        g.add_edge("router", "sink")
        g.add_edge("work", "sink")
        return g.compile()

    base = _payloads(50, 29)
    sim = SimExecutor(build()).run(
        [(i * 1e-3, Event(payload=dict(p))) for i, p in enumerate(base)])
    asy = AsyncExecutor(build()).run([Event(payload=dict(p)) for p in base])

    def shape(rep):
        return {ev.payload["x"]: ev.payload.get("worked", False)
                for ev in rep.results}

    s, a = shape(sim), shape(asy)
    assert s == a
    assert all(worked != (x % 2 == 0) for x, worked in s.items())
