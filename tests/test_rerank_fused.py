"""Parity contract of the fused one-user-many-candidates re-rank path:
every impl (pallas-interpret, xla) against the jnp oracle, at tile
boundaries (T padding, C not a multiple of the block, masked history), and
end-to-end through din.score_candidates with compacted histories."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.rerank_score.ops import rerank_score
from repro.kernels.rerank_score.ref import rerank_score_ref
from repro.serve.bucketing import ShapeBucketer, compact_history, step_buckets

TOL = dict(rtol=2e-5, atol=2e-5)


def _towers(rng, D, d_u, d_i, H1=16, H2=16, M1=32, M2=32):
    mk = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32) * 0.2)
    attn = [{"w": mk(4 * D, H1), "b": mk(H1)},
            {"w": mk(H1, H2), "b": mk(H2)},
            {"w": mk(H2, 1), "b": mk(1)}]
    mlp = [{"w": mk(2 * D + d_u + d_i, M1), "b": mk(M1)},
           {"w": mk(M1, M2), "b": mk(M2)},
           {"w": mk(M2, 1), "b": mk(1)}]
    return attn, mlp


@pytest.mark.parametrize("C,T", [(64, 7),      # T % 8 != 0 (zero-padded)
                                 (300, 12),    # C % block != 0
                                 (257, 33),    # both off-boundary
                                 (128, 1),     # single-event history
                                 (130, 16)])   # C just over the block
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_rerank_score_edge_shapes(C, T, impl, rng):
    D, d_u, d_i = 8, 16, 8
    hist = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32))
    mask = jnp.asarray((rng.random(T) > 0.3).astype(np.float32))
    tgt = jnp.asarray(rng.normal(size=(C, D)).astype(np.float32))
    uo = jnp.asarray(rng.normal(size=(d_u,)).astype(np.float32))
    io = jnp.asarray(rng.normal(size=(C, d_i)).astype(np.float32))
    attn, mlp = _towers(rng, D, d_u, d_i)
    flat = [p[k] for p in attn + mlp for k in ("w", "b")]
    want = rerank_score_ref(hist, mask, tgt, uo, io, *flat)
    got = rerank_score(hist, mask, tgt, uo, io, attn, mlp,
                       block_c=128, impl=impl, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_rerank_score_fully_masked_history(rng):
    """All-masked history ⇒ pooled term is exactly zero in both paths."""
    D, d_u, d_i, C, T = 8, 16, 8, 64, 24
    hist = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32))
    mask = jnp.zeros((T,), jnp.float32)
    tgt = jnp.asarray(rng.normal(size=(C, D)).astype(np.float32))
    uo = jnp.asarray(rng.normal(size=(d_u,)).astype(np.float32))
    io = jnp.asarray(rng.normal(size=(C, d_i)).astype(np.float32))
    attn, mlp = _towers(rng, D, d_u, d_i)
    flat = [p[k] for p in attn + mlp for k in ("w", "b")]
    want = rerank_score_ref(hist, mask, tgt, uo, io, *flat)
    got = rerank_score(hist, mask, tgt, uo, io, attn, mlp, impl="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def _din_setup(seed=0):
    from repro.configs import registry
    from repro.models.recsys import din
    arch = registry.get("din")
    cfg = arch.reduced(arch.config)
    params = din.init(jax.random.PRNGKey(seed), cfg)
    return din, cfg, params


def _dense_scores(din, params, user, cand, cfg, C, path):
    v, i = din.score_candidates(params, user, cand, cfg, top_k=C, path=path)
    out = np.empty(C, np.float32)
    out[np.asarray(i)] = np.asarray(v)
    return out


@pytest.mark.parametrize("C", [30, 64, 200])
def test_score_candidates_fused_matches_jnp(C, rng):
    din, cfg, params = _din_setup()
    hist = np.full(cfg.seq_len, -1, np.int32)
    n = max(1, cfg.seq_len - 3)
    hist[:n] = rng.integers(0, 1024, n)
    user = {"hist": jnp.asarray(hist)[None],
            "fields": {f.name: jnp.asarray(rng.integers(
                0, f.vocab, (1,) if f.bag == 1 else (1, f.bag)))
                for f in cfg.user_fields}}
    # duplicate-heavy candidate ids (realistic recall mix) must not upset
    # the fused gather or the top-k tie handling
    ids = rng.integers(0, 16, C)
    cand = {"item_id": jnp.asarray(ids),
            "item_cat": jnp.asarray(rng.integers(0, 1024, C))}
    s_jnp = _dense_scores(din, params, user, cand, cfg, C, "jnp")
    s_fused = _dense_scores(din, params, user, cand, cfg, C, "fused")
    np.testing.assert_allclose(s_fused, s_jnp, **TOL)


def test_score_candidates_compacted_history_exact(rng):
    """Compaction (valid rows gathered to a bucket) is score-exact vs the
    oracle on the full padded history."""
    din, cfg, params = _din_setup()
    C = 48
    hist = np.full(cfg.seq_len, -1, np.int32)
    # interleaved valid/masked rows — compaction must reorder-safely
    idx = rng.permutation(cfg.seq_len)[:5]
    hist[idx] = rng.integers(0, 1024, 5)
    fields = {f.name: jnp.asarray(rng.integers(
        0, f.vocab, (1,) if f.bag == 1 else (1, f.bag)))
        for f in cfg.user_fields}
    cand = {"item_id": jnp.asarray(rng.integers(0, 1024, C)),
            "item_cat": jnp.asarray(rng.integers(0, 1024, C))}
    buckets = ShapeBucketer(step_buckets(cfg.seq_len, step=4))
    u_full = {"hist": jnp.asarray(hist)[None], "fields": fields}
    u_comp = {"hist": jnp.asarray(compact_history(hist, buckets))[None],
              "fields": fields}
    s_full = _dense_scores(din, params, u_full, cand, cfg, C, "jnp")
    s_comp = _dense_scores(din, params, u_comp, cand, cfg, C, "fused")
    np.testing.assert_allclose(s_comp, s_full, **TOL)


def test_score_candidates_topk_order_consistent(rng):
    """Fused and oracle agree on the induced ranking (modulo float ties)."""
    din, cfg, params = _din_setup()
    C = 64
    hist = np.full(cfg.seq_len, -1, np.int32)
    hist[:cfg.seq_len] = rng.integers(0, 1024, cfg.seq_len)
    user = {"hist": jnp.asarray(hist)[None],
            "fields": {f.name: jnp.asarray(rng.integers(
                0, f.vocab, (1,) if f.bag == 1 else (1, f.bag)))
                for f in cfg.user_fields}}
    cand = {"item_id": jnp.asarray(rng.integers(0, 1024, C)),
            "item_cat": jnp.asarray(rng.integers(0, 1024, C))}
    _, i_jnp = din.score_candidates(params, user, cand, cfg, top_k=10,
                                    path="jnp")
    _, i_fused = din.score_candidates(params, user, cand, cfg, top_k=10,
                                      path="fused")
    assert set(np.asarray(i_jnp).tolist()) == set(np.asarray(i_fused).tolist())
