"""HLO analyzer correctness (loop multipliers!) + service-model benchmarks."""
import numpy as np
import pytest

from repro.core.service_model import (SERVICES, Knobs, alloc_factor,
                                      cube_hit_model, diurnal_rate,
                                      query_hit_model, run_service)
from repro.launch.hlo_analysis import analyze_hlo, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[128,512]") == 128 * 512 * 4
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("(s32[], f32[4,4])") == 4 + 64
    assert shape_bytes("pred[]") == 1


def test_analyzer_multiplies_scan_bodies():
    """The whole point: dot inside a 7-trip while must count 7×."""
    import subprocess, sys, os, textwrap
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2,4), ("data","model"))
        L, M, K, N = 7, 256, 512, 512
        def f(ws, x):
            def body(x, w):
                return x @ w, None
            return jax.lax.scan(body, x, ws)[0]
        ws = jax.ShapeDtypeStruct((L, K, N), jnp.float32)
        xs = jax.ShapeDtypeStruct((M, K), jnp.float32)
        with mesh:
            co = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P(None, None, "model")),
                NamedSharding(mesh, P("data", None))),
                out_shardings=NamedSharding(mesh, P("data", None))
                ).lower(ws, xs).compile()
        res = analyze_hlo(co.as_text(), 8)
        analytic = 2 * L * (M // 2) * K * (N // 4)
        ratio = res["flops_per_device"] / analytic
        assert 0.95 < ratio < 1.3, (res["flops_per_device"], analytic)
        assert res["collective_bytes_per_device"] > 0
        print("HLO-OK", ratio)
    """
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    # pin CPU: libtpu is present in the image but no TPU is attached, and
    # backend autodetection can stall for minutes probing TPU metadata;
    # the forced host-platform device count lives on the CPU platform anyway
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=300, env=env)
    assert p.returncode == 0, p.stdout + p.stderr
    assert "HLO-OK" in p.stdout


import os  # noqa: E402  (used above)


def test_alloc_factor_prefers_paper_opt_knobs():
    """Table 4: Opt = more arenas, huge pages Always, extents ~25."""
    noopt = alloc_factor(Knobs())
    opt = alloc_factor(Knobs(arenas=549, huge_page=True, max_active_extent=25))
    assert opt < noopt


def test_hit_models_anchor_paper_points():
    assert abs(cube_hit_model(1.0, 1.08) - 0.84) < 0.02
    assert abs(query_hit_model(120.0) - 0.1926) < 0.005
    assert query_hit_model(300.0) > query_hit_model(60.0)


def test_diurnal_rate_peaks_in_evening():
    rates = [diurnal_rate(h, 100.0) for h in range(24)]
    assert 19 <= int(np.argmax(rates)) <= 23
    assert max(rates) / min(rates) > 2.0


def test_run_service_sedp_beats_legacy_capacity():
    spec = SERVICES["A"]
    sedp, rt, inst_s = run_service(spec, Knobs(), n_events=800, seed=1)
    legacy, _, inst_l = run_service(spec, Knobs(), n_events=800, seed=1,
                                    legacy=True)
    assert len(sedp.results) == 800 and len(legacy.results) == 800
    assert inst_s < inst_l                         # Table 2's headline
    assert sedp.avg_latency < legacy.avg_latency
    assert rt.cube_cache.overall_hit_ratio > 0.5   # caches actually engaged


def test_query_cache_window_knob_moves_hits():
    spec = SERVICES["A"]
    _, rt_short, _ = run_service(spec, Knobs(query_cache_window=60),
                                 n_events=1200, seed=2)
    _, rt_long, _ = run_service(spec, Knobs(query_cache_window=600),
                                n_events=1200, seed=2)
    assert rt_long.query_cache.stats.hit_ratio >= \
        rt_short.query_cache.stats.hit_ratio
