"""Embedding substrate: property-based (hypothesis) + sharded-vs-dense."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import runtime
from repro.launch.mesh import make_mesh
from repro.sparse.embedding import (TableSpec, embedding_bag_padded,
                                    embedding_bag_ragged, init_table, lookup,
                                    offsets_to_segment_ids)
from repro.sparse.hashing import hash_bucket, hash_bucket_np, signature_np
from repro.sparse.sharded import sharded_embedding_bag_2d, sharded_lookup


@st.composite
def bag_case(draw):
    V = draw(st.integers(4, 64))
    D = draw(st.integers(1, 16))
    B = draw(st.integers(1, 8))
    K = draw(st.integers(1, 6))
    seed = draw(st.integers(0, 2**31 - 1))
    return V, D, B, K, seed


@settings(max_examples=40, deadline=None)
@given(bag_case())
def test_property_padded_bag_equals_loop_oracle(case):
    V, D, B, K, seed = case
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    ids = rng.integers(0, V, (B, K)).astype(np.int32)
    w = (rng.random((B, K)) > 0.3).astype(np.float32)
    got = np.asarray(embedding_bag_padded(table, jnp.asarray(ids),
                                          jnp.asarray(w)))
    want = np.zeros((B, D), np.float32)
    for b in range(B):
        for k in range(K):
            want[b] += w[b, k] * np.asarray(table)[ids[b, k]]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(bag_case())
def test_property_ragged_equals_padded(case):
    V, D, B, K, seed = case
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    ids = rng.integers(0, V, (B, K)).astype(np.int32)
    seg = np.repeat(np.arange(B), K).astype(np.int32)
    padded = embedding_bag_padded(table, jnp.asarray(ids))
    ragged = embedding_bag_ragged(table, jnp.asarray(ids.reshape(-1)),
                                  jnp.asarray(seg), B)
    np.testing.assert_allclose(np.asarray(padded), np.asarray(ragged),
                               rtol=1e-5, atol=1e-5)
    # mean combiner too
    p2 = embedding_bag_padded(table, jnp.asarray(ids), combiner="mean")
    r2 = embedding_bag_ragged(table, jnp.asarray(ids.reshape(-1)),
                              jnp.asarray(seg), B, combiner="mean")
    np.testing.assert_allclose(np.asarray(p2), np.asarray(r2),
                               rtol=1e-5, atol=1e-5)


def test_offsets_to_segments():
    seg = offsets_to_segment_ids(np.array([0, 3, 3, 7]), 10)
    assert list(seg) == [0, 0, 0, 2, 2, 2, 2, 3, 3, 3]


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 1_000_000))
def test_property_hashing_deterministic_and_in_range(seed, vocab):
    rng = np.random.default_rng(seed)
    raw = rng.integers(0, 2**62, 100)
    h1 = hash_bucket_np(3, raw, vocab)
    h2 = hash_bucket_np(3, raw, vocab)
    assert np.array_equal(h1, h2)
    assert h1.min() >= 0 and h1.max() < vocab
    # device-side hash too
    d1 = hash_bucket(3, jnp.asarray(raw % (2**31), jnp.int32), vocab)
    d2 = hash_bucket(3, jnp.asarray(raw % (2**31), jnp.int32), vocab)
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    assert int(jnp.min(d1)) >= 0 and int(jnp.max(d1)) < vocab


def test_hash_spread():
    """Signatures spread ~uniformly across buckets (universal hashing)."""
    ids = np.arange(100_000)
    buckets = hash_bucket_np(1, ids, 64)
    counts = np.bincount(buckets, minlength=64)
    assert counts.min() > 0.8 * counts.mean()
    assert counts.max() < 1.2 * counts.mean()
    # different groups decorrelate
    b2 = hash_bucket_np(2, ids, 64)
    assert (buckets == b2).mean() < 0.05


def test_sharded_lookup_matches_dense_on_unit_mesh(rng):
    """shard_map path (1-device mesh axes) ≡ dense take."""
    mesh = make_mesh((1, 1), ("data", "model"))
    table = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, (4, 3)).astype(np.int32))
    with runtime.use_mesh(mesh):
        got = sharded_lookup(table, ids)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.take(table, ids, axis=0)))


def test_sharded_bag_2d_matches_dense(rng):
    mesh = make_mesh((1, 1), ("data", "model"))
    table = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, (6, 4)).astype(np.int32))
    w = jnp.asarray(rng.random((6, 4)).astype(np.float32))
    with runtime.use_mesh(mesh):
        got = sharded_embedding_bag_2d(table, ids, w)
    want = embedding_bag_padded(table, ids, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_sharded_lookup_gradient_is_sparse_scatter(rng):
    mesh = make_mesh((1, 1), ("data", "model"))
    table = jnp.asarray(rng.normal(size=(32, 4)).astype(np.float32))
    ids = jnp.asarray(np.array([1, 5, 5, 9], np.int32))
    with runtime.use_mesh(mesh):
        g = jax.grad(lambda t: sharded_lookup(t, ids).sum())(table)
    g = np.asarray(g)
    assert g[5, 0] == 2.0 and g[1, 0] == 1.0 and g[0, 0] == 0.0
