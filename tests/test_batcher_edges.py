"""Batcher edge cases (ISSUE 2 satellite): MicroBatcher flush ordering and
ContinuousBatcher slot churn."""
import numpy as np

from repro.serve.batcher import ContinuousBatcher, MicroBatcher


# ------------------------------------------------------------ MicroBatcher

def test_microbatcher_size_flush_wins_before_timeout():
    """The size trigger fires on the offer that fills the batch, even if the
    window would not close for a long time — and FIFO order is preserved."""
    mb = MicroBatcher(max_batch=3, max_wait_s=100.0)
    assert mb.offer("a", now=0.0) is None
    assert mb.offer("b", now=1.0) is None
    assert mb.offer("c", now=2.0) == ["a", "b", "c"]
    assert len(mb) == 0


def test_microbatcher_timeout_flush_wins_before_size():
    """A partial batch flushes at first_at + max_wait_s; the window restarts
    from the NEXT first offer, not from the flush."""
    mb = MicroBatcher(max_batch=100, max_wait_s=1.0)
    mb.offer("a", now=0.0)
    mb.offer("b", now=0.5)
    assert mb.poll(now=0.99) is None               # window still open
    assert mb.poll(now=1.0) == ["a", "b"]          # boundary is inclusive
    mb.offer("c", now=5.0)
    assert mb.poll(now=5.5) is None                # fresh window from 5.0
    assert mb.poll(now=6.0) == ["c"]


def test_microbatcher_empty_poll_and_flush():
    mb = MicroBatcher(max_batch=4, max_wait_s=0.1)
    assert mb.poll(now=123.0) is None
    assert mb.flush() is None
    assert len(mb) == 0
    # an offer right after an empty poll starts a new window at that offer
    mb.offer("x", now=200.0)
    assert mb.poll(now=200.05) is None
    deadline = mb.deadline()
    assert abs(deadline - 200.1) < 1e-9
    assert mb.poll(now=deadline) == ["x"]


def test_microbatcher_size_flush_resets_window():
    """After a size flush, the next offer opens a new window — stale
    first_at must not cause an instant timeout flush."""
    mb = MicroBatcher(max_batch=2, max_wait_s=1.0)
    mb.offer(1, now=0.0)
    assert mb.offer(2, now=0.2) == [1, 2]
    mb.offer(3, now=10.0)
    assert mb.poll(now=10.5) is None               # NOT flushed via old window
    assert mb.poll(now=11.0) == [3]


# ------------------------------------------------------- ContinuousBatcher

def test_continuous_batcher_join_mid_decode():
    """A request submitted while others are mid-decode claims a free slot
    immediately and decodes from its own prefill length."""
    cb = ContinuousBatcher(n_slots=3, s_max=64)
    cb.submit(0, prompt_len=4, max_new=8)
    cb.submit(1, prompt_len=6, max_new=8)
    cb.step_complete(np.array([False, False, False]))   # 0,1 advance
    assert cb.lengths().tolist() == [5, 7, 0]
    cb.submit(2, prompt_len=10, max_new=4)              # joins mid-decode
    assert cb.active_mask.tolist() == [True, True, True]
    cb.step_complete(np.array([False, False, False]))
    assert cb.lengths().tolist() == [6, 8, 11]
    assert cb.completed == []


def test_continuous_batcher_eos_and_max_new_same_step():
    """EOS on one slot and max_new exhaustion on another in the SAME step:
    both complete exactly once, both slots free for waiters."""
    cb = ContinuousBatcher(n_slots=2, s_max=64)
    cb.submit(7, prompt_len=3, max_new=1)      # exhausts max_new this step
    cb.submit(8, prompt_len=3, max_new=9)      # EOS this step
    cb.submit(9, prompt_len=2, max_new=2)      # waiting
    cb.submit(10, prompt_len=2, max_new=2)     # waiting
    cb.step_complete(np.array([False, True]))
    assert sorted(cb.completed) == [7, 8]
    assert len(cb.completed) == 2              # no double-completion
    # both freed slots were refilled from the waiting queue in FIFO order
    assert [s.request_id for s in cb.slots] == [9, 10]
    assert cb.waiting == []


def test_continuous_batcher_admission_order_fifo():
    cb = ContinuousBatcher(n_slots=1, s_max=64)
    for req in (100, 101, 102):
        cb.submit(req, prompt_len=2, max_new=1)
    served = []
    while cb.active_mask.any():
        served.append(cb.slots[0].request_id)
        cb.step_complete(np.array([False]))
    assert served == [100, 101, 102]           # strict submission order


def test_continuous_batcher_s_max_cap_and_utilization():
    """A sequence hitting s_max completes even with max_new remaining;
    utilization tracks the active fraction of slots."""
    cb = ContinuousBatcher(n_slots=4, s_max=5)
    cb.submit(0, prompt_len=4, max_new=100)
    cb.submit(1, prompt_len=1, max_new=100)
    assert cb.utilization == 0.5
    cb.step_complete(np.zeros(4, bool))        # req 0 reaches s_max=5
    assert cb.completed == [0]
    assert cb.utilization == 0.25
    for _ in range(3):
        cb.step_complete(np.zeros(4, bool))    # req 1: 2→5
    assert cb.completed == [0, 1]
    assert cb.utilization == 0.0
