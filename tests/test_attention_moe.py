"""Attention (chunked/online-softmax + decode) and MoE dispatch correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.models.attention import chunked_attention, decode_attention
from repro.models.moe import _capacity, moe_apply, moe_expert_init


def naive_attention(q, k, v, causal, q_offset=0):
    B, Sq, H, G, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k) / np.sqrt(D)
    if causal:
        mask = (q_offset + jnp.arange(Sq))[:, None] >= jnp.arange(Sk)[None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v)


@st.composite
def attn_case(draw):
    B = draw(st.integers(1, 3))
    Sq = draw(st.integers(1, 24))
    H = draw(st.integers(1, 3))
    G = draw(st.integers(1, 3))
    D = draw(st.sampled_from([4, 8, 16]))
    chunk = draw(st.sampled_from([3, 8, 16]))
    causal = draw(st.booleans())
    seed = draw(st.integers(0, 10_000))
    return B, Sq, H, G, D, chunk, causal, seed


@settings(max_examples=25, deadline=None)
@given(attn_case())
def test_property_chunked_attention_equals_naive(case):
    B, Sq, H, G, D, chunk, causal, seed = case
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, G, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Sq, H, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Sq, H, D)).astype(np.float32))
    got = chunked_attention(q, k, v, causal=causal, chunk=chunk)
    want = naive_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_masks_by_length(rng):
    B, S, H, G, D = 2, 32, 2, 2, 8
    q = jnp.asarray(rng.normal(size=(B, 1, H, G, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    out_12 = decode_attention(q, k, v, jnp.asarray(12))
    # garbage beyond position 12 must not matter
    k2 = k.at[:, 12:].set(999.0)
    v2 = v.at[:, 12:].set(-999.0)
    out_12b = decode_attention(q, k2, v2, jnp.asarray(12))
    np.testing.assert_allclose(np.asarray(out_12), np.asarray(out_12b),
                               rtol=1e-6)
    want = naive_attention(q, k[:, :12], v[:, :12], causal=False)
    np.testing.assert_allclose(np.asarray(out_12), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- MoE

def dense_moe_oracle(p, x, cfg, act="silu"):
    """Per-token dense evaluation of the same routing (no capacity drops)."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    h1 = jnp.einsum("td,edf->tef", x, p["w1"])
    h3 = jnp.einsum("td,edf->tef", x, p["w3"])
    h = jax.nn.silu(h1) * h3
    y_all = jnp.einsum("tef,efd->ted", h, p["w2"])          # (T,E,d)
    out = jnp.zeros_like(x)
    for j in range(cfg.top_k):
        out = out + jnp.take_along_axis(
            y_all, idx[:, j][:, None, None], axis=1)[:, 0] \
            * gate[:, j, None].astype(x.dtype)
    return out


@pytest.mark.parametrize("T,E,k,d,f", [(32, 8, 2, 16, 8), (64, 4, 1, 8, 16)])
def test_moe_dispatch_matches_dense_oracle(T, E, k, d, f, rng):
    cfg = MoEConfig(n_routed=E, top_k=k, d_ff_expert=f,
                    capacity_factor=float(E))   # capacity ⇒ no drops
    key = jax.random.PRNGKey(0)
    p = moe_expert_init(key, d, cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(T, d)).astype(np.float32))
    got, aux = moe_apply(p, x, cfg)
    want = dense_moe_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens(rng):
    cfg = MoEConfig(n_routed=4, top_k=2, d_ff_expert=8, capacity_factor=0.25)
    p = moe_expert_init(jax.random.PRNGKey(0), 8, cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    got, _ = moe_apply(p, x, cfg)
    want = dense_moe_oracle(p, x, cfg)
    # with tiny capacity some tokens must differ (drops) but none blow up
    assert not np.allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    assert np.isfinite(np.asarray(got)).all()


def test_moe_grad_flows(rng):
    cfg = MoEConfig(n_routed=4, top_k=2, d_ff_expert=8)
    p = moe_expert_init(jax.random.PRNGKey(0), 8, cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))

    def loss(p):
        out, aux = moe_apply(p, x, cfg)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("w1", "w2", "w3", "router"):
        assert float(jnp.abs(g[name]).sum()) > 0, name


def test_capacity_rounding():
    cfg = MoEConfig(n_routed=8, top_k=2, d_ff_expert=8, capacity_factor=1.25)
    c = _capacity(1024, cfg)
    assert c % 8 == 0 and c >= 1024 * 2 * 1.25 / 8
