"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (deliverable f).
The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import synthetic
from repro.models import schnet, transformer
from repro.launch.specs import REC_MODULES

LM_ARCHS = ["qwen3-8b", "smollm-135m", "starcoder2-7b",
            "deepseek-v2-lite-16b", "deepseek-v3-671b"]
REC_ARCHS = ["two-tower-retrieval", "mind", "din", "dien"]


def _gnorm(grads):
    return float(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                     for g in jax.tree.leaves(grads)))


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke(arch_id, rng):
    a = registry.get(arch_id)
    cfg = a.reduced(a.config)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(synthetic.lm_batch(rng, cfg, 2, 16)["tokens"])
    loss, grads = jax.value_and_grad(transformer.lm_loss)(params, toks, cfg)
    assert np.isfinite(float(loss)) and np.isfinite(_gnorm(grads))
    # decode + prefill round trip
    logits_p, cache = transformer.prefill(params, toks, cfg, smax=32)
    assert logits_p.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits_p)).all()
    logits_d, cache = transformer.decode_step(params, cache, toks[:, :1], cfg)
    assert logits_d.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits_d)).all()
    assert int(cache.length) == 17


@pytest.mark.parametrize("arch_id", LM_ARCHS[:4])
def test_lm_decode_matches_prefill(arch_id, rng):
    """Decoding token t after prefilling t-1 must equal prefilling t —
    validates cache layout, rope positions, and (for MLA) the absorbed
    decode path against the expanded train path."""
    a = registry.get(arch_id)
    cfg = a.reduced(a.config)
    params = transformer.init(jax.random.PRNGKey(1), cfg)
    toks = jnp.asarray(synthetic.lm_batch(rng, cfg, 2, 12)["tokens"])
    full_logits, _ = transformer.prefill(params, toks, cfg, smax=16)
    _, cache = transformer.prefill(params, toks[:, :-1], cfg, smax=16)
    step_logits, _ = transformer.decode_step(params, cache, toks[:, -1:], cfg)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_gnn_smoke(rng):
    a = registry.get("schnet")
    cfg = a.reduced(a.config)
    mol = synthetic.molecule_batch(rng, cfg, 4, 8, 16)
    params = schnet.init(jax.random.PRNGKey(0), cfg)
    inputs = {k: jnp.asarray(v) for k, v in mol.items()
              if k not in ("targets", "n_graphs")}
    energies = schnet.forward(params, inputs, cfg, n_graphs=4)
    assert energies.shape == (4,)
    loss, grads = jax.value_and_grad(schnet.loss_fn)(
        params, inputs, jnp.asarray(mol["targets"]), cfg, n_graphs=4)
    assert np.isfinite(float(loss)) and np.isfinite(_gnorm(grads))


def test_gnn_feature_graph_and_sampler(rng):
    from repro.data.sampler import CSRGraph, sample_fanout, subgraph_sizes
    a = registry.get("schnet")
    cfg = a.reduced(a.config)
    graph = CSRGraph.random(rng, 500, avg_degree=8)
    seeds = rng.integers(0, 500, 16)
    nodes, edges, mask = sample_fanout(graph, seeds, (3, 2), rng)
    n_sub, e_sub = subgraph_sizes(16, (3, 2))
    assert len(nodes) == n_sub and len(edges) == e_sub
    assert edges.max() <= n_sub
    params = schnet.init(jax.random.PRNGKey(0), cfg, d_feat_in=9)
    inputs = {"node_feat": jnp.asarray(rng.normal(size=(n_sub, 9)),
                                       jnp.float32),
              "edges": jnp.asarray(edges),
              "edge_dist": jnp.asarray(rng.uniform(0.5, 9, e_sub),
                                       jnp.float32),
              "graph_ids": jnp.zeros(n_sub, jnp.int32)}
    out = schnet.forward(params, inputs, cfg, n_graphs=1)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("arch_id", REC_ARCHS)
def test_recsys_smoke(arch_id, rng):
    a = registry.get(arch_id)
    cfg = a.reduced(a.config)
    mod = REC_MODULES[cfg.model]
    params = mod.init(jax.random.PRNGKey(0), cfg)
    batch = jax.tree.map(jnp.asarray, synthetic.recsys_batch(rng, cfg, 8))
    loss, grads = jax.value_and_grad(mod.loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss)) and np.isfinite(_gnorm(grads))
    scores = mod.serve_scores(params, batch, cfg)
    assert scores.shape == (8,)
    assert np.isfinite(np.asarray(scores)).all()


@pytest.mark.parametrize("arch_id", REC_ARCHS)
def test_recsys_retrieval(arch_id, rng):
    a = registry.get(arch_id)
    cfg = a.reduced(a.config)
    mod = REC_MODULES[cfg.model]
    params = mod.init(jax.random.PRNGKey(0), cfg)
    batch = jax.tree.map(jnp.asarray, synthetic.recsys_batch(rng, cfg, 4))
    cand = {f.name: jnp.asarray(
        synthetic.recsys_ids(rng, [f], 64)[f.name])
        for f in cfg.item_fields}
    if cfg.model == "two_tower":
        u1 = jax.tree.map(lambda x: x[:1], batch["user"]["fields"])
        v, i = mod.retrieve(params, u1, cand, cfg, top_k=8)
    else:
        ub = jax.tree.map(lambda x: x[:1], batch["user"])
        fn = getattr(mod, "retrieve", None) or mod.score_candidates
        v, i = fn(params, ub, cand, cfg, top_k=8)
    assert v.shape == (8,) and i.shape == (8,)
    assert np.all(np.diff(np.asarray(v)) <= 1e-6)      # sorted descending
    assert np.isfinite(np.asarray(v)).all()


def test_param_counts_match_public_configs():
    """Analytic parameter counts land near the published sizes."""
    cases = {"qwen3-8b": (8.2e9, 0.1), "smollm-135m": (135e6, 0.1),
             "starcoder2-7b": (7.2e9, 0.12),
             "deepseek-v2-lite-16b": (15.7e9, 0.15),
             "deepseek-v3-671b": (671e9, 0.1)}
    for arch_id, (target, tol) in cases.items():
        n = registry.get(arch_id).config.param_count()
        assert abs(n - target) / target < tol, (arch_id, n, target)


def test_registry_covers_40_cells():
    cells = registry.all_cells()
    assert len(cells) == 40
    assert len(registry.ARCHS) == 10
