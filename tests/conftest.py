import os

# Tests see exactly ONE device (the dry-run sets its own placeholder fleet
# in a subprocess) — per the dry-run contract, never set
# xla_force_host_platform_device_count globally.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
