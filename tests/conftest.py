import os
import sys

# Tests see exactly ONE device (the dry-run sets its own placeholder fleet
# in a subprocess) — per the dry-run contract, never set
# xla_force_host_platform_device_count globally.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# The container image has no `hypothesis`; fall back to the deterministic
# shim in tests/_stubs (same strategy domains, seeded sweeps, no shrinking).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))

import numpy as np
import pytest


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
