import os
import sys

# Tests see exactly ONE device (the dry-run sets its own placeholder fleet
# in a subprocess) — per the dry-run contract, never set
# xla_force_host_platform_device_count globally.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# The container image has no `hypothesis`; fall back to the deterministic
# shim in tests/_stubs (same strategy domains, seeded sweeps, no shrinking).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))

import faulthandler

import numpy as np
import pytest

# Per-test hang watchdog: threaded executor tests that deadlock would
# otherwise stall the whole tier-1 run silently until the CI job timeout.
# faulthandler dumps every thread's stack and kills the process instead,
# pointing straight at the stuck lock. 0 disables it.
_TEST_TIMEOUT_S = float(os.environ.get("REPRO_TEST_TIMEOUT_S", "180"))


@pytest.fixture(autouse=True)
def _hang_watchdog():
    if _TEST_TIMEOUT_S > 0:
        faulthandler.dump_traceback_later(_TEST_TIMEOUT_S, exit=True)
    yield
    if _TEST_TIMEOUT_S > 0:
        faulthandler.cancel_dump_traceback_later()


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
