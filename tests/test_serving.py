"""Serving substrate: batchers + launchers (smoke via subprocess)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.serve.batcher import ContinuousBatcher, MicroBatcher

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_microbatcher_size_trigger():
    mb = MicroBatcher(max_batch=4, max_wait_s=10.0)
    assert mb.offer(1, now=0.0) is None
    assert mb.offer(2, now=0.0) is None
    assert mb.offer(3, now=0.0) is None
    out = mb.offer(4, now=0.0)
    assert out == [1, 2, 3, 4]


def test_microbatcher_timeout_trigger():
    mb = MicroBatcher(max_batch=100, max_wait_s=0.5)
    mb.offer("a", now=0.0)
    assert mb.poll(now=0.1) is None
    assert mb.poll(now=0.6) == ["a"]
    assert mb.poll(now=0.7) is None


def test_continuous_batcher_join_leave():
    cb = ContinuousBatcher(n_slots=2, s_max=16)
    for i in range(4):
        cb.submit(i, prompt_len=4, max_new=2)
    assert cb.active_mask.sum() == 2 and len(cb.waiting) == 2
    cb.step_complete(np.array([False, False]))
    cb.step_complete(np.array([False, False]))   # max_new exhausted
    assert sorted(cb.completed) == [0, 1]
    assert cb.active_mask.sum() == 2             # waiters admitted
    cb.step_complete(np.array([True, True]))     # early EOS
    assert sorted(cb.completed) == [0, 1, 2, 3]
    assert cb.utilization == 0.0


def _run(cmd, extra_env=None, timeout=600):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    # pin CPU: libtpu is present in the image but no TPU is attached, and
    # backend autodetection can stall for minutes probing TPU metadata;
    # the forced host-platform device count lives on the CPU platform anyway
    env["JAX_PLATFORMS"] = "cpu"
    env.update(extra_env or {})
    p = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=ROOT)
    assert p.returncode == 0, p.stdout[-1500:] + p.stderr[-1500:]
    return p.stdout


def test_train_launcher_reduced_with_resume(tmp_path):
    out = _run([sys.executable, "-m", "repro.launch.train", "--reduced",
                "--steps", "6", "--ckpt-every", "3",
                "--ckpt-dir", str(tmp_path)])
    assert "done; latest checkpoint" in out
    out2 = _run([sys.executable, "-m", "repro.launch.train", "--reduced",
                 "--steps", "3", "--ckpt-dir", str(tmp_path)])
    assert "resumed from" in out2


def test_serve_launcher_lm_mode():
    out = _run([sys.executable, "-m", "repro.launch.serve", "--mode", "lm",
                "--requests", "6"])
    assert "decoded" in out and "completed" in out
