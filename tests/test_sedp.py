"""SEDP graph + executor behaviour (paper §4)."""
import numpy as np
import pytest

from repro.core.executors import AsyncExecutor, LegacyExecutor, SimExecutor
from repro.core.multitenant import TrafficSplit, make_dispatch_op
from repro.core.sedp import SEDP, Event, GraphError, passthrough


def _tag(name):
    def op(batch, ctx):
        for ev in batch:
            ev.payload.setdefault("trace", []).append(name)
        return batch
    return op


def make_chain():
    g = SEDP()
    for n in ("a", "b", "c"):
        g.add_stage(n, _tag(n), batch_size=4, sim_per_item_s=1e-4)
    g.chain("a", "b", "c")
    return g


def test_compile_topology():
    plan = make_chain().compile()
    assert plan.order.index("a") < plan.order.index("b") < plan.order.index("c")
    assert plan.sources == ["a"] and plan.sinks == ["c"]


def test_cycle_detected():
    g = make_chain()
    g.add_edge("c", "a")
    with pytest.raises(GraphError, match="cycle"):
        g.compile()


def test_duplicate_stage_and_edge():
    g = make_chain()
    with pytest.raises(GraphError):
        g.add_stage("a", passthrough)
    with pytest.raises(GraphError):
        g.add_edge("a", "b")


def test_shared_channel_join():
    """Two predecessors feed ONE channel (Definition 2)."""
    g = SEDP()
    g.add_stage("src", _tag("src"))
    g.add_stage("l", _tag("l"))
    g.add_stage("r", _tag("r"))
    g.add_stage("join", _tag("join"))
    g.add_edge("src", "l")
    g.add_edge("src", "r")
    g.add_edge("l", "join")
    g.add_edge("r", "join")
    plan = g.compile()
    assert plan.preds["join"] == ["l", "r"]
    ex = SimExecutor(plan)
    rep = ex.run([(0.0, Event(payload={}))])
    # fan-out duplicated the event; both copies traverse join
    assert len(rep.results) == 2
    assert all("join" in ev.payload["trace"] for ev in rep.results)


def test_sim_executor_conservation_and_determinism():
    plan = make_chain().compile()
    arrivals = [(i * 1e-3, Event(payload={"i": i})) for i in range(100)]
    rep1 = SimExecutor(plan).run(list(arrivals))
    assert len(rep1.results) == 100
    assert sorted(ev.payload["i"] for ev in rep1.results) == list(range(100))
    arrivals2 = [(i * 1e-3, Event(payload={"i": i})) for i in range(100)]
    rep2 = SimExecutor(plan).run(arrivals2)
    assert rep1.latencies == rep2.latencies                    # deterministic


def test_routing_shortcut():
    g = SEDP()

    def router(batch, ctx):
        for ev in batch:
            if ev.payload["i"] % 2 == 0:
                ev.route = "sink"
        return batch

    g.add_stage("router", router)
    g.add_stage("slow", _tag("slow"), sim_per_item_s=1.0)
    g.add_stage("sink", _tag("sink"))
    g.add_edge("router", "slow")
    g.add_edge("router", "sink")
    g.add_edge("slow", "sink")
    rep = SimExecutor(g.compile()).run(
        [(0.0, Event(payload={"i": i})) for i in range(10)])
    evens = [ev for ev in rep.results if ev.payload["i"] % 2 == 0]
    assert all("slow" not in ev.payload["trace"] for ev in evens)


def test_async_executor_end_to_end():
    plan = make_chain().compile()
    ex = AsyncExecutor(plan)
    rep = ex.run([Event(payload={"i": i}) for i in range(64)])
    assert len(rep.results) == 64
    assert all(ev.payload["trace"] == ["a", "b", "c"] for ev in rep.results)


def test_sedp_beats_legacy_on_long_tail():
    """The paper's core §4 claim: async stages remove long-tail stalls."""
    def tail_op(batch, ctx):
        for ev in batch:
            ev.meta["cost_s"] = 0.1 if ev.payload["i"] % 17 == 0 else 1e-3
        return batch

    def build():
        g = SEDP()
        g.add_stage("work", tail_op, batch_size=8, parallelism=16)
        g.add_stage("out", passthrough, batch_size=8)
        g.add_edge("work", "out")
        return g.compile()

    from repro.core.service_model import service_time_model
    arrivals = [(i * 2e-3, Event(payload={"i": i})) for i in range(200)]
    sedp = SimExecutor(build(), service_time=service_time_model).run(
        [(t, Event(payload=dict(ev.payload))) for t, ev in arrivals])
    legacy = LegacyExecutor(build(), service_time=service_time_model,
                            batch_size=8).run(arrivals)
    # legacy's batch barrier pays the 100ms tail for every rider in the
    # batch; SEDP isolates it to the tail item itself
    assert sedp.avg_latency < legacy.avg_latency
    assert sedp.latency_percentile(0.5) < legacy.latency_percentile(0.5)


def test_multitenant_dispatch_stable():
    split = TrafficSplit({"dnn_a": 0.5, "dnn_b": 0.5})
    assign = [split.assign(u) for u in range(1000)]
    assert {a for a in assign} == {"dnn_a", "dnn_b"}
    assert assign == [split.assign(u) for u in range(1000)]   # deterministic
    frac = assign.count("dnn_a") / 1000
    assert 0.35 < frac < 0.65


def test_property_random_dags_conserve_events():
    """Property: any random DAG processes every event exactly once per
    source→sink path multiplicity (no loss, no spurious duplication)."""
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 6), st.integers(0, 2**31 - 1), st.integers(1, 40))
    def run(n_stages, seed, n_events):
        import numpy as np
        rng = np.random.default_rng(seed)
        g = SEDP()
        for i in range(n_stages):
            g.add_stage(f"s{i}", _tag(f"s{i}"), batch_size=int(rng.integers(1, 5)))
        # random forward edges (i < j keeps it acyclic); ensure connectivity
        n_paths_to = [1] + [0] * (n_stages - 1)
        for j in range(1, n_stages):
            preds = [i for i in range(j) if rng.random() < 0.6] or [j - 1]
            for i in preds:
                g.add_edge(f"s{i}", f"s{j}")
                n_paths_to[j] += n_paths_to[i]
        plan = g.compile()
        # expected sink copies = sum of path multiplicities into sinks
        expected = sum(n_paths_to[int(s[1:])] for s in plan.sinks
                       if s != "s0" or n_stages == 1)
        if "s0" in plan.sinks and n_stages > 1:
            expected += 1  # isolated source-sink (no outgoing edges)
        arrivals = [(i * 1e-4, Event(payload={"i": i})) for i in range(n_events)]
        rep = SimExecutor(plan).run(arrivals)
        assert len(rep.results) == expected * n_events

    run()
