"""Scenario-API contract tests (DESIGN.md §7).

  * every registered ScenarioSpec builds, compiles and serves a batch on
    BOTH executors, with Sim/Async result equivalence;
  * payload-contract violations fail at BUILD time, not mid-traffic;
  * the multi-scenario service fans one request stream across N pipelines
    over ONE shared substrate (shared feature groups, scoped query cache);
  * multi-group CubeFetchStage: every item-field group resolved under one
    pinned version — per-group no-torn-reads under a live delta stream;
  * the bounded reverse map prunes by invalidate-and-forget;
  * delta-stream integrity: a corrupted npz is skipped (and retried),
    never applied; GroupDelta.item_ids invalidates never-seen items.
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.core.sedp import SEDP, Event
from repro.core.service import (InferenceService, MultiScenarioService,
                                MultiServiceConfig, ServiceConfig)
from repro.serve.scenario import (BoundedReverseMap, ContractError,
                                  PipelineBuilder, Request, ScenarioSpec,
                                  ServingSubstrate, make_request_events,
                                  registered_scenarios)
from repro.serve.stages import Stage
from repro.update import DeltaBatch, GroupDelta


# ------------------------------------------------------------ typed payloads

def test_request_mapping_protocol_and_copy():
    req = Request(user_id=7, item_id=3, user_fields={"user_id": 7},
                  item_fields={"item_id": 3})
    assert req["user_id"] == 7 and "hist" not in req
    req["score"] = 0.5                       # extras via mapping writes
    assert req.get("score") == 0.5 and "score" in req
    assert req.get("missing", "d") == "d"
    as_dict = dict(req)                      # keys()/__getitem__ protocol
    assert as_dict["score"] == 0.5 and "candidates" not in as_dict
    clone = req.copy()
    clone["score"] = 0.9
    clone["hashed"] = {"item_id": 1}
    assert req["score"] == 0.5 and "hashed" not in req


# --------------------------------------------------- every registered spec

def _single(spec, seed=0):
    """One-scenario service for a spec (shed off → executor-independent
    candidate sets, so Sim and Async results are comparable)."""
    spec = dataclasses.replace(spec, shed=False, seed=seed)
    return MultiScenarioService(MultiServiceConfig(scenarios=(spec,)))


@pytest.mark.parametrize("spec", registered_scenarios(),
                         ids=lambda s: s.name)
def test_registered_spec_builds_and_serves_with_executor_equivalence(spec):
    """Build + compile + serve a batch on BOTH executors; scores/topk must
    agree (the DAG is the same graph on a virtual clock)."""
    a = _single(spec)
    rep_a = a.run(n_requests=12, executor="async")
    b = _single(spec)
    rep_b = b.run(n_requests=12, executor="sim", rate_qps=2000.0)
    assert len(rep_a.results) == 12 and len(rep_b.results) == 12

    def keyed(rep):
        out = {}
        for ev in rep.results:
            out[(ev.payload["user_id"], ev.payload["item_id"])] = ev.payload
        return out

    ka, kb = keyed(rep_a), keyed(rep_b)
    assert ka.keys() == kb.keys()
    for k in ka:
        pa, pb = ka[k], kb[k]
        if spec.pipeline == "rerank":
            assert pa["score"] == pytest.approx(pb["score"], abs=1e-6)
        if "topk" in pa or "topk" in pb:
            assert [i for i, _ in pa["topk"]] == [i for i, _ in pb["topk"]]
            for (_, sa), (_, sb) in zip(pa["topk"], pb["topk"]):
                assert sa == pytest.approx(sb, abs=1e-6)
    # typed responses stamped at the sink
    for ev in rep_a.results:
        r = ev.meta["response"]
        assert r.scenario == spec.name
        if spec.pipeline == "retrieval":
            assert r.topk and r.score is None


# ------------------------------------------------------- build-time checks

def test_contract_violation_fails_at_build_not_mid_traffic():
    """A rerank pipeline without its cube stage can never satisfy the
    rerank stage's payload contract — the builder must say so at compile
    time."""
    sub = ServingSubstrate()
    b = PipelineBuilder(sub)
    b.add_ingress("ingress")
    b.add_scenario(ScenarioSpec(name="bad", arch_id="din",
                                cube_fetch=False, shed=False),
                   namespaced=False)
    b.g.add_edge("ingress", b.entries["bad"])
    with pytest.raises(ContractError, match="cube_rows"):
        b.compile()


def test_contract_checker_uses_path_intersection():
    """A key provided on only ONE path into a multi-pred stage is not
    guaranteed — the checker takes the intersection over predecessors."""

    class Provider(Stage):
        name = "provider"
        provides = ("thing",)

        def op(self, batch, ctx):
            return batch

    class Needs(Stage):
        name = "needs"
        requires = ("thing",)

        def op(self, batch, ctx):
            return batch

    from repro.serve.scenario import validate_contracts
    g = SEDP()
    g.add_stage("src_a", Provider().op)
    g.add_stage("src_b", lambda b, c: b)          # provides nothing
    g.add_stage("sink", Needs().op)
    g.add_edge("src_a", "sink")
    g.add_edge("src_b", "sink")
    with pytest.raises(ContractError, match="thing"):
        validate_contracts(g.compile(), ingress_keys=set())
    # with both paths providing it, the same graph validates
    g2 = SEDP()
    g2.add_stage("src_a", Provider().op)
    g2.add_stage("src_b", Provider().op)
    g2.add_stage("sink", Needs().op)
    g2.add_edge("src_a", "sink")
    g2.add_edge("src_b", "sink")
    validate_contracts(g2.compile(), ingress_keys=set())


# --------------------------------------------------- multi-scenario service

@pytest.fixture(scope="module")
def multi():
    return MultiScenarioService(MultiServiceConfig(seed=0))


def test_multi_scenario_serves_every_scenario_from_one_substrate(multi):
    rep = multi.run(n_requests=16)
    by = multi.by_scenario(rep)
    assert set(by) == {"din-rerank", "dien-rerank", "mind-retrieval"}
    assert all(len(evs) == 16 for evs in by.values())
    for ev in by["din-rerank"] + by["dien-rerank"]:
        assert np.isfinite(ev.payload["score"])
        assert 0.0 <= ev.payload["score"] <= 1.0
    for ev in by["mind-retrieval"]:
        assert "score" not in ev.payload or ev.payload.get("generation") is None
        assert ev.payload["topk"]
    # ONE substrate: DIN/DIEN/MIND share the (item_id, 1024) and
    # (item_cat, 1024) feature groups — two groups total, not six
    assert len(multi.substrate.groups) == 2
    # every pipeline pinned a cube version from the same shared cube
    versions = {ev.payload.get("cube_version") for ev in rep.results
                if "cube_version" in ev.payload}
    assert versions


def test_multi_scenario_query_cache_is_scenario_scoped(multi):
    multi.run(n_requests=16)                    # warm (same seed as fixture)
    before = multi.query_cache.stats.hits
    rep = multi.run(n_requests=16)              # identical traffic
    assert multi.query_cache.stats.hits > before
    # hits route straight to respond WITH a score but WITHOUT a
    # generation stamp; retrieval scenarios never enter the cache
    by = multi.by_scenario(rep)
    hit_evs = [ev for ev in by["din-rerank"] + by["dien-rerank"]
               if "generation" not in ev.payload]
    assert hit_evs, "second identical wave produced no query-cache hits"
    assert all("topk" in ev.payload for ev in by["mind-retrieval"])


def test_fanout_clones_are_independent(multi):
    """Each scenario's stages write into their own Request clone — one
    scenario's intermediates never leak into a sibling's payload."""
    rep = multi.run(n_requests=8)
    by_req: dict = {}
    for ev in rep.results:
        by_req.setdefault(ev.req_id, []).append(ev)
    multi_served = [evs for evs in by_req.values() if len(evs) > 1]
    assert multi_served, "no request was served by >1 scenario"
    for evs in multi_served:
        payloads = [ev.payload for ev in evs]
        assert len({id(p) for p in payloads}) == len(payloads)
        scens = {p["scenario"] for p in payloads}
        assert len(scens) == len(payloads)


def test_async_executor_accounts_for_op_created_events():
    """Regression for the fanout-on-AsyncExecutor accounting: an op that
    RETURNS more events than it consumed must not make run() return
    early (or hang when events are dropped)."""
    from repro.core.executors import AsyncExecutor

    def clone_op(batch, ctx):
        out = []
        for ev in batch:
            out.append(ev)
            out.append(Event(payload=dict(ev.payload), req_id=ev.req_id))
        return out

    def drop_op(batch, ctx):
        return [ev for ev in batch if ev.payload.get("keep", True)]

    g = SEDP()
    g.add_stage("clone", clone_op, batch_size=4)
    g.add_stage("drop", drop_op, batch_size=4)
    g.add_stage("sink", lambda b, c: b, batch_size=4)
    g.chain("clone", "drop", "sink")
    events = [Event(payload={"i": i, "keep": i % 2 == 0}) for i in range(10)]
    rep = AsyncExecutor(g.compile()).run(events)
    # 10 in → 20 after clone → clones of odd events dropped (keep=False
    # rides the shallow copy) → 10 out; completing without a hang IS the
    # accounting fix
    assert len(rep.results) == 10


# ------------------------------------- multi-group fetch delta coherence

def test_multi_group_fetch_resolves_all_groups_under_one_pin():
    """Deterministic slice of the tentpole property: after a delta batch
    touching BOTH item-field groups, one cube stage pass attaches every
    group's new rows, all stamped with one pinned version."""
    svc = InferenceService(ServiceConfig(arch_id="din", batch_size=8,
                                         shed=False, seed=3))
    vocab = svc.model_cfg.item_fields[0].vocab
    ids = np.arange(vocab)
    dv = svc.updates.stats.last_version + 1
    svc.updates.apply(DeltaBatch(dv, [
        GroupDelta(group=0, ids=ids,
                   rows=np.full((vocab, 4), 5.0, np.float32)),
        GroupDelta(group=1, ids=ids,
                   rows=np.full((vocab, 4), 7.0, np.float32))]))
    evs = svc.make_requests(6, seed=42)
    svc.plan.stages["features"].op(evs, None)
    svc.plan.stages["cube"].op(evs, None)
    for ev in evs:
        rows = ev.payload["cube_rows_all"]
        assert set(rows) == {"item_id", "item_cat"}
        np.testing.assert_array_equal(rows["item_id"],
                                      np.full(4, 5.0, np.float32))
        np.testing.assert_array_equal(rows["item_cat"],
                                      np.full(4, 7.0, np.float32))
        assert ev.payload["cube_version"] == svc.cube.version
        # the primary group's row keeps its historical slot
        np.testing.assert_array_equal(ev.payload["cube_rows"],
                                      rows["item_id"])


def test_multi_group_no_torn_reads_under_live_delta_stream():
    """test_live_update-style property, per group: AsyncExecutor workers
    serve while a writer streams delta batches touching BOTH groups
    through the UpdateManager (cube + cache invalidation + guards). Every
    response's per-group rows must be uniform and match exactly the value
    published at the version the response pinned."""
    svc = InferenceService(ServiceConfig(arch_id="din", batch_size=8,
                                         shed=False, seed=11))
    vocab = svc.model_cfg.item_fields[0].vocab
    ids = np.arange(vocab)
    svc.run(n_requests=8)                   # fold build indexes, warm jits
    published = {0: {}, 1: {}}              # group → {cube_version: value}
    stop = threading.Event()
    first_batch = threading.Event()
    writer_err = []

    def writer():
        try:
            first_batch.wait(timeout=10)
            x = 1.0
            dv = svc.updates.stats.last_version + 1
            while not stop.is_set():
                v0 = svc.cube.version
                # record BEFORE publish: the WHOLE batch publishes
                # atomically — both groups land at v0+1 in ONE bump
                published[0][v0 + 1] = x
                published[1][v0 + 1] = x
                svc.updates.apply(DeltaBatch(dv, [
                    GroupDelta(group=0, ids=ids, rows=np.full(
                        (vocab, 4), x, np.float32)),
                    GroupDelta(group=1, ids=ids, rows=np.full(
                        (vocab, 4), x, np.float32))]))
                x += 1.0
                dv += 1
                time.sleep(0.002)
        except Exception as e:              # pragma: no cover - debug aid
            writer_err.append(e)

    def expected(group, pin_version):
        vs = [v for v in published[group] if v <= pin_version]
        return published[group][max(vs)] if vs else None

    th = threading.Thread(target=writer, daemon=True)
    th.start()
    first_batch.set()
    time.sleep(0.01)                        # let the first batch publish
    try:
        reports = [svc.run(n_requests=24) for _ in range(3)]
    finally:
        stop.set()
        th.join(timeout=10)
    assert not writer_err
    checked = 0
    seen_versions = set()
    for rep in reports:
        for ev in rep.results:
            p = ev.payload
            if "cube_rows_all" not in p:
                continue                    # query-cache hit short-circuit
            pv = p["cube_version"]
            for group, fname in ((0, "item_id"), (1, "item_cat")):
                rows = p["cube_rows_all"][fname]
                vals = np.unique(rows)
                # NO TORN READ within the group: one value ⇒ one version
                assert vals.size == 1, f"torn read in group {group}: {vals}"
                exp = expected(group, pv)
                if exp is None:
                    continue                # served before the first batch
                # ATTRIBUTION: the value matches the pinned version exactly
                assert float(vals[0]) == exp, (
                    f"group {group} rows show {vals[0]} but version {pv} "
                    f"published {exp}")
                checked += 1
            # CROSS-GROUP atomicity (batch publish): one pin ⇒ both
            # groups observed at the SAME value/version — the §7.3
            # window where adjacent groups sat at adjacent versions
            # cannot open under apply_batch
            g0 = np.unique(p["cube_rows_all"]["item_id"])
            g1 = np.unique(p["cube_rows_all"]["item_cat"])
            if expected(0, pv) is not None and expected(1, pv) is not None:
                assert float(g0[0]) == float(g1[0]), (
                    f"cross-group torn read at version {pv}: "
                    f"group 0 = {g0[0]}, group 1 = {g1[0]}")
            seen_versions.add(pv)
    assert checked > 0
    assert len(seen_versions) >= 2, seen_versions   # stream landed mid-run


# ------------------------------------------------------ bounded reverse map

def test_bounded_reverse_map_prunes_and_reports_dropped_items():
    m = BoundedReverseMap(max_items=8, prune_fraction=0.5)
    for i in range(12):
        m.add(bucket=i % 4, item=i)
    assert m.total == 12
    dropped = m.maybe_prune()
    assert m.total <= 4                     # 8 * (1 - 0.5)
    remaining = {i for s in m.buckets.values() for i in s}
    assert remaining | set(dropped) == set(range(12))
    assert remaining.isdisjoint(dropped)
    assert m.maybe_prune() == []            # under the cap: no-op


def test_reverse_map_prune_invalidates_query_cache_first():
    """The bound keeps the over-invalidation-is-safe property: any item
    whose mapping is dropped leaves the query cache in the same stage
    pass, so a later delta can never miss it."""
    svc = InferenceService(ServiceConfig(arch_id="din", batch_size=8,
                                         shed=False, seed=5,
                                         reverse_map_items=16))
    evs = svc.make_requests(64, seed=99)
    items = sorted({int(ev.payload["item_id"]) for ev in evs})
    for it in items:
        svc.query_cache.put("warm-user", it, 0.5, now=0.0)
    svc.plan.stages["features"].op(evs, None)
    group0 = svc.substrate.bucket_items[0]
    assert group0.total <= 16
    mapped = {i for s in group0.buckets.values() for i in s}
    for it in items:
        if it not in mapped:
            # mapping forgotten ⇒ score must already be invalidated
            assert svc.query_cache.get("warm-user", it, now=0.1) is None


# ------------------------------------------------------- stream integrity

def test_corrupted_delta_skipped_and_retried_never_applied(tmp_path):
    from repro.update import (DeltaEmitter, DeltaIntegrityError,
                              DeltaWatcher, write_delta)
    em = DeltaEmitter(str(tmp_path))
    batch = em.emit([GroupDelta(group=0, ids=np.arange(8),
                                rows=np.ones((8, 4), np.float32))])
    npz = tmp_path / "delta_000000000000" / "group_0.npz"
    blob = bytearray(npz.read_bytes())
    blob[len(blob) // 2] ^= 0xFF            # flip one byte mid-file
    npz.write_bytes(bytes(blob))
    applied = []
    w = DeltaWatcher(str(tmp_path), lambda b: applied.append(b.version))
    with pytest.raises(DeltaIntegrityError):
        w.check_once()
    assert applied == [] and w.applied_version == -1
    assert w.integrity_failures == 1
    # the training side re-emits the same version; the retry applies it
    write_delta(str(tmp_path), batch)
    assert w.check_once()
    assert applied == [0] and w.applied_version == 0


def test_unmanifested_npz_rejected_and_reemit_cleans_leftovers(tmp_path):
    """read_delta applies every group_*.npz in the directory, so a file
    the manifest does not name must fail verification — and a re-emit of
    the same version with fewer groups (the corrupt-delta recovery path)
    must remove the previous attempt's leftovers rather than let them
    ride along."""
    from repro.update import (DeltaBatch, DeltaIntegrityError, read_delta,
                              verify_delta, write_delta)
    two = DeltaBatch(0, [
        GroupDelta(group=0, ids=np.arange(4),
                   rows=np.ones((4, 4), np.float32)),
        GroupDelta(group=1, ids=np.arange(4),
                   rows=np.ones((4, 4), np.float32))])
    path = write_delta(str(tmp_path), two)
    # a stray/tampered npz dropped into the published dir fails closed
    np.savez(tmp_path / "delta_000000000000" / "group_7.npz",
             ids=np.arange(2), rows=np.zeros((2, 4), np.float32),
             delete_ids=np.empty(0, np.int64))
    with pytest.raises(DeltaIntegrityError, match="group_7"):
        verify_delta(path)
    # re-emitting the version with ONE group drops group_1 and group_7
    one = DeltaBatch(0, [GroupDelta(group=0, ids=np.arange(4),
                                    rows=np.ones((4, 4), np.float32))])
    write_delta(str(tmp_path), one)
    assert verify_delta(path) is True
    assert [g.group for g in read_delta(path).groups] == [0]


def test_pre_checksum_deltas_still_accepted(tmp_path):
    """Deltas emitted before the CHECKSUMS manifest existed (or by foreign
    emitters) apply unverified — integrity is opt-out-compatible."""
    import os
    from repro.update import DeltaWatcher, verify_delta
    d = tmp_path / "delta_000000000000"
    d.mkdir()
    np.savez(d / "group_0.npz", ids=np.arange(4),
             rows=np.ones((4, 4), np.float32),
             delete_ids=np.empty(0, np.int64))
    (d / "DONE").write_text("")
    assert verify_delta(str(d)) is False    # nothing to verify against
    applied = []
    w = DeltaWatcher(str(tmp_path), lambda b: applied.append(b.version))
    assert w.check_once()
    assert applied == [0]
    assert os.path.exists(d)                # prune_applied defaults off


def test_group_delta_item_ids_invalidate_items_never_seen_by_service():
    """ROADMAP open item: a delta landing BEFORE an item's first request
    must still invalidate a warm-started query-cache entry — the training
    side ships the raw item ids, the manager unions them with the
    reverse-map lookup."""
    from repro.sparse.hashing import hash_bucket_np
    svc = InferenceService(ServiceConfig(arch_id="din", batch_size=8,
                                         shed=False, seed=21))
    vocab = svc.model_cfg.item_fields[0].vocab
    raw_item = 777_777                      # never requested: map is cold
    bucket = int(hash_bucket_np(0, np.array([raw_item]), vocab)[0])
    svc.query_cache.put("warm-user", raw_item, 0.9, now=0.0)
    svc.updates.apply(DeltaBatch(
        svc.updates.stats.last_version + 1,
        [GroupDelta(group=0, ids=np.array([bucket]),
                    rows=np.full((1, 4), 2.0, np.float32),
                    item_ids=np.array([raw_item]))]))
    assert svc.query_cache.get("warm-user", raw_item, now=0.1) is None


# -------------------------------------------------------- request generator

def test_make_request_events_covers_union_of_configs():
    from repro.configs import registry as arch_registry
    cfgs = []
    for arch in ("din", "mind", "two-tower-retrieval"):
        a = arch_registry.get(arch)
        cfgs.append(a.reduced(a.config))
    evs = make_request_events(cfgs, 5, seed=1)
    assert len(evs) == 5
    for ev in evs:
        req = ev.payload
        assert isinstance(req, Request)
        for mc in cfgs:
            for f in mc.user_fields:
                assert f.name in req["user_fields"]
                assert np.asarray(req["user_fields"][f.name]).size == f.bag
            for f in mc.item_fields:
                assert f.name in req["item_fields"]
        assert req["hist"] is not None       # din/mind carry history
        assert len(req["candidates"]) == 64
