"""Hot swap + delta stream under LIVE traffic: AsyncExecutor stage workers
serve in parallel while new generations and delta versions publish mid-run.
Contracts under test (DESIGN.md §6):

  * no torn reads — every row a request observes belongs to exactly one
    published cube version (never a mix, never a half-applied delta);
  * attribution — each response carries the version it was served at, and
    its contents match that version exactly;
  * a generation hot swap mid-run gives every response the scores of
    exactly one generation, and the query cache never resells the old
    generation's scores after the swap;
  * a failing loader never silently stalls the poll thread (backoff+retry).
"""
import threading
import time

import numpy as np
import pytest

from repro.core.cube import ParameterCube
from repro.core.executors import AsyncExecutor
from repro.core.sedp import SEDP, Event
from repro.serve.hotload import DoubleBuffer, Generation, ModelMonitor
from repro.update import DeltaBatch, GroupDelta, UpdateManager

DIM = 4
N_IDS = 256


def _value_cube():
    """Cube whose every row is filled with the version that published it:
    row content IS the version stamp, so torn reads are detectable by
    value."""
    cube = ParameterCube(n_servers=4, replication=2, block_rows=32)
    cube.load_table(0, np.zeros((N_IDS, DIM), np.float32))
    cube.lookup(0, np.array([0]))          # fold the build → version 1
    return cube


def test_no_torn_reads_single_version_attribution_under_delta_stream(rng):
    cube = _value_cube()
    ids_all = np.arange(N_IDS)
    published = {cube.version: 0.0}        # version → fill value
    stop = threading.Event()
    first_batch = threading.Event()
    writer_err = []

    def writer():
        try:
            first_batch.wait(timeout=10)
            k = 0
            while not stop.is_set():
                next_v = cube.version + 1
                published[next_v] = float(next_v)   # record BEFORE publish
                got = cube.apply_delta(
                    0, ids_all, np.full((N_IDS, DIM), float(next_v),
                                        np.float32))
                assert got == next_v
                k += 1
                if k % 7 == 0:
                    v = cube.compact()              # value unchanged
                    published[v] = published[v - 1]
                time.sleep(0.001)
        except Exception as e:             # pragma: no cover - debug aid
            writer_err.append(e)

    def op_lookup(batch, ctx):
        first_batch.set()
        with cube.pin() as pv:
            for ev in batch:
                ids = ev.payload["ids"]
                rows = cube.lookup(0, ids, version=pv)
                ev.payload["version"] = pv.version
                ev.payload["values"] = np.unique(rows)
        time.sleep(0.0005)                 # stretch the run past >1 publish
        return batch

    g = SEDP()
    g.add_stage("ingress", lambda b, c: b, batch_size=4, parallelism=2)
    g.add_stage("lookup", op_lookup, batch_size=8, parallelism=3)
    g.add_stage("respond", lambda b, c: b, batch_size=8)
    g.chain("ingress", "lookup", "respond")
    plan = g.compile()

    events = [Event(payload={"ids": rng.integers(0, N_IDS, 32)})
              for _ in range(240)]
    th = threading.Thread(target=writer, daemon=True)
    th.start()
    try:
        report = AsyncExecutor(plan).run(events)
    finally:
        stop.set()
        th.join(timeout=10)
    assert not writer_err
    assert len(report.results) == len(events)
    seen_versions = set()
    for ev in report.results:
        vals = ev.payload["values"]
        # NO TORN READ: all rows in one response share one value ⇒ they all
        # came from a single published version
        assert vals.size == 1, f"torn read: values {vals}"
        ver = ev.payload["version"]
        # ATTRIBUTION: the value matches the version the response claims
        assert published[ver] == float(vals[0])
        seen_versions.add(ver)
    # the stream actually landed mid-run: multiple versions were served
    assert len(seen_versions) >= 2, seen_versions
    assert cube.version > 1


def test_generation_swap_mid_run_yields_single_generation_responses(rng):
    """DoubleBuffer hot swap while AsyncExecutor workers score in parallel:
    each response's score must equal the stamp of the generation it claims
    (a response mixing two generations' params would show a foreign
    value)."""
    buf = DoubleBuffer(Generation(1, np.full((DIM,), 1.0, np.float32)))
    published = {1}
    stop = threading.Event()
    first_batch = threading.Event()

    def swapper():
        first_batch.wait(timeout=10)
        stamp = 2
        while not stop.is_set():
            published.add(stamp)           # record BEFORE publish
            buf.load(Generation(stamp, np.full((DIM,), float(stamp),
                                               np.float32)))
            stamp += 1
            time.sleep(0.002)

    def op_score(batch, ctx):
        first_batch.set()
        gen = buf.active                   # bind ONCE per batch
        for ev in batch:
            vals = np.unique(gen.payload)
            assert vals.size == 1          # params internally consistent
            ev.payload["gen"] = gen.stamp
            ev.payload["score"] = float(vals[0])
        time.sleep(0.0005)
        return batch

    g = SEDP()
    g.add_stage("score", op_score, batch_size=8, parallelism=3)
    g.add_stage("respond", lambda b, c: b, batch_size=8)
    g.chain("score", "respond")
    events = [Event(payload={}) for _ in range(200)]
    th = threading.Thread(target=swapper, daemon=True)
    th.start()
    try:
        report = AsyncExecutor(g.compile()).run(events)
    finally:
        stop.set()
        th.join(timeout=10)
    assert len(report.results) == len(events)
    gens = set()
    for ev in report.results:
        assert ev.payload["score"] == float(ev.payload["gen"])
        assert ev.payload["gen"] in published
        gens.add(ev.payload["gen"])
    assert len(gens) >= 2, gens            # swaps really landed mid-run


def test_swap_bumps_query_cache_via_on_swap(rng):
    """The DoubleBuffer → UpdateManager wiring: a hot swap must stop the
    query cache from reselling the old generation's scores (the latent
    staleness bug — previously they survived until TTL)."""
    from repro.core.query_cache import QueryCache
    cube = _value_cube()
    qc = QueryCache(capacity=16, window_s=1e9)
    mgr = UpdateManager(cube, query_cache=qc)
    buf = DoubleBuffer(Generation(0, "params-g0"))
    buf.on_swap.append(mgr.on_generation_swap)
    qc.put("u", "i", 0.9, now=0.0)
    assert qc.get("u", "i", now=1.0) == 0.9
    assert buf.load(Generation(1, "params-g1"))
    assert qc.get("u", "i", now=1.0) is None
    assert not buf.load(Generation(1, "stale"))    # stale swap → no bump
    assert mgr.stats.generation_swaps == 1


def test_deltas_and_swaps_interleaved_with_manager(rng):
    """Full wiring: AsyncExecutor traffic + DeltaWatcher-style applies via
    UpdateManager + generation swaps, all concurrent. Every response is
    attributable to exactly one (cube_version, generation) pair."""
    cube = _value_cube()
    mgr = UpdateManager(cube, compact_after_blocks=64)
    buf = DoubleBuffer(Generation(1, 1.0))
    published = {cube.version: 0.0}
    stop = threading.Event()
    first_batch = threading.Event()

    def updater():
        first_batch.wait(timeout=10)
        dv = 0
        while not stop.is_set():
            next_v = cube.version + 1
            published[next_v] = float(next_v)
            mgr.apply(DeltaBatch(dv, [GroupDelta(
                group=0, ids=np.arange(N_IDS),
                rows=np.full((N_IDS, DIM), float(next_v), np.float32))]))
            buf.load(Generation(buf.active.stamp + 1, float(next_v)))
            dv += 1
            time.sleep(0.002)

    def op(batch, ctx):
        first_batch.set()
        gen = buf.active
        with cube.pin() as pv:
            for ev in batch:
                rows = cube.lookup(0, ev.payload["ids"], version=pv)
                vals = np.unique(rows)
                assert vals.size == 1
                ev.payload["cube_version"] = pv.version
                ev.payload["value"] = float(vals[0])
                ev.payload["gen"] = gen.stamp
        time.sleep(0.0005)
        return batch

    g = SEDP()
    g.add_stage("op", op, batch_size=8, parallelism=3)
    g.add_stage("respond", lambda b, c: b, batch_size=8)
    g.chain("op", "respond")
    events = [Event(payload={"ids": rng.integers(0, N_IDS, 24)})
              for _ in range(160)]
    th = threading.Thread(target=updater, daemon=True)
    th.start()
    try:
        report = AsyncExecutor(g.compile()).run(events)
    finally:
        stop.set()
        th.join(timeout=10)
    for ev in report.results:
        assert published[ev.payload["cube_version"]] == ev.payload["value"]
    assert len(report.results) == len(events)
    assert mgr.stats.deltas_applied > 0


# ----------------------------------------------------- monitor resilience

def test_model_monitor_loader_fails_once_then_succeeds(tmp_path):
    """Satellite regression: a loader exception must not kill or silently
    stall the poll thread — it logs, backs off, retries, and the next
    success loads the generation and resets the backoff."""
    gen_dir = tmp_path / "gen_5"
    gen_dir.mkdir()
    (gen_dir / "DONE").write_text("")
    calls = {"n": 0}

    def flaky_loader(path):
        calls["n"] += 1
        if calls["n"] == 1:
            raise IOError("truncated checkpoint")
        return f"payload:{path}"

    buf = DoubleBuffer(Generation(0, None))
    mon = ModelMonitor(str(tmp_path), buf, loader=flaky_loader, poll_s=0.01)
    mon.start()
    try:
        deadline = time.monotonic() + 5.0
        while buf.active.stamp != 5 and time.monotonic() < deadline:
            time.sleep(0.01)
    finally:
        mon.stop()
    assert buf.active.stamp == 5               # recovered after the failure
    assert calls["n"] == 2                     # exactly one retry needed
    assert mon.total_failures == 1
    assert mon.failures == 0                   # success reset the backoff
    assert mon.last_error is None


def test_model_monitor_backoff_grows_and_caps():
    # jitter=False restores the exact exponential schedule (the default
    # decorrelated-jitter path is covered by tests/test_faults.py)
    mon = ModelMonitor("/nonexistent", DoubleBuffer(Generation(0, None)),
                       loader=lambda p: p, poll_s=0.5, max_backoff_s=4.0,
                       jitter=False)
    assert mon._backoff_s() == 0.5
    mon.failures = 1
    assert mon._backoff_s() == 1.0
    mon.failures = 2
    assert mon._backoff_s() == 2.0
    mon.failures = 10
    assert mon._backoff_s() == 4.0             # capped


def test_model_monitor_check_once_still_raises_for_tests(tmp_path):
    """Direct check_once keeps raising (the thread is what absorbs) — the
    existing test-suite contract."""
    gen_dir = tmp_path / "gen_1"
    gen_dir.mkdir()
    (gen_dir / "DONE").write_text("")

    def bad_loader(path):
        raise ValueError("boom")

    mon = ModelMonitor(str(tmp_path), DoubleBuffer(Generation(0, None)),
                       loader=bad_loader)
    with pytest.raises(ValueError):
        mon.check_once()


# -------------------------------------------- cache-aside race regressions

@pytest.fixture(scope="module")
def svc():
    from repro.core.service import InferenceService, ServiceConfig
    return InferenceService(ServiceConfig(arch_id="din", batch_size=8,
                                          shed=False, seed=0))


def test_op_cube_drops_inserts_raced_by_delta(svc):
    """A delta landing between op_cube's pinned fetch and its cache insert
    must not resurrect pre-delta rows as fresh cache entries: the post-put
    version check drops the batch's own inserts."""
    from repro.update import DeltaBatch, GroupDelta
    evs = svc.make_requests(4, seed=777)
    svc.plan.stages["features"].op(evs, None)
    keys = sorted({int(ev.payload["hashed"]["item_id"]) for ev in evs})
    svc.cube_cache.invalidate_keys(keys)        # start from cold cache
    real_put = svc.cube_cache.put_many

    def racy_put(ks, vs):
        # the delta applies INSIDE the race window: after the pinned
        # lookup, before the insert — worst-case interleaving
        svc.updates.apply(DeltaBatch(
            svc.updates.stats.last_version + 1,
            [GroupDelta(group=0, ids=np.asarray(keys, np.int64),
                        rows=np.full((len(keys), 4), 42.0, np.float32))]))
        real_put(ks, vs)

    svc.cube_cache.put_many = racy_put
    try:
        svc.plan.stages["cube"].op(evs, None)
    finally:
        svc.cube_cache.put_many = real_put
    # the raced inserts (pre-delta rows) must be gone...
    assert all(svc.cube_cache.get(k) is None for k in keys)
    # ...and the next batch serves the post-delta rows
    evs2 = svc.make_requests(4, seed=777)
    svc.plan.stages["features"].op(evs2, None)
    svc.plan.stages["cube"].op(evs2, None)
    for ev in evs2:
        np.testing.assert_array_equal(ev.payload["cube_rows"],
                                      np.full(4, 42.0, np.float32))


def test_delta_invalidates_raw_item_scores_despite_hashed_ids(svc):
    """The cube is keyed by HASHED item ids, the query cache by RAW ones:
    a delta touching a hashed row must invalidate the raw items that map to
    it (via the op_features reverse map), not treat hashed ids as items."""
    from repro.sparse.hashing import hash_bucket_np
    from repro.update import DeltaBatch, GroupDelta
    evs = svc.make_requests(3, seed=555)
    svc.plan.stages["features"].op(evs, None)   # records bucket → items
    raw = int(evs[0].payload["item_id"])
    bucket = int(hash_bucket_np(0, np.array([raw]),
                                svc.model_cfg.item_fields[0].vocab)[0])
    svc.query_cache.put("uX", raw, 0.77, now=0.0)
    svc.updates.apply(DeltaBatch(
        svc.updates.stats.last_version + 1,
        [GroupDelta(group=0, ids=np.array([bucket]),
                    rows=np.full((1, 4), 1.0, np.float32))]))
    assert svc.query_cache.get("uX", raw, now=0.1) is None


def test_query_cache_put_with_captured_version_cannot_mark_stale_fresh():
    """op_dnn stamps scores with the model version captured BEFORE binding
    the generation: a swap racing the batch leaves the entries pre-bump-
    stamped, i.e. invalid — never old scores marked fresh."""
    from repro.core.query_cache import QueryCache
    qc = QueryCache(capacity=8, window_s=1e9)
    captured = qc.model_version            # batch starts: capture, bind gen
    qc.bump_model_version()                # hot swap lands mid-batch
    qc.put_many(["u"], ["i"], [0.9], now=0.0, version=captured)
    assert qc.get("u", "i", now=0.1) is None   # stamped pre-bump → invalid
    qc.put("u", "i", 0.4, now=1.0)             # post-swap score is fresh
    assert qc.get("u", "i", now=1.5) == 0.4


def test_op_cube_serves_deleted_items_as_zero_rows(svc):
    """A delta DELETE is a legitimate serving state: the cube stage must
    serve the tombstoned row as the zero/default row, not raise KeyError
    (which would kill the AsyncExecutor stage worker and hang the run)."""
    from repro.update import DeltaBatch, GroupDelta
    evs = svc.make_requests(3, seed=999)
    svc.plan.stages["features"].op(evs, None)
    bucket = int(evs[0].payload["hashed"]["item_id"])
    original = svc.cube.lookup(0, np.array([bucket]))
    svc.cube_cache.invalidate_keys([bucket])
    svc.updates.apply(DeltaBatch(
        svc.updates.stats.last_version + 1,
        [GroupDelta(group=0, delete_ids=np.array([bucket]))]))
    svc.plan.stages["cube"].op(evs, None)          # must not raise
    np.testing.assert_array_equal(evs[0].payload["cube_rows"],
                                  np.zeros(4, np.float32))
    # restore the row for the rest of the module's tests
    svc.cube_cache.invalidate_keys([bucket])
    svc.updates.apply(DeltaBatch(
        svc.updates.stats.last_version + 1,
        [GroupDelta(group=0, ids=np.array([bucket]),
                    rows=original.astype(np.float32))]))


def test_op_cube_keeps_inserts_when_raced_delta_touched_other_keys(svc):
    """The cache-aside guard is TARGETED: a delta racing the batch but
    touching unrelated keys must not cost the batch its warm inserts."""
    from repro.update import DeltaBatch, GroupDelta
    evs = svc.make_requests(4, seed=4242)
    svc.plan.stages["features"].op(evs, None)
    keys = sorted({int(ev.payload["hashed"]["item_id"]) for ev in evs})
    vocab = svc.model_cfg.item_fields[0].vocab
    other = next(k for k in range(vocab) if k not in keys)
    svc.cube_cache.invalidate_keys(keys)
    real_put = svc.cube_cache.put_many

    def racy_put(ks, vs):
        svc.updates.apply(DeltaBatch(
            svc.updates.stats.last_version + 1,
            [GroupDelta(group=0, ids=np.array([other]),
                        rows=np.full((1, 4), 3.0, np.float32))]))
        real_put(ks, vs)

    svc.cube_cache.put_many = racy_put
    try:
        svc.plan.stages["cube"].op(evs, None)
    finally:
        svc.cube_cache.put_many = real_put
    assert all(svc.cube_cache.get(k) is not None for k in keys)


# ------------------------------------------- failover x update plane

def test_failover_reads_bit_identical_under_kills_and_delta_stream(rng):
    """Versioned failover (DESIGN.md §8.3): while deltas publish, the
    compactor folds, AND servers die and revive mid-traffic, every pinned
    read stays attributable to exactly one published version — replica
    rows are bit-identical to what the primary served at the pin."""
    from repro.core.cube import TIER_REPLICA
    cube = _value_cube()
    ids_all = np.arange(N_IDS)
    published = {cube.version: 0.0}
    stop = threading.Event()
    first_batch = threading.Event()
    bg_err = []

    def writer():
        try:
            first_batch.wait(timeout=10)
            k = 0
            while not stop.is_set():
                next_v = cube.version + 1
                published[next_v] = float(next_v)
                cube.apply_delta(0, ids_all,
                                 np.full((N_IDS, DIM), float(next_v),
                                         np.float32))
                k += 1
                if k % 5 == 0:
                    v = cube.compact()
                    published[v] = published[v - 1]
                time.sleep(0.001)
        except Exception as e:             # pragma: no cover - debug aid
            bg_err.append(e)

    def killer():
        try:
            first_batch.wait(timeout=10)
            sid = 0
            while not stop.is_set():
                cube.kill_server(sid)      # one dead server at a time:
                time.sleep(0.002)          # replication=2 keeps every row
                cube.revive_server(sid)    # reachable via its replica
                sid = (sid + 1) % cube.n_servers
        except Exception as e:             # pragma: no cover - debug aid
            bg_err.append(e)

    def op_lookup(batch, ctx):
        first_batch.set()
        with cube.pin() as pv:
            for ev in batch:
                rows, tiers = cube.lookup_ex(0, ev.payload["ids"],
                                             version=pv)
                ev.payload["version"] = pv.version
                ev.payload["values"] = np.unique(rows)
                ev.payload["max_tier"] = int(tiers.max())
        time.sleep(0.0005)
        return batch

    g = SEDP()
    g.add_stage("ingress", lambda b, c: b, batch_size=4, parallelism=2)
    g.add_stage("lookup", op_lookup, batch_size=8, parallelism=3)
    g.add_stage("respond", lambda b, c: b, batch_size=8)
    g.chain("ingress", "lookup", "respond")
    plan = g.compile()

    events = [Event(payload={"ids": rng.integers(0, N_IDS, 32)})
              for _ in range(240)]
    threads = [threading.Thread(target=writer, daemon=True),
               threading.Thread(target=killer, daemon=True)]
    for th in threads:
        th.start()
    try:
        report = AsyncExecutor(plan).run(events)
    finally:
        stop.set()
        for th in threads:
            th.join(timeout=10)
    assert not bg_err
    assert len(report.results) == len(events)
    for ev in report.results:
        vals = ev.payload["values"]
        # bit-identical failover: one value per response ⇒ one version,
        # whether the rows came from the primary or a replica snapshot
        assert vals.size == 1, f"torn failover read: values {vals}"
        assert published[ev.payload["version"]] == float(vals[0])
        # the ladder never fell past the versioned-replica rung
        assert ev.payload["max_tier"] <= TIER_REPLICA
    # the drill actually exercised the replica path
    assert cube.metrics.replica_rows > 0
