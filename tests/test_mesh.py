"""Multi-host serving mesh (DESIGN.md §11).

Contracts under test:

  * rendezvous routing is deterministic, stable under topology REPUBLISH
    (failover bumps the version without moving a single key) and minimal
    under RESHARD (only the new shard's wins move);
  * a mesh pin freezes ONE cross-shard frontier: pinned multi-group
    reads racing multi-shard ``apply_batch`` publishes observe every
    group on every shard at a single batch version (the §6.6 guarantee
    extended across the shard tier — the torn-read hunter below is the
    tentpole's acceptance test);
  * a dead host degrades DATA reads (zeros + ``TIER_DEFAULT``) but never
    membership, failover restores bit-identical rows, and a hedged
    request races a second host and CANCELS the loser;
  * the replica-fleet balancer drains a killed replica: post-kill
    arrivals route to survivors only, queued events still complete;
  * the satellites: bit-exact vectorized arrivals, one-strike host
    breakers, hot-shard reconstruction from an exported Chrome trace
    alone, ``snap_<v>/shard_<s>/`` snapshot roundtrip, labeled mesh
    metrics.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core.cube import TIER_DEFAULT, ParameterCube
from repro.core.executors import AsyncExecutor, SimExecutor
from repro.core.multitenant import make_balance_op
from repro.core.sedp import SEDP, Event
from repro.data.synthetic import (diurnal_burst_arrivals,
                                  diurnal_burst_arrivals_loop)
from repro.faults.health import BREAKER_OPEN, HealthRegistry
from repro.mesh import (FleetBalancer, MeshCube, Replica, ShardHost,
                        ShardClient, ShardRouter, make_topology, mix64,
                        register_mesh_collectors)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import (TraceBuffer, Tracer, add_child_spans,
                             shard_fanout_spans, shard_profile)
from repro.sparse.hashing import signature_np
from repro.update.snapshot import (SnapshotIntegrityError,
                                   latest_valid_sharded_snapshot,
                                   latest_valid_snapshot,
                                   load_sharded_snapshot,
                                   verify_sharded_snapshot,
                                   write_sharded_snapshot)

DIM = 4
N_IDS = 256
N_GROUPS = 3
ALL_IDS = np.arange(N_IDS, dtype=np.int64)


def _mesh(n_shards=4, n_hosts=4, n_groups=N_GROUPS, **kw):
    kw.setdefault("n_servers", 2)
    kw.setdefault("cube_replication", 2)
    kw.setdefault("block_rows", 64)
    mesh = MeshCube(n_shards=n_shards, n_hosts=n_hosts, **kw)
    for g in range(n_groups):
        mesh.load_table(g, np.zeros((N_IDS, DIM), np.float32),
                        raw_ids=ALL_IDS)
    return mesh


def _batch_parts(value, n_groups=N_GROUPS, ids=None):
    ids = ALL_IDS if ids is None else ids
    return [(g, ids, np.full((ids.size, DIM), float(value), np.float32),
             None) for g in range(n_groups)]


# ------------------------------------------------------------------ routing

def test_rendezvous_routing_deterministic_and_stable_under_republish():
    topo = make_topology(4, ("host0", "host1", "host2", "host3"),
                         replication=2)
    sigs = mix64(np.arange(20000, dtype=np.uint64))
    owners = topo.shard_of(sigs)
    assert owners.min() >= 0 and owners.max() < 4
    counts = np.bincount(owners, minlength=4)
    assert counts.min() > 0.15 * sigs.size  # rendezvous balances ~evenly

    # failover REPUBLISH: version bumps, preference order demotes the dead
    # host, and the key→shard mapping does not move one key
    down = topo.with_host_down("host1")
    assert down.version == topo.version + 1
    np.testing.assert_array_equal(down.shard_of(sigs), owners)
    for s in range(4):
        hosts = down.hosts_for(s)
        if "host1" in topo.hosts_for(s):
            assert hosts[-1] == "host1"       # demoted, still failover-able
        assert set(hosts) == set(topo.hosts_for(s))

    # the router swaps topologies atomically and refuses rollbacks
    router = ShardRouter(topo)
    router.publish(down)
    assert router.topology is down
    with pytest.raises(ValueError):
        router.publish(topo)                  # stale version: never rolls back

    # split() is a partition consistent with shard_of, one capture per batch
    parts = router.split(sigs)
    seen = np.concatenate([idx for _, idx in parts])
    assert np.array_equal(np.sort(seen), np.arange(sigs.size))
    for s, idx in parts:
        assert np.all(owners[idx] == s)


def test_reshard_moves_only_the_new_shards_keys():
    topo4 = make_topology(4, ("h0", "h1", "h2", "h3"))
    topo5 = topo4.with_shards(5)
    sigs = mix64(np.arange(50000, dtype=np.uint64))
    old, new = topo4.shard_of(sigs), topo5.shard_of(sigs)
    moved = old != new
    assert np.all(new[moved] == 4)            # only the added shard gains keys
    frac = moved.mean()
    assert 0.15 < frac < 0.25                 # ~1/5, the rendezvous bound


# ------------------------------------------------- cube-surface equivalence

def test_mesh_lookup_bit_identical_to_single_cube_oracle(rng):
    mesh = _mesh()
    oracle = ParameterCube(n_servers=4, replication=2, block_rows=64)
    for g in range(N_GROUPS):
        oracle.load_table(g, np.zeros((N_IDS, DIM), np.float32),
                          raw_ids=ALL_IDS)
    for r in range(4):                        # identical churn on both
        parts = []
        for g in range(N_GROUPS):
            ids = rng.choice(N_IDS, 50, replace=False).astype(np.int64)
            rows = rng.standard_normal((50, DIM)).astype(np.float32)
            dels = rng.choice(N_IDS, 6, replace=False).astype(np.int64)
            parts.append((g, ids, rows, dels))
        mesh.apply_batch(parts)
        oracle.apply_batch(parts)
    try:
        for g in range(N_GROUPS):
            live = oracle.contains(g, ALL_IDS)
            np.testing.assert_array_equal(mesh.contains(g, ALL_IDS), live)
            rows, tiers = mesh.lookup_ex(g, ALL_IDS)
            want, _ = oracle.lookup_ex(g, ALL_IDS)
            np.testing.assert_array_equal(rows, want)
            assert np.all(tiers < TIER_DEFAULT)   # healthy: nothing degraded
            np.testing.assert_array_equal(mesh.lookup(g, ALL_IDS[live]),
                                          oracle.lookup(g, ALL_IDS[live]))
        mesh.compact(max_rows_per_pass=100)       # per-shard incremental fold
        oracle.compact()
        assert mesh.overlay_blocks == 0
        for g in range(N_GROUPS):
            rows, _ = mesh.lookup_ex(g, ALL_IDS)
            want, _ = oracle.lookup_ex(g, ALL_IDS)
            np.testing.assert_array_equal(rows, want)
    finally:
        mesh.shutdown()


def test_mesh_pin_freezes_cross_shard_frontier():
    mesh = _mesh()
    try:
        v0 = mesh.version
        with mesh.pin() as pv:
            v1 = mesh.apply_batch(_batch_parts(7.0))
            assert v1 == v0 + 1               # one bump for 3 groups × 4 shards
            for g in range(N_GROUPS):         # pinned reader: whole OLD frontier
                assert np.all(mesh.lookup(g, ALL_IDS, version=pv) == 0.0)
        for g in range(N_GROUPS):             # fresh pin: whole NEW frontier
            assert np.all(mesh.lookup(g, ALL_IDS) == 7.0)
    finally:
        mesh.shutdown()


def test_mesh_apply_batch_validation_failure_publishes_nothing():
    mesh = _mesh()
    try:
        v0, overlays0 = mesh.version, mesh.overlay_blocks
        ids = np.arange(8, dtype=np.int64)
        good = (0, ids, np.full((8, DIM), 4.0, np.float32), None)
        bad = (1, ids, np.full((8, DIM + 1), 4.0, np.float32), None)
        with pytest.raises(ValueError):
            mesh.apply_batch([good, bad])     # validated BEFORE any shard apply
        assert mesh.version == v0
        assert mesh.overlay_blocks == overlays0
        assert np.all(mesh.lookup(0, ids) == 0.0)
    finally:
        mesh.shutdown()


# ------------------------------------------------------- torn-read hunter

def _hunter_expected(published, pin_version):
    vs = [v for v in published if v <= pin_version]
    return published[max(vs)] if vs else None


def test_cross_shard_torn_read_hunter_async(rng):
    """THE tentpole acceptance test: concurrent pinned readers hammer
    multi-group lookups against a 4-shard mesh while a writer streams
    value-stamped multi-shard delta batches and incremental compactions.
    Every pin must observe all groups ON ALL SHARDS at one single batch
    version — a torn frontier shows up as two values under one pin."""
    mesh = _mesh()
    published = {mesh.version: 0.0}
    stop = threading.Event()
    first_batch = threading.Event()
    writer_err = []
    pins_checked = [0]

    def writer():
        try:
            first_batch.wait(timeout=10)
            k = 0
            while not stop.is_set():
                next_v = mesh.version + 1
                published[next_v] = float(next_v)   # record BEFORE publish
                assert mesh.apply_batch(_batch_parts(float(next_v))) == next_v
                k += 1
                if k % 5 == 0:
                    # compact republishes too: the intermediate versions
                    # carry the same values, _hunter_expected resolves them
                    mesh.compact(max_rows_per_pass=64)
                time.sleep(0.002)
        except Exception as e:                 # pragma: no cover - debug aid
            writer_err.append(e)

    def op_lookup(batch, ctx):
        first_batch.set()
        for ev in batch:
            ids = ev.payload["ids"]
            with mesh.pin() as pv:             # ONE pin spanning shards+groups
                per_group = [np.unique(mesh.lookup(g, ids, version=pv))
                             for g in range(N_GROUPS)]
                ev.payload["version"] = pv.version
            ev.payload["values"] = np.unique(np.concatenate(per_group))
            pins_checked[0] += 1
        return batch

    g = SEDP()
    g.add_stage("ingress", lambda b, c: b, batch_size=4, parallelism=2)
    g.add_stage("lookup", op_lookup, batch_size=8, parallelism=3)
    g.add_stage("respond", lambda b, c: b, batch_size=8)
    g.chain("ingress", "lookup", "respond")
    events = [Event(payload={"ids": rng.integers(0, N_IDS, 24)})
              for _ in range(400)]
    th = threading.Thread(target=writer, daemon=True)
    th.start()
    try:
        report = AsyncExecutor(g.compile()).run(events)
    finally:
        stop.set()
        th.join(timeout=10)
        mesh.shutdown()
    assert not writer_err
    assert len(report.results) == len(events)
    assert pins_checked[0] >= 300
    seen_versions = set()
    for ev in report.results:
        vals = ev.payload["values"]
        # every row of every group under one pin carries ONE value ⇒ the
        # pin saw a single cross-shard batch frontier — no tear anywhere
        assert vals.size == 1, f"cross-shard torn read: values {vals}"
        assert _hunter_expected(published, ev.payload["version"]) == \
            float(vals[0])
        seen_versions.add(ev.payload["version"])
    assert len(seen_versions) >= 2, seen_versions


# ---------------------------------------------- degradation + failover

def test_host_kill_degrades_data_not_membership_and_failover_restores():
    mesh = _mesh(n_shards=4, n_hosts=4, replication=2)
    try:
        mesh.apply_batch(_batch_parts(3.0))
        baseline = mesh.lookup(0, ALL_IDS)
        assert np.all(baseline == 3.0)

        # shard 0 lives on hosts (0, 1); kill BOTH → its keys degrade to
        # zeros + TIER_DEFAULT while membership stays authoritative
        mesh.kill_host("host0")
        mesh.kill_host("host1")
        owners = mesh.router.topology.shard_of(signature_np(0, ALL_IDS))
        dead = owners == 0
        assert dead.any() and (~dead).any()
        rows, tiers = mesh.lookup_ex(0, ALL_IDS)
        assert np.all(tiers[dead] == TIER_DEFAULT)
        assert np.all(rows[dead] == 0.0)
        assert np.all(tiers[~dead] < TIER_DEFAULT)
        np.testing.assert_array_equal(rows[~dead], baseline[~dead])
        # membership is a local metadata probe: an outage never fabricates
        # tombstones (zeros stay marked degraded, not absent)
        assert mesh.contains(0, ALL_IDS).all()

        # single-host kill: the client fails over within the preference
        # list and the read stays bit-identical (degraded nowhere)
        mesh.revive_host("host1")
        rows2, tiers2 = mesh.lookup_ex(0, ALL_IDS)
        np.testing.assert_array_equal(rows2, baseline)
        assert np.all(tiers2 < TIER_DEFAULT)
        assert mesh.client.stats["failovers"] > 0

        # control-plane failover REPUBLISH stops paying the dead-host
        # probe: host0 demotes to the back of every preference list
        rejected_before = mesh.hosts["host0"].rejected
        assert rejected_before > 0
        topo = mesh.fail_over("host0")
        assert topo.version > 1
        for _ in range(3):
            mesh.lookup(0, ALL_IDS)
        assert mesh.hosts["host0"].rejected == rejected_before
        mesh.revive_host("host0")
        np.testing.assert_array_equal(mesh.lookup(0, ALL_IDS), baseline)
    finally:
        mesh.shutdown()


def test_hedged_request_cancels_the_loser():
    """Acceptance: a slow primary trips the hedge window, the secondary
    answers, and the loser is cancelled — it never touches the shard."""
    hosts = {"h0": ShardHost("h0", wall_latency=True),
             "h1": ShardHost("h1", wall_latency=True)}
    router = ShardRouter(make_topology(1, ("h0", "h1"), replication=2))
    client = ShardClient(hosts, router, hedge_after_s=0.02)
    hosts["h0"].extra_latency_s = 0.25        # primary stalls past the window
    executed = []

    def fn():
        executed.append(threading.current_thread().name)
        return "rows"

    try:
        out, meta = client.call(0, fn)
        assert out == "rows"
        assert meta["host"] == "h1" and meta["hedged"] is True
        assert client.stats["hedges"] == 1
        assert client.stats["hedge_wins"] == 1
        assert client.stats["cancelled"] == 1
        # the loser wakes from its injected stall, sees its cancel event,
        # and aborts BEFORE executing the shard read
        deadline = time.monotonic() + 2.0
        while hosts["h0"].cancelled == 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert hosts["h0"].cancelled == 1
        assert hosts["h0"].served == 0
        assert len(executed) == 1             # only the winner ran fn

        # control: with the stall gone no hedge launches
        hosts["h0"].extra_latency_s = 0.0
        out2, meta2 = client.call(0, fn)
        assert out2 == "rows" and meta2["hedged"] is False
        assert client.stats["hedges"] == 1    # unchanged
    finally:
        client.shutdown()


# ------------------------------------------------------------ fleet balancer

def _fleet_plan(bal, kill_at=None, kill_name="r0"):
    inner = make_balance_op(bal.pick)
    seen, kill_order = [0], [None]

    def balance(batch, ctx):
        out = inner(batch, ctx)
        for ev in out:
            seen[0] += 1
            ev.payload["order"] = seen[0]
        if (kill_at is not None and seen[0] >= kill_at
                and kill_order[0] is None):
            bal.kill(kill_name)
            kill_order[0] = seen[0]
        return out

    def replica_op(name):
        def op(batch, ctx):
            for ev in batch:
                ev.payload["served_by"] = name
            return batch
        return op

    g = SEDP()
    g.add_stage("ingress", lambda b, c: b, batch_size=4)
    g.add_stage("balance", balance, batch_size=4)
    for r in bal.replicas:
        g.add_stage(r.entry, replica_op(r.name), batch_size=4,
                    sim_base_s=1e-4)
        g.add_edge("balance", r.entry)
        g.add_stage(f"respond_{r.name}", lambda b, c: b, batch_size=4)
        g.add_edge(r.entry, f"respond_{r.name}")
    g.add_edge("ingress", "balance")
    return g.compile(), kill_order


def test_balancer_drains_killed_replica():
    """Acceptance: a replica killed mid-run receives ZERO post-kill
    arrivals; its queued events still complete; survivors absorb the
    rest of the stream."""
    bal = FleetBalancer([Replica("r0", "r0"), Replica("r1", "r1"),
                         Replica("r2", "r2")])
    plan, kill_order = _fleet_plan(bal, kill_at=24)
    arrivals = [(i * 1e-3, Event(payload={"i": i})) for i in range(90)]
    report = SimExecutor(plan).run(arrivals)
    assert len(report.results) == 90          # queued events drained, none lost
    assert kill_order[0] is not None
    routed_to_dead_after_kill = [
        ev for ev in report.results
        if ev.payload["order"] > kill_order[0]
        and ev.meta["replica"] == "r0"]
    assert not routed_to_dead_after_kill
    for ev in report.results:                 # balance decision = actual path
        assert ev.meta["replica"] == ev.payload["served_by"]
    snap = bal.snapshot()
    assert snap["r0"]["routed"] > 0           # it DID serve before the kill
    assert not snap["r0"]["alive"]
    assert snap["r1"]["routed"] + snap["r2"]["routed"] == 90 - \
        snap["r0"]["routed"]
    # survivors share the post-kill load instead of pile-on
    assert snap["r1"]["routed"] > 0 and snap["r2"]["routed"] > 0


def test_balancer_unroutable_fleet_terminates_events_with_error():
    bal = FleetBalancer([Replica("r0", "r0"), Replica("r1", "r1")])
    bal.kill("r0"), bal.kill("r1")
    plan, _ = _fleet_plan(bal)
    report = SimExecutor(plan).run(
        [(i * 1e-3, Event(payload={"i": i})) for i in range(5)])
    assert len(report.results) == 5
    for ev in report.results:
        assert ev.meta["error"] == "no live replica"
        assert "served_by" not in ev.payload  # never reached a replica
    assert bal.unroutable == 5


def test_balancer_open_breaker_skips_replica_like_a_kill():
    now = [0.0]
    health = HealthRegistry(keys=[("r0", "entry"), ("r1", "entry")],
                            clock=lambda: now[0], cooldown_s=60.0)
    bal = FleetBalancer([Replica("r0", "r0"), Replica("r1", "r1")],
                        health=health)
    health[("r0", "entry")].trip(now[0])

    class _Ctx:
        def queue_depth(self, stage):
            return 0
    for _ in range(6):
        assert bal.pick(Event(payload={}), _Ctx()) == "r1"
    assert bal.by_name["r0"].routed == 0


# -------------------------------------------------- one-strike host breakers

def test_dead_host_costs_one_strike_not_one_per_shard():
    """Satellite regression: (host, shard) breaker keys + the host-level
    verdict. The FIRST HostDown trips every breaker of the host at once —
    later calls for other shards skip it for free instead of paying one
    failed probe per shard."""
    now = [0.0]
    mesh = _mesh(n_shards=4, n_hosts=2, replication=2, n_groups=1)
    try:
        reg = mesh.attach_health(HealthRegistry.for_mesh(
            mesh.router.topology.hosts, 4, clock=lambda: now[0],
            failure_threshold=3, cooldown_s=5.0))
        mesh.kill_host("host0")
        # shard 0's primary is host0: ONE failed probe, then failover
        out, meta = mesh.client.call(0, lambda: "ok")
        assert out == "ok" and meta["host"] == "host1"
        assert mesh.hosts["host0"].rejected == 1
        assert mesh.client.stats["host_failures"] == 1
        # the single strike opened ALL of host0's breakers at once...
        assert all(st == BREAKER_OPEN
                   for st in reg.host_states("host0").values())
        assert all(reg[("host0", s)].opens == 1 for s in range(4))
        # ...so shard 2 (also primary host0) never probes the dead host
        out2, _ = mesh.client.call(2, lambda: "ok")
        assert out2 == "ok"
        assert mesh.hosts["host0"].rejected == 1      # STILL one
        assert mesh.client.stats["host_failures"] == 1
        # host1's breakers are untouched
        assert all(st != BREAKER_OPEN
                   for st in reg.host_states("host1").values())
        # cooldown: the revived host closes back via one half-open probe
        mesh.revive_host("host0")
        now[0] = 10.0
        out3, meta3 = mesh.client.call(0, lambda: "ok")
        assert out3 == "ok" and meta3["host"] == "host0"
        assert reg[("host0", 0)].state != BREAKER_OPEN
    finally:
        mesh.shutdown()


# ----------------------------------------------------- vectorized arrivals

@pytest.mark.parametrize("kw", [
    dict(base_qps=40.0, peak_mult=3.0, day_s=600.0),
    dict(base_qps=60.0, peak_mult=2.0, day_s=300.0,
         burst_rate_per_s=0.05, burst_mult=6.0, burst_dur_s=2.0),
    dict(base_qps=25.0, peak_mult=5.0, day_s=120.0, start_frac=0.0,
         burst_rate_per_s=0.5, burst_mult=3.0, burst_dur_s=0.25),
], ids=["diurnal", "bursty", "burst-heavy"])
def test_vectorized_arrivals_bit_identical_to_loop(kw):
    """Satellite: the chunked/vectorized NHPP thinning sampler must equal
    the per-event reference loop BIT-FOR-BIT at a fixed seed — same
    derived sub-streams, same float association, overshoot discarded."""
    fast = diurnal_burst_arrivals(np.random.default_rng(7), 3000, **kw)
    slow = diurnal_burst_arrivals_loop(np.random.default_rng(7), 3000, **kw)
    assert fast.dtype == slow.dtype
    np.testing.assert_array_equal(fast, slow)
    assert fast.size == 3000
    assert np.all(np.diff(fast) >= 0.0)       # arrival times, sorted
    again = diurnal_burst_arrivals(np.random.default_rng(7), 3000, **kw)
    np.testing.assert_array_equal(fast, again)  # deterministic


# --------------------------------------------------------- trace attribution

def test_hot_shard_reconstructed_from_exported_trace_alone():
    """Satellite: one slow host shows up as the hot shard in
    ``shard_profile`` — computed from an exported Chrome trace document
    ONLY (no live objects), the way the fleet bench attributes its tail."""
    mesh = _mesh(wall_latency=True, n_groups=1)
    try:
        mesh.hosts["host2"].extra_latency_s = 0.05   # shard 2's primary

        def op(batch, ctx):
            for ev in batch:
                with mesh.pin() as pv:
                    mesh.lookup(0, ev.payload["ids"], version=pv)
                fan = mesh.take_fanout()
                assert {f["shard"] for f in fan} == {0, 1, 2, 3}
                add_child_spans(ev, shard_fanout_spans(fan))
            return batch

        g = SEDP()
        g.add_stage("fetch", op, batch_size=2)
        g.add_stage("respond", lambda b, c: b, batch_size=2)
        g.chain("fetch", "respond")
        tr = Tracer()
        report = AsyncExecutor(g.compile(), tracer=tr).run(
            [Event(payload={"ids": ALL_IDS}) for _ in range(4)])
        assert len(report.results) == 4
        doc = tr.buffer.export_chrome()
        for rec in TraceBuffer.from_chrome(doc):
            prof = shard_profile(rec)
            assert set(prof) == {0, 1, 2, 3}
            hot = max(prof, key=lambda s: prof[s]["dur_s"])
            assert hot == 2                   # the stalled host's shard
            assert prof[2]["dur_s"] >= 0.04
            assert prof[2]["dur_s"] > 2 * max(
                prof[s]["dur_s"] for s in (0, 1, 3))
            assert "host2" in prof[2]["hosts"]
            # the stage's own exec span survived the child insertion
            execs = [sp for sp in rec["spans"]
                     if sp["stage"] == "fetch" and sp["kind"] == "exec"]
            assert len(execs) == 1 and execs[0]["t1"] >= execs[0]["t0"]
    finally:
        mesh.shutdown()


def test_fetch_stage_attaches_shard_fanout_spans_end_to_end():
    """The CubeFetchStage integration: a scenario pipeline on a mesh
    substrate (``mesh_shards=4`` — construction otherwise unchanged)
    yields traces whose cube stage carries per-shard ``shard_fetch``
    children, and the requests serve undegraded."""
    from repro.serve.scenario import (PipelineBuilder, ScenarioSpec,
                                      ServingSubstrate, make_request_events)
    sub = ServingSubstrate(mesh_shards=4, block_rows=512, seed=0)
    assert getattr(sub.cube, "is_mesh", False)
    try:
        b = PipelineBuilder(sub)
        b.add_ingress("ingress")
        rt = b.add_scenario(ScenarioSpec(name="din", arch_id="din",
                                         shed=False, seed=0),
                            namespaced=False)
        b.g.add_edge("ingress", b.entries["din"])
        _graph, plan = b.compile()
        tr = Tracer()
        reqs = make_request_events([rt.model_cfg], 8, seed=0)
        report = AsyncExecutor(plan, tracer=tr).run(reqs)
        assert len(report.results) == 8
        for ev in report.results:
            assert ev.meta["response"].degraded_tier == 0
        traced = tr.buffer.traces()
        assert len(traced) == 8
        with_fanout = 0
        for rec in traced:
            fetch = [sp for sp in rec["spans"]
                     if sp["kind"] == "shard_fetch"]
            if fetch:
                with_fanout += 1
                prof = shard_profile(rec)
                assert prof and all(p["n_fetches"] >= 1
                                    for p in prof.values())
        # cold cube cache ⇒ at least the early requests fan out to shards
        assert with_fanout > 0
    finally:
        sub.cube.shutdown()


# ------------------------------------------------------- sharded snapshots

def test_sharded_snapshot_roundtrip_two_shards(tmp_path, rng):
    mesh = _mesh(n_shards=2, n_hosts=2, n_groups=2)
    sd = str(tmp_path)
    try:
        for g in range(2):
            ids = rng.choice(N_IDS, 60, replace=False).astype(np.int64)
            rows = rng.standard_normal((60, DIM)).astype(np.float32)
            dels = rng.choice(N_IDS, 8, replace=False).astype(np.int64)
            mesh.apply_batch([(g, ids, rows, dels)])
        with mesh.pin() as pv:
            path = write_sharded_snapshot(
                sd, mesh, pv.snap, delta_version=7,
                groups=(("f0", N_IDS, 0), ("f1", N_IDS, 1)))
        assert os.path.basename(path) == "snap_000000000007"
        for s in range(2):                    # per-shard naming + publish
            assert os.path.exists(os.path.join(path, f"shard_{s}", "DONE"))
        assert verify_sharded_snapshot(path)
        assert latest_valid_sharded_snapshot(sd) == path
        # invisible to LEGACY single-cube recovery: no top-level DONE
        assert latest_valid_snapshot(sd) is None

        shards, meta = load_sharded_snapshot(path)
        assert meta["n_shards"] == 2
        assert meta["delta_version"] == 7
        assert meta["groups"] == [["f0", N_IDS, 0], ["f1", N_IDS, 1]]
        # the per-shard cursor map records each shard's pinned version
        assert meta["shard_cursors"] == {
            str(s): mesh.shards[s].version for s in range(2)}
        assert meta["topology"]["hosts"] == ["host0", "host1"]
        # bit-identical per shard at the pinned cursor, tombstones kept
        for g in range(2):
            sigs = signature_np(g, ALL_IDS)
            for s, idx in mesh.router.split(sigs):
                want_live = mesh.shards[s].contains(g, ALL_IDS[idx])
                got_live = shards[s].contains(g, ALL_IDS[idx])
                np.testing.assert_array_equal(want_live, got_live)
                np.testing.assert_array_equal(
                    shards[s].lookup(g, ALL_IDS[idx][got_live]),
                    mesh.shards[s].lookup(g, ALL_IDS[idx][want_live]))

        # a newer snapshot wins; a torn one (no MESH_DONE) is skipped
        mesh.apply_batch([(0, ALL_IDS[:4],
                           np.full((4, DIM), 9.0, np.float32), None)])
        with mesh.pin() as pv:
            p2 = write_sharded_snapshot(sd, mesh, pv.snap, delta_version=9)
        assert latest_valid_sharded_snapshot(sd) == p2
        os.remove(os.path.join(p2, "MESH_DONE"))
        with pytest.raises(SnapshotIntegrityError):
            verify_sharded_snapshot(p2)
        assert latest_valid_sharded_snapshot(sd) == path
    finally:
        mesh.shutdown()


# ---------------------------------------------------------------- metrics

def test_mesh_metrics_families_are_shard_host_replica_labeled():
    mesh = _mesh(n_shards=2, n_hosts=2, n_groups=1)
    try:
        fleet = FleetBalancer([Replica("r0", "r0"), Replica("r1", "r1")])
        fleet.by_name["r0"].routed = 5
        fleet.kill("r1")
        reg = MetricsRegistry()
        register_mesh_collectors(reg, mesh=mesh, fleet=fleet)
        mesh.lookup(0, ALL_IDS)
        mesh.kill_host("host1")
        snap = reg.snapshot()
        for s in range(2):
            assert snap[f"jizhi_mesh_shard_calls{{shard={s}}}"] >= 1.0
            assert snap[f"jizhi_mesh_shard_rows{{shard={s}}}"] > 0.0
        assert snap["jizhi_mesh_host_alive{host=host0}"] == 1.0
        assert snap["jizhi_mesh_host_alive{host=host1}"] == 0.0
        assert snap["jizhi_mesh_topology_version{}"] == 1.0
        assert snap["jizhi_mesh_version{}"] == float(mesh.version)
        assert snap["jizhi_fleet_replica_routed{replica=r0}"] == 5.0
        assert snap["jizhi_fleet_replica_alive{replica=r1}"] == 0.0
        prom = reg.to_prometheus()
        assert 'jizhi_mesh_shard_rows{shard="0"}' in prom
        assert 'jizhi_fleet_replica_alive{replica="r0"} 1' in prom
    finally:
        mesh.shutdown()
