"""Interpret-mode parity on EDGE shapes: every Pallas kernel vs its ref.py.

The sweeps in test_kernels.py cover bulk shapes; these pin the degenerate
corners that grid/padding logic tends to get wrong — single-element batches
(B=1), single-key bags (K=1), and padded bags whose weights are entirely
zero (all-padding rows must combine to exactly 0, and `mean` must not
divide by zero).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.augru.ops import augru
from repro.kernels.augru.ref import augru_ref
from repro.kernels.candidate_scorer.ops import candidate_scorer
from repro.kernels.candidate_scorer.ref import candidate_scorer_ref
from repro.kernels.din_attention.ops import din_attention
from repro.kernels.din_attention.ref import din_attention_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import flash_decode_ref

TOL = dict(rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("B,K", [(1, 1), (1, 5), (8, 1)])
@pytest.mark.parametrize("combiner", ["sum", "mean"])
def test_embedding_bag_edge_shapes(B, K, combiner, rng):
    table = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 32, (B, K)).astype(np.int32))
    w = jnp.asarray(rng.random((B, K)).astype(np.float32))
    got = embedding_bag(table, ids, w, combiner=combiner)
    want = embedding_bag_ref(table, ids, w, combiner=combiner)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("combiner", ["sum", "mean"])
def test_embedding_bag_all_zero_weight_bags(combiner, rng):
    """Fully-padded bags (every weight 0) must produce exactly the ref
    output — 0 for sum, 0/eps for mean — not NaN/garbage rows."""
    table = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 16, (3, 4)).astype(np.int32))
    w = jnp.zeros((3, 4), jnp.float32)
    got = np.asarray(embedding_bag(table, ids, w, combiner=combiner))
    want = np.asarray(embedding_bag_ref(table, ids, w, combiner=combiner))
    np.testing.assert_allclose(got, want, **TOL)
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, 0.0, atol=1e-6)


@pytest.mark.parametrize("B,T", [(1, 1), (1, 9), (5, 1)])
def test_din_attention_edge_shapes(B, T, rng):
    D, H1, H2 = 8, 16, 8
    hist = jnp.asarray(rng.normal(size=(B, T, D)).astype(np.float32))
    mask = jnp.asarray(np.ones((B, T), np.float32))
    tgt = jnp.asarray(rng.normal(size=(B, D)).astype(np.float32))
    w1 = jnp.asarray(rng.normal(size=(4 * D, H1)).astype(np.float32) * 0.2)
    w2 = jnp.asarray(rng.normal(size=(H1, H2)).astype(np.float32) * 0.2)
    w3 = jnp.asarray(rng.normal(size=(H2, 1)).astype(np.float32) * 0.2)
    b1, b2, b3 = (jnp.zeros(H1), jnp.zeros(H2), jnp.zeros(1))
    got = din_attention(hist, mask, tgt, w1, b1, w2, b2, w3, b3)
    want = din_attention_ref(hist, mask, tgt, w1, b1, w2, b2, w3, b3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_din_attention_zero_mask(rng):
    """All-zero history mask: kernel and oracle must agree bit-for-bit on
    the fully-masked degenerate case."""
    B, T, D, H1, H2 = 2, 6, 8, 16, 8
    hist = jnp.asarray(rng.normal(size=(B, T, D)).astype(np.float32))
    mask = jnp.zeros((B, T), jnp.float32)
    tgt = jnp.asarray(rng.normal(size=(B, D)).astype(np.float32))
    w1 = jnp.asarray(rng.normal(size=(4 * D, H1)).astype(np.float32) * 0.2)
    w2 = jnp.asarray(rng.normal(size=(H1, H2)).astype(np.float32) * 0.2)
    w3 = jnp.asarray(rng.normal(size=(H2, 1)).astype(np.float32) * 0.2)
    b1, b2, b3 = (jnp.zeros(H1), jnp.zeros(H2), jnp.zeros(1))
    got = din_attention(hist, mask, tgt, w1, b1, w2, b2, w3, b3)
    want = din_attention_ref(hist, mask, tgt, w1, b1, w2, b2, w3, b3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("B,T", [(1, 1), (1, 7), (4, 1)])
def test_augru_edge_shapes(B, T, rng):
    Din, H = 6, 10
    x = jnp.asarray(rng.normal(size=(B, T, Din)).astype(np.float32))
    att = jnp.asarray(rng.random((B, T)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(Din, 3 * H)).astype(np.float32) * 0.3)
    u = jnp.asarray(rng.normal(size=(H, 3 * H)).astype(np.float32) * 0.3)
    b = jnp.asarray(rng.normal(size=(3 * H,)).astype(np.float32) * 0.1)
    got = augru(x, att, w, u, b)
    want = augru_ref(x, att, w, u, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("B,S,L", [(1, 64, 1), (1, 32, 32), (3, 64, 1)])
def test_flash_decode_edge_shapes(B, S, L, rng):
    H, G, D = 2, 2, 16
    q = jnp.asarray(rng.normal(size=(B, H, G, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    got = flash_decode(q, k, v, L, block_k=32)
    want = flash_decode_ref(q, k, v, L)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("C,k", [(64, 1), (17, 4), (128, 128)])
def test_candidate_scorer_edge_shapes(C, k, rng):
    D = 16
    cands = jnp.asarray(rng.normal(size=(C, D)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(D,)).astype(np.float32))
    v, i = candidate_scorer(cands, q, k=k, block_c=64)
    rv, ri = candidate_scorer_ref(cands, q, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), **TOL)
    assert set(np.asarray(i).tolist()) == set(np.asarray(ri).tolist())
