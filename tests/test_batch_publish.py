"""Batch-atomic cube publish + incremental compaction (DESIGN.md §6.6).

Contracts under test:

  * ``apply_batch`` publishes EVERY group of a delta batch in ONE atomic
    snapshot swap — a pin taken at any instant observes all groups at the
    same version (the §7.3 cross-group torn window cannot open);
  * a validation failure anywhere in the batch leaves the cube untouched
    (no group published, no overlay blocks leaked);
  * ``compact(max_rows_per_pass=...)`` folds overlays across multiple
    short writer-lock holds, bit-identical to the monolithic pass, with
    pinned readers live (and bit-stable) throughout;
  * the delta log satellites: numeric group ordering in ``read_delta``,
    emitter restart resuming past existing versions, and the re-emit
    recovery path unpublishing (DONE removed) before rewriting.

The two torn-read hunters at the bottom are the tentpole's acceptance
test (ISSUE 7): ≥1k pinned multi-group reads racing a live multi-group
delta + chunked-compaction stream must observe zero cross-group version
mismatches.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core.cube import ParameterCube
from repro.core.executors import AsyncExecutor, SimExecutor
from repro.core.sedp import SEDP, Event
from repro.update import DeltaBatch, GroupDelta, UpdateManager
from repro.update.delta import (DeltaEmitter, DeltaIntegrityError,
                                DeltaWatcher, list_deltas, read_delta,
                                verify_delta, write_delta)

DIM = 4
N_IDS = 192
N_GROUPS = 3


def _multi_group_value_cube(n_groups=N_GROUPS):
    """Cube holding ``n_groups`` feature groups whose every row is filled
    with the value of the batch that published it — torn reads (within a
    group OR across groups) are detectable by value."""
    cube = ParameterCube(n_servers=4, replication=2, block_rows=32)
    for g in range(n_groups):
        cube.load_table(g, np.zeros((N_IDS, DIM), np.float32),
                        raw_ids=np.arange(N_IDS, dtype=np.int64))
    cube._ensure_primary_index()           # fold the build
    return cube


def _batch_parts(value, n_groups=N_GROUPS, ids=None):
    ids = np.arange(N_IDS, dtype=np.int64) if ids is None else ids
    return [(g, ids, np.full((ids.size, DIM), float(value), np.float32),
             None) for g in range(n_groups)]


# ------------------------------------------------------------- apply_batch

def test_apply_batch_one_bump_covers_all_groups():
    cube = _multi_group_value_cube()
    v0 = cube.version
    v1 = cube.apply_batch(_batch_parts(5.0))
    assert v1 == v0 + 1                    # ONE bump for three groups
    for g in range(N_GROUPS):
        rows = cube.lookup(g, np.arange(N_IDS, dtype=np.int64))
        assert np.all(rows == 5.0)
    # upserts + deletes mixed across groups, still one bump
    v2 = cube.apply_batch([
        (0, None, None, np.arange(4, dtype=np.int64)),
        (1, np.array([7], np.int64),
         np.full((1, DIM), 9.0, np.float32), np.array([8], np.int64)),
        (2, np.array([0], np.int64),
         np.full((1, DIM), 9.0, np.float32), None)])
    assert v2 == v1 + 1
    assert not cube.contains(0, np.arange(4, dtype=np.int64)).any()
    assert not cube.contains(1, np.array([8], np.int64))[0]
    assert cube.lookup(1, np.array([7], np.int64))[0, 0] == 9.0
    assert cube.lookup(2, np.array([0], np.int64))[0, 0] == 9.0


def test_apply_batch_empty_batch_still_bumps_once():
    cube = _multi_group_value_cube()
    v0 = cube.version
    assert cube.apply_batch([]) == v0 + 1
    assert cube.apply_batch([(0, None, None, None)]) == v0 + 2


def test_apply_delta_is_single_group_batch():
    cube = _multi_group_value_cube()
    v0 = cube.version
    ids = np.arange(8, dtype=np.int64)
    v1 = cube.apply_delta(0, ids, np.full((8, DIM), 3.0, np.float32))
    assert v1 == v0 + 1
    assert np.all(cube.lookup(0, ids) == 3.0)


def test_apply_batch_validation_failure_publishes_nothing():
    """A malformed group ANYWHERE in the batch must leave the cube exactly
    as it was: no version bump, no group applied, no overlay blocks
    leaked (a leaked replica-registered block would hold rows that never
    published — probeable through failover)."""
    cube = _multi_group_value_cube()
    v0, overlays0 = cube.version, cube.overlay_blocks
    ids = np.arange(8, dtype=np.int64)
    good = (0, ids, np.full((8, DIM), 4.0, np.float32), None)
    bad_dim = (1, ids, np.full((8, DIM + 1), 4.0, np.float32), None)
    with pytest.raises(ValueError):
        cube.apply_batch([good, bad_dim])  # good group FIRST: must not land
    assert cube.version == v0
    assert cube.overlay_blocks == overlays0
    assert np.all(cube.lookup(0, ids) == 0.0)   # group 0 unchanged
    bad_count = (1, ids, np.full((7, DIM), 4.0, np.float32), None)
    with pytest.raises(ValueError):
        cube.apply_batch([good, bad_count])
    assert cube.version == v0 and cube.overlay_blocks == overlays0


# --------------------------------------------------- incremental compaction

def _churn(cube, seed=11, rounds=6):
    rng = np.random.default_rng(seed)
    for r in range(rounds):
        parts = []
        for g in range(N_GROUPS):
            ids = rng.choice(N_IDS, 40, replace=False).astype(np.int64)
            rows = rng.standard_normal((40, DIM)).astype(np.float32)
            dels = rng.choice(N_IDS, 5, replace=False).astype(np.int64)
            parts.append((g, ids, rows, dels))
        cube.apply_batch(parts)


def test_chunked_compaction_bit_identical_to_monolithic():
    a, b = _multi_group_value_cube(), _multi_group_value_cube()
    _churn(a), _churn(b)
    a.compact()                            # monolithic
    b.compact(max_rows_per_pass=100)       # chunked
    assert a.metrics.compact_passes == 1
    assert b.metrics.compact_passes > 2    # actually ran incrementally
    assert a.overlay_blocks == 0 and b.overlay_blocks == 0
    ids = np.arange(N_IDS, dtype=np.int64)
    for g in range(N_GROUPS):
        la, lb = a.contains(g, ids), b.contains(g, ids)
        np.testing.assert_array_equal(la, lb)
        np.testing.assert_array_equal(a.lookup(g, ids[la]),
                                      b.lookup(g, ids[lb]))


def test_chunked_compaction_records_bounded_holds():
    cube = _multi_group_value_cube()
    _churn(cube)
    assert cube.metrics.compact_max_hold_s == 0.0
    cube.compact(max_rows_per_pass=64)
    assert cube.metrics.compactions == 1
    assert cube.metrics.compact_passes > 1
    assert cube.metrics.compact_max_hold_s > 0.0


def test_chunked_compaction_deleted_rows_stay_deleted():
    """Tombstone cleanup must not resurrect: a row deleted pre-compaction
    stays absent after the chunked fold, through the failover path too."""
    cube = _multi_group_value_cube()
    dels = np.arange(0, 20, dtype=np.int64)
    cube.apply_batch([(g, None, None, dels) for g in range(N_GROUPS)])
    # upsert-then-re-delete: the freshest state is a tombstone whose row
    # still sits in an overlay block — cleanup must keep it dead
    cube.apply_batch([(0, np.array([3], np.int64),
                       np.full((1, DIM), 8.0, np.float32), None)])
    cube.apply_batch([(0, None, None, np.array([3], np.int64))])
    cube.compact(max_rows_per_pass=48)
    for g in range(N_GROUPS):
        assert not cube.contains(g, dels).any(), g
    live = np.arange(20, N_IDS, dtype=np.int64)
    for g in range(N_GROUPS):
        assert cube.contains(g, live).all(), g


def test_chunked_compaction_pinned_reader_stays_bit_identical():
    cube = _multi_group_value_cube()
    _churn(cube, seed=3)
    ids = np.arange(N_IDS, dtype=np.int64)
    with cube.pin() as pv:
        live = ids[cube.contains(0, ids, version=pv)]
        before = cube.lookup(0, live, version=pv)
        cube.compact(max_rows_per_pass=64)     # folds while pv is live
        after = cube.lookup(0, live, version=pv)
        np.testing.assert_array_equal(before, after)
    cube.reclaim()
    assert cube.overlay_blocks == 0


def test_chunked_compaction_everything_deleted_compacts_to_empty():
    cube = _multi_group_value_cube()
    ids = np.arange(N_IDS, dtype=np.int64)
    cube.apply_batch([(g, None, None, ids) for g in range(N_GROUPS)])
    cube.compact(max_rows_per_pass=64)
    for g in range(N_GROUPS):
        assert not cube.contains(g, ids).any()
    assert cube._snap[1].size == 0         # no live entries, no tombstones


def test_manager_uses_chunked_compaction_knob():
    cube = _multi_group_value_cube()
    mgr = UpdateManager(cube, compact_after_blocks=1,
                        compact_max_rows_per_pass=48)
    ids = np.arange(N_IDS, dtype=np.int64)
    mgr.apply(DeltaBatch(0, [
        GroupDelta(group=g, ids=ids,
                   rows=np.full((N_IDS, DIM), 2.0, np.float32))
        for g in range(N_GROUPS)]))
    assert mgr.maybe_compact()
    assert cube.overlay_blocks == 0
    assert cube.metrics.compact_passes > 1  # the knob reached the cube


def test_manager_touched_log_one_entry_per_batch():
    cube = _multi_group_value_cube()
    mgr = UpdateManager(cube)
    ids = np.arange(6, dtype=np.int64)
    mgr.apply(DeltaBatch(0, [
        GroupDelta(group=g, ids=ids,
                   rows=np.full((6, DIM), 1.0, np.float32))
        for g in range(N_GROUPS)]))
    assert len(mgr._touched_log) == 1      # batch granularity, not per-group
    logged_v, keys, _ = mgr._touched_log[0]
    assert logged_v == cube.version        # logged at the CUBE batch version
    got = mgr.touched_since(logged_v - 1)
    assert got is not None
    # all three groups' keys live under the SINGLE batch version
    assert {(g, int(i)) for g in (1, 2) for i in ids} <= got[0]
    assert {int(i) for i in ids} <= got[0]  # group 0 keys by bare id


# ---------------------------------------------------- delta log satellites

def test_read_delta_orders_groups_numerically(tmp_path):
    """12 groups: lexical filename order (group_10 < group_2) must not
    leak into apply order."""
    n = 12
    batch = DeltaBatch(0, [
        GroupDelta(group=g, ids=np.array([g], np.int64),
                   rows=np.full((1, DIM), float(g), np.float32))
        for g in range(n)])
    path = write_delta(str(tmp_path), batch)
    got = read_delta(path)
    assert [g.group for g in got.groups] == list(range(n))
    for g in got.groups:
        assert g.rows[0, 0] == float(g.group)


def test_emitter_restart_resumes_past_existing_versions(tmp_path):
    log_dir = str(tmp_path)
    first = DeltaEmitter(log_dir)
    assert first.next_version == 0         # fresh dir still starts at 0
    ids = np.array([1], np.int64)
    rows = np.full((1, DIM), 1.0, np.float32)
    for _ in range(3):
        first.emit([GroupDelta(group=0, ids=ids, rows=rows)])
    sums_before = {v: open(os.path.join(p, "CHECKSUMS")).read()
                   for v, p in list_deltas(log_dir)}
    restarted = DeltaEmitter(log_dir)      # the mid-stream restart
    assert restarted.next_version == 3     # max(existing) + 1, NOT 0
    restarted.emit([GroupDelta(group=0, ids=ids,
                               rows=np.full((1, DIM), 9.0, np.float32))])
    published = list_deltas(log_dir)
    assert [v for v, _ in published] == [0, 1, 2, 3]
    for v, p in published[:3]:             # the old stream is untouched
        assert open(os.path.join(p, "CHECKSUMS")).read() == sums_before[v]
    assert DeltaEmitter(log_dir, start_version=0).next_version == 0


def test_emitter_restart_skips_torn_unpublished_version(tmp_path):
    log_dir = str(tmp_path)
    DeltaEmitter(log_dir).emit([GroupDelta(
        group=0, ids=np.array([1], np.int64),
        rows=np.full((1, DIM), 1.0, np.float32))])
    # a crashed emit: directory exists, never published (no DONE)
    os.makedirs(os.path.join(log_dir, f"delta_{5:012d}"))
    assert DeltaEmitter(log_dir).next_version == 6


def test_reemit_unpublishes_before_rewriting(tmp_path, monkeypatch):
    """The corrupt-delta recovery path: while the npz files are being
    rewritten, the stale DONE marker and manifest must already be gone —
    a watcher polling mid-rewrite sees an unpublished delta, never a
    published one with half-replaced content."""
    log_dir = str(tmp_path)
    ids = np.array([1, 2], np.int64)
    batch = DeltaBatch(0, [GroupDelta(
        group=0, ids=ids, rows=np.full((2, DIM), 1.0, np.float32))])
    path = write_delta(log_dir, batch)
    assert os.path.exists(os.path.join(path, "DONE"))
    seen = []
    real_savez = np.savez

    def spy(file, **kw):
        seen.append((os.path.exists(os.path.join(path, "DONE")),
                     os.path.exists(os.path.join(path, "CHECKSUMS"))))
        return real_savez(file, **kw)

    monkeypatch.setattr(np, "savez", spy)
    write_delta(log_dir, batch)            # the re-emit
    assert seen and all(s == (False, False) for s in seen)
    assert verify_delta(path)              # republished coherently
    assert os.path.exists(os.path.join(path, "DONE"))


def test_watcher_racing_reemit_applies_only_coherent_content(tmp_path,
                                                            monkeypatch):
    """End-to-end re-emit race: corrupt a published delta (watcher skips
    it), then re-emit with FEWER groups while a watcher polls mid-rewrite
    — the mid-rewrite poll applies nothing (unpublished), and the final
    poll applies exactly the re-emitted content."""
    log_dir = str(tmp_path)
    ids = np.array([1, 2], np.int64)
    write_delta(log_dir, DeltaBatch(0, [
        GroupDelta(group=g, ids=ids,
                   rows=np.full((2, DIM), 1.0, np.float32))
        for g in range(2)]))
    path = os.path.join(log_dir, f"delta_{0:012d}")
    with open(os.path.join(path, "group_1.npz"), "ab") as f:
        f.write(b"bitrot")                 # corrupt AFTER publish
    applied = []
    watcher = DeltaWatcher(log_dir, apply_fn=lambda b: applied.append(b))
    with pytest.raises(DeltaIntegrityError):
        watcher.check_once()               # corrupt → skipped, not applied
    assert not applied and watcher.integrity_failures == 1

    real_savez = np.savez

    def racing_poll(file, **kw):
        # the watcher polls WHILE the re-emit rewrites: the delta is
        # unpublished (DONE gone) so nothing may be applied
        assert watcher.check_once() is False
        return real_savez(file, **kw)

    monkeypatch.setattr(np, "savez", racing_poll)
    reemit = DeltaBatch(0, [GroupDelta(
        group=0, ids=ids, rows=np.full((2, DIM), 7.0, np.float32))])
    write_delta(log_dir, reemit)
    monkeypatch.setattr(np, "savez", real_savez)
    assert watcher.check_once() is True
    assert len(applied) == 1
    assert [g.group for g in applied[0].groups] == [0]   # stale group gone
    assert np.all(applied[0].groups[0].rows == 7.0)


# ------------------------------------------------------- torn-read hunters

def _hunter_expected(published, pin_version):
    vs = [v for v in published if v <= pin_version]
    return published[max(vs)] if vs else None


def test_cross_group_torn_read_hunter_async(rng):
    """THE tentpole acceptance test (ISSUE 7): concurrent pinned readers
    hammer lookups across 3 feature groups on AsyncExecutor while a
    writer streams multi-group delta batches and CHUNKED compactions.
    Every pin must observe all groups at one single version — ≥1k pinned
    multi-group reads, zero cross-group mismatches."""
    cube = _multi_group_value_cube()
    published = {cube.version: 0.0}        # delta-publish version → value
    stop = threading.Event()
    first_batch = threading.Event()
    writer_err = []
    pins_checked = [0]

    def writer():
        try:
            first_batch.wait(timeout=10)
            k = 0
            while not stop.is_set():
                next_v = cube.version + 1
                published[next_v] = float(next_v)   # record BEFORE publish
                got = cube.apply_batch(_batch_parts(float(next_v)))
                assert got == next_v
                k += 1
                if k % 5 == 0:
                    # chunked: several intermediate versions publish, all
                    # carrying the same values — _hunter_expected resolves
                    # them to the latest delta at or below the pin
                    cube.compact(max_rows_per_pass=64)
                time.sleep(0.001)
        except Exception as e:             # pragma: no cover - debug aid
            writer_err.append(e)

    def op_lookup(batch, ctx):
        first_batch.set()
        for ev in batch:
            ids = ev.payload["ids"]
            with cube.pin() as pv:         # ONE pin spanning all groups
                per_group = [np.unique(cube.lookup(g, ids, version=pv))
                             for g in range(N_GROUPS)]
                ev.payload["version"] = pv.version
            ev.payload["values"] = np.unique(np.concatenate(per_group))
            pins_checked[0] += 1
        return batch

    g = SEDP()
    g.add_stage("ingress", lambda b, c: b, batch_size=4, parallelism=2)
    g.add_stage("lookup", op_lookup, batch_size=8, parallelism=3)
    g.add_stage("respond", lambda b, c: b, batch_size=8)
    g.chain("ingress", "lookup", "respond")
    plan = g.compile()

    events = [Event(payload={"ids": rng.integers(0, N_IDS, 32)})
              for _ in range(1100)]
    th = threading.Thread(target=writer, daemon=True)
    th.start()
    try:
        report = AsyncExecutor(plan).run(events)
    finally:
        stop.set()
        th.join(timeout=10)
    assert not writer_err
    assert len(report.results) == len(events)
    assert pins_checked[0] >= 1000
    seen_versions = set()
    for ev in report.results:
        vals = ev.payload["values"]
        # all rows of ALL groups under one pin share ONE value ⇒ the pin
        # observed every group at a single version — no cross-group tear
        assert vals.size == 1, f"cross-group torn read: values {vals}"
        ver = ev.payload["version"]
        assert _hunter_expected(published, ver) == float(vals[0])
        seen_versions.add(ver)
    assert len(seen_versions) >= 2, seen_versions


def test_cross_group_torn_read_hunter_sim():
    """SimExecutor variant: the virtual-clock executor is single-threaded,
    so the stream is driven from a stage op — a batch publish + a chunked
    compaction land BETWEEN pins, and every pin must still see all groups
    at one value."""
    cube = _multi_group_value_cube()
    published = {cube.version: 0.0}
    calls = [0]

    def op_lookup(batch, ctx):
        calls[0] += 1
        if calls[0] % 3 == 0:              # stream mid-run, from the op
            next_v = cube.version + 1
            published[next_v] = float(next_v)
            cube.apply_batch(_batch_parts(float(next_v)))
            if calls[0] % 9 == 0:
                cube.compact(max_rows_per_pass=64)
        for ev in batch:
            ids = ev.payload["ids"]
            with cube.pin() as pv:
                vals = np.unique(np.concatenate(
                    [cube.lookup(g, ids, version=pv)
                     for g in range(N_GROUPS)]))
            ev.payload["version"] = pv.version
            ev.payload["values"] = np.unique(vals)
        return batch

    g = SEDP()
    g.add_stage("lookup", op_lookup, batch_size=4)
    g.add_stage("respond", lambda b, c: b, batch_size=4)
    g.chain("lookup", "respond")
    rng = np.random.default_rng(5)
    arrivals = [(i * 1e-3, Event(payload={"ids": rng.integers(0, N_IDS, 16)}))
                for i in range(120)]
    report = SimExecutor(g.compile()).run(arrivals)
    assert len(report.results) == len(arrivals)
    seen = set()
    for ev in report.results:
        vals = ev.payload["values"]
        assert vals.size == 1, f"cross-group torn read: {vals}"
        assert _hunter_expected(published, ev.payload["version"]) == \
            float(vals[0])
        seen.add(ev.payload["version"])
    assert len(seen) >= 2
