"""AsyncExecutor reuse + SimExecutor queue discipline regressions."""
import threading

from repro.core.executors import AsyncExecutor, SimExecutor
from repro.core.sedp import SEDP, Event


def _tag(name):
    def op(batch, ctx):
        for ev in batch:
            ev.payload.setdefault("trace", []).append(name)
        return batch
    return op


def _chain_plan():
    g = SEDP()
    for n in ("a", "b", "c"):
        g.add_stage(n, _tag(n), batch_size=4, parallelism=2,
                    sim_per_item_s=1e-4)
    g.chain("a", "b", "c")
    return g.compile()


def test_async_executor_run_twice_no_leak_no_double_count():
    """A second run() on the same executor must work (the stop flag is
    cleared), must not leak worker threads, and must not double-count
    stage stats from the first run."""
    ex = AsyncExecutor(_chain_plan())
    before = threading.active_count()

    rep1 = ex.run([Event(payload={}) for _ in range(12)])
    assert len(rep1.latencies) == 12
    assert ex.stats["a"].events == 12
    after_first = threading.active_count()
    # workers were joined: no thread lingers past run()
    assert after_first <= before + 1

    rep2 = ex.run([Event(payload={}) for _ in range(7)])
    assert len(rep2.latencies) == 7
    # fresh stats — 7, not 12 + 7
    assert ex.stats["a"].events == 7
    assert threading.active_count() <= before + 1
    assert all(ev.payload["trace"] == ["a", "b", "c"] for ev in rep2.results)


def test_sim_executor_uses_deques():
    """Stage queues are deques (O(1) popleft), and dispatch still conserves
    events in FIFO arrival order."""
    from collections import deque
    plan = _chain_plan()
    ex = SimExecutor(plan)
    assert all(isinstance(q, deque) for q in ex._queues.values())
    arrivals = [(i * 1e-3, Event(payload={"i": i})) for i in range(50)]
    rep = ex.run(arrivals)
    assert len(rep.latencies) == 50
    assert [ev.payload["i"] for ev in rep.results] == list(range(50))
