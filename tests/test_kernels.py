"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.augru.ops import augru
from repro.kernels.augru.ref import augru_ref
from repro.kernels.din_attention.ops import din_attention
from repro.kernels.din_attention.ref import din_attention_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import flash_decode_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("V,D,B,K", [(64, 8, 8, 3), (128, 64, 16, 5),
                                     (1000, 128, 8, 10), (32, 256, 24, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("combiner", ["sum", "mean"])
def test_embedding_bag_sweep(V, D, B, K, dtype, combiner, rng):
    table = jnp.asarray(rng.normal(size=(V, D))).astype(dtype)
    ids = jnp.asarray(rng.integers(0, V, (B, K)).astype(np.int32))
    w = jnp.asarray((rng.random((B, K)) > 0.2).astype(np.float32))
    got = embedding_bag(table, ids, w, combiner=combiner)
    want = embedding_bag_ref(table, ids, w, combiner=combiner)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("B,T,D,H1,H2", [(8, 8, 8, 8, 4), (16, 100, 18, 80, 40),
                                         (12, 33, 16, 32, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_din_attention_sweep(B, T, D, H1, H2, dtype, rng):
    hist = jnp.asarray(rng.normal(size=(B, T, D))).astype(dtype)
    mask = jnp.asarray((rng.random((B, T)) > 0.2).astype(np.float32))
    tgt = jnp.asarray(rng.normal(size=(B, D))).astype(dtype)
    w1 = jnp.asarray(rng.normal(size=(4 * D, H1)) * 0.2).astype(dtype)
    w2 = jnp.asarray(rng.normal(size=(H1, H2)) * 0.2).astype(dtype)
    w3 = jnp.asarray(rng.normal(size=(H2, 1)) * 0.2).astype(dtype)
    b1, b2, b3 = (jnp.zeros(H1, dtype), jnp.zeros(H2, dtype),
                  jnp.zeros(1, dtype))
    got = din_attention(hist, mask, tgt, w1, b1, w2, b2, w3, b3)
    want = din_attention_ref(hist, mask, tgt, w1, b1, w2, b2, w3, b3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("B,T,Din,H", [(8, 8, 8, 8), (16, 100, 18, 108),
                                       (4, 25, 12, 20)])
def test_augru_sweep(B, T, Din, H, rng):
    x = jnp.asarray(rng.normal(size=(B, T, Din)).astype(np.float32))
    att = jnp.asarray(rng.random((B, T)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(Din, 3 * H)).astype(np.float32) * 0.3)
    u = jnp.asarray(rng.normal(size=(H, 3 * H)).astype(np.float32) * 0.3)
    b = jnp.asarray(rng.normal(size=(3 * H,)).astype(np.float32) * 0.1)
    got = augru(x, att, w, u, b)
    want = augru_ref(x, att, w, u, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_augru_zero_attention_freezes_state(rng):
    """Property: a_t = 0 ⇒ h never moves (AUGRU gate algebra)."""
    x = jnp.asarray(rng.normal(size=(4, 12, 8)).astype(np.float32))
    att = jnp.zeros((4, 12), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, 24)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(8, 24)).astype(np.float32))
    b = jnp.zeros(24, jnp.float32)
    out = augru(x, att, w, u, b)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


@pytest.mark.parametrize("B,S,H,G,D,L", [(2, 128, 4, 3, 16, 100),
                                         (1, 256, 2, 1, 64, 256),
                                         (4, 64, 8, 4, 32, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, S, H, G, D, L, dtype, rng):
    q = jnp.asarray(rng.normal(size=(B, H, G, D))).astype(dtype)
    k = jnp.asarray(rng.normal(size=(B, S, H, D))).astype(dtype)
    v = jnp.asarray(rng.normal(size=(B, S, H, D))).astype(dtype)
    got = flash_decode(q, k, v, L, block_k=32)
    want = flash_decode_ref(q, k, v, L)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_decode_matches_model_decode_path(rng):
    """Kernel ≡ the model's decode_attention (same masking semantics)."""
    from repro.models.attention import decode_attention
    B, S, H, G, D, L = 2, 96, 2, 2, 16, 70
    q4 = jnp.asarray(rng.normal(size=(B, 1, H, G, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    want = decode_attention(q4, k, v, jnp.asarray(L))[:, 0]    # (B,H,G,D)
    got = flash_decode(q4[:, 0], k, v, L, block_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("C,D,k,bc", [(4096, 64, 8, 512), (1000, 16, 4, 256),
                                      (300, 256, 8, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_candidate_scorer_sweep(C, D, k, bc, dtype, rng):
    from repro.kernels.candidate_scorer.ops import candidate_scorer
    from repro.kernels.candidate_scorer.ref import candidate_scorer_ref
    cands = jnp.asarray(rng.normal(size=(C, D))).astype(dtype)
    q = jnp.asarray(rng.normal(size=(D,))).astype(dtype)
    v, i = candidate_scorer(cands, q, k=k, block_c=bc)
    rv, ri = candidate_scorer_ref(cands, q, k)
    np.testing.assert_allclose(np.asarray(v, np.float32),
                               np.asarray(rv, np.float32), **TOL[dtype])
    if dtype == jnp.float32:           # bf16 near-ties may permute indices
        assert set(np.asarray(i).tolist()) == set(np.asarray(ri).tolist())
