"""Per-request SEDP tracing (DESIGN.md §10.1): shed / expired / degraded /
errored requests each leave a complete span tree on BOTH executors with
identical topology, fanout clones keep the trace identity, the tail-based
buffer holds its bounds, and the Chrome export round-trips losslessly
enough for critical-path analysis."""
import time

import numpy as np
import pytest

from repro.core.executors import AsyncExecutor, SimExecutor
from repro.core.irm.shedding import OnlineShedder
from repro.core.multitenant import make_fanout_op
from repro.core.sedp import SEDP, Event, propagate_trace
from repro.obs.trace import (TraceBuffer, Tracer, annotate, critical_path,
                             span_topology, stage_path)


def _chain(op_b=None, batch_size=4, slow_a=False):
    """a → b → c. ``slow_a`` gives stage a real+virtual service time so a
    small deadline expires every event at b's dispatch on both executors."""
    def op_a(batch, ctx):
        if slow_a:
            time.sleep(0.005)
        return batch

    g = SEDP()
    g.add_stage("a", op_a, batch_size=batch_size,
                sim_base_s=(5e-3 if slow_a else 1e-4))
    g.add_stage("b", op_b or (lambda b, c: b), batch_size=batch_size,
                sim_per_item_s=1e-4)
    g.add_stage("c", lambda b, c: b, batch_size=batch_size,
                sim_base_s=1e-4)
    g.chain("a", "b", "c")
    return g.compile()


def _events(n, **meta):
    return [Event(payload={"i": i}, meta=dict(meta)) for i in range(n)]


def _run_both(plan_fn, n=8, spacing_s=1e-3, **meta):
    """Run the same workload traced on both executors; return
    (sim_traces, async_traces) keyed off each tracer's buffer."""
    tr_sim, tr_async = Tracer(), Tracer()
    SimExecutor(plan_fn(), tracer=tr_sim).run(
        [(i * spacing_s, ev) for i, ev in enumerate(_events(n, **meta))])
    AsyncExecutor(plan_fn(), tracer=tr_async).run(_events(n, **meta))
    sim, asy = tr_sim.buffer.traces(), tr_async.buffer.traces()
    assert len(sim) == len(asy) == n
    return sim, asy


def _assert_topology_parity(sim, asy):
    for s, a in zip(sorted(sim, key=lambda r: r["req_id"]),
                    sorted(asy, key=lambda r: r["req_id"])):
        assert span_topology(s) == span_topology(a)
        for rec in (s, a):
            for sp in rec["spans"]:
                assert sp["t1"] >= sp["t0"]


# --------------------------------------------------------------- the cases

def test_ok_requests_identical_topology():
    sim, asy = _run_both(lambda: _chain())
    _assert_topology_parity(sim, asy)
    want = [(st, k) for st in ("a", "b", "c")
            for k in ("queue", "assemble", "exec")]
    assert span_topology(sim[0]) == want
    assert stage_path(sim[0]) == ["a", "b", "c"]
    assert all(r["status"] == "ok" for r in sim + asy)


def test_shed_request_full_span_tree_on_both_executors():
    """Op-path shedding (candidate pruning): the trace keeps its full
    topology and the shed decision lands on the shed stage's exec span."""
    def plan():
        shedder = OnlineShedder(lambda f: np.array([0.9]), min_keep=4)
        g = SEDP()
        g.add_stage("a", lambda b, c: b, batch_size=4, sim_base_s=1e-4)
        g.add_stage("shed", shedder.op, batch_size=4, sim_base_s=1e-4)
        g.add_stage("c", lambda b, c: b, batch_size=4, sim_base_s=1e-4)
        g.chain("a", "shed", "c")
        return g.compile()

    def events():
        return [Event(payload={"i": i, "candidates":
                               [(j, float(j)) for j in range(40)]})
                for i in range(6)]

    tr_sim, tr_async = Tracer(), Tracer()
    SimExecutor(plan(), tracer=tr_sim).run(
        [(i * 1e-3, ev) for i, ev in enumerate(events())])
    AsyncExecutor(plan(), tracer=tr_async).run(events())
    sim, asy = tr_sim.buffer.traces(), tr_async.buffer.traces()
    _assert_topology_parity(sim, asy)
    for rec in sim + asy:
        assert rec["status"] == "ok"
        shed_exec = [sp for sp in rec["spans"]
                     if sp["stage"] == "shed" and sp["kind"] == "exec"]
        assert len(shed_exec) == 1
        assert shed_exec[0]["attrs"]["shed"] == 36          # 40 → min_keep 4
        assert shed_exec[0]["attrs"]["cutoff_ratio"] == 0.9
        assert stage_path(rec) == ["a", "shed", "c"]


def test_expired_request_span_tree_on_both_executors():
    """A request that outlives its deadline is shed at the next dispatch:
    the trace ends with that stage's queue+assemble spans (no exec) and
    the expiry decision annotated."""
    # one request, batch_size 1: the expiry stage is deterministic on both
    # executors (with several queued requests WHICH stage a request dies
    # at depends on server occupancy, which only matches statistically)
    sim, asy = _run_both(lambda: _chain(slow_a=True, batch_size=1), n=1,
                         deadline_s=1e-3)
    _assert_topology_parity(sim, asy)
    for rec in sim + asy:
        assert rec["status"] == "expired"
        assert span_topology(rec) == [
            ("a", "queue"), ("a", "assemble"), ("a", "exec"),
            ("b", "queue"), ("b", "assemble")]              # no b exec
        assert rec["spans"][-1]["attrs"]["expired"] is True
        assert stage_path(rec) == ["a", "b"]                # b reached, not run

    # under contention the expiry stage varies, but every expired trace
    # must still close well-formed: complete exec triplets up to the final
    # queue+assemble pair carrying the expiry decision
    tr = Tracer()
    SimExecutor(_chain(slow_a=True), tracer=tr).run(
        [(0.0, ev) for ev in _events(4, deadline_s=1e-3)])
    expired = tr.buffer.find(status="expired")
    assert expired
    for rec in expired:
        topo = span_topology(rec)
        assert topo[-1][1] == "assemble" and topo[-2][1] == "queue"
        assert rec["spans"][-1]["attrs"]["expired"] is True
        assert all(k == "exec" for _, k in topo[:-2][2::3])


def test_errored_request_span_tree_on_both_executors():
    """A stage op that raises error-terminates its batch: the exec span is
    closed with the error and the record carries it."""
    def boom(batch, ctx):
        if any(ev.payload["i"] == 2 for ev in batch):
            raise RuntimeError("kaput")
        return batch

    sim, asy = _run_both(lambda: _chain(op_b=boom, batch_size=1), n=4)
    _assert_topology_parity(sim, asy)
    for traces in (sim, asy):
        errored = [r for r in traces if r["status"] == "error"]
        assert len(errored) == 1
        rec = errored[0]
        assert "RuntimeError" in rec["error"]
        b_exec = [sp for sp in rec["spans"]
                  if sp["stage"] == "b" and sp["kind"] == "exec"]
        assert "RuntimeError" in b_exec[0]["attrs"]["error"]
        # error-terminal: b executed (and failed), c never reached
        assert stage_path(rec) == ["a", "b"]


def test_degraded_request_flagged_on_both_executors():
    """A stage serving off the degradation ladder (tier ≥ 2) marks the
    request; the tracer flags the whole trace for retention."""
    def degrade(batch, ctx):
        for ev in batch:
            ev.payload["degraded_tier"] = 2
            ev.meta["_degraded"] = True
            annotate(ev, degraded_tier=2)
        return batch

    sim, asy = _run_both(lambda: _chain(op_b=degrade), n=4)
    _assert_topology_parity(sim, asy)
    for rec in sim + asy:
        assert rec["status"] == "ok" and rec["degraded_tier"] == 2
        b_exec = [sp for sp in rec["spans"]
                  if sp["stage"] == "b" and sp["kind"] == "exec"]
        assert b_exec[0]["attrs"]["degraded_tier"] == 2
        assert stage_path(rec) == ["a", "b", "c"]           # full pipeline
    # degraded traces land in the always-keep compartment
    tb = TraceBuffer(max_recent=0, max_top=0)
    for rec in sim:
        tb.add(rec)
    assert len(tb.traces()) == len(sim)


def test_sim_overflow_drop_leaves_dropped_trace():
    """Channel-overflow shedding (Sim-only overflow_policy): the dropped
    request still yields a terminal trace, flagged for retention."""
    g = SEDP()
    g.add_stage("a", lambda b, c: b, batch_size=1, sim_base_s=5e-3,
                max_queue=2)
    plan = g.compile()
    tr = Tracer()
    rep = SimExecutor(plan, overflow_policy=lambda stage, ev, ctx: None,
                      tracer=tr).run(
        [(0.0, ev) for ev in _events(8)])
    assert rep.dropped > 0
    dropped = tr.buffer.find(status="dropped")
    assert len(dropped) == rep.dropped
    for rec in dropped:
        assert span_topology(rec) == [("a", "queue")]
        assert rec["spans"][0]["attrs"]["dropped"] is True
    assert len(tr.buffer.traces()) == 8                     # none lost


# ------------------------------------------------------- fanout propagation

def test_fanout_clones_share_trace_identity():
    ev = Event(payload={"i": 0})
    Tracer().begin(ev, 0.0)
    ev.meta["spans"].append({"stage": "ingress", "kind": "exec",
                             "t0": 0.0, "t1": 1.0, "attrs": {}})
    clone = Event(payload={"i": 0}, req_id=ev.req_id)
    assert propagate_trace(ev, clone) is clone
    assert clone.trace_id == ev.trace_id
    assert clone.meta["spans"] == ev.meta["spans"]
    clone.meta["spans"].append({"stage": "x", "kind": "exec",
                                "t0": 1.0, "t1": 2.0, "attrs": {}})
    assert len(ev.meta["spans"]) == 1                       # branch-private
    untraced = Event(payload={})
    assert "trace_id" not in propagate_trace(untraced,
                                             Event(payload={})).meta


def test_fanout_op_propagates_trace_to_clones():
    """Through the real multitenant fanout on SimExecutor: every tenant
    branch records a complete tree under ONE trace id."""
    g = SEDP()
    g.add_stage("fan", make_fanout_op(["t1", "t2"]), batch_size=1)
    g.add_stage("t1", lambda b, c: b, batch_size=1, sim_base_s=1e-4)
    g.add_stage("t2", lambda b, c: b, batch_size=1, sim_base_s=1e-4)
    g.add_edge("fan", "t1")
    g.add_edge("fan", "t2")
    plan = g.compile()
    tr = Tracer()
    SimExecutor(plan, tracer=tr).run([(0.0, ev) for ev in _events(3)])
    traces = tr.buffer.traces()
    assert len(traces) == 6                                 # 3 reqs × 2 tenants
    by_id = {}
    for rec in traces:
        by_id.setdefault(rec["trace_id"], []).append(rec)
    assert len(by_id) == 3
    for recs in by_id.values():
        paths = sorted(stage_path(r)[-1] for r in recs)
        assert paths == ["t1", "t2"]
        for r in recs:
            assert stage_path(r)[0] == "fan"                # shared prefix


# ------------------------------------------------- buffer bounds + export

def test_trace_buffer_tail_sampling_bounds():
    tb = TraceBuffer(max_flagged=2, max_top=2, max_recent=3)
    mk = lambda i, lat, status="ok", tier=0: {
        "trace_id": i, "req_id": i, "born_at": 0.0, "done_at": lat,
        "latency_s": lat, "status": status, "degraded_tier": tier,
        "spans": []}
    for i in range(10):
        tb.add(mk(i, lat=float(i + 1)))
    tb.add(mk(100, 0.1, status="error"))
    tb.add(mk(101, 0.1, status="expired"))
    tb.add(mk(102, 0.1, tier=2))
    assert tb.added == 13 and tb.flagged_total == 3
    kept = tb.traces()
    assert len(kept) <= 2 + 2 + 3
    flagged_ids = {r["trace_id"] for r in kept if r["status"] != "ok"
                   or r["degraded_tier"]}
    assert flagged_ids == {101, 102}                        # newest 2 flagged
    ok_lat = {r["latency_s"] for r in kept if r["status"] == "ok"
              and not r["degraded_tier"]}
    assert {9.0, 10.0} <= ok_lat                            # top-K slowest
    assert tb.find(degraded_tier=2)[0]["trace_id"] == 102
    tb.clear()
    assert tb.traces() == []


def test_chrome_export_roundtrip_and_critical_path(tmp_path):
    tr = Tracer()
    SimExecutor(_chain(), tracer=tr).run(
        [(i * 1e-3, ev) for i, ev in enumerate(_events(5))])
    path = str(tmp_path / "trace.json")
    doc = tr.buffer.export_chrome(path)
    assert all(e["ph"] == "X" for e in doc["traceEvents"])
    for back in (TraceBuffer.from_chrome(doc),
                 TraceBuffer.from_chrome(path)):
        orig = tr.buffer.traces()
        assert len(back) == len(orig) == 5
        for o, b in zip(sorted(orig, key=lambda r: r["trace_id"]), back):
            assert span_topology(b) == span_topology(o)
            assert b["status"] == o["status"]
            assert b["req_id"] == o["req_id"]
            assert b["latency_s"] == pytest.approx(o["latency_s"], abs=1e-9)
            cp = critical_path(b)
            assert cp["total_s"] == pytest.approx(b["latency_s"], abs=1e-9)
            assert {seg["stage"] for seg in cp["segments"]} == {"a", "b", "c"}
            covered = sum(seg["dur_s"] for seg in cp["segments"])
            assert covered + cp["unattributed_s"] >= cp["total_s"] - 1e-9
