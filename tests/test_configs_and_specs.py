"""Config/spec invariants: knob roundtrips, ZeRO spec derivation, shapes."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.configs.base import LM_SHAPES, REC_SHAPES
from repro.core.service_model import Knobs
from repro.launch.mesh import make_mesh
from repro.launch import sharding as shr


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-1000, 1000), min_size=10, max_size=10))
def test_knobs_vector_roundtrip_clamps_to_bounds(xs):
    k = Knobs.from_vector(np.array(xs))
    v = k.to_vector()
    for (name, lo, hi), val in zip(Knobs.BOUNDS, v):
        assert lo <= val <= hi, (name, val)
    # roundtrip is a fixed point once clamped
    k2 = Knobs.from_vector(v)
    assert k2 == k


def _abstract_mesh(shape, axes):
    try:                                  # jax >= 0.5: (axis_sizes, axis_names)
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:                     # jax 0.4.x: (((name, size), ...),)
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def test_zero_specs_add_data_axis_to_big_unsharded_leaves():
    mesh = _abstract_mesh((4, 2), ("data", "model"))
    shapes = {
        "big": jax.ShapeDtypeStruct((1024, 2048), np.float32),
        "small": jax.ShapeDtypeStruct((8, 8), np.float32),
        "sharded": jax.ShapeDtypeStruct((1024, 2048), np.float32),
        "odd": jax.ShapeDtypeStruct((1023, 2047), np.float32),
    }
    pspecs = {"big": P(None, None), "small": P(None, None),
              "sharded": P("data", None), "odd": P(None, None)}
    z = shr.zero_specs(shapes, pspecs, mesh, min_size=1 << 10)
    assert "data" in tuple(a for s in z["big"] if s for a in
                           (s if isinstance(s, tuple) else (s,)))
    assert z["small"] == P(None, None)            # too small
    assert z["sharded"] == P("data", None)        # already data-sharded
    assert z["odd"] == P(None, None)              # indivisible


def test_kv_cache_specs_shard_sequence():
    from repro.configs import registry as reg
    mesh = _abstract_mesh((4, 2), ("data", "model"))
    cfg = reg.get("qwen3-8b").config
    a, b, l = shr.kv_cache_specs(cfg, batch=8, mesh=mesh)
    assert a == P(None, ("data",), ("model",), None, None)
    # batch-1 long context: sequence over every axis
    a1, _, _ = shr.kv_cache_specs(cfg, batch=1, mesh=mesh)
    assert a1 == P(None, None, ("data", "model"), None, None)


def test_every_arch_has_every_assigned_shape():
    want = {"lm": {"train_4k", "prefill_32k", "decode_32k", "long_500k"},
            "gnn": {"full_graph_sm", "minibatch_lg", "ogb_products", "molecule"},
            "recsys": {"train_batch", "serve_p99", "serve_bulk",
                       "retrieval_cand"}}
    for arch in registry.ARCHS.values():
        names = {s.name for s in arch.shapes}
        assert names == want[arch.family], arch.arch_id


def test_recsys_tables_shard_evenly_over_both_meshes():
    for arch in registry.ARCHS.values():
        if arch.family != "recsys":
            continue
        for f in arch.config.user_fields + arch.config.item_fields:
            assert f.vocab % 512 == 0, (arch.arch_id, f.name)


def test_lm_vocab_divisible_by_model_axis():
    for aid in ("qwen3-8b", "smollm-135m", "starcoder2-7b",
                "deepseek-v2-lite-16b", "deepseek-v3-671b"):
        assert registry.get(aid).config.vocab % 16 == 0, aid
