"""Minimal, dependency-free stand-in for `hypothesis`.

The container image does not ship hypothesis and we cannot install it; this
shim is placed on sys.path by tests/conftest.py ONLY when the real package is
absent, so the property-based tests keep running (as deterministic, seeded
random sweeps — weaker than true shrinking-enabled hypothesis, but the same
property assertions on the same strategy domains).

Implements the subset this repo uses: ``given``, ``settings``,
``strategies.{integers,floats,booleans,lists,sampled_from,composite}``.
"""
from __future__ import annotations

import functools
import random as _random

__version__ = "0.0-stub"


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example(self, rnd: _random.Random):
        return self._draw(rnd)


class strategies:  # noqa: N801 — mirrors the hypothesis module name
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rnd: rnd.random() < 0.5)

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rnd: seq[rnd.randrange(len(seq))])

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rnd):
            n = rnd.randint(min_size, max_size)
            return [elements.example(rnd) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def composite(fn):
        @functools.wraps(fn)
        def build(*args, **kwargs):
            def draw_strategy(rnd):
                return fn(lambda strat: strat.example(rnd), *args, **kwargs)
            return _Strategy(draw_strategy)
        return build


st = strategies


def settings(max_examples: int = 20, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        n = getattr(fn, "_stub_max_examples", 20)

        def runner(*args, **kwargs):
            for i in range(n):
                rnd = _random.Random(0xC0FFEE + i)   # deterministic sweep
                drawn = tuple(s.example(rnd) for s in strats)
                fn(*args, *drawn, **kwargs)
        # copy identity by hand: functools.wraps would set __wrapped__ and
        # pytest would then read the ORIGINAL signature and hunt for fixtures
        # named after the strategy parameters.
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner._stub_max_examples = n
        return runner
    return deco
