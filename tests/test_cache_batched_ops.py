"""Batched cache ops vs the equivalent sequential loop (ISSUE 2 satellite).

`TwoTierLFUCache.get_many/put_many` and `QueryCache.get_many/put_many` must
be BIT-IDENTICAL to a sequential get/put loop: same returned values, same
hit/miss/expiration accounting, same internal state (entry order, LFU
counts, tier residency) — including at eviction boundaries, where a
bookkeeping divergence would silently change what production keeps hot.
"""
import numpy as np
import pytest

from repro.core.cube_cache import TwoTierLFUCache
from repro.core.query_cache import QueryCache


def _lfu_state(cache: TwoTierLFUCache):
    # simulated_latency_s is compared separately (to float tolerance):
    # the batched path legitimately sums a batch locally before one
    # accumulator add, so the exact float differs in the last ulp
    return {
        "mem_data": dict(cache.mem.data),
        "disk_data": dict(cache.disk.data),
        "mem_counts": dict(cache.mem.counts),
        "disk_counts": dict(cache.disk.counts),
        "stats": {t: (s.hits, s.misses) for t, s in cache.stats.items()},
    }


def _qc_state(qc: QueryCache):
    return {
        "data": list(qc._data.items()),        # ordered: LRU order matters
        "by_user": {u: set(s) for u, s in qc._by_user.items() if s},
        "stats": (qc.stats.hits, qc.stats.misses, qc.stats.expirations,
                  qc.stats.invalidations),
    }


def _random_kv_trace(seed: int, n_ops: int, key_space: int):
    """(op, keys, values) trace with heavy key reuse so hits, promotions and
    evictions all occur."""
    rng = np.random.default_rng(seed)
    trace = []
    for _ in range(n_ops):
        n = int(rng.integers(1, 9))
        keys = [int(k) for k in rng.integers(0, key_space, n)]
        if rng.random() < 0.5:
            trace.append(("get", keys, None))
        else:
            trace.append(("put", keys, [k * 10 + 1 for k in keys]))
    return trace


@pytest.mark.parametrize("mem_cap,disk_cap", [(2, 3), (1, 1), (4, 8), (3, 0)])
@pytest.mark.parametrize("seed", [0, 7])
def test_two_tier_lfu_batched_equals_sequential(mem_cap, disk_cap, seed):
    """Tiny capacities force constant eviction/demotion/promotion churn —
    the boundary where batched bookkeeping could diverge."""
    batched = TwoTierLFUCache(mem_cap, disk_cap)
    seq = TwoTierLFUCache(mem_cap, disk_cap)
    for op, keys, values in _random_kv_trace(seed, 60, key_space=10):
        if op == "get":
            got_b = batched.get_many(keys)
            got_s = [seq.get(k) for k in keys]
            assert got_b == got_s
        else:
            batched.put_many(keys, values)
            for k, v in zip(keys, values):
                seq.put(k, v)
        assert _lfu_state(batched) == _lfu_state(seq)
        assert batched.simulated_latency_s == \
            pytest.approx(seq.simulated_latency_s, rel=1e-12)
    assert batched.overall_hit_ratio == seq.overall_hit_ratio
    # the trace actually exercised both tiers and evictions
    assert batched.stats["mem"].hits > 0
    assert len(batched.mem.data) <= mem_cap
    assert len(batched.disk.data) <= max(disk_cap, 1)


def test_two_tier_duplicate_key_in_one_batch_promotes_once():
    """A duplicate of a disk-resident key must hit memory after the first
    occurrence promotes it (same as sequential gets) — not disk twice."""
    c = TwoTierLFUCache(2, 4)
    s = TwoTierLFUCache(2, 4)
    for cache in (c, s):
        cache.put("cold", 1)
        # push "cold" out of the memory tier
        cache.put("a", 2)
        cache.put("b", 3)
    assert "cold" in c.disk.data
    got = c.get_many(["cold", "cold"])
    exp = [s.get("cold"), s.get("cold")]
    assert got == exp == [1, 1]
    assert _lfu_state(c) == _lfu_state(s)
    assert c.simulated_latency_s == pytest.approx(s.simulated_latency_s,
                                                 rel=1e-12)
    assert c.stats["disk"].hits == 1 and c.stats["mem"].hits == 1


@pytest.mark.parametrize("capacity", [3, 6, 1000])
@pytest.mark.parametrize("seed", [1, 13])
def test_query_cache_batched_equals_sequential(capacity, seed):
    rng = np.random.default_rng(seed)
    batched = QueryCache(capacity=capacity, window_s=10.0)
    seq = QueryCache(capacity=capacity, window_s=10.0)
    now = 0.0
    for _ in range(50):
        now += float(rng.exponential(2.0))      # some entries expire
        n = int(rng.integers(1, 7))
        users = [int(u) for u in rng.integers(0, 5, n)]
        items = [int(i) for i in rng.integers(0, 8, n)]
        if rng.random() < 0.5:
            got_b = batched.get_many(users, items, now)
            got_s = [seq.get(u, i, now) for u, i in zip(users, items)]
            assert got_b == got_s
        else:
            scores = [float(s) for s in rng.random(n)]
            batched.put_many(users, items, scores, now)
            for u, i, s in zip(users, items, scores):
                seq.put(u, i, s, now)
        if rng.random() < 0.1:
            u = int(rng.integers(0, 5))
            batched.user_feedback(u)
            seq.user_feedback(u)
        assert _qc_state(batched) == _qc_state(seq)
    st = batched.stats
    assert st.hits > 0 and st.misses > 0
    if capacity >= 1000:       # small caps LRU-evict before entries expire
        assert st.expirations > 0
    assert len(batched) <= capacity


def test_query_cache_put_many_respects_admission_and_capacity():
    """Admission predicate filters inside put_many; capacity trimming after
    the batch evicts exactly the LRU entries a sequential loop would."""
    admit = lambda s: s >= 0.5
    batched = QueryCache(capacity=3, admit=admit)
    seq = QueryCache(capacity=3, admit=admit)
    users = [1, 2, 3, 4, 5, 6]
    items = [10, 20, 30, 40, 50, 60]
    scores = [0.9, 0.1, 0.8, 0.2, 0.7, 0.6]     # only 4 admitted, cap 3
    batched.put_many(users, items, scores, now=0.0)
    for u, i, s in zip(users, items, scores):
        seq.put(u, i, s, now=0.0)
    assert _qc_state(batched) == _qc_state(seq)
    assert len(batched) == 3
    assert batched.get_many(users, items, now=1.0) == \
        [None, None, 0.8, None, 0.7, 0.6]
